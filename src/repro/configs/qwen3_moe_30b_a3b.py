"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4, head_dim 128)
expert d_ff=768 vocab=151936, MoE 128 experts top-8
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936, num_experts=128, top_k=8, expert_d_ff=768,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16, d_ff=96,
    vocab=256, num_experts=8, top_k=2, expert_d_ff=96, remat=False)
