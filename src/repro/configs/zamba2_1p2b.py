"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf].  Simplifications noted in DESIGN.md: single
shared block (real model alternates two), no embedding-concat into the
shared block.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="mamba_hybrid",
    n_layers=38, d_model=2048, n_heads=32, kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_heads=64, ssm_head_dim=64,
    shared_attn_period=6,
)

SMOKE = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, kv_heads=4, d_ff=128, vocab=256,
    ssm_state=16, ssm_heads=4, ssm_head_dim=32, shared_attn_period=2,
    remat=False)
