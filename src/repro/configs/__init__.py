"""Assigned architecture configs (exact values from the public pool) plus
the paper's own eGPU configurations.

``get(name)`` returns the full ModelConfig; ``get_smoke(name)`` returns a
reduced same-family config for CPU smoke tests; ``SHAPES`` defines the
four input-shape cells and ``cells()`` enumerates the 40-cell dry-run
matrix (with the documented long_500k skips).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "zamba2_1p2b", "qwen3_moe_30b_a3b", "granite_moe_3b_a800m", "yi_9b",
    "phi3_medium_14b", "llama3_405b", "minitron_4b",
    "seamless_m4t_large_v2", "xlstm_350m", "internvl2_2b",
]

#: CLI ids (--arch <id>) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}").CONFIG


def get_smoke(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}").SMOKE


def long_context_ok(name: str) -> bool:
    return get(name).supports_long_context()


def cells():
    """All 40 (arch x shape) cells; yields (arch, shape, runnable, why)."""
    for a in ARCHS:
        for s in SHAPES.values():
            if s.name == "long_500k" and not long_context_ok(a):
                yield a, s, False, "full-attention arch: no sub-quadratic path (DESIGN.md)"
            else:
                yield a, s, True, ""
