"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783].

Scale notes: bf16 params + bf16 optimizer state (ZeRO over the data axis)
is what fits 256 x 16GB v5e; fp32-master is possible at 512 chips.  See
EXPERIMENTS.md #Dry-run memory analysis.
"""
import jax.numpy as jnp
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, kv_heads=8, d_ff=53248,
    vocab=128256, param_dtype=jnp.bfloat16,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=8, kv_heads=2,
                       d_ff=384, vocab=512, param_dtype=jnp.float32,
                       remat=False)
