"""internvl2-2b [vlm]: InternViT (stub) + InternLM2-1.8B backbone:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf].  num_patches=1024 precomputed patch embeddings."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, kv_heads=8, d_ff=8192,
    vocab=92553, num_patches=1024,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=64, n_heads=4, kv_heads=2,
                       d_ff=128, vocab=256, num_patches=8, remat=False)
