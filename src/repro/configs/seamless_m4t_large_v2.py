"""seamless-m4t-large-v2 [audio, enc-dec]: 24L enc + 24L dec,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf].

The speech frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings (B, S, 1024).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=48, enc_layers=24, dec_layers=24,
    d_model=1024, n_heads=16, kv_heads=16, d_ff=8192, vocab=256206,
)

SMOKE = CONFIG.replace(n_layers=4, enc_layers=2, dec_layers=2, d_model=64,
                       n_heads=4, kv_heads=4, d_ff=128, vocab=256,
                       remat=False)
