"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 [arXiv:2407.14679; hf] — pruned nemotron.  24 heads do not
divide the 16-way model axis -> head_dim sharding."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, kv_heads=8, d_ff=9216,
    vocab=256000,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=96, n_heads=6, kv_heads=2,
                       d_ff=256, vocab=512, remat=False)
