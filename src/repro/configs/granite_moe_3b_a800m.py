"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) expert
d_ff=512 vocab=49155, MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, kv_heads=8, d_ff=512,
    vocab=49155, num_experts=40, top_k=8, expert_d_ff=512,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=4, kv_heads=2, d_ff=64, vocab=128,
    num_experts=5, top_k=2, expert_d_ff=64, remat=False)
