"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 [arXiv:2404.14219] — RoPE SwiGLU GQA.  40 heads do not
divide the 16-way model axis, so attention shards head_dim (DESIGN.md)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, kv_heads=10, d_ff=17920,
    vocab=100352,
)

SMOKE = CONFIG.replace(n_layers=2, d_model=80, n_heads=5, kv_heads=5,
                       d_ff=192, vocab=256, remat=False)
