"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 (blocks carry internal
projections) vocab=50304 [arXiv:2405.04517] — 7:1 mLSTM:sLSTM."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, kv_heads=4, d_ff=0, vocab=50304,
)

SMOKE = CONFIG.replace(n_layers=3, d_model=64, n_heads=2, kv_heads=2,
                       vocab=256, remat=False)
