"""maybe_scan: lax.scan or an unrolled python loop, by config.

Why: XLA's ``cost_analysis`` on the compiled dry-run counts a loop body
ONCE regardless of trip count (verified empirically — see
EXPERIMENTS.md §Roofline methodology).  The roofline calibration
therefore compiles small configurations with ``cfg.scan_layers=False``,
where every scan (layer stacks, SSD chunk loops, recurrent seq loops)
unrolls into straight-line HLO whose cost analysis is exact, and fits a
polynomial in (layers, sequence) to recover the true totals.
Production/training paths keep ``scan_layers=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def maybe_scan(body, carry, xs, *, unroll_py: bool, length: int | None = None):
    """Drop-in for ``lax.scan(body, carry, xs, length=...)``."""
    if not unroll_py:
        return lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
