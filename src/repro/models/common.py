"""Shared model components: config, norms, rotary, init, logical specs."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture (exact values live in ``repro/configs/<id>.py``)."""

    name: str
    family: str                 # dense | moe | mamba_hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    shared_attn_period: int = 0   # zamba2: shared block every k layers
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # vlm
    num_patches: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    remat: bool = True
    scan_layers: bool = True
    tie_embeddings: bool = False
    logits_chunk: int = 0       # 0 = unchunked loss
    max_seq: int = 8192

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def supports_long_context(self) -> bool:
        return self.family in ("mamba_hybrid", "xlstm")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Initialisers — all take an explicit key; leaves are created at param_dtype.
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Primitive layers (pure functions over param dicts)
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def rotary(x, positions, theta: float = 1e4):
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_in, w_gate, w_out):
    h = jnp.einsum("...d,df->...f", x, w_in)
    g = jnp.einsum("...d,df->...f", x, w_gate)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * h, w_out)


def softmax_cross_entropy(logits, targets, mask=None):
    """logits: (B, S, V) — fp32 log-softmax for stability."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# Logical sharding axis names (resolved by repro.sharding.partition)
# --------------------------------------------------------------------------
# "batch"   — data-parallel batch            -> ("pod","data")
# "fsdp"    — parameter shard (ZeRO)          -> "data" (when enabled)
# "heads"   — attention heads                 -> "model" (if divisible)
# "hd"      — attention head_dim              -> "model" fallback
# "ff"      — MLP hidden                      -> "model"
# "vocab"   — embedding rows                  -> "model" (if divisible)
# "experts" — MoE expert dim                  -> "model" (if divisible)
# "seq"     — sequence (SP / cache)           -> "model"
# None      — replicated
