"""zamba2 hybrid: a Mamba2 backbone with a *shared* transformer block
applied every ``shared_attn_period`` layers (the Zamba trick: one set of
attention weights reused at several depths).

Structure (38 mamba layers, period 6): groups of 6 scanned mamba blocks,
each followed by the shared GQA block; the scan keeps HLO size flat and
the shared block appears once per group in the HLO (honest FLOPs
accounting for the dry-run, vs. a lax.cond-in-scan which would obscure
the cost analysis).

Note (fidelity): real zamba2 concatenates the original embeddings into
the shared-block input and has two alternating shared blocks; we
implement the single-shared-block variant and note the simplification in
DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import attention, mamba2, transformer
from .scan_util import maybe_scan
from .common import ModelConfig, embed_init, rms_norm, softmax_cross_entropy


def _mamba_block_params(key, cfg):
    p, spec = mamba2.ssd_params(key, cfg)
    return p, spec


def init_params(key, cfg: ModelConfig):
    k_emb, k_m, k_sh, k_out = jax.random.split(key, 4)
    mkeys = jax.random.split(k_m, cfg.n_layers)
    mblocks = jax.vmap(lambda k: _mamba_block_params(k, cfg)[0])(mkeys)
    shared = transformer.block_params(k_sh, cfg)[0]
    return {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "mamba": mblocks,
        "shared_attn": shared,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "unembed": embed_init(k_out, (cfg.d_model, cfg.vocab), cfg.param_dtype),
    }


def param_specs(cfg: ModelConfig):
    _, mspec = mamba2.ssd_params(jax.random.PRNGKey(0), cfg.replace(
        d_model=8, ssm_heads=1, ssm_head_dim=8, ssm_state=8))  # structure only
    mspec = jax.tree.map(lambda s: ("layers",) + s, mspec,
                         is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("vocab", "fsdp"),
        "mamba": mspec,
        "shared_attn": transformer.block_specs(cfg),
        "ln_f": (None,),
        "unembed": ("fsdp", "vocab"),
    }


def _groups(cfg: ModelConfig):
    period = cfg.shared_attn_period
    bounds = list(range(0, cfg.n_layers, period)) + [cfg.n_layers]
    return [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]


def forward(cfg: ModelConfig, params, tokens, positions=None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def mamba_body(carry, lp):
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        return carry + mamba2.ssd_apply(cfg, lp, h), None
    if cfg.remat:
        mamba_body = jax.checkpoint(mamba_body, prevent_cse=False)

    for lo, hi in _groups(cfg):
        seg = jax.tree.map(lambda a: a[lo:hi], params["mamba"])
        x, _ = maybe_scan(mamba_body, x, seg, unroll_py=not cfg.scan_layers)
        x = transformer.block_apply(cfg, params["shared_attn"], x, positions)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))


def loss_fn(cfg: ModelConfig, params, tokens, mask=None):
    logits = forward(cfg, params, tokens[:, :-1])
    m = mask[:, 1:] if mask is not None else None
    return softmax_cross_entropy(logits, tokens[:, 1:], m)


# --------------------------------------------------------------------------
# Decode: mamba recurrent states + one KV cache per shared-block site
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_sites = len(_groups(cfg))
    return {
        "ssm": mamba2.init_ssd_state(cfg, batch, cfg.n_layers),
        "kv": attention.init_cache(cfg, batch, max_len, n_sites),
    }


def cache_specs(cfg: ModelConfig):
    return {
        "ssm": mamba2.ssd_state_spec(),
        "kv": attention.KVCache(attention.cache_specs(cfg),
                                attention.cache_specs(cfg)),
    }


def prefill(cfg: ModelConfig, params, tokens, max_len: int):
    """Prefill: chunked-SSD forward collecting per-layer final SSM states
    and per-site shared-attention K/V."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def mamba_body(carry, lp):
        h = rms_norm(carry, lp["ln"], cfg.norm_eps)
        y, st = mamba2.ssd_apply(cfg, lp, h, return_state=True)
        return carry + y, st

    ssm_states, site_k, site_v = [], [], []
    for lo, hi in _groups(cfg):
        seg = jax.tree.map(lambda a: a[lo:hi], params["mamba"])
        x, sts = maybe_scan(mamba_body, x, seg, unroll_py=not cfg.scan_layers)
        ssm_states.append(sts)
        sp = params["shared_attn"]
        h = rms_norm(x, sp["ln_attn"], cfg.norm_eps)
        a, (k, v) = attention.attend(cfg, sp["attn"], h, positions,
                                     return_kv=True)
        x = x + a
        h = rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
        from .common import swiglu
        m = sp["mlp"]
        x = x + swiglu(h, m["w_in"].astype(x.dtype),
                       m["w_gate"].astype(x.dtype), m["w_out"].astype(x.dtype))
        pad = max_len - s
        site_k.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        site_v.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["unembed"].astype(cfg.dtype))
    cache = {"ssm": jnp.concatenate(ssm_states, axis=0),
             "kv": attention.KVCache(jnp.stack(site_k), jnp.stack(site_v))}
    return logits, cache, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, params, cache, token, lengths):
    x = params["embed"].astype(cfg.dtype)[token]
    new_ssm = []
    new_k, new_v = [], []
    for site, (lo, hi) in enumerate(_groups(cfg)):
        for li in range(lo, hi):
            lp = jax.tree.map(lambda a: a[li], params["mamba"])
            h = rms_norm(x, lp["ln"], cfg.norm_eps)
            y, st = mamba2.ssd_decode(cfg, lp, h, cache["ssm"][li])
            x = x + y
            new_ssm.append(st)
        lc = attention.KVCache(cache["kv"].k[site], cache["kv"].v[site])
        x, nc = _shared_decode(cfg, params["shared_attn"], x, lc, lengths)
        new_k.append(nc.k)
        new_v.append(nc.v)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cfg.dtype))
    new_cache = {
        "ssm": jnp.stack(new_ssm),
        "kv": attention.KVCache(jnp.stack(new_k), jnp.stack(new_v)),
    }
    return logits, new_cache, lengths + 1


def _shared_decode(cfg, p, x, layer_cache, lengths):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    a, nc = attention.attend_decode(cfg, p["attn"], h, layer_cache, lengths)
    x = x + a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    from .common import swiglu
    m = p["mlp"]
    x = x + swiglu(h, m["w_in"].astype(x.dtype), m["w_gate"].astype(x.dtype),
                   m["w_out"].astype(x.dtype))
    return x, nc
