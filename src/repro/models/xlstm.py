"""xLSTM blocks: mLSTM (matrix memory, parallel training form) and sLSTM
(scalar memory, true recurrence), interleaved 7:1 as in the paper.

mLSTM training uses the stabilized parallel (attention-like) form — the
gate-decay matrix D plays the role of the causal mask; decode is the
O(1) recurrence C_t = f C + i v k^T.  sLSTM trains with a lax.scan over
time (it is not parallelisable by construction; that *is* the
architecture).  Both give ``long_500k`` an O(1)-per-token decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import (ModelConfig, dense_init, embed_init, rms_norm,
                     softmax_cross_entropy)
from .scan_util import maybe_scan


def _is_slstm(cfg: ModelConfig, i: int) -> bool:
    return i % 8 == 7            # 7:1 mLSTM:sLSTM


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_params(key, cfg: ModelConfig):
    d = cfg.d_model
    inner = 2 * d                 # proj_factor 2
    ks = jax.random.split(key, 8)
    p = {
        "ln": jnp.ones((d,), cfg.param_dtype),
        "w_up": dense_init(ks[0], (d, 2 * inner), 0, cfg.param_dtype),
        "w_q": dense_init(ks[1], (inner, inner), 0, cfg.param_dtype),
        "w_k": dense_init(ks[2], (inner, inner), 0, cfg.param_dtype),
        "w_v": dense_init(ks[3], (inner, inner), 0, cfg.param_dtype),
        "w_i": dense_init(ks[4], (inner, cfg.n_heads), 0, cfg.param_dtype),
        "w_f": dense_init(ks[5], (inner, cfg.n_heads), 0, cfg.param_dtype),
        "w_down": dense_init(ks[6], (inner, d), 0, cfg.param_dtype),
    }
    specs = {"ln": (None,), "w_up": ("fsdp", "ff"), "w_q": ("ff", "heads2"),
             "w_k": ("ff", "heads2"), "w_v": ("ff", "heads2"),
             "w_i": ("ff", None), "w_f": ("ff", None),
             "w_down": ("ff", "fsdp")}
    return p, specs


def _mlstm_qkvgates(cfg, p, xm):
    b, s, inner = xm.shape
    h = cfg.n_heads
    pd = inner // h
    q = jnp.einsum("bsi,ij->bsj", xm, p["w_q"].astype(xm.dtype)).reshape(b, s, h, pd)
    k = jnp.einsum("bsi,ij->bsj", xm, p["w_k"].astype(xm.dtype)).reshape(b, s, h, pd)
    v = jnp.einsum("bsi,ij->bsj", xm, p["w_v"].astype(xm.dtype)).reshape(b, s, h, pd)
    logi = jnp.einsum("bsi,ih->bsh", xm, p["w_i"].astype(xm.dtype)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", xm, p["w_f"].astype(xm.dtype)).astype(jnp.float32) + 1.0)
    return q, k, v, logi, logf, pd


def mlstm_apply(cfg: ModelConfig, p, x):
    """Parallel (training) form.  x: (B,S,d)."""
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h_in, p["w_up"].astype(x.dtype))
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v, logi, logf, pd = _mlstm_qkvgates(cfg, p, xm)
    # D[t,s] = exp(F[t] - F[s] + logi[s] - m[t]),  F = cumsum(logf)
    f_cum = jnp.cumsum(logf, axis=1)                        # (B,S,H)
    src = logi - f_cum                                      # (B,S,H)
    m = f_cum + lax.cummax(src, axis=1)                     # stabilizer (B,S,H)
    dmat = f_cum[:, :, None, :] - f_cum[:, None, :, :] \
        + logi[:, None, :, :] - m[:, :, None, :]            # (B,T,S,H)
    s_len = x.shape[1]
    causal = jnp.tril(jnp.ones((s_len, s_len), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    dexp = jnp.exp(dmat)
    att = jnp.einsum("bthp,bshp->btsh", q.astype(jnp.float32),
                     k.astype(jnp.float32)) / jnp.sqrt(pd)
    w = att * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m))  # (B,T,H)
    y = jnp.einsum("btsh,bshp->bthp", w, v.astype(jnp.float32))
    y = (y / norm[..., None]).astype(x.dtype)
    y = y.reshape(x.shape[0], s_len, -1)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                     p["w_down"].astype(x.dtype))
    return x + out


def mlstm_state(cfg: ModelConfig, batch: int):
    h, inner = cfg.n_heads, 2 * cfg.d_model
    pd = inner // h
    return {"c": jnp.zeros((batch, h, pd, pd), jnp.float32),
            "n": jnp.zeros((batch, h, pd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_decode(cfg: ModelConfig, p, x, st):
    """x: (B,d)."""
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bd,de->be", h_in, p["w_up"].astype(x.dtype))
    xm, z = jnp.split(up, 2, axis=-1)
    q, k, v, logi, logf, pd = _mlstm_qkvgates(cfg, p, xm[:, None, :])
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                     # (B,H,P)
    logi, logf = logi[:, 0], logf[:, 0]                     # (B,H)
    m_new = jnp.maximum(logf + st["m"], logi)
    f_ = jnp.exp(logf + st["m"] - m_new)
    i_ = jnp.exp(logi - m_new)
    kf = k.astype(jnp.float32) / jnp.sqrt(pd)
    c = st["c"] * f_[..., None, None] + \
        i_[..., None, None] * jnp.einsum("bhp,bhq->bhpq",
                                         v.astype(jnp.float32), kf)
    n = st["n"] * f_[..., None] + i_[..., None] * kf
    num = jnp.einsum("bhpq,bhq->bhp", c, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", n,
                                         q.astype(jnp.float32))),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype).reshape(x.shape[0], -1)
    out = jnp.einsum("be,ed->bd", y * jax.nn.silu(z),
                     p["w_down"].astype(x.dtype))
    return x + out, {"c": c, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_params(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    gates = {}
    for gi, g in enumerate(("i", "f", "z", "o")):
        gates[f"w_{g}"] = dense_init(ks[gi], (d, d), 0, cfg.param_dtype)
        gates[f"r_{g}"] = dense_init(ks[gi + 4], (d, d), 0, cfg.param_dtype) * 0.1
    p = {"ln": jnp.ones((d,), cfg.param_dtype), **gates,
         "w_down": dense_init(ks[8], (d, d), 0, cfg.param_dtype)}
    specs = {k: ("fsdp", "ff") for k in gates}
    specs.update({"ln": (None,), "w_down": ("ff", "fsdp")})
    return p, specs


def slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}


def _slstm_cell(p, xg, st, dtype):
    """xg: dict of (B,d) pre-activations from x; st: state dict."""
    h = st["h"]
    def rec(g):
        return xg[g] + jnp.einsum("bd,de->be", h, p[f"r_{g}"].astype(jnp.float32))
    it, ft = rec("i"), rec("f")
    zt = jnp.tanh(rec("z"))
    ot = jax.nn.sigmoid(rec("o"))
    m_new = jnp.maximum(ft + st["m"], it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + st["m"] - m_new)
    c = f_ * st["c"] + i_ * zt
    n = f_ * st["n"] + i_
    h_new = ot * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm_apply(cfg: ModelConfig, p, x):
    """x: (B,S,d) — true recurrence over S."""
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    pre = {g: jnp.einsum("bsd,de->bse", h_in,
                         p[f"w_{g}"].astype(x.dtype)).astype(jnp.float32)
           for g in ("i", "f", "z", "o")}
    st0 = slstm_state(cfg, x.shape[0])

    def body(st, xs):
        st2 = _slstm_cell(p, xs, st, x.dtype)
        return st2, st2["h"]

    xs = jax.tree.map(lambda a: a.transpose(1, 0, 2), pre)   # (S,B,d)
    _, hs = maybe_scan(body, st0, xs, unroll_py=not cfg.scan_layers)
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    return x + jnp.einsum("bsd,de->bse", y, p["w_down"].astype(x.dtype))


def slstm_decode(cfg: ModelConfig, p, x, st):
    h_in = rms_norm(x, p["ln"], cfg.norm_eps)
    pre = {g: jnp.einsum("bd,de->be", h_in,
                         p[f"w_{g}"].astype(x.dtype)).astype(jnp.float32)
           for g in ("i", "f", "z", "o")}
    st2 = _slstm_cell(p, pre, st, x.dtype)
    y = st2["h"].astype(x.dtype)
    return x + jnp.einsum("bd,de->be", y, p["w_down"].astype(x.dtype)), st2


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    k_emb, k_b, k_out = jax.random.split(key, 3)
    bkeys = jax.random.split(k_b, cfg.n_layers)
    blocks = []
    for i in range(cfg.n_layers):
        fn = slstm_params if _is_slstm(cfg, i) else mlstm_params
        blocks.append(fn(bkeys[i], cfg)[0])
    return {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "blocks": blocks,                     # heterogeneous: python list
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "unembed": embed_init(k_out, (cfg.d_model, cfg.vocab), cfg.param_dtype),
    }


def param_specs(cfg: ModelConfig):
    blocks = []
    for i in range(cfg.n_layers):
        fn = slstm_params if _is_slstm(cfg, i) else mlstm_params
        blocks.append(fn(jax.random.PRNGKey(0), cfg.replace(
            d_model=16, n_heads=cfg.n_heads, param_dtype=jnp.float32))[1])
    return {"embed": ("vocab", "fsdp"), "blocks": blocks, "ln_f": (None,),
            "unembed": ("fsdp", "vocab")}


def forward(cfg: ModelConfig, params, tokens):
    x = params["embed"].astype(cfg.dtype)[tokens]
    for i, bp in enumerate(params["blocks"]):
        fn = slstm_apply if _is_slstm(cfg, i) else mlstm_apply
        if cfg.remat:
            x = jax.checkpoint(lambda xx, pp, f=fn: f(cfg, pp, xx),
                               prevent_cse=False)(x, bp)
        else:
            x = fn(cfg, bp, x)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))


def loss_fn(cfg: ModelConfig, params, tokens, mask=None):
    logits = forward(cfg, params, tokens[:, :-1])
    m = mask[:, 1:] if mask is not None else None
    return softmax_cross_entropy(logits, tokens[:, 1:], m)


def init_cache(cfg: ModelConfig, batch: int):
    return [slstm_state(cfg, batch) if _is_slstm(cfg, i)
            else mlstm_state(cfg, batch) for i in range(cfg.n_layers)]


def cache_specs(cfg: ModelConfig):
    out = []
    for i in range(cfg.n_layers):
        if _is_slstm(cfg, i):
            out.append({k: ("batch", None) for k in ("c", "n", "h", "m")})
        else:
            out.append({"c": ("batch", None, None, None),
                        "n": ("batch", None, None), "m": ("batch", None)})
    return out


def prefill(cfg: ModelConfig, params, tokens):
    """Prefill by scanning the recurrent decode over the prompt (the
    state-building path; O(S) time, O(1) state — what makes long contexts
    legal for this family)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b)
    lengths = jnp.zeros((b,), jnp.int32)

    def body(carry, tok):
        cch, ln = carry
        logits, cch, ln = decode_step(cfg, params, cch, tok, ln)
        return (cch, ln), logits

    (cache, lengths), logits = maybe_scan(body, (cache, lengths), tokens.T,
                                          unroll_py=not cfg.scan_layers)
    return logits[-1], cache, lengths


def decode_step(cfg: ModelConfig, params, cache, token, lengths):
    x = params["embed"].astype(cfg.dtype)[token]
    new = []
    for i, bp in enumerate(params["blocks"]):
        fn = slstm_decode if _is_slstm(cfg, i) else mlstm_decode
        x, st = fn(cfg, bp, x, cache[i])
        new.append(st)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cfg.dtype))
    return logits, new, lengths + 1
