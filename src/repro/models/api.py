"""Uniform model API across the six architecture families.

Every family exposes:
  init_params(key, cfg)            -> params pytree
  param_specs(cfg)                 -> logical spec pytree (same structure)
  loss(cfg, params, batch)         -> scalar  (batch: family-specific dict)
  init_cache(cfg, batch, max_len)  -> decode cache
  cache_specs(cfg)                 -> logical specs for the cache
  decode(cfg, params, cache, token, lengths) -> (logits, cache, lengths)
  prefill(cfg, params, batch, max_len) -> (logits, cache, lengths)
"""
from __future__ import annotations

import jax.numpy as jnp

from . import attention, encdec, transformer, vlm, xlstm, zamba2
from .common import ModelConfig


def init_params(key, cfg: ModelConfig):
    if cfg.family == "mamba_hybrid":
        return zamba2.init_params(key, cfg)
    if cfg.family == "xlstm":
        return xlstm.init_params(key, cfg)
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    if cfg.family == "vlm":
        return vlm.init_params(key, cfg)
    return transformer.init_params(key, cfg)


def param_specs(cfg: ModelConfig):
    if cfg.family == "mamba_hybrid":
        return zamba2.param_specs(cfg)
    if cfg.family == "xlstm":
        return xlstm.param_specs(cfg)
    if cfg.family == "encdec":
        return encdec.param_specs(cfg)
    if cfg.family == "vlm":
        return vlm.param_specs(cfg)
    return transformer.param_specs(cfg)


def loss(cfg: ModelConfig, params, batch):
    if cfg.family == "mamba_hybrid":
        return zamba2.loss_fn(cfg, params, batch["tokens"],
                              batch.get("mask"))
    if cfg.family == "xlstm":
        return xlstm.loss_fn(cfg, params, batch["tokens"], batch.get("mask"))
    if cfg.family == "encdec":
        return encdec.loss_fn(cfg, params, batch["frames"], batch["tokens"],
                              batch.get("mask"))
    if cfg.family == "vlm":
        return vlm.loss_fn(cfg, params, batch["patches"], batch["tokens"],
                           batch.get("mask"))
    return transformer.loss_fn(cfg, params, batch["tokens"],
                               mask=batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 4096):
    if cfg.family == "mamba_hybrid":
        return zamba2.init_cache(cfg, batch, max_len)
    if cfg.family == "xlstm":
        return xlstm.init_cache(cfg, batch)
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len, enc_len)
    return attention.init_cache(cfg, batch, max_len, cfg.n_layers)


def cache_specs(cfg: ModelConfig):
    if cfg.family == "mamba_hybrid":
        return zamba2.cache_specs(cfg)
    if cfg.family == "xlstm":
        return xlstm.cache_specs(cfg)
    if cfg.family == "encdec":
        return encdec.cache_specs(cfg)
    cs = attention.cache_specs(cfg)
    return attention.KVCache(cs, cs)


def decode(cfg: ModelConfig, params, cache, token, lengths):
    if cfg.family == "mamba_hybrid":
        return zamba2.decode_step(cfg, params, cache, token, lengths)
    if cfg.family == "xlstm":
        return xlstm.decode_step(cfg, params, cache, token, lengths)
    if cfg.family == "encdec":
        return encdec.decode_step(cfg, params, cache, token, lengths)
    return transformer.decode_step(cfg, params, cache, token, lengths)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    if cfg.family == "mamba_hybrid":
        return zamba2.prefill(cfg, params, batch["tokens"], max_len)
    if cfg.family == "xlstm":
        return xlstm.prefill(cfg, params, batch["tokens"])
    if cfg.family == "encdec":
        enc_out = encdec.encode(cfg, params, batch["frames"])
        b = batch["frames"].shape[0]
        enc_lens = jnp.full((b,), batch["frames"].shape[1], jnp.int32)
        ck, cv, el = encdec.prefill_cross(cfg, params, enc_out, enc_lens)
        cache = encdec.init_cache(cfg, b, max_len, enc_out.shape[1])
        cache = dict(cache, cross_k=ck, cross_v=cv, enc_len=el)
        logits = jnp.zeros((b, cfg.vocab), cfg.dtype)
        return logits, cache, jnp.zeros((b,), jnp.int32)
    if cfg.family == "vlm":
        img = vlm._project(cfg, params, batch["patches"])
        txt = params["embed"].astype(cfg.dtype)[batch["tokens"]]
        embeds = jnp.concatenate([img, txt], axis=1)
        return transformer.prefill(cfg, params, None, embeds=embeds,
                                   max_len=max_len)
    return transformer.prefill(cfg, params, batch["tokens"], max_len=max_len)
