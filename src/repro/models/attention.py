"""GQA attention: training (full-sequence) and decode (KV cache) paths.

The jnp path here is what the CPU dry-run lowers and analyses; on TPU the
``repro.kernels.flash_attention`` Pallas kernel implements the same math
with KV-tile skipping (see kernels/flash_attention/kernel.py).  Ragged
request batches in serving reuse the cache ``lengths`` vector — the
paper's dynamic-wavefront masking at the request level.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, dense_init, rotary


def attn_params(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), 0, cfg.param_dtype),
        "wk": dense_init(ks[1], (d, kv, hd), 0, cfg.param_dtype),
        "wv": dense_init(ks[2], (d, kv, hd), 0, cfg.param_dtype),
        "wo": dense_init(ks[3], (h, hd, d), (0, 1), cfg.param_dtype),
    }
    specs = {
        "wq": ("fsdp", "heads", "hd"),
        "wk": ("fsdp", "kv_heads", "hd"),
        "wv": ("fsdp", "kv_heads", "hd"),
        "wo": ("heads", "hd", "fsdp"),
    }
    return p, specs


def _gqa_scores(q, k, causal: bool, q_pos, k_valid):
    """q: (B,KV,G,S,hd), k: (B,KV,T,hd) -> weights (B,KV,G,S,T)."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgsh,bkth->bkgst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    t = k.shape[2]
    mask = k_valid[:, None, None, None, :]                   # (B,1,1,1,T)
    if causal:
        kpos = jnp.arange(t)[None, None, None, None, :]
        mask = mask & (kpos <= q_pos[:, None, None, :, None])
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.where(mask, w, 0.0)


def attend(cfg: ModelConfig, p, x, positions, *, causal=True,
           kv_x=None, kv_valid=None, return_kv=False):
    """Full-sequence attention.  x: (B,S,d).  ``kv_x`` enables cross-attn.

    ``kv_valid``: (B, T) bool ragged-length mask (dynamic wavefront).
    ``return_kv``: also return (k, v) as (B,KV,T,hd) for prefill caching.
    """
    b, s, _ = x.shape
    h, kv = cfg.n_heads, cfg.kv_heads
    g = h // kv
    src = x if kv_x is None else kv_x
    t = src.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(x.dtype))
    if kv_x is None:  # rotary only for self-attention
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    q = q.reshape(b, s, kv, g, cfg.hd).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)                # (B,KV,T,hd)
    v = v.transpose(0, 2, 1, 3)
    if kv_valid is None:
        kv_valid = jnp.ones((b, t), bool)
    w = _gqa_scores(q, k, causal and kv_x is None, positions, kv_valid)
    o = jnp.einsum("bkgst,bkth->bkgsh", w.astype(x.dtype), v)
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, h, cfg.hd)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# Decode path
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, KV, S_max, hd)
    v: jnp.ndarray        # (B, KV, S_max, hd)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
               dtype=None):
    dtype = dtype or cfg.dtype
    shape = (n_layers, batch, cfg.kv_heads, max_len, cfg.hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def cache_specs(cfg: ModelConfig):
    """Logical spec for one cache leaf: (layers, batch, kv_heads, seq, hd).

    The partition rules decide whether the model axis lands on "seq"
    (SP — always divides) or "cache_heads" (when kv_heads divide; fewer
    collective-permutes on the write path — see EXPERIMENTS.md #Perf).
    """
    return (None, "batch", "cache_heads", "seq", None)


def _write_at(cache, new, lengths):
    """cache: (B,KV,S,hd); new: (B,KV,hd); lengths: (B,) write positions."""
    def upd(c, n, i):
        return lax.dynamic_update_slice(c, n[:, None, :], (0, i, 0))
    return jax.vmap(upd)(cache, new, lengths)


def attend_decode(cfg: ModelConfig, p, x, layer_cache: KVCache,
                  lengths, *, rope=True):
    """One-token decode.  x: (B,d); lengths: (B,) current lengths (the new
    token is written at ``lengths`` and attends to ``<= lengths``).

    Returns (out (B,d), new_cache).
    """
    b, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    g = h // kv
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"].astype(x.dtype))
    kn = jnp.einsum("bd,dhk->bhk", x, p["wk"].astype(x.dtype))
    vn = jnp.einsum("bd,dhk->bhk", x, p["wv"].astype(x.dtype))
    if rope:
        q = rotary(q[:, None], lengths[:, None], cfg.rope_theta)[:, 0]
        kn = rotary(kn[:, None], lengths[:, None], cfg.rope_theta)[:, 0]
    ck = _write_at(layer_cache.k, kn.astype(layer_cache.k.dtype), lengths)
    cv = _write_at(layer_cache.v, vn.astype(layer_cache.v.dtype), lengths)
    t = ck.shape[2]
    qg = q.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bkth->bkgt", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    valid = jnp.arange(t)[None, :] <= lengths[:, None]       # (B,T)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgt,bkth->bkgh", w, cv)
    o = o.reshape(b, h, hd)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x.dtype))
    return out, KVCache(k=ck, v=cv)
