"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over
stub audio-frame embeddings + causal decoder with cross-attention.

Per the assignment, the modality frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, S_enc, d).  Encoder frame
counts are ragged in practice — ``enc_valid`` masks dead frames, which
is where the dynamic-wavefront tile skipping applies on the encoder side.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import attention
from .scan_util import maybe_scan
from .common import (ModelConfig, dense_init, embed_init, rms_norm, swiglu,
                     softmax_cross_entropy)


def _enc_block_params(key, cfg):
    ka, kf = jax.random.split(key)
    ap, _ = attention.attn_params(ka, cfg)
    ks = jax.random.split(kf, 3)
    return {
        "attn": ap,
        "ln_attn": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_mlp": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "mlp": {
            "w_in": dense_init(ks[0], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_gate": dense_init(ks[1], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_out": dense_init(ks[2], (cfg.d_ff, cfg.d_model), 0, cfg.param_dtype),
        },
    }


def _dec_block_params(key, cfg):
    ka, kc, kf = jax.random.split(key, 3)
    ap, _ = attention.attn_params(ka, cfg)
    cp, _ = attention.attn_params(kc, cfg)
    ks = jax.random.split(kf, 3)
    return {
        "self_attn": ap, "cross_attn": cp,
        "ln_self": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_cross": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_mlp": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "mlp": {
            "w_in": dense_init(ks[0], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_gate": dense_init(ks[1], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_out": dense_init(ks[2], (cfg.d_ff, cfg.d_model), 0, cfg.param_dtype),
        },
    }


_ATTN_SPEC = {"wq": ("fsdp", "heads", "hd"), "wk": ("fsdp", "kv_heads", "hd"),
              "wv": ("fsdp", "kv_heads", "hd"), "wo": ("heads", "hd", "fsdp")}
_MLP_SPEC = {"w_in": ("fsdp", "ff"), "w_gate": ("fsdp", "ff"),
             "w_out": ("ff", "fsdp")}


def init_params(key, cfg: ModelConfig):
    k_e, k_enc, k_dec, k_out = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _enc_block_params(k, cfg))(
        jax.random.split(k_enc, cfg.enc_layers))
    dec = jax.vmap(lambda k: _dec_block_params(k, cfg))(
        jax.random.split(k_dec, cfg.dec_layers))
    return {
        "embed": embed_init(k_e, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "enc": enc, "dec": dec,
        "ln_enc": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ln_dec": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "unembed": embed_init(k_out, (cfg.d_model, cfg.vocab), cfg.param_dtype),
    }


def param_specs(cfg: ModelConfig):
    lyr = lambda s: jax.tree.map(lambda t: ("layers",) + t, s,
                                 is_leaf=lambda x: isinstance(x, tuple))
    enc = lyr({"attn": _ATTN_SPEC, "ln_attn": (None,), "ln_mlp": (None,),
               "mlp": _MLP_SPEC})
    dec = lyr({"self_attn": _ATTN_SPEC, "cross_attn": _ATTN_SPEC,
               "ln_self": (None,), "ln_cross": (None,), "ln_mlp": (None,),
               "mlp": _MLP_SPEC})
    return {"embed": ("vocab", "fsdp"), "enc": enc, "dec": dec,
            "ln_enc": (None,), "ln_dec": (None,),
            "unembed": ("fsdp", "vocab")}


def encode(cfg: ModelConfig, params, frame_embeds, enc_valid=None):
    x = frame_embeds.astype(cfg.dtype)
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        h = rms_norm(carry, lp["ln_attn"], cfg.norm_eps)
        carry = carry + attention.attend(cfg, lp["attn"], h, pos,
                                         causal=False, kv_valid=enc_valid)
        h = rms_norm(carry, lp["ln_mlp"], cfg.norm_eps)
        m = lp["mlp"]
        carry = carry + swiglu(h, m["w_in"].astype(carry.dtype),
                               m["w_gate"].astype(carry.dtype),
                               m["w_out"].astype(carry.dtype))
        return carry, None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = maybe_scan(body, x, params["enc"], unroll_py=not cfg.scan_layers)
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, tokens, enc_out, enc_valid=None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        h = rms_norm(carry, lp["ln_self"], cfg.norm_eps)
        carry = carry + attention.attend(cfg, lp["self_attn"], h, pos,
                                         causal=True)
        h = rms_norm(carry, lp["ln_cross"], cfg.norm_eps)
        carry = carry + attention.attend(cfg, lp["cross_attn"], h, pos,
                                         causal=False, kv_x=enc_out,
                                         kv_valid=enc_valid)
        h = rms_norm(carry, lp["ln_mlp"], cfg.norm_eps)
        m = lp["mlp"]
        carry = carry + swiglu(h, m["w_in"].astype(carry.dtype),
                               m["w_gate"].astype(carry.dtype),
                               m["w_out"].astype(carry.dtype))
        return carry, None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = maybe_scan(body, x, params["dec"], unroll_py=not cfg.scan_layers)
    x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))


def loss_fn(cfg: ModelConfig, params, frame_embeds, tokens, mask=None,
            enc_valid=None):
    enc_out = encode(cfg, params, frame_embeds, enc_valid)
    logits = decode_train(cfg, params, tokens[:, :-1], enc_out, enc_valid)
    m = mask[:, 1:] if mask is not None else None
    return softmax_cross_entropy(logits, tokens[:, 1:], m)


# --------------------------------------------------------------------------
# Serving: self-attn KV cache + precomputed cross-attention K/V
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    return {
        "self": attention.init_cache(cfg, batch, max_len, cfg.dec_layers),
        "cross_k": jnp.zeros((cfg.dec_layers, batch, cfg.kv_heads, enc_len,
                              cfg.hd), cfg.dtype),
        "cross_v": jnp.zeros((cfg.dec_layers, batch, cfg.kv_heads, enc_len,
                              cfg.hd), cfg.dtype),
        "enc_len": jnp.zeros((batch,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig):
    cs = attention.cache_specs(cfg)
    return {"self": attention.KVCache(cs, cs), "cross_k": cs, "cross_v": cs,
            "enc_len": ("batch",)}


def prefill_cross(cfg: ModelConfig, params, enc_out, enc_lengths):
    """Precompute per-layer cross K/V from encoder output."""
    def one(lp):
        k = jnp.einsum("btd,dhk->bhtk", enc_out,
                       lp["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dhk->bhtk", enc_out,
                       lp["cross_attn"]["wv"].astype(enc_out.dtype))
        return k, v
    ks, vs = jax.vmap(one)(params["dec"])
    return ks, vs, enc_lengths


def decode_step(cfg: ModelConfig, params, cache, token, lengths):
    x = params["embed"].astype(cfg.dtype)[token]
    enc_valid = jnp.arange(cache["cross_k"].shape[3])[None, :] \
        < cache["enc_len"][:, None]

    def body(carry, layer):
        (xc,) = carry
        lp, lc, ck, cv = layer
        h = rms_norm(xc, lp["ln_self"], cfg.norm_eps)
        a, nc = attention.attend_decode(cfg, lp["self_attn"], h, lc, lengths)
        xc = xc + a
        h = rms_norm(xc, lp["ln_cross"], cfg.norm_eps)
        xc = xc + _cross_decode(cfg, lp["cross_attn"], h, ck, cv, enc_valid)
        h = rms_norm(xc, lp["ln_mlp"], cfg.norm_eps)
        m = lp["mlp"]
        xc = xc + swiglu(h, m["w_in"].astype(xc.dtype),
                         m["w_gate"].astype(xc.dtype),
                         m["w_out"].astype(xc.dtype))
        return (xc,), nc

    (x,), new_self = maybe_scan(
        body, (x,), (params["dec"], cache["self"], cache["cross_k"],
                     cache["cross_v"]), unroll_py=not cfg.scan_layers)
    x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cfg.dtype))
    new_cache = dict(cache, self=new_self)
    return logits, new_cache, lengths + 1


def _cross_decode(cfg, p, x, ck, cv, valid):
    """x: (B,d); ck/cv: (B,KV,T,hd)."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    g = h // kv
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"].astype(x.dtype)).reshape(b, kv, g, hd)
    s = jnp.einsum("bkgh,bkth->bkgt", q.astype(jnp.float32),
                   ck.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgt,bkth->bkgh", w, cv).reshape(b, h, hd)
    return jnp.einsum("bhk,hkd->bd", o, p["wo"].astype(x.dtype))
