"""Model zoo: the ten assigned architectures, composable in pure JAX.

All families share the conventions in :mod:`repro.models.common`:
parameters are plain pytrees with layer-stacked leaves (leading ``L``
dim) consumed by ``lax.scan`` so HLO size — and dry-run compile time —
is depth-independent; every leaf has a parallel *logical sharding spec*
(tuples of logical axis names) resolved to mesh ``PartitionSpec`` s by
:mod:`repro.sharding.partition`.
"""
from . import api

__all__ = ["api"]
