"""internvl2: stub ViT frontend + InternLM2-style dense LM backbone.

Per the assignment, the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, P, d_vit); we keep only the connector
(2-layer MLP, as in InternVL) + the LM backbone.  Prefill consumes the
mixed [patch, token] sequence; decode is the plain LM decode over a cache
whose first P positions are image states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer
from .common import ModelConfig, dense_init, softmax_cross_entropy

D_VIT = 1024   # InternViT-300M hidden size (frontend stub output)


def init_params(key, cfg: ModelConfig):
    k_lm, k_c1, k_c2 = jax.random.split(key, 3)
    p = transformer.init_params(k_lm, cfg)
    p["connector"] = {
        "w1": dense_init(k_c1, (D_VIT, cfg.d_model), 0, cfg.param_dtype),
        "w2": dense_init(k_c2, (cfg.d_model, cfg.d_model), 0, cfg.param_dtype),
    }
    return p


def param_specs(cfg: ModelConfig):
    s = transformer.param_specs(cfg)
    s["connector"] = {"w1": (None, "fsdp"), "w2": ("fsdp", None)}
    return s


def _project(cfg, params, patch_embeds):
    h = jnp.einsum("bpe,ed->bpd", patch_embeds.astype(cfg.dtype),
                   params["connector"]["w1"].astype(cfg.dtype))
    return jnp.einsum("bpd,de->bpe", jax.nn.gelu(h),
                      params["connector"]["w2"].astype(cfg.dtype))


def forward(cfg: ModelConfig, params, patch_embeds, tokens):
    """patch_embeds: (B, P, D_VIT); tokens: (B, S_text)."""
    img = _project(cfg, params, patch_embeds)
    txt = params["embed"].astype(cfg.dtype)[tokens]
    x = jnp.concatenate([img, txt], axis=1)
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = transformer.run_stack(cfg, params["blocks"], x, pos)
    from .common import rms_norm
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    p_len = img.shape[1]
    return transformer.unembed(cfg, params, x[:, p_len:])


def loss_fn(cfg: ModelConfig, params, patch_embeds, tokens, mask=None):
    logits = forward(cfg, params, patch_embeds, tokens[:, :-1])
    m = mask[:, 1:] if mask is not None else None
    return softmax_cross_entropy(logits, tokens[:, 1:], m)


# Decode reuses the plain transformer cache/step (image states live in the
# first P cache positions after prefill).
init_cache = transformer.attention.init_cache
decode_step = transformer.decode_step
