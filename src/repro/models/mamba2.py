"""Mamba2 (SSD) blocks — chunked parallel scan for training, O(1)
recurrent state for decode.

Implements the minimal SSD recurrence (Dao & Gu, 2024):

    h_t = exp(a_t) * h_{t-1} + B_t x_t^T        (per head, state N)
    y_t = C_t h_t + D x_t

trained with the chunked algorithm: intra-chunk quadratic attention-like
term + inter-chunk state scan.  This is the sub-quadratic path that makes
``long_500k`` decode (and linear-time prefill) legal for the hybrid
archs, per DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import ModelConfig, dense_init, rms_norm
from .scan_util import maybe_scan

CHUNK = 256


def ssd_params(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.ssm_heads
    p_dim = cfg.ssm_head_dim
    n = cfg.ssm_state
    inner = h * p_dim
    ks = jax.random.split(key, 6)
    p = {
        # fused input projection: [x (inner), z (inner), B (h*n), C (h*n), dt (h)]
        "w_in": dense_init(ks[0], (d, 2 * inner + 2 * h * n + h), 0,
                           cfg.param_dtype),
        "w_out": dense_init(ks[1], (inner, d), 0, cfg.param_dtype),
        "a_log": jnp.zeros((h,), cfg.param_dtype),       # A = -exp(a_log)
        "d_skip": jnp.ones((h,), cfg.param_dtype),
        "dt_bias": jnp.zeros((h,), cfg.param_dtype),
        "ln": jnp.ones((d,), cfg.param_dtype),
    }
    specs = {
        "w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp"),
        "a_log": (None,), "d_skip": (None,), "dt_bias": (None,),
        "ln": (None,),
    }
    return p, specs


def _split_proj(cfg: ModelConfig, proj):
    h, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = h * p_dim
    x, z, bmat, cmat, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + h * n, 2 * inner + 2 * h * n],
        axis=-1)
    return x, z, bmat, cmat, dt


def _segsum(a):
    """a: (..., T) -> (..., T, T) lower-triangular cumulative sums:
    out[i, j] = sum(a[j+1..i]) for j < i."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_apply(cfg: ModelConfig, p, u, positions=None, return_state=False):
    """u: (B, S, d) -> (B, S, d). Chunked SSD, S % CHUNK == 0 (padded ok)."""
    b, s, d = u.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", u, p["w_in"].astype(u.dtype))
    x, z, bm, cm, dt = _split_proj(cfg, proj)
    x = x.reshape(b, s, h, pd)
    bm = bm.reshape(b, s, h, n).astype(jnp.float32)
    cm = cm.reshape(b, s, h, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt            # (B,S,H) log-decay
    xdt = x.astype(jnp.float32) * dt[..., None]

    cl = CHUNK if s % CHUNK == 0 else s      # small sequences: one chunk
    nc = s // cl
    # reshape into chunks: (B, NC, CL, ...)
    ar = a.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)            # (B,H,NC,CL)
    xr = xdt.reshape(b, nc, cl, h, pd)
    br = bm.reshape(b, nc, cl, h, n)
    cr = cm.reshape(b, nc, cl, h, n)

    # 1. intra-chunk (quadratic within the chunk)
    ls = jnp.exp(_segsum(ar))                                     # (B,H,NC,CL,CL)
    att = jnp.einsum("bclhn,bcshn->bhcls", cr, br)                # (B,H,NC,CL,CL)
    y_diag = jnp.einsum("bhcls,bhcls,bcshp->bclhp", att, ls, xr)

    # 2. chunk-final states
    a_cum = jnp.cumsum(ar, axis=-1)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)               # (B,H,NC,CL)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchnp", br, decay_states, xr)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[..., -1])                         # (B,H,NC)

    def scan_body(carry, inp):
        st, dec = inp                                             # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                         # emit prev state

    init = jnp.zeros((b, h, n, pd), jnp.float32)
    final_state, prev_states = maybe_scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
        unroll_py=not cfg.scan_layers)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)            # (B,NC,H,N,P)

    # 4. inter-chunk output contribution
    state_decay = jnp.exp(a_cum)                                  # (B,H,NC,CL)
    y_off = jnp.einsum("bclhn,bhcl,bchnp->bclhp", cr, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, pd)
    y = y + xdt.reshape(b, s, h, pd) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.astype(u.dtype).reshape(b, s, h * pd)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(u.dtype))
    if return_state:
        return out, final_state
    return out


# --------------------------------------------------------------------------
# Decode: recurrent state
# --------------------------------------------------------------------------

def init_ssd_state(cfg: ModelConfig, batch: int, n_layers: int):
    return jnp.zeros((n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_head_dim), jnp.float32)


def ssd_state_spec():
    return (None, "batch", None, None, None)


def ssd_decode(cfg: ModelConfig, p, u, state):
    """u: (B, d); state: (B, H, N, P) -> (y (B, d), new_state)."""
    b, d = u.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = jnp.einsum("bd,de->be", u, p["w_in"].astype(u.dtype))
    x, z, bm, cm, dt = _split_proj(cfg, proj)
    x = x.reshape(b, h, pd).astype(jnp.float32)
    bm = bm.reshape(b, h, n).astype(jnp.float32)
    cm = cm.reshape(b, h, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    decay = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32)) * dt)  # (B,H)
    xdt = x * dt[..., None]
    new_state = state * decay[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", bm, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", cm, new_state)
    y = y + xdt * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, h * pd).astype(u.dtype) * jax.nn.silu(z)
    return jnp.einsum("be,ed->bd", y, p["w_out"].astype(u.dtype)), new_state
