"""Decoder-only transformer (dense GQA or MoE FFN), layer-scanned.

Covers yi-9b, phi3-medium, llama3-405b, minitron-4b, qwen3-moe,
granite-moe, the internvl2 LM backbone, and the shared attention block of
zamba2.  Parameters are stacked on a leading layer dim and consumed with
``lax.scan`` (HLO size independent of depth — essential for the 80-cell
dry-run matrix); per-layer remat is a config flag.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import attention, moe
from .scan_util import maybe_scan
from .common import (ModelConfig, dense_init, embed_init, rms_norm, swiglu,
                     softmax_cross_entropy)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def block_params(key, cfg: ModelConfig):
    ka, kf = jax.random.split(key)
    ap, aspec = attention.attn_params(ka, cfg)
    p = {"attn": ap,
         "ln_attn": jnp.ones((cfg.d_model,), cfg.param_dtype),
         "ln_mlp": jnp.ones((cfg.d_model,), cfg.param_dtype)}
    specs = {"attn": aspec, "ln_attn": (None,), "ln_mlp": (None,)}
    if cfg.family == "moe" or cfg.num_experts:
        mp, mspec = moe.moe_params(kf, cfg)
        p["moe"] = mp
        specs["moe"] = mspec
    else:
        ks = jax.random.split(kf, 3)
        p["mlp"] = {
            "w_in": dense_init(ks[0], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_gate": dense_init(ks[1], (cfg.d_model, cfg.d_ff), 0, cfg.param_dtype),
            "w_out": dense_init(ks[2], (cfg.d_ff, cfg.d_model), 0, cfg.param_dtype),
        }
        specs["mlp"] = {"w_in": ("fsdp", "ff"), "w_gate": ("fsdp", "ff"),
                        "w_out": ("ff", "fsdp")}
    return p, specs


def block_specs(cfg: ModelConfig):
    specs = {"attn": {"wq": ("fsdp", "heads", "hd"),
                      "wk": ("fsdp", "kv_heads", "hd"),
                      "wv": ("fsdp", "kv_heads", "hd"),
                      "wo": ("heads", "hd", "fsdp")},
             "ln_attn": (None,), "ln_mlp": (None,)}
    if cfg.family == "moe" or cfg.num_experts:
        specs["moe"] = {"router": ("fsdp", None),
                        "w_in": ("experts", "fsdp", "expert_ff"),
                        "w_gate": ("experts", "fsdp", "expert_ff"),
                        "w_out": ("experts", "expert_ff", "fsdp")}
    else:
        specs["mlp"] = {"w_in": ("fsdp", "ff"), "w_gate": ("fsdp", "ff"),
                        "w_out": ("ff", "fsdp")}
    return specs


def init_params(key, cfg: ModelConfig):
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: block_params(k, cfg)[0])(block_keys)
    params = {
        "embed": embed_init(k_emb, (cfg.vocab, cfg.d_model), cfg.param_dtype),
        "blocks": blocks,
        "ln_f": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(k_out, (cfg.d_model, cfg.vocab),
                                       cfg.param_dtype)
    return params


def param_specs(cfg: ModelConfig):
    """Logical sharding specs, mirroring :func:`init_params` (layer-stacked
    block leaves get a leading "layers" axis)."""
    stack = jax.tree.map(lambda s: ("layers",) + s, block_specs(cfg),
                         is_leaf=lambda x: isinstance(x, tuple))
    specs = {"embed": ("vocab", "fsdp"), "blocks": stack, "ln_f": (None,)}
    if not cfg.tie_embeddings:
        specs["unembed"] = ("fsdp", "vocab")
    return specs


# --------------------------------------------------------------------------
# Forward (training / prefill)
# --------------------------------------------------------------------------

def block_apply(cfg: ModelConfig, p, x, positions):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    x = x + attention.attend(cfg, p["attn"], h, positions)
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe.moe_apply(cfg, p["moe"], h)
    else:
        m = p["mlp"]
        x = x + swiglu(h, m["w_in"].astype(x.dtype),
                       m["w_gate"].astype(x.dtype), m["w_out"].astype(x.dtype))
    return x


def run_stack(cfg: ModelConfig, blocks, x, positions):
    def body(carry, lp):
        return block_apply(cfg, lp, carry, positions), None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = maybe_scan(body, x, blocks, unroll_py=not cfg.scan_layers)
    return x


def forward(cfg: ModelConfig, params, tokens, *, embeds=None, positions=None):
    """tokens: (B, S) int32 (or ``embeds``: (B,S,d)).  Returns logits."""
    if embeds is None:
        x = params["embed"].astype(cfg.dtype)[tokens]
    else:
        x = embeds.astype(cfg.dtype)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = run_stack(cfg, params["blocks"], x, positions)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return unembed(cfg, params, x)


def unembed(cfg: ModelConfig, params, x):
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    return jnp.einsum("...d,dv->...v", x, w.astype(cfg.dtype))


def loss_fn(cfg: ModelConfig, params, tokens, *, embeds=None, mask=None):
    logits = forward(cfg, params, tokens[:, :-1],
                     embeds=None if embeds is None else embeds[:, :-1])
    targets = tokens[:, 1:]
    m = mask[:, 1:] if mask is not None else None
    return softmax_cross_entropy(logits, targets, m)


def prefill(cfg: ModelConfig, params, tokens, *, embeds=None, max_len=None):
    """Prefill: forward pass that also builds the KV cache.

    Returns (last-token logits (B, V), KVCache (L,B,KV,max_len,hd),
    lengths (B,)).
    """
    if embeds is None:
        x = params["embed"].astype(cfg.dtype)[tokens]
    else:
        x = embeds.astype(cfg.dtype)
    b, s = x.shape[:2]
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        h = rms_norm(carry, lp["ln_attn"], cfg.norm_eps)
        a, (k, v) = attention.attend(cfg, lp["attn"], h, positions,
                                     return_kv=True)
        carry = carry + a
        h = rms_norm(carry, lp["ln_mlp"], cfg.norm_eps)
        if "moe" in lp:
            carry = carry + moe.moe_apply(cfg, lp["moe"], h)
        else:
            m = lp["mlp"]
            carry = carry + swiglu(h, m["w_in"].astype(carry.dtype),
                                   m["w_gate"].astype(carry.dtype),
                                   m["w_out"].astype(carry.dtype))
        return carry, (k, v)

    x, (ks, vs) = maybe_scan(body, x, params["blocks"],
                             unroll_py=not cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1])
    pad = max_len - s
    if pad > 0:
        padw = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
        ks = jnp.pad(ks, padw)
        vs = jnp.pad(vs, padw)
    cache = attention.KVCache(k=ks, v=vs)
    return logits, cache, jnp.full((b,), s, jnp.int32)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def block_decode(cfg: ModelConfig, p, x, layer_cache, lengths):
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    a, new_cache = attention.attend_decode(cfg, p["attn"], h, layer_cache,
                                           lengths)
    x = x + a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe.moe_apply(cfg, p["moe"], h[:, None, :])[:, 0, :]
    else:
        m = p["mlp"]
        x = x + swiglu(h, m["w_in"].astype(x.dtype),
                       m["w_gate"].astype(x.dtype), m["w_out"].astype(x.dtype))
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache: attention.KVCache,
                token, lengths):
    """One decode step.  token: (B,) int32; lengths: (B,).

    Returns (logits (B, V), new_cache, new_lengths).
    """
    x = params["embed"].astype(cfg.dtype)[token]

    def body(carry, layer):
        xc, = carry
        lp, lc = layer
        xn, nc = block_decode(cfg, lp, xc, lc, lengths)
        return (xn,), nc

    (x,), new_kv = maybe_scan(body, (x,), (params["blocks"], cache),
                              unroll_py=not cfg.scan_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    return logits, new_kv, lengths + 1
