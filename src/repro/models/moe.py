"""Mixture-of-experts FFN with expert-parallel, capacity-based dispatch.

Two routing modes:

* ``expert_choice`` (default) — each expert picks its top-C tokens
  (Zhou et al., 2022).  Static shapes, no sort; C is set so compute
  matches the config's token-choice top-k budget (E*C = N*top_k).  This
  is the compile- and EP-friendly path used in the dry-runs.
* ``token_dense`` — exact token-choice top-k with a dense combine
  einsum; exact but O(E) compute per token, used for small smoke tests
  and as the routing oracle in tests.

The per-expert gathered blocks are exactly the paper's dynamic
wavefronts: tokens-per-expert is ragged, and on TPU the expert GEMMs run
through ``kernels/wavefront_matmul`` which skips inactive row tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init


def moe_params(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), 0, cfg.param_dtype),
        "w_in": dense_init(ks[1], (e, d, f), 1, cfg.param_dtype),
        "w_gate": dense_init(ks[2], (e, d, f), 1, cfg.param_dtype),
        "w_out": dense_init(ks[3], (e, f, d), 1, cfg.param_dtype),
    }
    specs = {
        "router": ("fsdp", None),
        "w_in": ("experts", "fsdp", "expert_ff"),
        "w_gate": ("experts", "fsdp", "expert_ff"),
        "w_out": ("experts", "expert_ff", "fsdp"),
    }
    return p, specs


def _expert_ffn(p, xe, dtype):
    """xe: (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                      p["w_out"].astype(dtype))


def moe_apply(cfg: ModelConfig, p, x, *, mode: str = "expert_choice",
              capacity_factor: float = 1.0):
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.top_k
    flat = x.reshape(n, d)
    gate_logits = jnp.einsum("nd,de->ne", flat, p["router"].astype(x.dtype))
    gates = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)

    if mode == "token_dense":
        # exact top-k token choice, dense combine (smoke/tests only)
        topv, topi = jax.lax.top_k(gates, k)                  # (N, k)
        topv = topv / jnp.sum(topv, -1, keepdims=True)
        combine = jnp.zeros((n, e), jnp.float32)
        combine = jax.vmap(lambda c, i, v: c.at[i].add(v))(combine, topi, topv)
        xe = jnp.einsum("ne,nd->end", combine.astype(x.dtype), flat)
        ye = _expert_ffn(p, xe, x.dtype)
        out = jnp.sum(ye, axis=0)                             # already weighted
        return out.reshape(b, s, d)

    # expert choice: each expert takes its top-C tokens
    cap = max(1, int(round(n * k * capacity_factor / e)))
    scores = gates.T                                          # (E, N)
    topv, topi = jax.lax.top_k(scores, cap)                   # (E, C)
    xe = jnp.take(flat, topi.reshape(-1), axis=0).reshape(e, cap, d)
    ye = _expert_ffn(p, xe, x.dtype)
    ye = ye * topv[..., None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[topi.reshape(-1)].add(
        ye.reshape(-1, d))
    return out.reshape(b, s, d)


def aux_load_balance_loss(gate_logits_f32: jnp.ndarray, top_k: int) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (per batch of logits)."""
    gates = jax.nn.softmax(gate_logits_f32, axis=-1)
    e = gates.shape[-1]
    frac_routed = jnp.mean(
        jax.nn.one_hot(jnp.argmax(gates, -1), e, dtype=jnp.float32), axis=0)
    frac_gate = jnp.mean(gates, axis=0)
    return e * jnp.sum(frac_routed * frac_gate)
