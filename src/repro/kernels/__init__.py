"""Pallas TPU kernels.

Each kernel adapts the paper's *dynamic thread-space* insight to the TPU:
the grid of VMEM tiles plays the role of the eGPU's SP x wavefront array,
and a scalar-prefetched activity bitmap plays the role of the 4-bit TSC
instruction field — `pl.when` skips whole tiles with zero dead time,
exactly as the eGPU skips wavefronts.

Layout per kernel: ``<name>/kernel.py`` (pl.pallas_call + BlockSpec),
``<name>/ops.py`` (jit'd public wrapper with backend dispatch),
``<name>/ref.py`` (pure-jnp oracle used for tests and for CPU lowering).
"""
