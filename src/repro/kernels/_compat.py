"""Version-compatibility shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams``, named ``TPUCompilerParams`` on jax < 0.5."""
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams — incompatible jax version")
    return cls(**kw)
