"""Flash attention with dynamic KV-tile skipping (Pallas TPU).

The online-softmax KV loop is the attention analogue of the eGPU's
wavefront depth: for a causal (or ragged-length) row block, only a prefix
of the KV tiles is live.  We compute that prefix bound from the
scalar-prefetched per-batch lengths and `pl.when`-skip everything beyond
it — the instruction-level "first 1/2 / first 1/4 wavefronts" codings of
Table 3, generalised to an exact per-row-block bound.

Grid: (batch*heads, q tiles, kv tiles); scratch: running max m, running
sum l, fp32 accumulator — all VMEM-resident across the KV loop.
"""
from __future__ import annotations

import functools

import jax
from jax import numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params as _CompilerParams

DEFAULT_TILE_Q = 128
DEFAULT_TILE_K = 128
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            tile_q: int, tile_k: int, causal: bool, sq: int, sk: int,
            heads: int):
    bh = pl.program_id(0)
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    b = bh // heads

    kv_len = len_ref[b]
    # last kv position this q tile may see (decode-style causal offset)
    q_last = iq * tile_q + (tile_q - 1) + (sk - sq) if causal else sk - 1
    limit = jnp.minimum(kv_len, q_last + 1) if causal else kv_len
    live = (ik * tile_k) < limit           # wavefront-depth subsetting

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)      # (tile_q, d)
        k = k_ref[0].astype(jnp.float32)      # (tile_k, d)
        v = v_ref[0].astype(jnp.float32)
        d = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) \
            * (1.0 / (d ** 0.5))              # (tile_q, tile_k)

        qpos = iq * tile_q + jax.lax.broadcasted_iota(
            jnp.int32, (tile_q, tile_k), 0)
        kpos = ik * tile_k + jax.lax.broadcasted_iota(
            jnp.int32, (tile_q, tile_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos + (sk - sq)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                  # (tile_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)               # (tile_q, tile_k)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "tile_q", "tile_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    lengths: jnp.ndarray | None = None,
                    causal: bool = True,
                    tile_q: int = DEFAULT_TILE_Q,
                    tile_k: int = DEFAULT_TILE_K,
                    interpret: bool = False) -> jnp.ndarray:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % tile_q == 0 and sk % tile_k == 0
    if lengths is None:
        lengths = jnp.full((b,), sk, jnp.int32)
    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * h, sk, d)
    vr = v.reshape(b * h, sk, d)
    grid = (b * h, sq // tile_q, sk // tile_k)
    out = pl.pallas_call(
        functools.partial(_kernel, tile_q=tile_q, tile_k=tile_k,
                          causal=causal, sq=sq, sk=sk, heads=h),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, tile_q, d), lambda bh, iq, ik, lens: (bh, iq, 0),
                             memory_space=pltpu.VMEM),
                pl.BlockSpec((1, tile_k, d), lambda bh, iq, ik, lens: (bh, ik, 0),
                             memory_space=pltpu.VMEM),
                pl.BlockSpec((1, tile_k, d), lambda bh, iq, ik, lens: (bh, ik, 0),
                             memory_space=pltpu.VMEM),
            ],
            out_specs=pl.BlockSpec((1, tile_q, d),
                                   lambda bh, iq, ik, lens: (bh, iq, 0),
                                   memory_space=pltpu.VMEM),
            scratch_shapes=[
                pltpu.VMEM((tile_q, 1), jnp.float32),
                pltpu.VMEM((tile_q, 1), jnp.float32),
                pltpu.VMEM((tile_q, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(b, h, sq, d)
