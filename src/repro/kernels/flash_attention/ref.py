"""Pure-jnp oracle for blocked causal/ragged attention."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            lengths: jnp.ndarray | None = None,
            causal: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D), k/v: (B, H, Sk, D), lengths: (B,) valid kv length.

    Returns (B, H, Sq, D) float32.  Causal alignment is decode-style:
    query i attends to kv positions <= i + (Sk - Sq).
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d)
    sq, sk = q.shape[2], k.shape[2]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        offs = sk - sq
        mask = (jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None] + offs)
    mask = jnp.broadcast_to(mask[None, None], logits.shape)
    if lengths is not None:
        lmask = jnp.arange(sk)[None, None, None, :] < lengths[:, None, None, None]
        mask = mask & lmask
    logits = jnp.where(mask, logits, -1e30)
    w = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    w = jnp.where(mask, w, 0.0)
    denom = jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w / denom, v)
