"""Public wrapper for flash attention with KV-tile skipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def flash_attention(q, k, v, lengths=None, causal: bool = True,
                    backend: str | None = None, **kw) -> jnp.ndarray:
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return _kernel.flash_attention(q, k, v, lengths, causal, **kw)
    if backend == "interpret":
        return _kernel.flash_attention(q, k, v, lengths, causal,
                                       interpret=True, **kw)
    return _ref.mha_ref(q, k, v, lengths, causal).astype(q.dtype)
