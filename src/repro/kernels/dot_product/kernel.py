"""The eGPU dot-product extension core as a Pallas TPU kernel.

The eGPU's DOT folds <Ra, Rb> over the active thread space in one issue;
on TPU we stream (TILE_T, L) tiles through VMEM, accumulate in a (1, 1)
VMEM scratch across sequential grid steps, and skip TSC-inactive tiles
with `pl.when` (skipped tiles cost neither FLOPs nor accumulator
traffic — the "subset read" analogue).
"""
from __future__ import annotations

import functools

import jax
from jax import numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_T = 8


def _kernel(active_ref, a_ref, b_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active_ref[i] != 0)
    def _accum():
        a = a_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        acc_ref[0, 0] += jnp.sum(a * b)

    @pl.when(i == n - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_product(a: jnp.ndarray, b: jnp.ndarray, active: jnp.ndarray,
                interpret: bool = False) -> jnp.ndarray:
    t, lanes = a.shape
    assert t % TILE_T == 0
    grid = (t // TILE_T,)
    spec = pl.BlockSpec((TILE_T, lanes), lambda i, act: (i, 0),
                        memory_space=pltpu.VMEM)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec],
            out_specs=pl.BlockSpec((1, 1), lambda i, act: (0, 0),
                                   memory_space=pltpu.VMEM),
            scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(active.astype(jnp.int32), a, b)
    return out[0, 0]
