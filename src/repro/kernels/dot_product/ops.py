"""Public wrapper for the DOT extension kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

TILE_T = _kernel.TILE_T


def dot_product(a, b, active, backend: str | None = None) -> jnp.ndarray:
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return _kernel.dot_product(a, b, active)
    if backend == "interpret":
        return _kernel.dot_product(a, b, active, interpret=True)
    return _ref.dot_product_ref(a, b, active, tile=TILE_T)
