"""Pure-jnp oracle for the DOT extension unit."""
from __future__ import annotations

import jax.numpy as jnp


def dot_product_ref(a: jnp.ndarray, b: jnp.ndarray,
                    active: jnp.ndarray, tile: int = 8) -> jnp.ndarray:
    """<a, b> over the active thread space (eGPU DOT): (T, L) -> scalar."""
    t = a.shape[0]
    mask = jnp.repeat(active.astype(bool), tile, total_repeat_length=t)
    prod = (a.astype(jnp.float32) * b.astype(jnp.float32))
    return jnp.sum(jnp.where(mask[:, None], prod, 0.0))
