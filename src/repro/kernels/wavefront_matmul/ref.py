"""Pure-jnp oracle for the dynamically-masked block matmul."""
from __future__ import annotations

import jax.numpy as jnp


def wavefront_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                         row_active: jnp.ndarray,
                         tile_m: int = 128) -> jnp.ndarray:
    """C = A @ B with whole row-tiles of A/C dynamically disabled.

    a: (M, K), b: (K, N), row_active: (M // tile_m,).  Inactive row tiles
    produce zeros (they were never issued — the eGPU wavefront-depth
    subsetting along M, e.g. tokens-per-expert in MoE dispatch).
    """
    m = a.shape[0]
    mask = jnp.repeat(row_active.astype(bool), tile_m, total_repeat_length=m)
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return jnp.where(mask[:, None], c, 0.0)
