"""Public wrapper for the dynamically-masked matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

TILE_M = _kernel.TILE_M


def wavefront_matmul(a, b, row_active, backend: str | None = None
                     ) -> jnp.ndarray:
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return _kernel.wavefront_matmul(a, b, row_active)
    if backend == "interpret":
        return _kernel.wavefront_matmul(a, b, row_active, interpret=True)
    return _ref.wavefront_matmul_ref(a, b, row_active, tile_m=TILE_M)
