"""Dynamically-masked block matmul — the paper's technique on the MXU.

C[M, N] = A[M, K] @ B[K, N], where row-tiles of M carry an activity
bitmap (scalar-prefetched, like the TSC field).  Inactive tiles skip the
whole K-loop: no MXU issue, no VMEM accumulation — the direct analogue of
the eGPU skipping wavefronts ("subset write can be 16x faster").

Used for MoE expert compute, where M is the token dimension grouped by
expert and most groups are ragged (tokens-per-expert << capacity).

Block sizes are MXU-native (128x128) with a K-major accumulation loop in
a VMEM scratch accumulator (fp32), B streamed K-tile by K-tile.
"""
from __future__ import annotations

import functools

import jax
from jax import numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params as _CompilerParams

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _kernel(active_ref, a_ref, b_ref, o_ref, acc_ref):
    mi = pl.program_id(0)
    k = pl.program_id(2)
    nk = pl.num_programs(2)
    is_active = active_ref[mi] != 0

    @pl.when(is_active & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(is_active)
    def _accum():
        acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _write():
        o_ref[...] = jnp.where(is_active, acc_ref[...].astype(o_ref.dtype),
                               jnp.zeros_like(o_ref))


@functools.partial(jax.jit, static_argnames=("interpret",))
def wavefront_matmul(a: jnp.ndarray, b: jnp.ndarray,
                     row_active: jnp.ndarray,
                     interpret: bool = False) -> jnp.ndarray:
    m, kdim = a.shape
    k2, n = b.shape
    assert kdim == k2
    assert m % TILE_M == 0 and n % TILE_N == 0 and kdim % TILE_K == 0
    grid = (m // TILE_M, n // TILE_N, kdim // TILE_K)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((TILE_M, TILE_K), lambda i, j, k, act: (i, k),
                             memory_space=pltpu.VMEM),
                pl.BlockSpec((TILE_K, TILE_N), lambda i, j, k, act: (k, j),
                             memory_space=pltpu.VMEM),
            ],
            out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, k, act: (i, j),
                                   memory_space=pltpu.VMEM),
            scratch_shapes=[pltpu.VMEM((TILE_M, TILE_N), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(row_active.astype(jnp.int32), a, b)
