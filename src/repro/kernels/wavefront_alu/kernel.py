"""Wavefront ALU execute stage as a Pallas TPU kernel.

The eGPU issues one 16-lane wavefront per cycle and the TSC field drops
inactive wavefronts from the issue schedule.  On TPU the natural
"wavefront" is a VMEM tile aligned to the VPU (8, 128) vector registers;
the activity bitmap arrives via scalar prefetch (it is known before the
grid runs, like the TSC field is known at decode) and `pl.when` skips the
tile's compute entirely.
"""
from __future__ import annotations

import functools

import jax
from jax import numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: sublane tile (threads) — one "wavefront block"; lanes are fixed at 128.
TILE_T = 8


def _kernel(active_ref, a_ref, b_ref, init_ref, o_ref, *, op: str):
    i = pl.program_id(0)
    is_active = active_ref[i] != 0

    @pl.when(is_active)
    def _compute():
        a = a_ref[...]
        b = b_ref[...]
        if op == "add":
            r = a + b
        elif op == "sub":
            r = a - b
        elif op == "mul":
            r = a * b
        elif op == "max":
            r = jnp.maximum(a, b)
        else:
            r = jnp.minimum(a, b)
        o_ref[...] = r

    @pl.when(jnp.logical_not(is_active))
    def _skip():
        # inactive wavefront: registers unchanged (eGPU write_enable = 0)
        o_ref[...] = init_ref[...]


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def wavefront_alu(a: jnp.ndarray, b: jnp.ndarray, init: jnp.ndarray,
                  active: jnp.ndarray, op: str = "add",
                  interpret: bool = False) -> jnp.ndarray:
    t, lanes = a.shape
    assert t % TILE_T == 0, "thread space must tile by the wavefront block"
    grid = (t // TILE_T,)
    spec = pl.BlockSpec((TILE_T, lanes), lambda i, act: (i, 0),
                        memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[spec, spec, spec],
            out_specs=spec,
        ),
        out_shape=jax.ShapeDtypeStruct((t, lanes), a.dtype),
        interpret=interpret,
    )(active.astype(jnp.int32), a, b, init)
