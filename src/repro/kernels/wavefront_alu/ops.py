"""Public wrapper: dispatches to the Pallas kernel on TPU, the jnp
reference elsewhere (the reference produces the HLO the CPU dry-run
analyses; the kernel is the TPU artifact, validated in interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

TILE_T = _kernel.TILE_T


def wavefront_alu(a, b, init, active, op: str = "add",
                  backend: str | None = None) -> jnp.ndarray:
    """Masked wavefront ALU op.  ``active``: (T // TILE_T,) tile bitmap."""
    backend = backend or jax.default_backend()
    if backend == "tpu":
        return _kernel.wavefront_alu(a, b, init, active, op)
    if backend == "interpret":
        return _kernel.wavefront_alu(a, b, init, active, op, interpret=True)
    return _ref.wavefront_alu_ref(a, b, init, active, op, tile=TILE_T)
