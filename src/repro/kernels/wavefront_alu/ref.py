"""Pure-jnp oracle for the wavefront ALU."""
from __future__ import annotations

import jax.numpy as jnp

OPS = ("add", "sub", "mul", "max", "min")


def wavefront_alu_ref(a: jnp.ndarray, b: jnp.ndarray, init: jnp.ndarray,
                      active: jnp.ndarray, op: str,
                      tile: int = 8) -> jnp.ndarray:
    """Execute ``op`` over the thread space; tiles with ``active==0`` keep
    ``init`` (the eGPU semantics: a TSC-disabled wavefront's registers are
    untouched).

    a, b, init: (T, L) float32; active: (T // tile,) int32/bool.
    """
    f = {"add": jnp.add, "sub": jnp.subtract, "mul": jnp.multiply,
         "max": jnp.maximum, "min": jnp.minimum}[op]
    out = f(a, b)
    t = a.shape[0]
    mask = jnp.repeat(active.astype(bool), tile, total_repeat_length=t)
    return jnp.where(mask[:, None], out, init)
