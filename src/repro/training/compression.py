"""Gradient compression: per-tensor int8 quantisation with error
feedback (EF-SGD style).  Applied before the data-parallel reduction so
the wire format is 4x smaller; the residual buffer carries quantisation
error into the next step (bounded bias, tested by property tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x):
    """x (f32/bf16) -> (int8 codes, f32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, residual):
    """Compress each gradient leaf; the quantisation error accumulates in
    ``residual`` and is re-injected next step."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = quantize(target)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), target - deq
    out = jax.tree.map(one, grads, residual)
    newg = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newr = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newg, newr
