"""Train / serve step builders (the functions the launcher jits and the
dry-run lowers).

Distributed-optimization features, all config-gated:
  * microbatch gradient accumulation (scan) with *drop-stale-microbatch*
    straggler mitigation — a boolean keep-mask zeroes contributions from
    microbatches flagged as stragglers, rescaling by the kept count;
  * gradient compression (int8 + error feedback) around the DP reduction;
  * NaN/non-finite sentinel: the update is skipped (params passed
    through) when the loss or grad norm is non-finite, and the sentinel
    is reported so the driver can restore from checkpoint.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..models import api
from ..models.common import ModelConfig
from . import compression, optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    compress_grads: bool = False
    straggler_mitigation: bool = False


def make_train_step(cfg: ModelConfig, ocfg: opt_mod.OptConfig,
                    settings: TrainSettings = TrainSettings()):
    """Returns train_step(params, opt_state, batch, ef_residual) ->
    (params, opt_state, ef_residual, metrics)."""

    def loss_of(params, batch):
        return api.loss(cfg, params, batch)

    def grads_of(params, batch):
        if settings.microbatches <= 1:
            return jax.value_and_grad(loss_of)(params, batch)
        mb = settings.microbatches

        def split(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
        batch_mb = jax.tree.map(split, batch)
        keep = batch.get("microbatch_keep")
        if keep is None:
            keep = jnp.ones((mb,), jnp.float32)

        def body(carry, inp):
            acc_l, acc_g = carry
            b, k = inp
            l, g = jax.value_and_grad(loss_of)(params, b)
            acc_g = jax.tree.map(
                lambda a, x: a + k * x.astype(jnp.float32), acc_g, g)
            return (acc_l + k * l.astype(jnp.float32), acc_g), None

        zero_g = jax.tree.map(
            lambda p_: jnp.zeros(p_.shape, jnp.float32), params)
        mb_batches = {k: v for k, v in batch_mb.items()
                      if k != "microbatch_keep"}
        (tl, tg), _ = lax.scan(body, (jnp.float32(0.0), zero_g),
                               (mb_batches, keep))
        denom = jnp.maximum(jnp.sum(keep), 1.0)
        return tl / denom, jax.tree.map(lambda g: g / denom, tg)

    def train_step(params, opt_state, batch, ef_residual):
        loss, grads = grads_of(params, batch)
        if settings.compress_grads:
            grads, ef_residual = compression.apply_error_feedback(
                grads, ef_residual)
        new_params, new_opt, info = opt_mod.apply(params, grads, opt_state,
                                                  ocfg)
        finite = jnp.isfinite(loss) & jnp.isfinite(info["grad_norm"])
        # non-finite sentinel: skip the update (fault tolerance)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_params, params)
        new_opt = jax.tree.map(
            lambda n, o: jnp.where(finite, n, o), new_opt, opt_state)
        metrics = {"loss": loss, "grad_norm": info["grad_norm"],
                   "lr": info["lr"],
                   "finite": finite.astype(jnp.float32)}
        return new_params, new_opt, ef_residual, metrics

    return train_step


def make_serve_decode_step(cfg: ModelConfig, mask_cache: bool = False):
    """decode_step(params, cache, token, lengths, active) — ``active`` is
    the per-request dynamic-wavefront mask: finished/empty slots keep
    their lengths frozen (no dead time, Table 3 semantics at request
    granularity).

    ``mask_cache=False`` (default, #Perf iteration): only ``lengths`` are
    masked.  An inactive slot still writes its (garbage) k/v at its
    frozen position, but that row is overwritten when the slot is
    re-prefilled for a new request and is never read meanwhile — masking
    lengths alone avoids a full cache read+select+write per step.
    ``mask_cache=True`` keeps the fully-masked (pristine-cache) variant.
    """

    def step(params, cache, token, lengths, active):
        logits, new_cache, new_lengths = api.decode(cfg, params, cache,
                                                    token, lengths)
        keep = active.astype(jnp.bool_)
        if mask_cache:
            def merge(new, old):
                if new.shape == old.shape and new.ndim >= 1 \
                        and old.shape[0] == keep.shape[0]:
                    bshape = (keep.shape[0],) + (1,) * (new.ndim - 1)
                    return jnp.where(keep.reshape(bshape), new, old)
                if new.ndim >= 2 and new.shape[1] == keep.shape[0]:
                    bshape = (1, keep.shape[0]) + (1,) * (new.ndim - 2)
                    return jnp.where(keep.reshape(bshape), new, old)
                return new
            new_cache = jax.tree.map(merge, new_cache, cache)
        new_lengths = jnp.where(keep, new_lengths, lengths)
        return logits, new_cache, new_lengths

    return step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def step(params, batch):
        return api.prefill(cfg, params, batch, max_len)
    return step
