"""Synthetic, deterministic, shardable data pipeline.

Real deployments swap in a file-backed loader with the same interface:
``next_batch(step) -> dict of np arrays`` (host-side), which the launcher
places onto the mesh with ``jax.make_array_from_process_local_data`` /
``jax.device_put`` with the batch sharding.

The synthetic stream is a fixed-seed Zipf-ish token distribution with a
learnable bigram structure, so small models measurably descend in loss
(used by the end-to-end training example and the convergence test).
"""
from __future__ import annotations

import numpy as np

from ..models.common import ModelConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 17):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        rng = np.random.default_rng(seed)
        v = cfg.vocab
        # sparse deterministic bigram: each token has a few likely successors
        self._succ = rng.integers(0, v, size=(v, 4))
        self._seed = seed

    def next_batch(self, step: int) -> dict:
        rng = np.random.default_rng(self._seed + 1000 + step)
        b, s, v = self.batch, self.seq, self.cfg.vocab
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        choice = rng.integers(0, 4, size=(b, s))
        noise = rng.random((b, s))
        rand_tok = rng.integers(0, v, size=(b, s))
        for t in range(1, s):
            nxt = self._succ[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t] < 0.1, rand_tok[:, t], nxt)
        out = {"tokens": toks}
        if self.cfg.family == "encdec":
            r = np.random.default_rng(self._seed + 2000 + step)
            out["frames"] = r.standard_normal(
                (b, s, self.cfg.d_model)).astype(np.float32)
        if self.cfg.family == "vlm":
            r = np.random.default_rng(self._seed + 3000 + step)
            out["patches"] = r.standard_normal(
                (b, self.cfg.num_patches, 1024)).astype(np.float32)
        return out
