"""Sharded, atomic, *elastic* checkpointing.

Fault-tolerance contract (1000+ node deployments):
  * atomic: written to ``<dir>/tmp.<step>`` then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint;
  * self-describing: a JSON manifest records step, mesh topology, and
    per-leaf paths/shapes/dtypes;
  * elastic: ``restore`` only needs the *target* sharding — a run saved
    on a (2,16,16) mesh restores onto (16,16) (dropped pod) or any other
    topology, because leaves are stored as full logical arrays (per-shard
    storage with reassembly is the natural extension; the logical format
    keeps the elasticity property testable on one host);
  * async: ``save_async`` snapshots to host memory synchronously (the
    step barrier) and writes files on a background thread.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Blocking save.  Returns the final checkpoint directory."""
    leaves, _ = _flatten(tree)
    tmp = f"{path}.tmp.{step}"
    final = f"{path}/step_{step:08d}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.makedirs(path, exist_ok=True)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _write_latest(path, final)
    return final


def save_async(path: str, step: int, tree, extra: dict | None = None
               ) -> threading.Thread:
    """Device->host snapshot now; file I/O on a background thread."""
    host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
    t = threading.Thread(target=save, args=(path, step, host_tree, extra),
                         daemon=True)
    t.start()
    return t


def _write_latest(path, final):
    tmp = os.path.join(path, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(tmp, os.path.join(path, "LATEST"))


def latest_step(path: str) -> int | None:
    latest = os.path.join(path, "LATEST")
    if not os.path.exists(latest):
        return None
    name = open(latest).read().strip()
    return int(name.split("_")[-1])


def restore(path: str, like_tree, step: int | None = None,
            shardings=None):
    """Restore onto the current mesh.  ``shardings`` (optional pytree of
    NamedSharding, same structure) re-places leaves — the elastic path."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError("checkpoint/model structure mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (like, meta, sh) in enumerate(
            zip(leaves, manifest["leaves"], shard_leaves)):
        arr = np.load(os.path.join(d, meta["file"]))
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != {like.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(like.dtype)))
    return jax.tree.unflatten(treedef, out), manifest["step"], manifest["extra"]
