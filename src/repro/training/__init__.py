from . import optimizer, steps, data, checkpoint, compression  # noqa: F401
