"""AdamW with gradient clipping and warmup-cosine schedule — pure JAX.

Optimizer state mirrors the parameter tree (same logical sharding specs),
so ZeRO/FSDP placement of m/v falls out of the same partition rules.
``state_dtype`` lets llama3-405b keep bf16 moments (the memory analysis
in EXPERIMENTS.md shows fp32 moments do not fit 256 x 16GB).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32


def init(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: OptConfig, count):
    warm = jnp.minimum(1.0, (count + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((count - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(params, grads, state, cfg: OptConfig):
    count = state["count"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, count)
    t = (count + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:      # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": newm, "v": newv, "count": count + 1}
    return newp, new_state, {"grad_norm": gnorm, "lr": lr}


def state_specs(param_specs):
    """Optimizer-state logical specs mirror the parameter specs."""
    return {"m": param_specs, "v": param_specs, "count": ()}
