"""Multi-device fleet: shard the job stream across every local device.

:class:`ShardedFleetScheduler` extends :class:`~repro.fleet.scheduler.
FleetScheduler` — same ``submit``/``drain``/``drain_isolated`` API, same
crash-safety and salvage invariants — but executes across a set of jax
devices instead of one:

* **same-program megabatches** — a group big enough to fill every
  device (``>= n_devices * batch_size`` jobs of one program at one
  thread count) is packed into exact slabs of ``n_devices *
  batch_size`` rows and dispatched as ONE ``shard_map`` call over the
  1-D ``("jobs",)`` device mesh: each device runs the compiled light
  path over its ``batch_size``-row shard.  Every row is an independent
  core, so sharding the leading batch axis is bit-identical to the
  single-device dispatch (the degenerate-path equivalence tests pin
  this).  Slab inputs keep their own device-sharded
  :class:`~repro.fleet.engine.ResidencyCache`, and the ``shard_map``
  executable is AOT-compiled and cached per (program, slab shape);
* **heterogeneous mixes** — everything else routes through per-device
  queues: jobs group by program (so one device keeps a program's
  residency and compile caches warm), groups are assigned to the
  least-loaded device by the cost model's per-job estimates
  (:func:`~repro.fleet.devices.balance_units`), and each device's
  private pinned :class:`FleetScheduler` drains its lane on its own
  thread — one dispatch stream per device;
* **shared accounting** — every sub-scheduler reports into this
  scheduler's :class:`~repro.obs.metrics.MetricsRegistry` under its own
  ``device`` label (megabatches report as ``device="mesh"``: one
  dispatch spans every device), so ``stats`` aggregates fleet-wide and
  ``stats.per_device()`` splits it back out.

Crash-safety composes: a failing device lane re-queues its unprocessed
jobs and stashes its computed results inside its sub-scheduler; this
scheduler *adopts* that state (checksum-verified) before re-raising, so
the caller sees exactly the single-scheduler contract — a failed drain
loses no work, computed or queued, whichever device failed.

With one device the behavior (and every architectural result) is
bit-identical to a plain ``FleetScheduler`` — multi-device is purely a
throughput layer.
"""
from __future__ import annotations

import concurrent.futures
import contextvars
import hashlib
import time
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import machine as machine_mod
from ..core.blockc import program_key
from ..core.config import EGPUConfig
from ..obs import counters as obs_counters
from ..obs import trace as obs_trace
from . import faults
from .devices import (balance_units, device_label, fleet_devices,
                      make_job_mesh)
from .engine import ResidencyCache
from .scheduler import (DrainCancelled, FleetJob, FleetScheduler,
                        JobResult, _prog_digest, _result_checksum)

__all__ = ["ShardedFleetScheduler"]

#: AOT shard_map executables kept per scheduler (LRU)
_MEGA_EXECS_MAX = 32


class ShardedFleetScheduler(FleetScheduler):
    """A :class:`FleetScheduler` sharded over local jax devices.

    ``devices`` accepts everything :func:`~repro.fleet.devices.
    fleet_devices` does: ``"all"`` (default — every local device), an
    int N (the first N), or an explicit device sequence.  All other
    knobs match :class:`FleetScheduler` and apply to every per-device
    lane.
    """

    def __init__(self, cfg: EGPUConfig, batch_size: int = 32, *,
                 devices: Any = "all", **kw):
        super().__init__(cfg, batch_size, **kw)
        self.devices = fleet_devices(devices)
        self.n_devices = len(self.devices)
        self.device_labels = tuple(device_label(d) for d in self.devices)
        #: megabatch dispatches span the whole mesh, so their metrics
        #: land under this label instead of any one device
        self._dev = "mesh"
        self._mesh = make_job_mesh(self.devices)
        #: one pinned scheduler per device, all reporting into OUR
        #: registry (lifetime totals aggregate fleet-wide); jobs are
        #: injected into the lanes' queues with *our* handles, so their
        #: results/failures/salvage need no remapping
        self._scheds = tuple(
            FleetScheduler(cfg, batch_size,
                           pack_by_cost=self.pack_by_cost,
                           validate=self.validate,
                           use_compiler=self.use_compiler,
                           compile_min=self.compile_min,
                           tier_policy=kw.get("tier_policy"),
                           residency_max=kw.get("residency_max", 32),
                           fixed_bucket=self.fixed_bucket,
                           trace=self.tracer, metrics=self._m,
                           device=d)
            for d in self.devices)
        #: device-sharded megabatch inputs (separate from the base
        #: cache: same content on one device vs mesh-sharded are
        #: different placements and must never alias)
        self._mega_residency = ResidencyCache(kw.get("residency_max", 32))
        self._mega_execs: OrderedDict = OrderedDict()

    def cancel(self) -> None:
        super().cancel()
        for s in self._scheds:
            s.cancel()

    # -------------------------------------------------------- megabatch
    @property
    def _slab(self) -> int:
        """Megabatch slab: one full batch per device, dispatched as one
        ``shard_map`` call.  Exact slabs only — one XLA shape per
        program, like serving's ``fixed_bucket``."""
        return self.n_devices * self.batch_size

    def _mega_exec(self, cp, shared, tdx):
        """The AOT-compiled ``shard_map`` light executable for this
        (program, slab shape), plus compile seconds (0.0 when warm)."""
        from jax.experimental.shard_map import shard_map

        key = (program_key(cp.image), cp.threads, cp.mode,
               np.shape(shared))
        e = self._mega_execs.get(key)
        if e is not None and e["cp"] is cp:
            self._mega_execs.move_to_end(key)
            self._m.inc("fleet_compile_cache_total", result="hit")
            return e["exe"], 0.0
        self._m.inc("fleet_compile_cache_total", result="miss")
        t0 = time.perf_counter()
        with obs_trace.span("compile", kind="xla_mega", tier=cp.mode,
                            batch=np.shape(shared)[0],
                            devices=self.n_devices):
            fn = shard_map(cp.light_fn(), mesh=self._mesh,
                           in_specs=(P("jobs", None), P("jobs")),
                           out_specs=(P("jobs", None), P("jobs"),
                                      P("jobs")))
            exe = jax.jit(fn).lower(shared, tdx).compile()
        self._mega_execs[key] = {"cp": cp, "exe": exe}
        self._mega_execs.move_to_end(key)
        while len(self._mega_execs) > _MEGA_EXECS_MAX:
            self._mega_execs.popitem(last=False)
        return exe, time.perf_counter() - t0

    def _mega_inputs(self, cp, chunk: list[FleetJob]):
        """Mesh-sharded slab inputs, replayed from the megabatch
        residency cache when this exact content was transferred
        before (same digest discipline as the base scheduler)."""
        S = self.cfg.shared_words
        h = hashlib.blake2b(digest_size=16)
        for j in chunk:
            if j.shared_init is None:
                h.update(b"\x00")
            else:
                h.update(b"\x01")
                dt = str(j.shared_init.dtype).encode()
                h.update(len(dt).to_bytes(4, "little"))
                h.update(dt)
                payload = j.shared_init.tobytes()
                h.update(len(payload).to_bytes(8, "little"))
                h.update(payload)
            h.update(int(j.tdx_dim).to_bytes(4, "little", signed=True))
        key = (program_key(cp.image), cp.threads, self.validate,
               len(chunk), h.digest())

        def build():
            shared = np.zeros((len(chunk), S), np.uint32)
            for i, j in enumerate(chunk):
                if j.shared_init is None:
                    continue
                buf = machine_mod.pack_shared_init(j.shared_init, S)
                shared[i, :buf.size] = buf
            tdx = np.asarray([j.tdx_dim for j in chunk], np.int32)
            sh_dev = jax.device_put(
                jnp.asarray(shared),
                NamedSharding(self._mesh, P("jobs", None)))
            tdx_dev = jax.device_put(
                jnp.asarray(tdx), NamedSharding(self._mesh, P("jobs")))
            return sh_dev, tdx_dev

        if faults.fire("residency_evict") is not None:
            self._mega_residency.clear()
        arrays, hit = self._mega_residency.lookup(key, cp, build)
        self._m.inc("fleet_residency_lookups_total",
                    result="hit" if hit else "miss")
        return arrays, hit

    def _run_megabatch(self, cp, chunk: list[FleetJob],
                       results: dict[int, JobResult]) -> None:
        """One exact slab — ``n_devices * batch_size`` same-program
        jobs — as a single ``shard_map`` dispatch over the job mesh."""
        real = len(chunk)
        with obs_trace.span("batch", tier=cp.mode, jobs=real,
                            device="mesh", devices=self.n_devices):
            t0 = time.perf_counter()
            with obs_trace.span("residency") as rsp:
                (shared_dev, tdx_dev), res_hit = \
                    self._mega_inputs(cp, chunk)
            if rsp.active:
                rsp.set(hit=res_hit)
            exe, compile_s = self._mega_exec(cp, shared_dev, tdx_dev)
            self._m.inc("fleet_compile_seconds_total", compile_s)
            t_disp = time.perf_counter()
            with obs_trace.span("dispatch", cores=real, device="mesh"):
                faults.maybe_raise("dispatch", tier=cp.mode, cores=real,
                                   device="mesh")
                shared_out, _, _ = exe(shared_dev, tdx_dev)
            t_sync = time.perf_counter()
            with obs_trace.span("device_sync"):
                hang = faults.hang_seconds("device_sync", tier=cp.mode,
                                           device="mesh")
                if hang:
                    time.sleep(hang)
                shared_out.block_until_ready()
            t_done = time.perf_counter()
            self._m.observe("fleet_dispatch_seconds", t_sync - t_disp,
                            tier=cp.mode, device="mesh")
            self._m.observe("fleet_device_sync_seconds", t_done - t_sync,
                            tier=cp.mode, device="mesh")
            wall = time.perf_counter() - t0 - compile_s
            with obs_trace.span("collect"):
                self._collect_light(cp, shared_out, chunk, real, wall,
                                    results)

    def _take_megabatches(self, jobs: list[FleetJob]):
        """Split out exact same-program slabs for the ``shard_map``
        path; returns ``(slabs, rest)`` where each slab is
        ``(CompiledProgram, jobs)`` and ``rest`` keeps submission
        order."""
        slab = self._slab
        groups: dict[tuple, list[FleetJob]] = {}
        order: list[FleetJob] = []
        for j in jobs:
            groups.setdefault((program_key(j.image), j.threads),
                              []).append(j)
        slabs: list[tuple[Any, list[FleetJob]]] = []
        rest_set: set[int] = set()
        for group in groups.values():
            n_slabs = len(group) // slab
            if n_slabs == 0:
                rest_set.update(id(j) for j in group)
                continue
            cp = self._compile_unit(group[0], self.batch_size,
                                    jobs=len(group))
            if cp is None:               # interpreter tier: per-device
                rest_set.update(id(j) for j in group)
                continue
            self._event("megabatch", program=_prog_digest(cp.image),
                        jobs=n_slabs * slab, slabs=n_slabs,
                        devices=self.n_devices, tier=cp.mode)
            for i in range(n_slabs):
                slabs.append((cp, group[i * slab:(i + 1) * slab]))
            rest_set.update(id(j) for j in group[n_slabs * slab:])
        for j in jobs:
            if id(j) in rest_set:
                order.append(j)
        return slabs, order

    # ------------------------------------------------- per-device lanes
    def _adopt_sub_state(self, sub: FleetScheduler,
                         results: dict[int, JobResult]) -> None:
        """Absorb a failed lane's crash-safety state: its computed
        (stashed) results join ours after checksum verification —
        corruption is dropped and re-executed, exactly the base
        salvage contract — and its re-queued jobs are released (our
        own requeue path re-queues every uncollected handle)."""
        for h, r in sub._salvaged.items():
            if _result_checksum(r) != sub._salvage_sums.get(h):
                self._m.inc("fleet_salvage_dropped_total")
                self._event("salvage_corrupt", cat="serve", handle=h)
                continue
            results[h] = r
        sub._salvaged, sub._salvage_sums, sub._salvage_jobs = {}, {}, {}
        sub._queue = []

    def _run_balanced(self, jobs: list[FleetJob],
                      results: dict[int, JobResult],
                      failures: dict[int, Exception],
                      isolate: bool) -> None:
        """Route a heterogeneous mix through the per-device lanes:
        same-program groups stay whole (cache locality), lanes fill
        least-loaded-first by summed job cost, and every device drains
        its lane concurrently on its own thread."""
        if not jobs:
            return
        groups: dict[tuple, list[FleetJob]] = {}
        for j in jobs:
            groups.setdefault((program_key(j.image), j.threads),
                              []).append(j)
        units = list(groups.values())
        lanes = balance_units(units, self.n_devices,
                              cost=lambda u: sum(j.cost for j in u))

        def lane_drain(d: int):
            sub = self._scheds[d]
            for unit in lanes[d]:
                sub._queue.extend(unit)
            with obs_trace.span("device_lane",
                                device=self.device_labels[d],
                                jobs=sub.pending):
                return (sub.drain_isolated() if isolate
                        else (sub.drain(), {}))

        active = [d for d in range(self.n_devices) if lanes[d]]
        outcomes: list[tuple[int, Any, BaseException | None]] = []
        if len(active) <= 1:
            for d in active:
                try:
                    outcomes.append((d, lane_drain(d), None))
                except BaseException as e:
                    outcomes.append((d, None, e))
        else:
            with concurrent.futures.ThreadPoolExecutor(
                    max_workers=len(active),
                    thread_name_prefix="fleet-dev") as ex:
                futs = [(d, ex.submit(contextvars.copy_context().run,
                                      lane_drain, d))
                        for d in active]
                for d, f in futs:
                    try:
                        outcomes.append((d, f.result(), None))
                    except BaseException as e:
                        outcomes.append((d, None, e))
        first_err: BaseException | None = None
        for d, out, err in outcomes:
            if err is None:
                res, fails = out
                results.update(res)
                failures.update(fails)
            else:
                self._adopt_sub_state(self._scheds[d], results)
                self._event("device_lane_failed", cat="serve",
                            device=self.device_labels[d],
                            error=type(err).__name__)
                if first_err is None or isinstance(err, DrainCancelled):
                    first_err = err
        if first_err is not None:
            raise first_err

    # ------------------------------------------------------------ drain
    def _drain(self, isolate: bool = False):
        results, delivered_jobs = self._take_salvaged()
        n_salvaged = len(results)
        failures: dict[int, Exception] = {}
        all_jobs = self._queue
        self._queue = []
        if not self._cancelled:          # a fresh drain clears old flags
            for s in self._scheds:
                s._cancelled = False

        with obs_trace.span("drain", jobs=len(all_jobs),
                            devices=self.n_devices) as dsp:
            try:
                pending = all_jobs
                slabs: list = []
                if self.use_compiler:
                    with obs_trace.span("partition", jobs=len(pending)):
                        slabs, pending = self._take_megabatches(pending)
                for cp, chunk in slabs:
                    if self._cancelled:
                        raise DrainCancelled("drain cancelled")
                    if isolate:
                        try:
                            self._run_megabatch(cp, chunk, results)
                        except DrainCancelled:
                            raise
                        except Exception as e:
                            # contain: the per-device isolated lanes
                            # (bisection, tier degradation) absorb it
                            self._event("megabatch_failed", cat="serve",
                                        jobs=len(chunk), tier=cp.mode,
                                        error=type(e).__name__)
                            pending = pending + chunk
                    else:
                        self._run_megabatch(cp, chunk, results)
                if self._cancelled:
                    raise DrainCancelled("drain cancelled")
                self._run_balanced(pending, results, failures, isolate)
            except BaseException:
                unprocessed = [j for j in all_jobs
                               if j.handle not in results
                               and j.handle not in failures]
                unprocessed.sort(key=lambda j: j.handle)
                self._queue = unprocessed + self._queue
                self._stash_salvage(results, delivered_jobs, all_jobs)
                raise

            tr = obs_trace.current_tracer()
            if tr is not None:
                agg = obs_counters.aggregate(
                    r.counters for r in results.values())
                if agg is not None:
                    flat = agg.flat()
                    tr.event("drain_counters", **flat)
                    tr.add_counters(flat)
                if dsp.active:
                    dsp.set(delivered=len(results),
                            failed=len(failures),
                            devices=self.n_devices)
        if n_salvaged:
            self._m.inc("fleet_salvaged_jobs_total", n_salvaged)
        return results, failures
