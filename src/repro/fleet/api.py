"""User-facing fleet API.

    fleet = Fleet(cfg, batch_size=32)
    h0 = fleet.submit(image_a, shared_init=data_a, threads=512)
    h1 = fleet.submit(image_b, shared_init=data_b, threads=64)
    results = fleet.drain()          # one vmapped dispatch per batch
    results[h0].shared_f32(), results[h1].cycles

``Fleet`` is a thin facade over :class:`FleetScheduler`; ``run_jobs`` is
the one-shot convenience for a fixed job list; ``serve_jobs`` is the
same convenience routed through the always-on serving loop
(:class:`repro.fleet.service.FleetService` — per-job futures, deadlines,
retries, backpressure, fault isolation).
"""
from __future__ import annotations

from typing import Any

from ..core.assembler import ProgramImage
from ..core.blockc import TierPolicy
from ..core.config import EGPUConfig
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .scheduler import FleetScheduler, FleetStats, JobResult
from .service import FleetService
from .sharded import ShardedFleetScheduler


class Fleet:
    """A homogeneous array of eGPU cores behind a job queue.

    Same-program jobs are automatically grouped onto the compiled
    lock-step tiers (same blocks, different data), with the
    :class:`~repro.core.blockc.TierPolicy` cost model choosing between
    the basic-block driver and the superblock runner per (program,
    batch width); mixed batches fall back to the vmapped interpreter.
    ``use_compiler=False`` forces the interpreter for everything
    (results are bit-identical either way), and ``tier_policy``
    overrides the cost model's threshold table.  Compiled-tier batch
    inputs stay device-resident across drains — repeat drains of the
    same program over the same inputs pay zero host->device transfer
    (``stats.residency_hits``).

    ``trace=True`` records every drain (spans, per-job latency, event
    counters, tier decisions) into ``fleet.tracer``; a path string
    additionally writes the cumulative Chrome/Perfetto trace JSON there
    after each drain (``python -m repro.obs.report <path>`` summarizes
    it).  Tracing never changes results — they stay bit-identical —
    and costs nothing when off.

    ``devices=`` shards drains across local accelerators through
    :class:`~repro.fleet.sharded.ShardedFleetScheduler` — ``"all"``
    takes every visible device, an int the first N, or pass an explicit
    device sequence.  Results stay bit-identical to the single-device
    fleet; ``devices=None`` (default) is exactly today's scheduler.
    """

    def __init__(self, cfg: EGPUConfig, batch_size: int = 32, *,
                 pack_by_cost: bool = True, validate: bool = True,
                 use_compiler: bool = True, compile_min: int = 2,
                 tier_policy: TierPolicy | None = None,
                 residency_max: int = 32,
                 trace: bool | str | obs_trace.Tracer | None = None,
                 metrics: obs_metrics.MetricsRegistry | None = None,
                 devices: Any = None):
        kw = dict(pack_by_cost=pack_by_cost,
                  validate=validate,
                  use_compiler=use_compiler,
                  compile_min=compile_min,
                  tier_policy=tier_policy,
                  residency_max=residency_max,
                  trace=trace, metrics=metrics)
        if devices is None:
            self._sched = FleetScheduler(cfg, batch_size, **kw)
        else:
            self._sched = ShardedFleetScheduler(cfg, batch_size,
                                                devices=devices, **kw)

    @property
    def cfg(self) -> EGPUConfig:
        return self._sched.cfg

    @property
    def batch_size(self) -> int:
        return self._sched.batch_size

    @property
    def pending(self) -> int:
        return self._sched.pending

    @property
    def stats(self) -> FleetStats:
        return self._sched.stats

    @property
    def tracer(self) -> obs_trace.Tracer | None:
        """The fleet's own tracer (``trace=`` knob), or ``None``."""
        return self._sched.tracer

    @property
    def metrics(self) -> obs_metrics.MetricsRegistry:
        """The fleet's metrics registry (``stats`` is a view over it);
        ``metrics.to_prometheus()`` exports it."""
        return self._sched.stats.registry

    def save_trace(self, path: str) -> None:
        """Write the fleet tracer's Chrome/Perfetto trace JSON."""
        if self._sched.tracer is None:
            raise ValueError("fleet was created without trace=")
        self._sched.tracer.save(path)

    def submit(self, image: ProgramImage, shared_init=None, *,
               threads: int | None = None, tdx_dim: int = 16,
               tag: Any = None, weight: float | None = None) -> int:
        """Queue one program execution; returns a result handle.

        ``weight`` is an optional relative cost hint used to pack
        similar-cost jobs into the same lock-step batch.
        """
        return self._sched.submit(image, shared_init, threads=threads,
                                  tdx_dim=tdx_dim, tag=tag, weight=weight)

    def drain(self) -> dict[int, JobResult]:
        """Run all queued jobs in fixed-shape vmapped batches."""
        return self._sched.drain()


def run_jobs(cfg: EGPUConfig, jobs: list[dict], *,
             batch_size: int = 32) -> list[JobResult]:
    """One-shot: run a list of job dicts, results in submission order.

    Each job dict holds ``image`` plus optional ``shared_init``,
    ``threads``, ``tdx_dim``, ``tag`` (the :meth:`Fleet.submit` keywords).
    """
    fleet = Fleet(cfg, batch_size)
    handles = [fleet.submit(j["image"], j.get("shared_init"),
                            threads=j.get("threads"),
                            tdx_dim=j.get("tdx_dim", 16),
                            tag=j.get("tag")) for j in jobs]
    results = fleet.drain()
    return [results[h] for h in handles]


def serve_jobs(cfg: EGPUConfig, jobs: list[dict], *,
               batch_size: int = 32,
               **service_kw) -> list[JobResult | Exception]:
    """One-shot through the serving path: submit every job dict to a
    :class:`~repro.fleet.service.FleetService`, wait for all futures,
    and return outcomes in submission order — a
    :class:`~repro.fleet.scheduler.JobResult` per success, the
    :class:`~repro.fleet.service.JobError` per failure (every future
    resolves; nothing raises out of this call).  Job dicts take the
    :meth:`Fleet.submit` keywords plus ``priority`` and ``deadline_s``;
    ``service_kw`` forwards to :class:`FleetService` (retry/backoff,
    admission budget, faults, trace...)."""
    with FleetService(cfg, batch_size, **service_kw) as svc:
        futs = [svc.submit(j["image"], j.get("shared_init"),
                           threads=j.get("threads"),
                           tdx_dim=j.get("tdx_dim", 16),
                           tag=j.get("tag"), weight=j.get("weight"),
                           priority=j.get("priority", 1),
                           deadline_s=j.get("deadline_s")) for j in jobs]
        out: list[JobResult | Exception] = []
        for f in futs:
            try:
                out.append(f.result())
            except Exception as e:       # noqa: BLE001 — JobError by contract
                out.append(e)
    return out
