"""Always-on serving loop over the fleet: continuous batching with
deadlines, priorities, retries, backpressure, and fault isolation.

:class:`FleetService` turns the batch-mode ``submit()``/``drain()``
scheduler into a stream-serving front-end:

* **per-job futures** — :meth:`FleetService.submit` returns a
  :class:`concurrent.futures.Future` that resolves to a
  :class:`~repro.fleet.scheduler.JobResult` or raises a structured
  :class:`JobError` (kind, attempts, cause).  Every submitted future
  resolves, always — that is the serving contract.  Wrap with
  ``asyncio.wrap_future`` to await from an event loop;
* **deadline-or-size batching** — a background dispatcher forms a
  lock-step cohort the moment ``batch_size`` jobs are ready *or* the
  oldest ready job has waited ``max_delay_s``, whichever fires first;
* **priority lanes** — lower ``priority`` dispatches first within a
  trigger (ties broken by submission order);
* **per-job deadlines** — a job past its deadline is *masked out of its
  batch slot* and failed fast with ``JobError(kind="deadline")``: the
  paper's per-instruction thread-space subsetting (TSC) applied at
  request granularity, exactly like the slot-masked decode loop in
  :mod:`repro.launch.serve`;
* **bounded admission** — once queued+in-flight cost (the cost model's
  per-job estimates) exceeds ``cost_budget`` (or ``max_pending`` jobs),
  ``submit`` blocks (``admission="block"``) or raises
  :class:`AdmissionError` (``admission="reject"``): overload degrades
  into latency or fast rejections, never an unbounded queue;
* **per-job retries with exponential backoff** — a failed dispatch is
  bisected by :meth:`FleetScheduler.drain_isolated` so one poison job
  cannot starve its cohort; jobs that still fail are retried up to
  ``max_retries`` times (backoff ``backoff_s * backoff_factor**k``),
  then fail their future with a structured :class:`JobError` instead of
  poisoning the drain;
* **dispatch watchdog** — with ``dispatch_timeout_s`` set, a hung
  dispatch (e.g. a device sync that never returns — the
  ``device_sync`` fault site) is abandoned: the scheduler is replaced
  wholesale and the cohort is retried/failed as timeouts;
* **per-device dispatchers** — with ``devices=`` set, every device gets
  its own dispatcher thread and pinned scheduler, all fed from the ONE
  shared admission queue (work-stealing: whichever device is free takes
  the next ready cohort).  Watchdogs and scheduler resets are
  per-device, so a hung device costs capacity, not availability; a
  device that keeps failing (``device_unhealthy_after`` consecutive
  cohorts, or the ``device_fail`` fault site) is marked unhealthy and
  its dispatcher retires — its queued work migrates to the survivors.
  ``devices=None`` (default) is exactly the single-dispatcher service.

Invariants (see ``docs/architecture.md``):

* **every future resolves** — with a :class:`~repro.fleet.scheduler.
  JobResult` or a :class:`JobError`; never dropped, whatever faults,
  hangs, resets or device deaths occur;
* **one delivery per job** — a ticket resolves exactly once; retries
  re-enqueue the same ticket, never clone it;
* **ERROR rejects pre-compile** — the static verifier runs at
  ``submit`` and broken programs fail there (``kind="rejected"``),
  before any compile or device work;
* **overload degrades, never grows** — admission is bounded by cost
  budget / queue depth; shedding is explicit (block or reject).

Failure injection for all of the above is
:class:`repro.fleet.faults.FaultPlan` — pass one as ``faults=`` (or
install it ambiently) and the chaos run stays deterministic.

    svc = FleetService(cfg, batch_size=32, max_delay_s=0.002)
    fut = svc.submit(image, data, deadline_s=0.5, priority=0)
    res = fut.result()               # JobResult, or raises JobError
    svc.close()
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

from ..core.assembler import ProgramImage
from ..core.blockc import TierPolicy
from ..core.config import EGPUConfig
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace
from . import faults as faults_mod
from .devices import device_label, fleet_devices
from .scheduler import FleetScheduler, JobResult, check_job

__all__ = ["FleetService", "ServiceStats", "JobError", "AdmissionError",
           "register_serve_metrics"]


class JobError(Exception):
    """Structured per-job failure: resolves the job's future.

    ``kind`` is one of ``"deadline"`` (missed its deadline before
    dispatch), ``"timeout"`` (dispatch watchdog fired and retries ran
    out), ``"error"`` (failed on every tier and every retry),
    ``"shutdown"`` (service closed without draining), ``"rejected"``
    (the static verifier found ERROR-level defects at submit; ``cause``
    is the :class:`~repro.analysis.ProgramVerificationError` and
    carries the full diagnostic report).  ``attempts`` is
    how many dispatches the job consumed; ``cause`` the last underlying
    exception (``None`` for deadline/shutdown).  ``recent_events`` is
    the flight recorder's tail for this ticket's cohort (the ticket's
    own records plus id-less context: dispatches, resets, faults) so a
    chaos failure is self-explaining without a full trace."""

    def __init__(self, kind: str, *, ticket: int = -1, attempts: int = 0,
                 detail: str = "", cause: Exception | None = None,
                 recent_events: list | None = None):
        self.kind = kind
        self.ticket = ticket
        self.attempts = attempts
        self.detail = detail
        self.cause = cause
        self.recent_events = list(recent_events or [])
        msg = f"job {ticket} failed ({kind}) after {attempts} attempt(s)"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class AdmissionError(RuntimeError):
    """``submit()`` rejected: the service is over its admission budget
    (``admission="reject"``) — shed load upstream or retry later."""


@dataclasses.dataclass
class _Ticket:
    """One in-flight service job (internal)."""

    tid: int
    image: ProgramImage
    shared_init: Any
    threads: int
    tdx_dim: int
    tag: Any
    weight: float | None
    priority: int
    cost: float
    submit_t: float                  # monotonic, for latency accounting
    enqueue_t: float                 # reset on retry: batching trigger
    deadline: float | None           # absolute monotonic, or None
    future: Future
    attempts: int = 0
    not_before: float = 0.0          # backoff gate
    dispatch_t: float = 0.0          # last dispatch, for job latency


def register_serve_metrics(reg: obs_metrics.MetricsRegistry,
                           window_s: float = 60.0) -> None:
    """Declare the serving-layer metric families (idempotent).
    ``window_s`` sets the rolling-SLO window on the latency
    histograms; the first registration of a family wins."""
    reg.counter("serve_submitted_total", "jobs admitted", ("priority",))
    reg.counter("serve_completed_total",
                "futures resolved with a JobResult", ("tier",))
    reg.counter("serve_failed_total",
                "futures resolved with a JobError", ("kind",))
    reg.counter("serve_rejected_total",
                "AdmissionError raised at submit")
    reg.counter("serve_lint_rejected_total",
                "programs the static verifier rejected at submit")
    reg.counter("serve_retries_total",
                "re-queues after a failed attempt", ("kind",))
    reg.counter("serve_dispatches_total",
                "cohorts handed to a scheduler, by device", ("device",))
    reg.counter("serve_dispatched_jobs_total",
                "jobs across all dispatched cohorts")
    reg.counter("serve_scheduler_resets_total",
                "schedulers abandoned (hang/crash)",
                ("reason", "device"))
    reg.counter("serve_watchdog_jobs_total",
                "jobs in cohorts abandoned by the dispatch watchdog")
    reg.gauge("serve_device_unhealthy",
              "1 when the device's dispatcher has retired", ("device",))
    reg.counter("serve_faults_injected_total",
                "FaultPlan injections observed", ("fault_site",))
    reg.gauge("serve_queue_depth", "jobs queued, not yet dispatched")
    reg.gauge("serve_pending_cost", "summed cost of queued jobs")
    reg.gauge("serve_inflight_cost", "summed cost of dispatched jobs")
    reg.histogram("serve_request_latency_seconds",
                  "submit -> future-resolution latency", ("outcome",),
                  window_s=window_s)
    reg.histogram("serve_job_latency_seconds",
                  "dispatch -> future-resolution latency",
                  window_s=window_s)
    reg.histogram("serve_cohort_size", "jobs per dispatched cohort",
                  buckets=obs_metrics.SIZE_BUCKETS)


class ServiceStats:
    """Aggregate serving counters (monotonic across the service life).

    Views over the service's
    :class:`~repro.obs.metrics.MetricsRegistry` — the registry is the
    single source of truth (it also feeds the Prometheus exporter and
    :class:`~repro.obs.metrics.MetricsSnapshot`), so these fields, the
    exported counters, and per-drain scheduler stats can never drift
    apart.  Field names and semantics are unchanged from the dataclass
    this replaces.
    """

    def __init__(self, registry: obs_metrics.MetricsRegistry | None
                 = None):
        self.registry = (registry if registry is not None
                         else obs_metrics.MetricsRegistry())
        register_serve_metrics(self.registry)
        #: set by :meth:`FleetService.close`: the final
        #: :class:`~repro.obs.metrics.MetricsSnapshot` of the service
        self.final_snapshot: obs_metrics.MetricsSnapshot | None = None
        #: ... and the most recent flight-recorder blackbox dump path
        #: (``None`` when the service never dumped)
        self.blackbox_path: str | None = None

    def _t(self, name, **labels):
        return int(round(self.registry.total(name, **labels)))

    @property
    def submitted(self) -> int:
        return self._t("serve_submitted_total")

    @property
    def completed(self) -> int:
        return self._t("serve_completed_total")

    @property
    def failed(self) -> int:
        """Futures resolved with JobError."""
        return self._t("serve_failed_total")

    @property
    def rejected(self) -> int:
        """AdmissionError raised at submit."""
        return self._t("serve_rejected_total")

    @property
    def lint_rejected(self) -> int:
        """Programs the static verifier rejected at submit."""
        return self._t("serve_lint_rejected_total")

    @property
    def deadline_misses(self) -> int:
        """Failed with kind="deadline"."""
        return self._t("serve_failed_total", kind="deadline")

    @property
    def timeouts(self) -> int:
        """Dispatch watchdog firings (jobs)."""
        return self._t("serve_watchdog_jobs_total")

    @property
    def retries(self) -> int:
        """Re-queues after a failed attempt."""
        return self._t("serve_retries_total")

    @property
    def dispatches(self) -> int:
        """Cohorts handed to the scheduler."""
        return self._t("serve_dispatches_total")

    @property
    def dispatched_jobs(self) -> int:
        return self._t("serve_dispatched_jobs_total")

    @property
    def scheduler_resets(self) -> int:
        """Schedulers abandoned (hang/crash)."""
        return self._t("serve_scheduler_resets_total")

    @property
    def resolved(self) -> int:
        return self.completed + self.failed

    def __repr__(self) -> str:
        return (f"ServiceStats(submitted={self.submitted}, "
                f"completed={self.completed}, failed={self.failed}, "
                f"rejected={self.rejected}, retries={self.retries}, "
                f"scheduler_resets={self.scheduler_resets})")


class FleetService:
    """An always-on serving front-end over :class:`FleetScheduler`.

    One background dispatcher thread owns the scheduler; ``submit`` is
    thread-safe and never touches the device.  ``trace=`` accepts the
    same knob as :class:`~repro.fleet.api.Fleet` (``True`` / path /
    :class:`~repro.obs.Tracer`); serving events (``job_retry``,
    ``job_failed``, ``dispatch_timeout``, ``admission_reject``,
    ``tier_degrade``, ``fault_injected``) land in the same Perfetto
    trace as the drain spans, with per-request ``request`` async pairs
    measuring true submit->resolve latency (queue wait included).
    ``faults=`` installs a :class:`~repro.fleet.faults.FaultPlan` for
    everything the dispatcher runs.
    """

    def __init__(self, cfg: EGPUConfig, batch_size: int = 32, *,
                 max_delay_s: float = 0.005,
                 max_retries: int = 2, backoff_s: float = 0.002,
                 backoff_factor: float = 2.0,
                 dispatch_timeout_s: float | None = None,
                 default_deadline_s: float | None = None,
                 cost_budget: float | None = None,
                 max_pending: int | None = None,
                 admission: str = "block",
                 faults: faults_mod.FaultPlan | None = None,
                 trace: bool | str | obs_trace.Tracer | None = None,
                 pack_by_cost: bool = True, validate: bool = True,
                 use_compiler: bool = True, compile_min: int = 1,
                 tier_policy: TierPolicy | None = None,
                 residency_max: int = 32, fixed_bucket: bool = True,
                 telemetry: bool = True,
                 metrics: obs_metrics.MetricsRegistry | None = None,
                 recorder: obs_recorder.FlightRecorder | None = None,
                 recorder_capacity: int = 4096,
                 blackbox_dir: str | None = None,
                 slo_latency_s: float | None = None,
                 slo_target: float = 0.99,
                 slo_window_s: float = 60.0,
                 devices: Any = None,
                 device_unhealthy_after: int = 3):
        if admission not in ("block", "reject"):
            raise ValueError("admission must be 'block' or 'reject'")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if device_unhealthy_after < 1:
            raise ValueError("device_unhealthy_after must be >= 1")
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_delay_s = max_delay_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_factor = backoff_factor
        self.dispatch_timeout_s = dispatch_timeout_s
        self.default_deadline_s = default_deadline_s
        self.cost_budget = cost_budget
        self.max_pending = max_pending
        self.admission = admission
        self.faults = faults
        #: ``telemetry=False`` strips the optional instrumentation
        #: (latency histograms, gauges, flight recorder) — the baseline
        #: side of the CI overhead gate.  The registry itself stays:
        #: its counters ARE the stats store.
        self._tm = bool(telemetry)
        self.slo_latency_s = slo_latency_s
        self.slo_target = slo_target
        self.slo_window_s = slo_window_s
        #: one registry for the service's whole life — every watchdog
        #: replacement scheduler writes into it, so lifetime totals and
        #: per-drain counts cannot drift
        self.metrics = (metrics if metrics is not None
                        else obs_metrics.MetricsRegistry())
        register_serve_metrics(self.metrics, window_s=slo_window_s)
        #: always-on bounded ring of recent events, dumped as a
        #: Perfetto blackbox on watchdog reset / retry exhaustion /
        #: injected fault
        self.recorder: obs_recorder.FlightRecorder | None = None
        if self._tm:
            self.recorder = (recorder if recorder is not None
                             else obs_recorder.FlightRecorder(
                                 recorder_capacity,
                                 blackbox_dir=blackbox_dir))
        self.stats = ServiceStats(self.metrics)

        self.tracer: obs_trace.Tracer | None = None
        self._trace_path: str | None = None
        if isinstance(trace, obs_trace.Tracer):
            self.tracer = trace
        elif isinstance(trace, str):
            self.tracer = obs_trace.Tracer("service")
            self._trace_path = trace
        elif trace:
            self.tracer = obs_trace.Tracer("service")

        # all schedulers (incl. watchdog replacements) share one tracer
        # and one residency/compile-cache regime.  Serving defaults
        # differ from batch drains: ``compile_min=1`` (programs repeat
        # forever, so even a singleton group should ride the cached
        # compiled tier, not the interpreter) and ``fixed_bucket=True``
        # (one XLA shape per program — ragged cohort sizes must not
        # spray pow2 bucket shapes, each a multi-second compile, across
        # the steady-state latency profile)
        self._sched_kw = dict(pack_by_cost=pack_by_cost,
                              validate=validate,
                              use_compiler=use_compiler,
                              compile_min=compile_min,
                              tier_policy=tier_policy,
                              residency_max=residency_max,
                              fixed_bucket=fixed_bucket,
                              metrics=self.metrics)
        #: ``devices=None`` keeps the single unpinned dispatcher
        #: (today's service, bit-for-bit); anything else resolves via
        #: :func:`~repro.fleet.devices.fleet_devices` to one pinned
        #: dispatcher + scheduler per device, all fed from the shared
        #: admission queue
        self._devices: tuple = ((None,) if devices is None
                                else fleet_devices(devices))
        self._dev_labels = tuple(device_label(d) for d in self._devices)
        self.device_unhealthy_after = device_unhealthy_after
        self._scheds = [self._make_sched(i)
                        for i in range(len(self._devices))]
        self._fail_streak = [0] * len(self._devices)
        self._dead: set[int] = set()

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: list[_Ticket] = []
        self._pending_cost = 0.0         # queued, not yet dispatched
        self._inflight_cost = 0.0        # dispatched, not yet resolved
        self._next_tid = 0
        self._closed = False
        self._abandoned: list[threading.Thread] = []
        if self._tm:
            for lbl in self._dev_labels:
                self.metrics.set_gauge("serve_device_unhealthy", 0,
                                       device=lbl)
        self._threads = [
            threading.Thread(target=self._loop, args=(i,),
                             name=f"fleet-service-dispatch-{lbl}",
                             daemon=True)
            for i, lbl in enumerate(self._dev_labels)]
        for th in self._threads:
            th.start()

    def _make_sched(self, idx: int = 0) -> FleetScheduler:
        return FleetScheduler(self.cfg, self.batch_size,
                              trace=self.tracer,
                              device=self._devices[idx],
                              **self._sched_kw)

    @property
    def _sched(self) -> FleetScheduler:
        """The first dispatcher's scheduler (single-device compat)."""
        return self._scheds[0]

    @property
    def n_devices(self) -> int:
        return len(self._devices)

    @property
    def healthy_devices(self) -> tuple[str, ...]:
        """Labels of devices whose dispatchers are still serving."""
        with self._lock:
            return tuple(lbl for i, lbl in enumerate(self._dev_labels)
                         if i not in self._dead)

    def _event(self, name: str, cat: str = "serve", **args) -> None:
        """A serving event: into the flight recorder (always on) and
        the tracer (when installed)."""
        if self.recorder is not None:
            self.recorder.record(name, cat=cat, **args)
        if self.tracer is not None:
            self.tracer.event(name, cat=cat, **args)

    def _update_gauges(self) -> None:
        """Queue-shape gauges; caller holds the lock."""
        if not self._tm:
            return
        m = self.metrics
        m.set_gauge("serve_queue_depth", len(self._queue))
        m.set_gauge("serve_pending_cost", self._pending_cost)
        m.set_gauge("serve_inflight_cost", self._inflight_cost)

    # ----------------------------------------------------------- intake
    @property
    def pending(self) -> int:
        """Jobs queued but not yet dispatched (in-flight excluded)."""
        with self._lock:
            return len(self._queue)

    def _load_cost(self) -> float:
        return self._pending_cost + self._inflight_cost

    def _over_budget(self, cost: float) -> bool:
        if self.max_pending is not None \
                and len(self._queue) >= self.max_pending:
            return True
        return self.cost_budget is not None \
            and self._load_cost() + cost > self.cost_budget

    def submit(self, image: ProgramImage, shared_init=None, *,
               threads: int | None = None, tdx_dim: int = 16,
               tag: Any = None, weight: float | None = None,
               priority: int = 1,
               deadline_s: float | None = None) -> Future:
        """Queue one job; returns its future (``result()`` ->
        :class:`~repro.fleet.scheduler.JobResult`, or raises
        :class:`JobError`).  Malformed inputs fail here, synchronously,
        with ``ValueError`` — never mid-drain.  ``deadline_s`` is
        relative to now (``default_deadline_s`` when ``None``); a job
        that cannot dispatch before its deadline is masked out of its
        batch and failed fast.  Over budget, ``submit`` blocks or
        raises :class:`AdmissionError` per the ``admission`` mode.
        Programs the static verifier proves broken raise
        :class:`JobError` (``kind="rejected"``) here, before any
        compile; the verifier's report rides on ``.cause.report``."""
        try:
            shared_init, threads = check_job(self.cfg, image, shared_init,
                                             threads, tdx_dim=tdx_dim)
        except Exception as e:
            diags = getattr(e, "diagnostics", None)
            if diags is None:
                raise
            self.metrics.inc("serve_lint_rejected_total")
            self._event("admission_lint_reject", prog_len=image.n,
                        errors=len(diags),
                        codes=",".join(sorted({d.code for d in diags})))
            raise JobError("rejected", detail=str(e), cause=e) from e
        cost = float(weight) if weight is not None \
            else float(image.static_cycle_estimate())
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        now = time.monotonic()
        with self._work:
            if self._closed:
                raise RuntimeError("service is closed")
            while self._over_budget(cost):
                if self.admission == "reject":
                    self.metrics.inc("serve_rejected_total")
                    self._event("admission_reject", cost=cost,
                                load=self._load_cost())
                    raise AdmissionError(
                        f"admission budget exceeded (load "
                        f"{self._load_cost():.0f} + job {cost:.0f} > "
                        f"budget {self.cost_budget}, pending "
                        f"{len(self._queue)})")
                self._work.wait(0.05)
                if self._closed:
                    raise RuntimeError("service is closed")
            tid = self._next_tid
            self._next_tid += 1
            now = time.monotonic()
            t = _Ticket(tid=tid, image=image, shared_init=shared_init,
                        threads=threads, tdx_dim=tdx_dim, tag=tag,
                        weight=weight, priority=priority, cost=cost,
                        submit_t=now, enqueue_t=now,
                        deadline=None if deadline_s is None
                        else now + deadline_s,
                        future=Future())
            self.metrics.inc("serve_submitted_total",
                             priority=priority)
            self._pending_cost += cost
            self._queue.append(t)
            self._update_gauges()
            self._work.notify_all()
        if self.tracer is not None:
            self.tracer.async_begin("request", id=tid,
                                    priority=priority, cost=cost)
        return t.future

    # ------------------------------------------------------- dispatcher
    def _loop(self, idx: int) -> None:
        with contextlib.ExitStack() as stack:
            # a fresh thread has a fresh context: install the service's
            # tracer, fault plan, flight recorder and metrics registry
            # for everything the dispatcher runs (drain threads inherit
            # via contextvars.copy_context)
            if self.tracer is not None:
                stack.enter_context(self.tracer)
            if self.faults is not None:
                stack.enter_context(self.faults)
            if self.recorder is not None:
                stack.enter_context(self.recorder.installed())
            stack.enter_context(self.metrics.installed())
            while True:
                expired, cohort = [], []
                with self._work:
                    if idx in self._dead:
                        break            # retired: survivors take over
                    if self._closed and not self._queue:
                        break
                    now = time.monotonic()
                    expired = [t for t in self._queue
                               if t.deadline is not None
                               and now >= t.deadline]
                    if expired:
                        gone = {t.tid for t in expired}
                        self._queue = [t for t in self._queue
                                       if t.tid not in gone]
                        for t in expired:
                            self._pending_cost -= t.cost
                            self._inflight_cost += t.cost  # _fail releases
                        self._work.notify_all()
                    else:
                        ready = [t for t in self._queue
                                 if t.not_before <= now]
                        oldest = min((t.enqueue_t for t in ready),
                                     default=None)
                        full = len(ready) >= self.batch_size
                        due = oldest is not None \
                            and now - oldest >= self.max_delay_s
                        if ready and (full or due or self._closed):
                            ready.sort(key=lambda t: (t.priority, t.tid))
                            cohort = ready[:self.batch_size]
                            gone = {t.tid for t in cohort}
                            self._queue = [t for t in self._queue
                                           if t.tid not in gone]
                            for t in cohort:
                                self._pending_cost -= t.cost
                                self._inflight_cost += t.cost
                            self._update_gauges()
                        else:
                            self._work.wait(self._next_wake(now))
                            continue
                # futures resolve outside the lock (their callbacks may
                # re-enter submit)
                for t in expired:
                    self._fail(t, "deadline",
                               detail="deadline passed before dispatch")
                if cohort:
                    self._dispatch(cohort, idx)

    def _next_wake(self, now: float) -> float | None:
        """Seconds until the next scheduled trigger (batch-delay expiry,
        backoff release, or deadline), or ``None`` to wait for work."""
        nxt = None
        for t in self._queue:
            cands = [max(t.not_before, t.enqueue_t + self.max_delay_s)]
            if t.deadline is not None:
                cands.append(t.deadline)
            c = min(cands)
            nxt = c if nxt is None else min(nxt, c)
        if nxt is None:
            return None
        return max(1e-4, nxt - now)

    def _dispatch(self, cohort: list[_Ticket], idx: int = 0) -> None:
        m = self.metrics
        label = self._dev_labels[idx]
        if idx in self._dead:
            # killed between cohort formation and dispatch: hand the
            # cohort back untouched for a surviving device
            self._requeue_cohort(cohort)
            return
        if faults_mod.fire("device_fail", device=label) is not None:
            # whole-device death: the dispatcher retires and the cohort
            # re-enters the shared queue *without consuming an attempt*
            # — a dead device is capacity lost, not jobs failed
            if self._kill_device(idx, "device_fail"):
                self._requeue_cohort(cohort)
                return
            # refused: last healthy device keeps serving
        m.inc("serve_dispatches_total", device=label)
        m.inc("serve_dispatched_jobs_total", len(cohort))
        now = time.monotonic()
        if self._tm:
            m.observe("serve_cohort_size", len(cohort))
            self._event("dispatch", jobs=len(cohort),
                        queued=self.pending, device=label)
        for t in cohort:
            t.dispatch_t = now
        sched = self._scheds[idx]
        try:
            handle2t = {
                sched.submit(t.image, t.shared_init, threads=t.threads,
                             tdx_dim=t.tdx_dim, tag=t.tag,
                             weight=t.weight): t
                for t in cohort}
            out = self._drain(sched)
        except Exception as e:
            # the scheduler itself misbehaved (not a contained per-unit
            # failure): abandon it — its internal queue may still hold
            # re-queued jobs — and retry the cohort on a fresh one
            self._reset_sched(idx, "drain_error", e)
            self._note_device_failure(idx)
            for t in cohort:
                self._retry_or_fail(t, "error", e)
            return
        if out is None:                  # watchdog fired: hung dispatch
            self._reset_sched(idx, "dispatch_timeout", None,
                              jobs=len(cohort))
            self.metrics.inc("serve_watchdog_jobs_total", len(cohort))
            self._note_device_failure(idx)
            for t in cohort:
                self._retry_or_fail(t, "timeout", None)
            return
        self._fail_streak[idx] = 0
        results, failures = out
        for h, t in handle2t.items():
            if h in results:
                self._complete(t, results[h])
            else:
                self._retry_or_fail(t, "error", failures.get(h))

    def _requeue_cohort(self, cohort: list[_Ticket]) -> None:
        """Return an undispatched cohort to the shared queue untouched:
        a device death is not the jobs' fault, so no attempt is consumed
        and no backoff applies (the jobs' deadlines still do)."""
        now = time.monotonic()
        with self._work:
            for t in cohort:
                self._inflight_cost -= t.cost
                self._pending_cost += t.cost
                t.enqueue_t = now
                self._queue.append(t)
            self._update_gauges()
            self._work.notify_all()

    def _note_device_failure(self, idx: int) -> None:
        """One more consecutive cohort failure on this device; at
        ``device_unhealthy_after`` in a row the device is retired (its
        jobs were already re-queued/retried by the caller)."""
        self._fail_streak[idx] += 1
        if self._fail_streak[idx] >= self.device_unhealthy_after:
            self._kill_device(idx, "unhealthy")

    def _kill_device(self, idx: int, why: str) -> bool:
        """Mark device ``idx`` unhealthy and retire its dispatcher.
        Refuses (returns False) when it is the last healthy device —
        degraded capacity must never become zero availability."""
        with self._work:
            if idx in self._dead:
                return True
            if all(i in self._dead or i == idx
                   for i in range(len(self._devices))):
                return False
            self._dead.add(idx)
            self._work.notify_all()
        label = self._dev_labels[idx]
        if self._tm:
            self.metrics.set_gauge("serve_device_unhealthy", 1,
                                   device=label)
        self._event("device_unhealthy", device=label, reason=why)
        if self.recorder is not None:
            path = self.recorder.dump(f"device_{why}", device=label)
            if path is not None:
                self.stats.blackbox_path = path
        return True

    def _drain(self, sched: FleetScheduler):
        """``drain_isolated`` with the watchdog: returns ``(results,
        failures)``, or ``None`` when the dispatch exceeded
        ``dispatch_timeout_s`` (the drain thread is abandoned; its late
        results are discarded along with its scheduler)."""
        if self.dispatch_timeout_s is None:
            return sched.drain_isolated()
        box: dict[str, Any] = {}
        ctx = contextvars.copy_context()   # carry tracer + fault plan

        def run():
            try:
                box["out"] = ctx.run(sched.drain_isolated)
            except BaseException as e:     # noqa: BLE001 — relayed below
                box["err"] = e

        th = threading.Thread(target=run, daemon=True,
                              name="fleet-service-drain")
        th.start()
        th.join(self.dispatch_timeout_s)
        if th.is_alive():
            sched.cancel()   # orphan stops at its next unit boundary
            self._abandoned.append(th)
            return None
        if "err" in box:
            raise box["err"]
        return box["out"]

    def _reset_sched(self, idx: int, why: str, err: Exception | None,
                     **info) -> None:
        label = self._dev_labels[idx]
        self.metrics.inc("serve_scheduler_resets_total", reason=why,
                         device=label)
        self._event(why, error=type(err).__name__ if err else "",
                    device=label, **info)
        # the blackbox: the ring's last ~N events are exactly the
        # context a post-mortem of a hung/crashed scheduler needs
        if self.recorder is not None:
            path = self.recorder.dump(
                why, error=type(err).__name__ if err else "", **info)
            if path is not None:
                self.stats.blackbox_path = path
        self._scheds[idx] = self._make_sched(idx)

    # ------------------------------------------------------- resolution
    def _release(self, t: _Ticket) -> None:
        with self._work:
            self._inflight_cost -= t.cost
            self._update_gauges()
            self._work.notify_all()

    def _observe_latency(self, t: _Ticket, outcome: str) -> None:
        if not self._tm:
            return
        now = time.monotonic()
        self.metrics.observe("serve_request_latency_seconds",
                             now - t.submit_t, outcome=outcome)
        if t.dispatch_t:
            self.metrics.observe("serve_job_latency_seconds",
                                 now - t.dispatch_t)

    def _complete(self, t: _Ticket, res: JobResult) -> None:
        t.attempts += 1
        self._release(t)
        self.metrics.inc("serve_completed_total", tier=res.tier)
        self._observe_latency(t, "ok")
        if self.tracer is not None:
            self.tracer.async_end("request", id=t.tid, tier=res.tier,
                                  attempts=t.attempts)
        t.future.set_result(res)

    def _retry_or_fail(self, t: _Ticket, kind: str,
                       cause: Exception | None) -> None:
        t.attempts += 1
        now = time.monotonic()
        missed = t.deadline is not None and now >= t.deadline
        if missed or t.attempts > self.max_retries:
            self._fail(t, "deadline" if missed else kind,
                       cause=cause,
                       detail="" if missed else
                       f"retries exhausted ({t.attempts} attempts)")
            return
        delay = self.backoff_s * self.backoff_factor ** (t.attempts - 1)
        t.not_before = now + delay
        self.metrics.inc("serve_retries_total", kind=kind)
        self._event("job_retry", id=t.tid, attempts=t.attempts,
                    kind=kind, backoff_s=round(delay, 6))
        with self._work:
            self._inflight_cost -= t.cost
            self._pending_cost += t.cost
            t.enqueue_t = now
            self._queue.append(t)
            self._update_gauges()
            self._work.notify_all()

    def _fail(self, t: _Ticket, kind: str, *,
              cause: Exception | None = None, detail: str = "") -> None:
        self._release(t)
        self.metrics.inc("serve_failed_total", kind=kind)
        self._observe_latency(t, "error")
        self._event("job_failed", id=t.tid, kind=kind,
                    attempts=t.attempts)
        if self.tracer is not None:
            self.tracer.async_end("request", id=t.tid, error=kind)
        recent: list = []
        if self.recorder is not None:
            # retry exhaustion is a production failure worth a blackbox
            # (deadline misses and shutdown drops are normal shedding)
            if kind in ("error", "timeout"):
                path = self.recorder.dump("retry_exhausted",
                                          ticket=t.tid, kind=kind)
                if path is not None:
                    self.stats.blackbox_path = path
            recent = self.recorder.recent_for(t.tid)
        t.future.set_exception(JobError(
            kind, ticket=t.tid, attempts=t.attempts, detail=detail,
            cause=cause, recent_events=recent))

    # --------------------------------------------------------- shutdown
    def close(self, wait: bool = True,
              timeout: float | None = None) -> None:
        """Stop the service.  ``wait=True`` (default) drains everything
        still queued (deadlines and retries still apply) before the
        dispatcher exits; ``wait=False`` fails queued jobs fast with
        ``JobError(kind="shutdown")``.  Idempotent."""
        with self._work:
            self._closed = True
            dropped = []
            if not wait:
                dropped, self._queue = self._queue, []
                for t in dropped:
                    self._pending_cost -= t.cost
                    self._inflight_cost += t.cost  # _fail releases it
            self._work.notify_all()
        for t in dropped:
            self._fail(t, "shutdown", detail="service closed")
        for th in self._threads:
            th.join(timeout)
        # give watchdog-abandoned drains a bounded chance to finish so
        # the interpreter doesn't tear down under a live XLA dispatch (a
        # truly wedged one stays a daemon and is dropped with the
        # process)
        for th in self._abandoned:
            th.join(2.0)
        self._abandoned = [th for th in self._abandoned if th.is_alive()]
        if self._trace_path is not None and self.tracer is not None:
            self.tracer.save(self._trace_path)
        # flush the service's final telemetry into the stats object so
        # a closed service remains fully inspectable (and the blackbox
        # path survives the recorder)
        snap = self.metrics.snapshot()
        snap.meta["slo"] = self.slo_status(snap)
        if self.recorder is not None and self.recorder.dumps:
            self.stats.blackbox_path = self.recorder.dumps[-1]
            snap.meta["blackbox_path"] = self.stats.blackbox_path
        self.stats.final_snapshot = snap

    def slo_status(self, snapshot: obs_metrics.MetricsSnapshot | None
                   = None) -> dict:
        """Rolling-window latency percentiles and error-budget burn.

        ``burn`` (present when ``slo_latency_s`` is set) counts a
        request as *bad* when it resolved with an error — however fast
        — or completed slower than ``slo_latency_s``; the rate is the
        bad fraction over the window divided by the budget
        ``1 - slo_target`` (1.0 = burning exactly at budget).
        """
        snap = snapshot if snapshot is not None \
            else self.metrics.snapshot()
        name = "serve_request_latency_seconds"
        out = {
            "window_s": self.slo_window_s,
            "request_p50_s": snap.percentile(name, 0.50, window=True),
            "request_p99_s": snap.percentile(name, 0.99, window=True),
            "job_p50_s": snap.percentile(
                "serve_job_latency_seconds", 0.50, window=True),
            "job_p99_s": snap.percentile(
                "serve_job_latency_seconds", 0.99, window=True),
            "lifetime_request_p99_s": snap.percentile(name, 0.99),
        }
        if self.slo_latency_s is not None:
            total = snap.hist_count(name, window=True)
            good = snap.count_le(name, self.slo_latency_s,
                                 window=True, outcome="ok")
            bad_frac = (1.0 - good / total) if total else 0.0
            out.update(
                slo_latency_s=self.slo_latency_s,
                slo_target=self.slo_target,
                window_requests=total,
                window_good=good,
                burn=bad_frac / max(1e-9, 1.0 - self.slo_target))
        return out

    def save_trace(self, path: str) -> None:
        """Write the service tracer's Chrome/Perfetto trace JSON."""
        if self.tracer is None:
            raise ValueError("service was created without trace=")
        self.tracer.save(path)

    def __enter__(self) -> "FleetService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close(wait=exc == (None, None, None))
        return False
