"""Always-on serving loop over the fleet: continuous batching with
deadlines, priorities, retries, backpressure, and fault isolation.

:class:`FleetService` turns the batch-mode ``submit()``/``drain()``
scheduler into a stream-serving front-end:

* **per-job futures** — :meth:`FleetService.submit` returns a
  :class:`concurrent.futures.Future` that resolves to a
  :class:`~repro.fleet.scheduler.JobResult` or raises a structured
  :class:`JobError` (kind, attempts, cause).  Every submitted future
  resolves, always — that is the serving contract.  Wrap with
  ``asyncio.wrap_future`` to await from an event loop;
* **deadline-or-size batching** — a background dispatcher forms a
  lock-step cohort the moment ``batch_size`` jobs are ready *or* the
  oldest ready job has waited ``max_delay_s``, whichever fires first;
* **priority lanes** — lower ``priority`` dispatches first within a
  trigger (ties broken by submission order);
* **per-job deadlines** — a job past its deadline is *masked out of its
  batch slot* and failed fast with ``JobError(kind="deadline")``: the
  paper's per-instruction thread-space subsetting (TSC) applied at
  request granularity, exactly like the slot-masked decode loop in
  :mod:`repro.launch.serve`;
* **bounded admission** — once queued+in-flight cost (the cost model's
  per-job estimates) exceeds ``cost_budget`` (or ``max_pending`` jobs),
  ``submit`` blocks (``admission="block"``) or raises
  :class:`AdmissionError` (``admission="reject"``): overload degrades
  into latency or fast rejections, never an unbounded queue;
* **per-job retries with exponential backoff** — a failed dispatch is
  bisected by :meth:`FleetScheduler.drain_isolated` so one poison job
  cannot starve its cohort; jobs that still fail are retried up to
  ``max_retries`` times (backoff ``backoff_s * backoff_factor**k``),
  then fail their future with a structured :class:`JobError` instead of
  poisoning the drain;
* **dispatch watchdog** — with ``dispatch_timeout_s`` set, a hung
  dispatch (e.g. a device sync that never returns — the
  ``device_sync`` fault site) is abandoned: the scheduler is replaced
  wholesale and the cohort is retried/failed as timeouts.

Failure injection for all of the above is
:class:`repro.fleet.faults.FaultPlan` — pass one as ``faults=`` (or
install it ambiently) and the chaos run stays deterministic.

    svc = FleetService(cfg, batch_size=32, max_delay_s=0.002)
    fut = svc.submit(image, data, deadline_s=0.5, priority=0)
    res = fut.result()               # JobResult, or raises JobError
    svc.close()
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

from ..core.assembler import ProgramImage
from ..core.blockc import TierPolicy
from ..core.config import EGPUConfig
from ..obs import trace as obs_trace
from . import faults as faults_mod
from .scheduler import FleetScheduler, JobResult, check_job

__all__ = ["FleetService", "ServiceStats", "JobError", "AdmissionError"]


class JobError(Exception):
    """Structured per-job failure: resolves the job's future.

    ``kind`` is one of ``"deadline"`` (missed its deadline before
    dispatch), ``"timeout"`` (dispatch watchdog fired and retries ran
    out), ``"error"`` (failed on every tier and every retry),
    ``"shutdown"`` (service closed without draining).  ``attempts`` is
    how many dispatches the job consumed; ``cause`` the last underlying
    exception (``None`` for deadline/shutdown)."""

    def __init__(self, kind: str, *, ticket: int = -1, attempts: int = 0,
                 detail: str = "", cause: Exception | None = None):
        self.kind = kind
        self.ticket = ticket
        self.attempts = attempts
        self.detail = detail
        self.cause = cause
        msg = f"job {ticket} failed ({kind}) after {attempts} attempt(s)"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class AdmissionError(RuntimeError):
    """``submit()`` rejected: the service is over its admission budget
    (``admission="reject"``) — shed load upstream or retry later."""


@dataclasses.dataclass
class _Ticket:
    """One in-flight service job (internal)."""

    tid: int
    image: ProgramImage
    shared_init: Any
    threads: int
    tdx_dim: int
    tag: Any
    weight: float | None
    priority: int
    cost: float
    submit_t: float                  # monotonic, for latency accounting
    enqueue_t: float                 # reset on retry: batching trigger
    deadline: float | None           # absolute monotonic, or None
    future: Future
    attempts: int = 0
    not_before: float = 0.0          # backoff gate


@dataclasses.dataclass
class ServiceStats:
    """Aggregate serving counters (monotonic across the service life)."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0                  # futures resolved with JobError
    rejected: int = 0                # AdmissionError raised at submit
    deadline_misses: int = 0         # failed with kind="deadline"
    timeouts: int = 0                # dispatch watchdog firings (jobs)
    retries: int = 0                 # re-queues after a failed attempt
    dispatches: int = 0              # cohorts handed to the scheduler
    dispatched_jobs: int = 0
    scheduler_resets: int = 0        # schedulers abandoned (hang/crash)

    @property
    def resolved(self) -> int:
        return self.completed + self.failed


class FleetService:
    """An always-on serving front-end over :class:`FleetScheduler`.

    One background dispatcher thread owns the scheduler; ``submit`` is
    thread-safe and never touches the device.  ``trace=`` accepts the
    same knob as :class:`~repro.fleet.api.Fleet` (``True`` / path /
    :class:`~repro.obs.Tracer`); serving events (``job_retry``,
    ``job_failed``, ``dispatch_timeout``, ``admission_reject``,
    ``tier_degrade``, ``fault_injected``) land in the same Perfetto
    trace as the drain spans, with per-request ``request`` async pairs
    measuring true submit->resolve latency (queue wait included).
    ``faults=`` installs a :class:`~repro.fleet.faults.FaultPlan` for
    everything the dispatcher runs.
    """

    def __init__(self, cfg: EGPUConfig, batch_size: int = 32, *,
                 max_delay_s: float = 0.005,
                 max_retries: int = 2, backoff_s: float = 0.002,
                 backoff_factor: float = 2.0,
                 dispatch_timeout_s: float | None = None,
                 default_deadline_s: float | None = None,
                 cost_budget: float | None = None,
                 max_pending: int | None = None,
                 admission: str = "block",
                 faults: faults_mod.FaultPlan | None = None,
                 trace: bool | str | obs_trace.Tracer | None = None,
                 pack_by_cost: bool = True, validate: bool = True,
                 use_compiler: bool = True, compile_min: int = 1,
                 tier_policy: TierPolicy | None = None,
                 residency_max: int = 32, fixed_bucket: bool = True):
        if admission not in ("block", "reject"):
            raise ValueError("admission must be 'block' or 'reject'")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_delay_s = max_delay_s
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_factor = backoff_factor
        self.dispatch_timeout_s = dispatch_timeout_s
        self.default_deadline_s = default_deadline_s
        self.cost_budget = cost_budget
        self.max_pending = max_pending
        self.admission = admission
        self.faults = faults
        self.stats = ServiceStats()

        self.tracer: obs_trace.Tracer | None = None
        self._trace_path: str | None = None
        if isinstance(trace, obs_trace.Tracer):
            self.tracer = trace
        elif isinstance(trace, str):
            self.tracer = obs_trace.Tracer("service")
            self._trace_path = trace
        elif trace:
            self.tracer = obs_trace.Tracer("service")

        # all schedulers (incl. watchdog replacements) share one tracer
        # and one residency/compile-cache regime.  Serving defaults
        # differ from batch drains: ``compile_min=1`` (programs repeat
        # forever, so even a singleton group should ride the cached
        # compiled tier, not the interpreter) and ``fixed_bucket=True``
        # (one XLA shape per program — ragged cohort sizes must not
        # spray pow2 bucket shapes, each a multi-second compile, across
        # the steady-state latency profile)
        self._sched_kw = dict(pack_by_cost=pack_by_cost,
                              validate=validate,
                              use_compiler=use_compiler,
                              compile_min=compile_min,
                              tier_policy=tier_policy,
                              residency_max=residency_max,
                              fixed_bucket=fixed_bucket)
        self._sched = self._make_sched()

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: list[_Ticket] = []
        self._pending_cost = 0.0         # queued, not yet dispatched
        self._inflight_cost = 0.0        # dispatched, not yet resolved
        self._next_tid = 0
        self._closed = False
        self._abandoned: list[threading.Thread] = []
        self._thread = threading.Thread(target=self._loop,
                                        name="fleet-service-dispatch",
                                        daemon=True)
        self._thread.start()

    def _make_sched(self) -> FleetScheduler:
        return FleetScheduler(self.cfg, self.batch_size,
                              trace=self.tracer, **self._sched_kw)

    # ----------------------------------------------------------- intake
    @property
    def pending(self) -> int:
        """Jobs queued but not yet dispatched (in-flight excluded)."""
        with self._lock:
            return len(self._queue)

    def _load_cost(self) -> float:
        return self._pending_cost + self._inflight_cost

    def _over_budget(self, cost: float) -> bool:
        if self.max_pending is not None \
                and len(self._queue) >= self.max_pending:
            return True
        return self.cost_budget is not None \
            and self._load_cost() + cost > self.cost_budget

    def submit(self, image: ProgramImage, shared_init=None, *,
               threads: int | None = None, tdx_dim: int = 16,
               tag: Any = None, weight: float | None = None,
               priority: int = 1,
               deadline_s: float | None = None) -> Future:
        """Queue one job; returns its future (``result()`` ->
        :class:`~repro.fleet.scheduler.JobResult`, or raises
        :class:`JobError`).  Malformed inputs fail here, synchronously,
        with ``ValueError`` — never mid-drain.  ``deadline_s`` is
        relative to now (``default_deadline_s`` when ``None``); a job
        that cannot dispatch before its deadline is masked out of its
        batch and failed fast.  Over budget, ``submit`` blocks or
        raises :class:`AdmissionError` per the ``admission`` mode."""
        shared_init, threads = check_job(self.cfg, image, shared_init,
                                         threads)
        cost = float(weight) if weight is not None \
            else float(image.static_cycle_estimate())
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        now = time.monotonic()
        with self._work:
            if self._closed:
                raise RuntimeError("service is closed")
            while self._over_budget(cost):
                if self.admission == "reject":
                    self.stats.rejected += 1
                    if self.tracer is not None:
                        self.tracer.event("admission_reject", cat="serve",
                                          cost=cost,
                                          load=self._load_cost())
                    raise AdmissionError(
                        f"admission budget exceeded (load "
                        f"{self._load_cost():.0f} + job {cost:.0f} > "
                        f"budget {self.cost_budget}, pending "
                        f"{len(self._queue)})")
                self._work.wait(0.05)
                if self._closed:
                    raise RuntimeError("service is closed")
            tid = self._next_tid
            self._next_tid += 1
            now = time.monotonic()
            t = _Ticket(tid=tid, image=image, shared_init=shared_init,
                        threads=threads, tdx_dim=tdx_dim, tag=tag,
                        weight=weight, priority=priority, cost=cost,
                        submit_t=now, enqueue_t=now,
                        deadline=None if deadline_s is None
                        else now + deadline_s,
                        future=Future())
            self.stats.submitted += 1
            self._pending_cost += cost
            self._queue.append(t)
            self._work.notify_all()
        if self.tracer is not None:
            self.tracer.async_begin("request", id=tid,
                                    priority=priority, cost=cost)
        return t.future

    # ------------------------------------------------------- dispatcher
    def _loop(self) -> None:
        with contextlib.ExitStack() as stack:
            # a fresh thread has a fresh context: install the service's
            # tracer and fault plan for everything the dispatcher runs
            if self.tracer is not None:
                stack.enter_context(self.tracer)
            if self.faults is not None:
                stack.enter_context(self.faults)
            while True:
                expired, cohort = [], []
                with self._work:
                    if self._closed and not self._queue:
                        break
                    now = time.monotonic()
                    expired = [t for t in self._queue
                               if t.deadline is not None
                               and now >= t.deadline]
                    if expired:
                        gone = {t.tid for t in expired}
                        self._queue = [t for t in self._queue
                                       if t.tid not in gone]
                        for t in expired:
                            self._pending_cost -= t.cost
                            self._inflight_cost += t.cost  # _fail releases
                        self._work.notify_all()
                    else:
                        ready = [t for t in self._queue
                                 if t.not_before <= now]
                        oldest = min((t.enqueue_t for t in ready),
                                     default=None)
                        full = len(ready) >= self.batch_size
                        due = oldest is not None \
                            and now - oldest >= self.max_delay_s
                        if ready and (full or due or self._closed):
                            ready.sort(key=lambda t: (t.priority, t.tid))
                            cohort = ready[:self.batch_size]
                            gone = {t.tid for t in cohort}
                            self._queue = [t for t in self._queue
                                           if t.tid not in gone]
                            for t in cohort:
                                self._pending_cost -= t.cost
                                self._inflight_cost += t.cost
                        else:
                            self._work.wait(self._next_wake(now))
                            continue
                # futures resolve outside the lock (their callbacks may
                # re-enter submit)
                for t in expired:
                    self._fail(t, "deadline",
                               detail="deadline passed before dispatch")
                if cohort:
                    self._dispatch(cohort)

    def _next_wake(self, now: float) -> float | None:
        """Seconds until the next scheduled trigger (batch-delay expiry,
        backoff release, or deadline), or ``None`` to wait for work."""
        nxt = None
        for t in self._queue:
            cands = [max(t.not_before, t.enqueue_t + self.max_delay_s)]
            if t.deadline is not None:
                cands.append(t.deadline)
            c = min(cands)
            nxt = c if nxt is None else min(nxt, c)
        if nxt is None:
            return None
        return max(1e-4, nxt - now)

    def _dispatch(self, cohort: list[_Ticket]) -> None:
        self.stats.dispatches += 1
        self.stats.dispatched_jobs += len(cohort)
        sched = self._sched
        try:
            handle2t = {
                sched.submit(t.image, t.shared_init, threads=t.threads,
                             tdx_dim=t.tdx_dim, tag=t.tag,
                             weight=t.weight): t
                for t in cohort}
            out = self._drain(sched)
        except Exception as e:
            # the scheduler itself misbehaved (not a contained per-unit
            # failure): abandon it — its internal queue may still hold
            # re-queued jobs — and retry the cohort on a fresh one
            self._reset_sched("drain_error", e)
            for t in cohort:
                self._retry_or_fail(t, "error", e)
            return
        if out is None:                  # watchdog fired: hung dispatch
            self._reset_sched("dispatch_timeout", None)
            self.stats.timeouts += len(cohort)
            for t in cohort:
                self._retry_or_fail(t, "timeout", None)
            return
        results, failures = out
        for h, t in handle2t.items():
            if h in results:
                self._complete(t, results[h])
            else:
                self._retry_or_fail(t, "error", failures.get(h))

    def _drain(self, sched: FleetScheduler):
        """``drain_isolated`` with the watchdog: returns ``(results,
        failures)``, or ``None`` when the dispatch exceeded
        ``dispatch_timeout_s`` (the drain thread is abandoned; its late
        results are discarded along with its scheduler)."""
        if self.dispatch_timeout_s is None:
            return sched.drain_isolated()
        box: dict[str, Any] = {}
        ctx = contextvars.copy_context()   # carry tracer + fault plan

        def run():
            try:
                box["out"] = ctx.run(sched.drain_isolated)
            except BaseException as e:     # noqa: BLE001 — relayed below
                box["err"] = e

        th = threading.Thread(target=run, daemon=True,
                              name="fleet-service-drain")
        th.start()
        th.join(self.dispatch_timeout_s)
        if th.is_alive():
            sched.cancel()   # orphan stops at its next unit boundary
            self._abandoned.append(th)
            return None
        if "err" in box:
            raise box["err"]
        return box["out"]

    def _reset_sched(self, why: str, err: Exception | None) -> None:
        self.stats.scheduler_resets += 1
        if self.tracer is not None:
            self.tracer.event(why, cat="serve",
                              error=type(err).__name__ if err else "")
        self._sched = self._make_sched()

    # ------------------------------------------------------- resolution
    def _release(self, t: _Ticket) -> None:
        with self._work:
            self._inflight_cost -= t.cost
            self._work.notify_all()

    def _complete(self, t: _Ticket, res: JobResult) -> None:
        t.attempts += 1
        self._release(t)
        self.stats.completed += 1
        if self.tracer is not None:
            self.tracer.async_end("request", id=t.tid, tier=res.tier,
                                  attempts=t.attempts)
        t.future.set_result(res)

    def _retry_or_fail(self, t: _Ticket, kind: str,
                       cause: Exception | None) -> None:
        t.attempts += 1
        now = time.monotonic()
        missed = t.deadline is not None and now >= t.deadline
        if missed or t.attempts > self.max_retries:
            self._fail(t, "deadline" if missed else kind,
                       cause=cause,
                       detail="" if missed else
                       f"retries exhausted ({t.attempts} attempts)")
            return
        delay = self.backoff_s * self.backoff_factor ** (t.attempts - 1)
        t.not_before = now + delay
        self.stats.retries += 1
        if self.tracer is not None:
            self.tracer.event("job_retry", cat="serve", id=t.tid,
                              attempts=t.attempts, kind=kind,
                              backoff_s=round(delay, 6))
        with self._work:
            self._inflight_cost -= t.cost
            self._pending_cost += t.cost
            t.enqueue_t = now
            self._queue.append(t)
            self._work.notify_all()

    def _fail(self, t: _Ticket, kind: str, *,
              cause: Exception | None = None, detail: str = "") -> None:
        self._release(t)
        self.stats.failed += 1
        if kind == "deadline":
            self.stats.deadline_misses += 1
        if self.tracer is not None:
            self.tracer.event("job_failed", cat="serve", id=t.tid,
                              kind=kind, attempts=t.attempts)
            self.tracer.async_end("request", id=t.tid, error=kind)
        t.future.set_exception(JobError(
            kind, ticket=t.tid, attempts=t.attempts, detail=detail,
            cause=cause))

    # --------------------------------------------------------- shutdown
    def close(self, wait: bool = True,
              timeout: float | None = None) -> None:
        """Stop the service.  ``wait=True`` (default) drains everything
        still queued (deadlines and retries still apply) before the
        dispatcher exits; ``wait=False`` fails queued jobs fast with
        ``JobError(kind="shutdown")``.  Idempotent."""
        with self._work:
            self._closed = True
            dropped = []
            if not wait:
                dropped, self._queue = self._queue, []
                for t in dropped:
                    self._pending_cost -= t.cost
                    self._inflight_cost += t.cost  # _fail releases it
            self._work.notify_all()
        for t in dropped:
            self._fail(t, "shutdown", detail="service closed")
        self._thread.join(timeout)
        # give watchdog-abandoned drains a bounded chance to finish so
        # the interpreter doesn't tear down under a live XLA dispatch (a
        # truly wedged one stays a daemon and is dropped with the
        # process)
        for th in self._abandoned:
            th.join(2.0)
        self._abandoned = [th for th in self._abandoned if th.is_alive()]
        if self._trace_path is not None and self.tracer is not None:
            self.tracer.save(self._trace_path)

    def save_trace(self, path: str) -> None:
        """Write the service tracer's Chrome/Perfetto trace JSON."""
        if self.tracer is None:
            raise ValueError("service was created without trace=")
        self.tracer.save(path)

    def __enter__(self) -> "FleetService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close(wait=exc == (None, None, None))
        return False
