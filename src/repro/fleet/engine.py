"""The vmapped fleet runner: N cores, one ``while_loop``, one dispatch.

The single-core executor advances one instruction per ``while_loop``
iteration with real control flow (``lax.switch`` takes one branch).  The
fleet runner vmaps that same step function over a leading core axis:

* the loop condition becomes "any core still running";
* a halted (or faulted/out-of-bounds) core no-ops: its step result is
  discarded leaf-wise, freezing its state — cycles, stats and shared
  memory included — so per-job results are bit-identical to what
  :func:`repro.core.executor.run_program` produces for that job alone;
* all cores share one configuration (homogeneous fleet) and one padded
  program length, but each core carries its *own* program image, runtime
  thread count and shared memory, so the batch is heterogeneous in every
  dynamically-scalable axis of the paper.

The step function is built for this path (``make_step`` with
``flat_dispatch=True``): per-opcode values come from a fused
nested-``where`` chain over the batch's instruction working set, small
state structures update via one-hot selects, and the one true scatter
(STO to shared memory) is applied here as a single flattened batch
scatter gated on "any core stores this cycle" — batched scatters are the
slowest op on the CPU backend by an order of magnitude.
"""
from __future__ import annotations

import functools
import time
import weakref
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import semantics
from ..core.assembler import ProgramImage
from ..core.config import EGPUConfig
from ..core.executor import make_step, pad_image, padded_length
from ..core.isa import Op
from ..core.machine import MachineState, init_state
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from . import devices as devices_mod
from . import faults


class ResidencyCache:
    """Device-resident batch inputs for the compiled lock-step tier.

    A drain of N same-program jobs transfers one ``(N, S)`` shared-memory
    image (plus the TDX grid vector) host -> device before launching the
    batched runner.  Serving workloads drain the *same* programs over the
    same inputs repeatedly, so this cache keeps the already-transferred
    device arrays resident across drains: a repeat drain whose key —
    which embeds a content digest of the batch (per-job shared image +
    TDX grid, order-sensitive, length-prefixed) — matches an entry
    replays the resident buffers and pays **zero host -> device
    transfer**.  That is only sound because the compiled
    light path (:meth:`repro.core.blockc.CompiledProgram.run_light_dev`)
    never donates its inputs — a donated buffer is consumed by XLA and
    cannot be replayed.

    Entries are LRU-bounded and **invalidated with the compile cache**:
    each entry holds a weak reference to the :class:`CompiledProgram` it
    was built against, and a lookup whose compiled program is no longer
    that exact object (evicted and recompiled, or garbage-collected)
    rebuilds rather than replays — the compiled program's identity is
    the invalidation token, so the two caches cannot drift apart.
    """

    def __init__(self, max_entries: int = 32):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self._max = max_entries
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        """Drop every resident entry (a later lookup just rebuilds and
        re-transfers — an eviction is always a miss, never an error).
        The ``residency_evict`` fault site exercises exactly this."""
        self._entries.clear()

    def lookup(self, key, cp, build):
        """Return ``(arrays, hit)``: the device-resident input arrays
        for ``key`` (whose content identity the caller encodes in the
        key itself) if the entry was built against this exact ``cp``;
        otherwise call ``build()`` (which must return the device
        arrays), cache, and return them."""
        e = self._entries.get(key)
        if e is not None and e["cp"]() is cp:
            self._entries.move_to_end(key)
            self.hits += 1
            return e["arrays"], True
        arrays = build()
        self._entries[key] = {"cp": weakref.ref(cp), "arrays": arrays}
        self._entries.move_to_end(key)
        while len(self._entries) > self._max:
            self._entries.popitem(last=False)      # LRU eviction
        self.misses += 1
        return arrays, False


def stack_states(states: list[MachineState]) -> MachineState:
    """Stack per-core states along a new leading fleet axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_state(batched: MachineState, i: int) -> MachineState:
    """Extract core ``i``'s state from a batched fleet state."""
    return jax.tree_util.tree_map(lambda x: x[i], batched)


#: instruction steps per ``while_loop`` trip.  Unrolling amortises the
#: loop-boundary buffer copies XLA inserts around the carried state; the
#: act-gating in the step makes overshooting a core's STOP harmless.
_UNROLL = 8


@functools.lru_cache(maxsize=32)
def _make_fleet_runner(cfg: EGPUConfig, prog_len: int,
                       ops_subset: frozenset | None = None,
                       unroll: int = _UNROLL, validate: bool = True):
    step, running = make_step(cfg, prog_len, ops_subset,
                              flat_dispatch=True, check_hazards=validate,
                              collect_stats=validate)
    S = cfg.shared_words
    vstep = jax.vmap(step)
    vrunning = jax.vmap(running)

    def cond(carry):
        return jnp.any(vrunning(carry[0]))

    def substep(states, progs):
        act = vrunning(states)          # halted cores no-op via the gate
        sts, sidx, rdv = vstep(states, progs, act)

        # the deferred STO writes of the whole batch as ONE flat scatter
        # (semantics.store — shared with the block compiler), skipped
        # entirely on cycles where no core is storing (a batched per-core
        # scatter is the single slowest op on the CPU backend)
        shared = lax.cond(jnp.any(sidx < S),
                          lambda sh: semantics.store(sh, sidx, rdv),
                          lambda sh: sh, sts.shared)
        return sts._replace(shared=shared)

    def body(carry):
        states, progs = carry
        for _ in range(unroll):
            states = substep(states, progs)
        return (states, progs)

    # donate the carried batch state: XLA reuses the (N, T, R) register
    # files / (N, S) shared memories in place instead of copying them on
    # every dispatch (callers get the final state back)
    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(progs, states):
        final, _ = lax.while_loop(cond, body, (states, progs))
        return final

    return run


def _pack_programs(images: list[ProgramImage], prog_len: int | None = None):
    """Pad every image to one shared length, stack to ``(N, L, 7)``, and
    collect the batch's instruction working set (for switch
    specialization)."""
    if prog_len is None:
        prog_len = max(padded_length(im.n) for im in images)
    packed = np.stack([pad_image(im, prog_len)[0] for im in images])
    ops = frozenset(int(o) for im in images for o in np.unique(im.op))
    ops |= {int(Op.STOP)}           # padding rows
    return jnp.asarray(packed), prog_len, ops


#: AOT-compiled fleet executables keyed on (runner, batch shape): the
#: jit wrapper would fold XLA compilation into the first dispatch, which
#: makes the scheduler's wall-time attribution lie — ``lower().compile()``
#: splits it out (``timings["compile_s"]``) without an extra execution.
_FLEET_EXECS: OrderedDict = OrderedDict()
_FLEET_EXECS_MAX = 64


def _fleet_exec(runner, progs, states, device=None):
    """The AOT executable for this (runner, shapes, device), plus the
    host seconds spent compiling it now (0.0 on a cache hit).  AOT
    executables are pinned to the devices their inputs were lowered on,
    so ``device`` (None -> default placement) is part of the key."""
    key = (runner, progs.shape, device)
    exe = _FLEET_EXECS.get(key)
    if exe is not None:
        _FLEET_EXECS.move_to_end(key)
        obs_metrics.inc("fleet_compile_cache_total", result="hit")
        return exe, 0.0
    obs_metrics.inc("fleet_compile_cache_total", result="miss")
    t0 = time.perf_counter()
    with obs_trace.span("compile", kind="fleet_runner",
                        batch=progs.shape[0], prog_len=progs.shape[1]):
        exe = runner.lower(progs, states).compile()
    _FLEET_EXECS[key] = exe
    while len(_FLEET_EXECS) > _FLEET_EXECS_MAX:
        _FLEET_EXECS.popitem(last=False)
    return exe, time.perf_counter() - t0


def fleet_run(images: list[ProgramImage],
              states: list[MachineState] | MachineState | None = None, *,
              prog_len: int | None = None,
              init_kw: list[dict] | None = None,
              validate: bool = True,
              timings: dict | None = None,
              device=None) -> MachineState:
    """Execute one program per core, all cores in one vmapped dispatch.

    ``images`` must share a configuration (homogeneous cores).  ``states``
    — a list of per-core states or an already-batched state — or per-job
    ``init_kw`` dicts for :func:`init_state` supply each core's shared
    memory, runtime thread count and TDX grid.  Returns the batched final
    :class:`MachineState`; slice per-core results out with
    :func:`unstack_state`.

    ``validate=False`` drops the hazard checker and the instruction-mix
    counters from the compiled step (architectural results unchanged) —
    use for throughput runs.

    ``timings``, if given, receives ``{"compile_s": ...}`` — the host
    seconds spent XLA-compiling the runner for this batch shape during
    *this* call (0.0 when warm), so callers timing the dispatch can
    attribute one-time compile cost separately.

    ``device`` pins the dispatch to one jax device: inputs are placed
    there, the AOT executable is compiled against that placement (and
    cached per device), and metrics/fault-site info carry its label.
    ``None`` keeps today's default-device behavior bit-for-bit.
    """
    if not images:
        raise ValueError("empty fleet")
    cfg = images[0].cfg
    for im in images[1:]:
        if im.cfg != cfg:
            raise ValueError("fleet cores must share one EGPUConfig")
    if states is None:
        init_kw = init_kw or [{}] * len(images)
        states = [init_state(cfg, threads=im.threads_active, **kw)
                  for im, kw in zip(images, init_kw)]
    if isinstance(states, list):
        if len(states) != len(images):
            raise ValueError("one state per core required")
        states = stack_states(states)
    progs, length, ops = _pack_programs(images, prog_len)
    if device is not None:
        progs = jax.device_put(progs, device)
        states = jax.device_put(states, device)
    dev_label = devices_mod.device_label(device)
    runner = _make_fleet_runner(cfg, length, ops, validate=validate)
    exe, compile_s = _fleet_exec(runner, progs, states, device)
    if timings is not None:
        timings["compile_s"] = compile_s
    t_disp = time.perf_counter()
    with obs_trace.span("dispatch", cores=len(images), prog_len=length,
                        device=dev_label):
        faults.maybe_raise("dispatch", tier="interp", cores=len(images),
                           device=dev_label)
        out = exe(progs, states)
    t_sync = time.perf_counter()
    with obs_trace.span("device_sync"):
        hang = faults.hang_seconds("device_sync", tier="interp",
                                   device=dev_label)
        if hang:
            time.sleep(hang)
        out.cycles.block_until_ready()
    t_done = time.perf_counter()
    obs_metrics.observe("fleet_dispatch_seconds", t_sync - t_disp,
                        tier="interp", device=dev_label)
    obs_metrics.observe("fleet_device_sync_seconds", t_done - t_sync,
                        tier="interp", device=dev_label)
    return out
