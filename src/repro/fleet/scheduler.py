"""Work-queue scheduler: pack heterogeneous jobs into fleet batches.

``submit()`` enqueues jobs — each with its own program, shared-memory
image, runtime thread count and TDX grid — and ``drain()`` packs them
into fixed-shape batches of ``batch_size`` cores, runs each batch in one
vmapped XLA dispatch (:func:`repro.fleet.engine.fleet_run`) and scatters
per-job results back by handle.

Invariants the layers above build on (see ``docs/architecture.md``):

* **one delivery per job** — every submitted handle appears in exactly
  one drain's results (or, under ``drain_isolated``, in exactly one of
  results/failures), even across drain crashes: unprocessed jobs
  re-queue, computed results stash and deliver next drain;
* **checksummed salvage** — a result stashed across a failed drain is
  content-checksummed when stashed and re-verified at delivery; a
  corrupted result is dropped and its job re-executed, never served;
* **bit-identical tiers** — a job's architectural outputs (shared
  image, cycles, steps) are identical whichever tier runs it, so tier
  choice, degradation and bisection are pure performance decisions;
* **admission lint precedes compile** — ``submit`` rejects
  statically-broken programs (``ProgramVerificationError``) before any
  compile or dispatch sees them;
* **device pinning is optional** — ``device=None`` (the default) is
  today's single-device scheduler, bit-for-bit; a pinned scheduler
  places inputs, AOT executables and metrics on/for its device, which
  is what the multi-device fleet (``fleet/sharded.py``) composes.

Packing rules:

* programs are padded to the shared ``_PAD`` grid (the executor's
  compile cache is keyed on padded length, so batches whose longest
  programs land on the same grid line reuse compiles);
* jobs are packed heaviest-first by a cost ``weight`` (caller-supplied
  hint, defaulting to padded program length) so jobs of similar cost
  share a batch — lock-step cores finish together instead of idling
  behind one straggler;
* a trailing partial batch is padded with trivial STOP jobs, keeping the
  batch shape (and therefore the jit cache entry) fixed.

The batched initial state is built host-side in one NumPy pass (one
device transfer per leaf, not one per core) and results come back the
same way — per-job Python overhead is what a throughput engine lives or
dies by.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis import ProgramVerificationError, analyze_cached
from ..core import isa
from ..core import machine as machine_mod
from ..core.assembler import Asm, ProgramImage
from ..core.blockc import (BlockCompileError, TierPolicy, compile_program,
                           default_policy_for_device, normalize_threads,
                           program_key)
from ..core.config import EGPUConfig
from ..core.executor import padded_length
from ..core.machine import MachineState
from ..obs import counters as obs_counters
from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace
from ..obs.counters import EventCounters
from . import faults
from .devices import device_label
from .engine import ResidencyCache, fleet_run


def check_job(cfg: EGPUConfig, image: ProgramImage, shared_init,
              threads: int | None, *, tdx_dim: int = 16,
              lint: bool = True) -> tuple[np.ndarray | None, int]:
    """Validate one job's inputs against ``cfg`` **at submission time**,
    so a malformed job fails fast with a clear ``ValueError`` instead of
    a deep XLA/NumPy shape or cast error mid-drain (where it would take
    its whole batch down with it).  Returns the coerced
    ``(shared_init, threads)`` pair.  Shared by :meth:`FleetScheduler.submit`
    and :meth:`repro.fleet.service.FleetService.submit`.

    With ``lint=True`` (the default) the whole-program static verifier
    (:func:`repro.analysis.analyze`) also runs — cached per (config,
    program, threads) — and ERROR-level findings (out-of-image branch
    targets, undefined TSC width codings, stack underflow/overflow,
    proven out-of-bounds accesses, programs that cannot halt) raise
    :class:`repro.analysis.ProgramVerificationError`, a ``ValueError``
    subclass carrying the structured diagnostics, *before* any compile
    or dispatch touches the job."""
    # Per-image memo: the steady-state submit path costs one attribute
    # probe, not a bytes-keyed cache hash (ProgramImage is a plain
    # dataclass, so the instance dict is writable).  A hit also proves
    # the (cfg, threads) pair already passed the config/thread checks
    # below — same cfg object, same arguments — so the warm path skips
    # re-validating them.
    if lint:
        try:
            memo = image._lint_memo
        except AttributeError:
            memo = None
        if memo is not None and memo[0] is cfg and memo[1] == threads \
                and memo[2] == tdx_dim:
            if not memo[4]:
                raise ProgramVerificationError(memo[5])
            threads = memo[3]
            if shared_init is None:
                return None, threads
            arr = np.asarray(shared_init)
            if arr.dtype.kind not in "fiub":
                raise ValueError(
                    f"shared_init dtype {arr.dtype} is not packable into "
                    f"32-bit shared-memory words; pass float/int/uint data")
            if arr.size > cfg.shared_words:
                raise ValueError(
                    f"shared_init ({arr.size} words) exceeds "
                    f"{cfg.shared_words}")
            return arr, threads
    if image.cfg != cfg:
        raise ValueError("job config does not match the fleet config")
    raw_threads = threads
    threads = normalize_threads(image, threads)
    if threads > cfg.max_threads or threads % cfg.num_sps:
        raise ValueError(f"bad runtime thread count {threads}")
    if lint:
        report = analyze_cached(image, threads, tdx_dim=tdx_dim)
        image._lint_memo = (cfg, raw_threads, tdx_dim, threads,
                           report.ok, report)
        if not report.ok:
            raise ProgramVerificationError(report)
    if shared_init is None:
        return None, threads
    arr = np.asarray(shared_init)
    if arr.dtype.kind not in "fiub":
        raise ValueError(
            f"shared_init dtype {arr.dtype} is not packable into 32-bit "
            f"shared-memory words; pass float/int/uint data")
    if arr.size > cfg.shared_words:
        raise ValueError(
            f"shared_init ({arr.size} words) exceeds "
            f"{cfg.shared_words}")
    return arr, threads


class DrainCancelled(RuntimeError):
    """Raised inside a drain whose scheduler was :meth:`cancelled
    <FleetScheduler.cancel>` — the serving watchdog abandons a hung
    drain this way so the orphaned thread stops at the next unit
    boundary instead of grinding through (and cold-compiling for) the
    rest of the queue nobody will read."""


def _prog_digest(image: ProgramImage) -> str:
    """Short content digest of a program — the ``program`` metric
    label (bounded cardinality: one value per distinct program)."""
    return hashlib.blake2b(program_key(image),
                           digest_size=4).hexdigest()


def _result_checksum(res: "JobResult") -> bytes:
    """Content digest of a result's architectural outputs — computed
    when a salvaged result is stashed across drains and re-verified at
    delivery, so silent corruption while stashed is detected (and the
    job re-executed) instead of served."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(res.shared).tobytes())
    h.update(int(res.cycles).to_bytes(8, "little", signed=True))
    h.update(int(res.steps).to_bytes(8, "little", signed=True))
    return h.digest()


@dataclasses.dataclass
class FleetJob:
    """One queued unit of work."""

    handle: int
    image: ProgramImage
    shared_init: np.ndarray | None
    threads: int
    tdx_dim: int
    tag: Any = None
    weight: float | None = None      # cost hint for batch packing

    @property
    def padded_len(self) -> int:
        return padded_length(self.image.n)

    @property
    def cost(self) -> float:
        return self.weight if self.weight is not None else self.padded_len


@dataclasses.dataclass
class JobResult:
    """Per-job outcome, sliced out of the batched fleet state."""

    handle: int
    tag: Any
    cycles: int
    steps: int
    time_us: float
    hazard_violations: int
    shared: np.ndarray               # (S,) uint32
    stat_cycles: np.ndarray          # (NUM_OP_CLASSES,) int32
    stat_instrs: np.ndarray
    #: execution tier that ran the job ("interp"/"blocks"/"superblock")
    tier: str = "interp"
    #: baked per-core event counters (compiled tiers always; interpreter
    #: tier only under tracing — they cost a host-side path walk there)
    counters: EventCounters | None = None

    def shared_u32(self) -> np.ndarray:
        return self.shared

    def shared_f32(self) -> np.ndarray:
        return self.shared.view(np.float32)

    def shared_i32(self) -> np.ndarray:
        return self.shared.view(np.int32)

    def profile(self) -> dict[str, tuple[int, int]]:
        return {c.name: (int(self.stat_cycles[c]), int(self.stat_instrs[c]))
                for c in isa.OpClass}


def _int_view(doc):
    """A FleetStats/ServiceStats int field backed by registry counters."""
    def deco(fn):
        def get(self):
            return int(round(fn(self)))
        get.__doc__ = doc
        return property(get)
    return deco


class FleetStats:
    """Aggregate counters across every drain of a scheduler.

    Since the always-on telemetry PR these are **views over a
    ** :class:`~repro.obs.metrics.MetricsRegistry` — the registry is
    the single source of truth (one store feeds the Prometheus
    exporter, the snapshot API, and these fields), and because the
    serving watchdog hands the *same* registry to every replacement
    scheduler, service-lifetime totals cannot drift from per-drain
    counts.  Every pre-existing field is kept as a read property, so
    no caller changes.
    """

    def __init__(self, registry: obs_metrics.MetricsRegistry | None
                 = None):
        self.registry = (registry if registry is not None
                         else obs_metrics.MetricsRegistry())
        register_fleet_metrics(self.registry)

    def _t(self, name, **labels):
        return self.registry.total(name, **labels)

    @_int_view("jobs executed (each counted once, when its batch runs)")
    def jobs(self):
        return self._t("fleet_jobs_total")

    @_int_view("batches dispatched, all tiers")
    def batches(self):
        return self._t("fleet_batches_total")

    @_int_view("filler lanes across all batches")
    def pad_slots(self):
        return self._t("fleet_pad_slots_total")

    @_int_view("architectural cycles across all jobs")
    def total_cycles(self):
        return self._t("fleet_cycles_total")

    @_int_view("instructions executed across all jobs")
    def total_steps(self):
        return self._t("fleet_steps_total")

    @property
    def wall_s(self) -> float:
        """Wall time of batch *execution* (input build + dispatch +
        sync + collect); one-time compile cost is split into
        ``compile_s``."""
        return self._t("fleet_wall_seconds_total")

    @property
    def compile_s(self) -> float:
        """Host/XLA compile seconds (block compiles, light-path and
        fleet runner XLA compiles) — kept out of ``wall_s`` so
        warm-vs-cold throughput comparisons measure execution, not
        compilation."""
        return self._t("fleet_compile_seconds_total")

    @_int_view("jobs run on either compiled tier")
    def compiled_jobs(self):
        return (self._t("fleet_jobs_total", tier="blocks")
                + self._t("fleet_jobs_total", tier="superblock"))

    @_int_view("batches run on either compiled tier")
    def compiled_batches(self):
        return (self._t("fleet_batches_total", tier="blocks")
                + self._t("fleet_batches_total", tier="superblock"))

    @_int_view("jobs run on the superblock tier")
    def superblock_jobs(self):
        return self._t("fleet_jobs_total", tier="superblock")

    @_int_view("batches run on the superblock tier")
    def superblock_batches(self):
        return self._t("fleet_batches_total", tier="superblock")

    @_int_view("compiled-tier batches replayed from device-resident "
               "inputs (zero host->device transfer)")
    def residency_hits(self):
        return self._t("fleet_residency_lookups_total", result="hit")

    @_int_view("compiled-tier batches rebuilt and transferred")
    def residency_misses(self):
        return self._t("fleet_residency_lookups_total", result="miss")

    @_int_view("results computed by a failed drain and delivered by a "
               "later one — already counted in jobs/wall_s when "
               "computed, so a per-drain consumer can subtract them "
               "instead of double-dipping")
    def salvaged_jobs(self):
        return self._t("fleet_salvaged_jobs_total")

    @_int_view("units that fell down the tier chain (superblock -> "
               "blocks -> interpreter) after a compile or dispatch "
               "failure, instead of failing the drain")
    def degraded_units(self):
        return self._t("fleet_degraded_units_total")

    @_int_view("failing batches split in half by the isolated drain "
               "so one poison job cannot starve its cohort")
    def bisections(self):
        return self._t("fleet_bisections_total")

    @_int_view("stashed salvaged results that failed their delivery "
               "checksum — dropped and re-executed, never served")
    def salvage_dropped(self):
        return self._t("fleet_salvage_dropped_total")

    @property
    def jobs_per_sec(self) -> float:
        """Aggregate throughput over every batch actually *run*: each
        job is counted exactly once, when its batch executes — delivery
        of salvaged results adds neither jobs nor wall time."""
        wall = self.wall_s
        return self.jobs / wall if wall else 0.0

    def per_device(self) -> dict[str, dict[str, int]]:
        """``{device_label: {"jobs": ..., "batches": ...}}`` across
        every device this registry has seen.  An unpinned scheduler
        reports under ``"default"``; the megabatch ``shard_map`` path
        reports under ``"mesh"`` (the dispatch spans every mesh
        device, so per-device attribution would be a lie)."""
        snap = self.registry.snapshot()
        out: dict[str, dict[str, int]] = {}
        for name, field in (("fleet_jobs_total", "jobs"),
                            ("fleet_batches_total", "batches")):
            m = snap._metric(name)
            if m is None:
                continue
            for s in m["samples"]:
                dev = s["labels"].get("device", "default")
                out.setdefault(dev, {"jobs": 0, "batches": 0})
                out[dev][field] += int(round(s["value"]))
        return out

    def __repr__(self) -> str:
        return (f"FleetStats(jobs={self.jobs}, batches={self.batches}, "
                f"wall_s={self.wall_s:.4f}, "
                f"compile_s={self.compile_s:.4f}, "
                f"compiled_jobs={self.compiled_jobs}, "
                f"superblock_jobs={self.superblock_jobs})")


def register_fleet_metrics(reg: obs_metrics.MetricsRegistry) -> None:
    """Declare the fleet-layer metric families (idempotent) so help
    text and label sets exist even before the first increment."""
    reg.counter("fleet_jobs_total",
                "jobs executed, by tier, program digest and device",
                ("tier", "program", "device"))
    reg.counter("fleet_batches_total",
                "batches dispatched, by tier, program digest and device",
                ("tier", "program", "device"))
    reg.counter("fleet_pad_slots_total", "filler lanes padded in")
    reg.counter("fleet_cycles_total", "architectural cycles retired")
    reg.counter("fleet_steps_total", "instructions executed")
    reg.counter("fleet_wall_seconds_total",
                "batch execution wall time (compile excluded)")
    reg.counter("fleet_compile_seconds_total",
                "host + XLA compile seconds")
    reg.counter("fleet_residency_lookups_total",
                "device-resident input lookups", ("result",))
    reg.counter("fleet_compile_cache_total",
                "light-path XLA compile cache lookups", ("result",))
    reg.counter("fleet_salvaged_jobs_total",
                "salvaged results delivered by a later drain")
    reg.counter("fleet_salvage_dropped_total",
                "salvaged results dropped on checksum mismatch")
    reg.counter("fleet_degraded_units_total",
                "units degraded down the tier chain",
                ("from_tier", "to_tier"))
    reg.counter("fleet_bisections_total",
                "failing batches bisected by the isolated drain")
    reg.histogram("fleet_dispatch_seconds",
                  "XLA dispatch wall per compiled-tier batch",
                  ("tier", "device"))
    reg.histogram("fleet_device_sync_seconds",
                  "device sync wall per compiled-tier batch",
                  ("tier", "device"))


def _batch_init_state(cfg: EGPUConfig, jobs: list[FleetJob]) -> MachineState:
    """The batched initial machine state, built in one NumPy pass
    (leaf-for-leaf identical to stacking per-job ``init_state`` results,
    sharing its shared-image packing and hazard-row constants)."""
    n = len(jobs)
    T, R, S = cfg.max_threads, cfg.regs_per_thread, cfg.shared_words
    D = max(1, cfg.predicate_levels)
    shared = np.zeros((n, S), np.uint32)
    for i, job in enumerate(jobs):
        if job.shared_init is None:
            continue
        buf = machine_mod.pack_shared_init(job.shared_init, S)
        shared[i, :buf.size] = buf
    hz = np.broadcast_to(machine_mod.hazard_init(R), (n, R + 2, 4))
    i32 = lambda shape: jnp.zeros((n,) + shape, jnp.int32)
    return MachineState(
        regs=jnp.zeros((n, T, R), jnp.uint32),
        shared=jnp.asarray(shared),
        pstack=jnp.zeros((n, T, D), jnp.bool_),
        pdepth=i32((T,)),
        lctr=i32((cfg.max_loop_depth,)),
        lsp=i32(()),
        cstack=i32((cfg.max_call_depth,)),
        csp=i32(()),
        pc=i32(()),
        cycles=i32(()),
        steps=i32(()),
        halted=jnp.zeros((n,), jnp.bool_),
        threads_active=jnp.asarray([j.threads for j in jobs], jnp.int32),
        tdx_dim=jnp.asarray([j.tdx_dim for j in jobs], jnp.int32),
        stat_cycles=i32((isa.NUM_OP_CLASSES,)),
        stat_instrs=i32((isa.NUM_OP_CLASSES,)),
        hazard=jnp.asarray(hz),
        hazard_violations=i32(()),
    )


class FleetScheduler:
    """FIFO-with-packing job queue over a homogeneous fleet.

    Jobs are executed on one of three tiers:

    * **superblock** — same-program jobs (identical instruction words,
      identical runtime thread count) are grouped into lock-step batches
      that run the compiler's batched **light path**
      (:meth:`repro.core.blockc.CompiledProgram.run_light_dev` — only
      the shared image comes back; cycles/stats/hazards are baked from
      the static path simulation); the
      :class:`~repro.core.blockc.TierPolicy` cost model picks the
      superblock runner whenever the batch width or the dispatch savings
      amortize its fixed cost — no ``while_loop``, no ``switch``, LOOP
      back-edges unrolled or ``fori_loop``-fused;
    * **block-compiled** — same-program groups the cost model routes to
      the basic-block ``while_loop`` + ``switch`` driver instead (over
      the trace budget, or too small to amortize;
      ``stats.superblock_batches`` vs ``stats.compiled_batches`` shows
      the split);
    * **interpreter** — everything else (mixed leftovers, groups smaller
      than ``compile_min``, programs the compiler rejects) is packed into
      heterogeneous vmapped batches exactly as before.

    Both compiled tiers keep their batch inputs **device-resident**
    across drains (:class:`~repro.fleet.engine.ResidencyCache`): a
    repeat drain of the same program over the same inputs replays the
    already-transferred device buffers — zero host->device transfer —
    and reports the replays in ``stats.residency_hits``.

    Results are bit-identical on every tier.
    """

    def __init__(self, cfg: EGPUConfig, batch_size: int = 32, *,
                 pack_by_cost: bool = True, validate: bool = True,
                 use_compiler: bool = True, compile_min: int = 2,
                 tier_policy: TierPolicy | None = None,
                 residency_max: int = 32, fixed_bucket: bool = False,
                 trace: bool | str | obs_trace.Tracer | None = None,
                 metrics: obs_metrics.MetricsRegistry | None = None,
                 device=None):
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        #: ``trace=True`` records every drain into ``self.tracer``;
        #: a path string additionally writes the cumulative trace JSON
        #: after each successful drain; a :class:`~repro.obs.Tracer`
        #: instance records into that tracer.  An ambient tracer
        #: (``with Tracer():`` around ``drain()``) works regardless.
        self.tracer: obs_trace.Tracer | None = None
        self._trace_path: str | None = None
        if isinstance(trace, obs_trace.Tracer):
            self.tracer = trace
        elif isinstance(trace, str):
            self.tracer = obs_trace.Tracer("fleet")
            self._trace_path = trace
        elif trace:
            self.tracer = obs_trace.Tracer("fleet")
        self.cfg = cfg
        self.batch_size = batch_size
        self.pack_by_cost = pack_by_cost
        self.validate = validate
        self.use_compiler = use_compiler
        self.compile_min = compile_min
        #: ``device=`` pins every dispatch (interpreter and compiled
        #: tier) to one jax device: inputs are placed there, AOT
        #: executables compile against (and cache per) that placement,
        #: and metrics/fault-site info carry the device label.  ``None``
        #: — the default — is today's unpinned single-device scheduler,
        #: bit-for-bit.  A pinned scheduler with no explicit
        #: ``tier_policy`` also picks the policy table registered for
        #: its device's backend kind (see
        #: :func:`repro.core.blockc.default_policy_for_device`).
        self.device = device
        self._dev = device_label(device)
        if tier_policy is None and device is not None:
            tier_policy = default_policy_for_device(device)
        self.tier_policy = tier_policy
        #: pad every compiled-tier unit to the full ``batch_size`` lanes
        #: instead of the next power of two.  Pow2 bucketing minimizes
        #: wasted lanes for one-shot batch drains, but every distinct
        #: (program, bucket) shape is a separate multi-second XLA
        #: compile — under continuous batching, where cohort sizes vary
        #: with arrival timing, that open-ended shape set turns into
        #: recurring compile storms.  A fixed bucket caps it at ONE
        #: shape per program: the serving default
        #: (:class:`repro.fleet.service.FleetService`).
        self.fixed_bucket = fixed_bucket
        #: ``metrics=`` shares one registry across schedulers (the
        #: serving watchdog passes the service's registry to every
        #: replacement scheduler so lifetime totals never reset)
        self.stats = FleetStats(metrics)
        self._m = self.stats.registry
        self._queue: list[FleetJob] = []
        self._next_handle = 0
        self._filler_image: ProgramImage | None = None
        #: device-resident compiled-tier inputs, replayed across drains
        self._residency = ResidencyCache(residency_max)
        #: results computed by a drain that later failed — delivered by
        #: the next drain so completed work is never lost.  Each stashed
        #: result carries a content checksum (verified at delivery: a
        #: corrupted result is dropped and its job re-executed) and the
        #: FleetJob that produced it (so a drop can re-queue it).
        self._salvaged: dict[int, JobResult] = {}
        self._salvage_sums: dict[int, bytes] = {}
        self._salvage_jobs: dict[int, FleetJob] = {}
        self._cancelled = False

    def cancel(self) -> None:
        """Ask an in-flight ``drain`` (possibly on another thread) to
        abort at the next unit boundary (:class:`DrainCancelled`; the
        crash-safe re-queue/salvage path runs as for any failure).  The
        unit already executing cannot be interrupted — XLA dispatches
        and compiles are uninterruptible — but nothing further starts."""
        self._cancelled = True

    # ------------------------------------------------------------- queue
    def submit(self, image: ProgramImage, shared_init=None, *,
               threads: int | None = None, tdx_dim: int = 16,
               tag: Any = None, weight: float | None = None) -> int:
        """Enqueue a job; returns its handle (stable across drains).

        Inputs are validated here (:func:`check_job`), so a malformed
        ``shared_init`` (wrong dtype, over-length) or thread count is a
        clear ``ValueError`` at submission, never a mid-drain batch
        failure; statically broken programs raise
        :class:`~repro.analysis.ProgramVerificationError` (also a
        ``ValueError``) with the verifier's diagnostics attached."""
        try:
            shared_init, threads = check_job(self.cfg, image, shared_init,
                                             threads, tdx_dim=tdx_dim)
        except Exception as e:
            diags = getattr(e, "diagnostics", None)
            if diags is not None:
                self._event("admission_lint_reject", prog_len=image.n,
                            errors=len(diags),
                            codes=",".join(sorted({d.code for d in diags})))
            raise
        handle = self._next_handle
        self._next_handle += 1
        self._queue.append(FleetJob(
            handle=handle, image=image, shared_init=shared_init,
            threads=threads, tdx_dim=tdx_dim, tag=tag, weight=weight))
        tr = self._trace()
        if tr is not None:              # open the submit->deliver pair
            tr.async_begin("job", id=handle, prog_len=image.n,
                           threads=threads)
        return handle

    def _trace(self) -> obs_trace.Tracer | None:
        """The ambient tracer if one is installed, else the fleet's own
        (``trace=`` knob) — ``None`` disables all per-job recording."""
        tr = obs_trace.current_tracer()
        return tr if tr is not None else self.tracer

    def _event(self, name: str, cat: str = "event", **args) -> None:
        """An anomaly/decision event: always into the ambient flight
        recorder (bounded ring, so failures ship with context), and
        into the tracer when one is installed."""
        obs_recorder.record(name, cat=cat, **args)
        tr = self._trace()
        if tr is not None:
            tr.event(name, cat=cat, **args)

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------- drain
    def _filler(self) -> FleetJob:
        """A do-nothing job used to pad partial batches to fixed shape."""
        if self._filler_image is None:
            a = Asm(self.cfg)
            a.stop()
            self._filler_image = a.assemble(threads_active=self.cfg.num_sps)
        return FleetJob(handle=-1, image=self._filler_image,
                        shared_init=None, threads=self.cfg.num_sps,
                        tdx_dim=16)

    def _batches(self, jobs: list[FleetJob]) -> list[list[FleetJob]]:
        if self.pack_by_cost:
            jobs = sorted(jobs, key=lambda j: -j.cost)
        return [jobs[i:i + self.batch_size]
                for i in range(0, len(jobs), self.batch_size)]

    def _split_compilable(self, jobs: list[FleetJob]):
        """Partition the queue into same-program groups big enough for
        the compiled tier, and the mixed remainder."""
        groups: dict[tuple, list[FleetJob]] = {}
        for j in jobs:
            groups.setdefault((program_key(j.image), j.threads),
                              []).append(j)
        compiled: list[tuple[Any, list[FleetJob]]] = []
        rest: list[FleetJob] = []
        for group in groups.values():
            if len(group) < self.compile_min:
                rest.extend(group)
                continue
            # the tier policy sees the width the group will actually
            # run at (its dominant pow2-bucketed chunk size): wide
            # lock-step batches amortize driver overhead differently
            # than single cores, and the cost model knows it
            hint = self.batch_size if self.fixed_bucket else \
                self._bucket(min(len(group), self.batch_size),
                             self.batch_size)
            cp = self._compile_unit(group[0], hint, jobs=len(group))
            if cp is None:
                rest.extend(group)
                continue
            self._event("tier_group", program=_prog_digest(cp.image),
                        jobs=len(group), threads=cp.threads,
                        batch_hint=hint, tier=cp.mode)
            compiled.append((cp, group))
        return compiled, rest

    def _compile_unit(self, job: FleetJob, hint: int, *,
                      jobs: int = 1):
        """Compile one same-program group for the compiled tier, with
        **per-unit graceful degradation**: a compile failure at the
        chosen tier (injected via the ``compile`` fault site, or a real
        unexpected exception) falls down the tier chain — superblock ->
        blocks -> interpreter — instead of failing the whole drain.
        Returns ``None`` for the interpreter tier.  Programs the
        compiler legitimately rejects (:class:`BlockCompileError`) go
        straight to the interpreter, as before."""
        tried = "auto"
        for mode in ("auto", "blocks"):
            t0 = time.perf_counter()
            try:
                cp = compile_program(job.image, job.threads,
                                     validate=self.validate,
                                     policy=self.tier_policy,
                                     batch_hint=hint, mode=mode)
                self._m.inc("fleet_compile_seconds_total",
                            time.perf_counter() - t0)
                tried = cp.mode
                faults.maybe_raise("compile", tier=cp.mode)
                return cp
            except BlockCompileError:
                self._m.inc("fleet_compile_seconds_total",
                            time.perf_counter() - t0)
                return None           # uncompilable: interpreter tier
            except Exception as e:
                self._m.inc("fleet_compile_seconds_total",
                            time.perf_counter() - t0)
                # "blocks" already failed (either auto picked it, or
                # this was the forced-blocks retry): end of the chain
                if mode == "blocks" or tried == "blocks":
                    self._degrade(tried, "interp", jobs, e)
                    return None
                self._degrade(tried, "blocks", jobs, e)
        return None

    def _degrade(self, from_tier: str, to_tier: str, jobs: int,
                 err: Exception | None) -> None:
        self._m.inc("fleet_degraded_units_total",
                    from_tier=from_tier, to_tier=to_tier)
        self._event("tier_degrade", cat="serve", from_tier=from_tier,
                    to_tier=to_tier, jobs=jobs,
                    error=type(err).__name__ if err else "")

    def _collect(self, final: MachineState, batch: list[FleetJob],
                 real: int, wall: float,
                 results: dict[int, JobResult]) -> None:
        """Slice per-job results out of a batched final state (one host
        transfer per leaf, then pure-NumPy scatter to jobs)."""
        shared = np.asarray(final.shared)
        cycles = np.asarray(final.cycles)
        steps = np.asarray(final.steps)
        hv = np.asarray(final.hazard_violations)
        stat_c = np.asarray(final.stat_cycles)
        stat_i = np.asarray(final.stat_instrs)
        tr = self._trace()
        sum_cycles = sum_steps = 0
        for i, job in enumerate(batch[:real]):
            res = JobResult(
                handle=job.handle, tag=job.tag, cycles=int(cycles[i]),
                steps=int(steps[i]),
                time_us=self.cfg.cycles_to_us(int(cycles[i])),
                hazard_violations=int(hv[i]), shared=shared[i],
                stat_cycles=stat_c[i], stat_instrs=stat_i[i],
                tier="interp")
            if tr is not None:
                res.counters = self._job_counters(job)
                tr.async_end("job", id=job.handle, cycles=res.cycles,
                             tier="interp")
            results[job.handle] = res
            sum_cycles += res.cycles
            sum_steps += res.steps
        # one registry pass per batch, not per job (hot path)
        m = self._m
        m.inc("fleet_batches_total", tier="interp", program="mixed",
              device=self._dev)
        m.inc("fleet_jobs_total", real, tier="interp", program="mixed",
              device=self._dev)
        m.inc("fleet_pad_slots_total", len(batch) - real)
        m.inc("fleet_wall_seconds_total", wall)
        m.inc("fleet_cycles_total", sum_cycles)
        m.inc("fleet_steps_total", sum_steps)

    def _job_counters(self, job: FleetJob) -> EventCounters | None:
        """Event counters for an interpreter-tier job (tracing only):
        the path simulation is tier-independent, so compile the program
        (block-compile cache, no XLA work) purely for its counters —
        ``None`` when the compiler rejects it."""
        try:
            cp = compile_program(job.image, job.threads,
                                 validate=self.validate,
                                 policy=self.tier_policy)
        except BlockCompileError:
            return None
        return cp.event_counters()

    @staticmethod
    def _bucket(n: int, cap: int) -> int:
        """Pad a compiled batch to the next power of two (capped at the
        fleet batch size) so jit shape-cache entries stay bounded."""
        b = 1
        while b < n:
            b *= 2
        return min(b, cap)

    def _resident_inputs(self, cp, chunk: list[FleetJob]):
        """The batch's device inputs — replayed from the residency cache
        when this exact (program, padded batch content) was transferred
        by an earlier drain, else packed host-side and transferred."""
        S = self.cfg.shared_words
        # every variable-length field is length-prefixed (and None gets
        # its own tag byte) so job boundaries cannot alias: without the
        # prefixes, two different batches whose concatenated bytes
        # happen to match would digest identically and silently replay
        # the wrong resident inputs
        h = hashlib.blake2b(digest_size=16)
        for j in chunk:
            if j.shared_init is None:
                h.update(b"\x00")
            else:
                h.update(b"\x01")
                dt = str(j.shared_init.dtype).encode()
                h.update(len(dt).to_bytes(4, "little"))
                h.update(dt)
                payload = j.shared_init.tobytes()
                h.update(len(payload).to_bytes(8, "little"))
                h.update(payload)
            h.update(int(j.tdx_dim).to_bytes(4, "little", signed=True))
        # the digest is part of the key: distinct batches of one program
        # (different data, or several chunks per drain) coexist in the
        # cache instead of thrashing a single per-program slot
        key = (program_key(cp.image), cp.threads, self.validate,
               len(chunk), h.digest())

        def build():
            shared = np.zeros((len(chunk), S), np.uint32)
            for i, j in enumerate(chunk):
                if j.shared_init is None:
                    continue
                buf = machine_mod.pack_shared_init(j.shared_init, S)
                shared[i, :buf.size] = buf
            tdx = np.asarray([j.tdx_dim for j in chunk], np.int32)
            sh_dev, tdx_dev = jnp.asarray(shared), jnp.asarray(tdx)
            if self.device is not None:
                # commit to the pinned device now, so the resident
                # entry replays with zero cross-device movement
                sh_dev = jax.device_put(sh_dev, self.device)
                tdx_dev = jax.device_put(tdx_dev, self.device)
            return sh_dev, tdx_dev

        if faults.fire("residency_evict") is not None:
            self._residency.clear()      # must be a miss, never an error
        arrays, hit = self._residency.lookup(key, cp, build)
        self._m.inc("fleet_residency_lookups_total",
                    result="hit" if hit else "miss")
        return arrays, hit

    def _collect_light(self, cp, shared_dev, batch: list[FleetJob],
                       real: int, wall: float,
                       results: dict[int, JobResult]) -> None:
        """Light-path result collection: the shared image is the only
        device->host transfer; cycles/steps/stats/hazards come baked
        from the compile-time path simulation — identical for every
        lock-step core running the program, and bit-identical to what
        ``run()`` returns (the equivalence suites pin this)."""
        shared = np.asarray(shared_dev)
        sim = cp.sim
        zeros = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_c = np.asarray(sim.stat_cycles) if self.validate else zeros
        stat_i = np.asarray(sim.stat_instrs) if self.validate else zeros
        cycles = int(sim.cycles)
        steps = int(sim.steps)
        hv = int(sim.violations)         # already 0 under validate=False
        time_us = self.cfg.cycles_to_us(cycles)
        counters = cp.event_counters()   # baked once, shared per program
        tr = self._trace()
        for i, job in enumerate(batch[:real]):
            results[job.handle] = JobResult(
                handle=job.handle, tag=job.tag, cycles=cycles,
                steps=steps, time_us=time_us, hazard_violations=hv,
                shared=shared[i], stat_cycles=stat_c, stat_instrs=stat_i,
                tier=cp.mode, counters=counters)
            if tr is not None:
                tr.async_end("job", id=job.handle, cycles=cycles,
                             tier=cp.mode)
        # one registry pass per batch, not per job (hot path)
        prog = _prog_digest(cp.image)
        m = self._m
        m.inc("fleet_batches_total", tier=cp.mode, program=prog,
              device=self._dev)
        m.inc("fleet_jobs_total", real, tier=cp.mode, program=prog,
              device=self._dev)
        m.inc("fleet_pad_slots_total", len(batch) - real)
        m.inc("fleet_wall_seconds_total", wall)
        m.inc("fleet_cycles_total", cycles * real)
        m.inc("fleet_steps_total", steps * real)

    def _run_compiled_unit(self, cp, chunk: list[FleetJob],
                           results: dict[int, JobResult]) -> None:
        """One compiled-tier batch: pow2-bucketed, same-program padded,
        run through the light path over device-resident inputs."""
        real = len(chunk)
        with obs_trace.span("batch", tier=cp.mode, jobs=real):
            with obs_trace.span("bucket"):
                size = self.batch_size if self.fixed_bucket else \
                    self._bucket(real, self.batch_size)
                pad = size - real
                chunk = chunk + chunk[:1] * pad   # same-program filler
            t0 = time.perf_counter()
            with obs_trace.span("residency") as rsp:
                (shared_dev, tdx_dev), res_hit = \
                    self._resident_inputs(cp, chunk)
            if rsp.active:
                rsp.set(hit=res_hit)
            # split one-time XLA compilation out of the timed dispatch
            compile_s = cp.light_compile(shared_dev, tdx_dev, self.device)
            self._m.inc("fleet_compile_seconds_total", compile_s)
            self._m.inc("fleet_compile_cache_total",
                        result="miss" if compile_s else "hit")
            t_disp = time.perf_counter()
            with obs_trace.span("dispatch", cores=size,
                                device=self._dev):
                faults.maybe_raise("dispatch", tier=cp.mode, cores=size,
                                   device=self._dev)
                shared_out, _, _ = cp.run_light_dev(shared_dev, tdx_dev,
                                                    self.device)
            t_sync = time.perf_counter()
            with obs_trace.span("device_sync"):
                hang = faults.hang_seconds("device_sync", tier=cp.mode,
                                           device=self._dev)
                if hang:
                    time.sleep(hang)
                shared_out.block_until_ready()
            t_done = time.perf_counter()
            self._m.observe("fleet_dispatch_seconds",
                            t_sync - t_disp, tier=cp.mode,
                            device=self._dev)
            self._m.observe("fleet_device_sync_seconds",
                            t_done - t_sync, tier=cp.mode,
                            device=self._dev)
            wall = time.perf_counter() - t0 - compile_s
            with obs_trace.span("collect"):
                self._collect_light(cp, shared_out, chunk, real, wall,
                                    results)

    def _run_interp_unit(self, batch: list[FleetJob],
                         results: dict[int, JobResult]) -> None:
        """One interpreter-tier batch: padded with STOP filler jobs."""
        real = len(batch)
        with obs_trace.span("batch", tier="interp", jobs=real):
            pad = self.batch_size - real
            batch = batch + [self._filler()] * pad
            t0 = time.perf_counter()
            with obs_trace.span("pack"):
                states = _batch_init_state(self.cfg, batch)
            timings: dict = {}
            final = fleet_run([j.image for j in batch], states,
                              validate=self.validate, timings=timings,
                              device=self.device)
            # one-time XLA compile cost, split out of execution wall
            self._m.inc("fleet_compile_seconds_total",
                        timings["compile_s"])
            wall = time.perf_counter() - t0 - timings["compile_s"]
            with obs_trace.span("collect"):
                self._collect(final, batch, real, wall, results)

    def drain(self) -> dict[int, JobResult]:
        """Run every queued job; returns ``{handle: JobResult}``.

        Crash-safe: if a batch raises, every job whose result has not
        been collected yet (including the failing batch's) is re-queued
        in submission order before the exception propagates, and results
        already computed by the failed drain are stashed — with content
        checksums, re-verified at delivery — and delivered by the next
        ``drain()``.  A failed drain loses no work, computed or queued,
        and a result corrupted while stashed is re-executed, never
        served.
        """
        return self._drain_traced(isolate=False)[0]

    def drain_isolated(self) -> tuple[dict[int, JobResult],
                                      dict[int, Exception]]:
        """Run every queued job, **containing** failures instead of
        aborting the drain: a failing multi-job unit is bisected (one
        poison job cannot starve its cohort), a single failing compiled
        job is retried down the tier chain (superblock -> blocks ->
        interpreter), and a job that fails on every tier lands in the
        returned failures dict.  Returns ``(results, failures)`` —
        every drained handle appears in exactly one of the two.  This
        is the serving front-end's drain
        (:class:`repro.fleet.service.FleetService`)."""
        return self._drain_traced(isolate=True)

    def _drain_traced(self, isolate: bool):
        # the registry rides the ambient contextvar through the drain
        # so leaf code (engine dispatch walls, runner-cache lookups,
        # fault sites) reports without signature plumbing
        with self._m.installed():
            if self.tracer is None:
                return self._drain(isolate)
            with self.tracer:            # install for nested spans
                out = self._drain(isolate)
        if self._trace_path is not None:
            self.tracer.save(self._trace_path)
        return out

    def _take_salvaged(self) -> tuple[dict[int, JobResult],
                                      dict[int, FleetJob]]:
        """Deliverable stashed results from a previously failed drain,
        after re-verifying each against the checksum recorded when it
        was stashed: a corrupted result is dropped (``stats.
        salvage_dropped``) and its job re-queued — re-executed by this
        very drain — so corruption costs a re-run, never a wrong
        answer."""
        results: dict[int, JobResult] = {}
        jobs_map: dict[int, FleetJob] = {}
        dropped: list[FleetJob] = []
        for h, r in self._salvaged.items():
            job = self._salvage_jobs.get(h)
            if _result_checksum(r) != self._salvage_sums.get(h):
                self._m.inc("fleet_salvage_dropped_total")
                self._event("salvage_corrupt", cat="serve", handle=h)
                if job is not None:
                    dropped.append(job)
                continue
            results[h] = r
            if job is not None:
                jobs_map[h] = job
        self._salvaged, self._salvage_sums, self._salvage_jobs = {}, {}, {}
        if dropped:                      # oldest first, ahead of the queue
            dropped.sort(key=lambda j: j.handle)
            self._queue = dropped + self._queue
        return results, jobs_map

    def _stash_salvage(self, results: dict[int, JobResult],
                       delivered_jobs: dict[int, FleetJob],
                       all_jobs: list[FleetJob]) -> None:
        """Stash computed results for the next drain, checksummed so
        delivery can detect corruption while stashed (the
        ``salvage_corrupt`` fault site flips a bit here — *after* the
        checksum — to prove exactly that)."""
        jobs_map = {h: j for h, j in delivered_jobs.items()
                    if h in results}
        jobs_map.update({j.handle: j for j in all_jobs
                         if j.handle in results})
        sums = {h: _result_checksum(r) for h, r in results.items()}
        if results and faults.fire("salvage_corrupt") is not None:
            r = results[min(results)]
            r.shared = r.shared.copy()   # don't touch the batch's base
            r.shared[0] ^= 1
        self._salvaged = results
        self._salvage_sums = sums
        self._salvage_jobs = jobs_map

    def _run_unit_isolated(self, cp, jobs: list[FleetJob],
                           results: dict[int, JobResult],
                           failures: dict[int, Exception]) -> None:
        """One unit with failure isolation: a failing multi-job unit is
        bisected (same tier) so one poison job cannot starve its
        cohort; a single failing compiled job is retried down the tier
        chain before being recorded in ``failures``."""
        if self._cancelled:              # also stops bisection chains
            raise DrainCancelled("drain cancelled")
        try:
            if cp is not None:
                self._run_compiled_unit(cp, jobs, results)
            else:
                self._run_interp_unit(jobs, results)
            return
        except Exception as e:
            err = e
        tier = cp.mode if cp is not None else "interp"
        tr = self._trace()
        if len(jobs) > 1:
            self._m.inc("fleet_bisections_total")
            self._event("batch_bisect", cat="serve", jobs=len(jobs),
                        tier=tier, error=type(err).__name__)
            mid = len(jobs) // 2
            self._run_unit_isolated(cp, jobs[:mid], results, failures)
            self._run_unit_isolated(cp, jobs[mid:], results, failures)
            return
        ncp, next_tier, degradable = self._next_tier(cp)
        if degradable:
            self._degrade(tier, next_tier, 1, err)
            self._run_unit_isolated(ncp, jobs, results, failures)
            return
        job = jobs[0]
        failures[job.handle] = err
        self._event("job_failed", cat="serve", handle=job.handle,
                    tier=tier, error=type(err).__name__)
        if tr is not None:
            tr.async_end("job", id=job.handle,
                         error=type(err).__name__)

    def _next_tier(self, cp):
        """The tier below ``cp`` for a single-job degraded retry:
        superblock -> blocks -> interpreter -> (exhausted).  Returns
        ``(compiled_or_None, tier_name, degradable)``."""
        if cp is None:
            return None, "", False       # interpreter already: exhausted
        if cp.mode == "superblock":
            try:
                ncp = compile_program(cp.image, cp.threads,
                                      validate=self.validate,
                                      policy=self.tier_policy,
                                      mode="blocks")
                return ncp, "blocks", True
            except Exception:            # blocks compile also failing
                return None, "interp", True
        return None, "interp", True

    def _drain(self, isolate: bool = False):
        results, delivered_jobs = self._take_salvaged()
        n_salvaged = len(results)        # counted only on delivery
        failures: dict[int, Exception] = {}
        all_jobs = self._queue
        self._queue = []
        units: list[tuple] | None = None
        idx = 0

        with obs_trace.span("drain", jobs=len(all_jobs)) as dsp:
            try:
                jobs = all_jobs
                compiled_groups: list = []
                if self.use_compiler:
                    with obs_trace.span("partition", jobs=len(all_jobs)):
                        compiled_groups, jobs = \
                            self._split_compilable(jobs)

                # units hold *real* jobs only (padding happens at run
                # time), so the units not yet collected are exactly what
                # a failure must put back on the queue.
                with obs_trace.span("bucket"):
                    units = []
                    for cp, group in compiled_groups:
                        for i in range(0, len(group), self.batch_size):
                            units.append(
                                (cp, group[i:i + self.batch_size]))
                    units.extend((None, batch)
                                 for batch in self._batches(jobs))

                for idx, (cp, unit_jobs) in enumerate(units):
                    if self._cancelled:
                        raise DrainCancelled("drain cancelled")
                    if isolate:
                        self._run_unit_isolated(cp, unit_jobs, results,
                                                failures)
                    elif cp is not None:
                        self._run_compiled_unit(cp, unit_jobs, results)
                    else:
                        self._run_interp_unit(unit_jobs, results)
            except BaseException:
                if units is None:            # failed while partitioning
                    unprocessed = list(all_jobs)
                else:
                    unprocessed = [j for _, us in units[idx:] for j in us
                                   if j.handle not in results
                                   and j.handle not in failures]
                unprocessed.sort(key=lambda j: j.handle)
                self._queue = unprocessed + self._queue
                self._stash_salvage(results, delivered_jobs, all_jobs)
                raise

            tr = obs_trace.current_tracer()
            if tr is not None:           # per-drain counter rollup
                agg = obs_counters.aggregate(
                    r.counters for r in results.values())
                if agg is not None:
                    flat = agg.flat()
                    tr.event("drain_counters", **flat)
                    tr.add_counters(flat)
                if dsp.active:
                    dsp.set(delivered=len(results),
                            failed=len(failures),
                            batches=len(units))
        # salvaged results were computed (and counted into jobs/wall_s/
        # tier splits) by the drain that ran them; delivery only marks
        # them so per-drain consumers don't double-dip the timing
        if n_salvaged:
            self._m.inc("fleet_salvaged_jobs_total", n_salvaged)
        return results, failures
