"""Fleet engine: batched multi-core eGPU execution.

Simulates N homogeneous eGPU cores in lock-step by ``jax.vmap``-ing the
single-core step function (:func:`repro.core.executor.make_step`) over a
batch of :class:`~repro.core.machine.MachineState`s, and schedules
heterogeneous jobs — different programs, per-job runtime thread counts
(the paper's dynamic scalability), per-job shared-memory images — into
fixed-shape batches that execute in one XLA dispatch.

This is the multi-core regime of the paper's follow-up work ("A 950 MHz
SIMT Soft Processor" scales the same microarchitecture to arrays of
cores) and what throughput studies against IP cores need.

    from repro.fleet import Fleet
    fleet = Fleet(cfg, batch_size=32)
    h = fleet.submit(image, shared_init=data, threads=256)
    results = fleet.drain()
    results[h].shared_f32()

For always-on serving (per-job futures, deadlines, priorities, retries
with backoff, bounded admission, deterministic fault injection):

    from repro.fleet import FleetService, FaultPlan
    with FleetService(cfg, batch_size=32, max_delay_s=0.002) as svc:
        fut = svc.submit(image, data, deadline_s=0.5)
        fut.result()                     # JobResult, or raises JobError
"""
from .api import Fleet, run_jobs, serve_jobs
from .devices import balance_units, device_label, fleet_devices, make_job_mesh
from .engine import ResidencyCache, fleet_run, stack_states, unstack_state
from .faults import FAULT_SITES, FaultPlan, FaultSpec, InjectedFault
from .scheduler import (FleetJob, FleetScheduler, FleetStats, JobResult,
                        check_job)
from .service import (AdmissionError, FleetService, JobError, ServiceStats,
                      register_serve_metrics)
from .sharded import ShardedFleetScheduler

__all__ = [
    "Fleet", "run_jobs", "serve_jobs", "fleet_run", "stack_states",
    "unstack_state", "FleetJob", "FleetScheduler", "FleetStats",
    "JobResult", "ResidencyCache", "check_job",
    "ShardedFleetScheduler", "fleet_devices", "device_label",
    "make_job_mesh", "balance_units",
    "FleetService", "ServiceStats", "JobError", "AdmissionError",
    "register_serve_metrics",
    "FaultPlan", "FaultSpec", "InjectedFault", "FAULT_SITES",
]
