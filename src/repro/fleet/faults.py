"""Deterministic fault injection for the fleet serving stack.

A :class:`FaultPlan` is a seeded schedule of failures threaded through
the scheduler/engine hook points (``with plan: ...`` installs it into a
contextvar, exactly like :class:`repro.obs.Tracer`).  Each hook names a
**site**; the plan decides — reproducibly, from its seed and the
encounter order — whether that visit faults:

=================== =====================================================
site                effect at the hook point
=================== =====================================================
``compile``         :class:`InjectedFault` raised after a tier compile
                    (the scheduler degrades the unit down the tier
                    chain: superblock -> blocks -> interpreter)
``dispatch``        :class:`InjectedFault` raised in place of a batch
                    dispatch (the isolated drain bisects the batch;
                    the service retries with backoff)
``device_sync``     the device sync stalls for ``hang_s`` seconds
                    (exercises the service's dispatch watchdog/timeout)
``residency_evict`` the device-resident input cache is dropped (must be
                    a harmless miss, never an error)
``salvage_corrupt`` one stashed salvaged result has a bit flipped while
                    it waits for the next drain (proves the salvage
                    path's delivery checksums catch corruption)
``device_fail``     a whole device is declared dead at the top of a
                    per-device dispatch: the multi-device service marks
                    it unhealthy, its dispatcher exits, and the cohort
                    re-enters the shared queue for the surviving
                    devices (proves capacity — not availability — is
                    what a dead device costs).  Pair with
                    ``where={"device": "cpu:2"}`` to kill one device.
=================== =====================================================

Sites the plan does not mention never fault, and with no plan installed
every hook is a no-op (one contextvar read), so production paths pay
nothing.  Every injection is logged on the plan (``plan.injected``,
``plan.log``) and emitted as a ``fault_injected`` trace event, so a
chaos run's outcome is auditable in the Perfetto trace.

    plan = FaultPlan(seed=7, dispatch=0.05,
                     compile={"p": 1.0, "count": 2, "where": {"tier": "superblock"}},
                     device_sync={"p": 0.01, "hang_s": 0.5})
    with plan:
        service.submit(...); ...
    plan.injected            # {"dispatch": 3, "compile": 2, ...}
"""
from __future__ import annotations

import contextvars
import dataclasses
import hashlib
import threading
from typing import Any, Mapping

import numpy as np

from ..obs import metrics as obs_metrics
from ..obs import recorder as obs_recorder
from ..obs import trace as obs_trace

__all__ = [
    "FAULT_SITES", "FaultPlan", "FaultSpec", "InjectedFault",
    "current_plan", "fire", "maybe_raise", "hang_seconds",
]

#: every hook point the fleet stack exposes (a plan naming anything
#: else is a typo and is rejected at construction)
FAULT_SITES = ("compile", "dispatch", "device_sync", "residency_evict",
               "salvage_corrupt", "device_fail")


class InjectedFault(RuntimeError):
    """A failure injected by the active :class:`FaultPlan`.

    Deliberately a plain ``RuntimeError`` subclass: the recovery paths
    under test (tier degradation, bisection, retries) must treat it
    like any unexpected production failure, not special-case it.
    """

    def __init__(self, site: str, info: dict | None = None):
        self.site = site
        self.info = dict(info or {})
        extra = "".join(f" {k}={v}" for k, v in self.info.items())
        super().__init__(f"injected fault at {site}{extra}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """How one site faults.

    ``p`` is the per-encounter injection probability; ``count`` caps the
    total injections at the site (``None`` = unlimited); ``after`` skips
    the first N matching encounters (deterministic "fail the Kth
    dispatch" plans); ``where`` filters on the hook's keyword info (e.g.
    ``{"tier": "superblock"}`` faults only superblock compiles);
    ``hang_s`` is the stall length for ``device_sync``.
    """

    p: float = 1.0
    count: int | None = None
    after: int = 0
    hang_s: float = 0.0
    where: Mapping[str, Any] | None = None


class FaultPlan:
    """A seeded, deterministic fault schedule (contextvar-installed).

    Construct with ``site=<p>`` shorthand or ``site={...}`` /
    ``site=FaultSpec(...)`` for the full knobs.  Two runs with the same
    seed, plan, and encounter order inject identical faults — the rng
    streams are derived per-site from the seed, so sites never perturb
    each other.  ``fire``/``maybe_raise``/``hang_seconds`` are the hook
    entry points (normally called via the module-level helpers).
    """

    def __init__(self, seed: int = 0, **sites: float | dict | FaultSpec):
        self.seed = int(seed)
        self.specs: dict[str, FaultSpec] = {}
        for site, spec in sites.items():
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; one of {FAULT_SITES}")
            if isinstance(spec, FaultSpec):
                pass
            elif isinstance(spec, Mapping):
                spec = FaultSpec(**spec)
            else:
                spec = FaultSpec(p=float(spec))
            self.specs[site] = spec
        # independent, order-insensitive streams: seed ^ blake2(site)
        self._rngs = {
            site: np.random.default_rng(self.seed ^ int.from_bytes(
                hashlib.blake2b(site.encode(), digest_size=8).digest(),
                "little"))
            for site in self.specs}
        #: per-site counts of hook visits / actual injections
        self.encounters: dict[str, int] = {s: 0 for s in self.specs}
        self.injected: dict[str, int] = {s: 0 for s in self.specs}
        #: every injection, in order, with the hook's info kwargs
        self.log: list[dict] = []
        self._lock = threading.Lock()
        # per-thread token stacks: contextvar reset tokens are only
        # valid in the context that set them, and one plan may be
        # entered concurrently from many dispatcher threads
        self._tokens = threading.local()

    # ------------------------------------------------------ activation
    def __enter__(self) -> "FaultPlan":
        stack = getattr(self._tokens, "stack", None)
        if stack is None:
            stack = self._tokens.stack = []
        stack.append(_PLAN.set(self))
        return self

    def __exit__(self, *exc) -> bool:
        _PLAN.reset(self._tokens.stack.pop())
        return False

    # ----------------------------------------------------------- hooks
    def fire(self, site: str, **info) -> FaultSpec | None:
        """Roll the site's dice for this encounter; returns the spec
        when a fault should be injected now, else ``None``."""
        spec = self.specs.get(site)
        if spec is None:
            return None
        if spec.where is not None and any(
                info.get(k) != v for k, v in spec.where.items()):
            return None
        with self._lock:
            self.encounters[site] += 1
            if self.encounters[site] <= spec.after:
                return None
            if spec.count is not None and self.injected[site] >= spec.count:
                return None
            if spec.p < 1.0 and self._rngs[site].random() >= spec.p:
                return None
            self.injected[site] += 1
            self.log.append({"site": site, "n": self.injected[site], **info})
        # the trace event also lands in the ambient flight recorder;
        # the counter and the blackbox dump make every injection
        # observable in always-on production telemetry too
        obs_trace.event("fault_injected", cat="fault", site=site, **info)
        obs_metrics.inc("serve_faults_injected_total", fault_site=site)
        obs_recorder.trigger(f"fault_{site}", fault_site=site, **info)
        return spec

    def total_injected(self) -> int:
        return sum(self.injected.values())


_PLAN: contextvars.ContextVar["FaultPlan | None"] = \
    contextvars.ContextVar("repro_fleet_fault_plan", default=None)


def current_plan() -> FaultPlan | None:
    """The fault plan installed in the current context, or ``None``."""
    return _PLAN.get()


def fire(site: str, **info) -> FaultSpec | None:
    """Hook: does the ambient plan (if any) fault this visit?"""
    plan = _PLAN.get()
    return plan.fire(site, **info) if plan is not None else None


def maybe_raise(site: str, **info) -> None:
    """Hook: raise :class:`InjectedFault` when the ambient plan says so."""
    if fire(site, **info) is not None:
        raise InjectedFault(site, info)


def hang_seconds(site: str, **info) -> float:
    """Hook: how long this visit should stall (0.0 = no fault)."""
    spec = fire(site, **info)
    return spec.hang_s if spec is not None else 0.0
