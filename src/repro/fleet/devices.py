"""Device topology + cost balancing for the multi-device fleet.

The fleet shards its job stream across every local accelerator.  This
module owns the three primitives everything above builds on:

* **resolution** — ``fleet_devices(spec)`` turns a user-facing device
  spec (``None``/``"all"``/count/explicit list) into a concrete tuple of
  jax devices, with an actionable error naming the
  ``--xla_force_host_platform_device_count`` recipe when a CPU-only box
  has fewer devices than asked for;
* **the job mesh** — ``make_job_mesh(devices)`` builds the 1-D
  ``("jobs",)`` mesh that same-program megabatches ``shard_map`` over
  (the batch axis is the *job* axis: every row is an independent core,
  so splitting it across devices is bit-identical to the single-device
  dispatch);
* **balancing** — ``balance_units(units, n, cost)`` greedily assigns
  routing units (same-program job groups) to the least-loaded device by
  the cost model's per-job estimates, keeping each group on one device
  so its ResidencyCache and AOT compile-cache entries stay warm.

Everything here is topology-only: no dispatch, no state.  The sharded
scheduler (``fleet/sharded.py``) and the serving layer
(``fleet/service.py``) compose these with per-device
``FleetScheduler`` instances.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

DeviceSpec = Any  # None | int | "all" | Device | Sequence[Device]


def device_label(dev) -> str:
    """Stable metrics/trace label for a device: ``"cpu:0"``, ``"gpu:1"``.

    ``None`` (an unpinned scheduler) maps to ``"default"`` so the
    degenerate single-device fleet never touches jax device state just
    to label a metric.
    """
    if dev is None:
        return "default"
    return f"{dev.platform}:{dev.id}"


def _oversubscribed(requested: int, available: int, what: str) -> ValueError:
    return ValueError(
        f"{what} needs {requested} devices but only {available} "
        f"{'is' if available == 1 else 'are'} visible to jax. On a "
        "CPU-only host, export "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={requested} "
        "before the first jax import (see README 'Multi-device')."
    )


def fleet_devices(spec: DeviceSpec = "all"):
    """Resolve a device spec to a concrete tuple of jax devices.

    * ``"all"`` / ``None`` — every local device, in ``jax.devices()``
      order;
    * an ``int`` N — the first N local devices (raises with the
      ``xla_force_host_platform_device_count`` recipe if fewer exist);
    * a single device or a sequence of devices — used as given.
    """
    if spec is None or spec == "all":
        return tuple(jax.devices())
    if isinstance(spec, int):
        if spec < 1:
            raise ValueError(f"device count must be >= 1, got {spec}")
        devs = jax.devices()
        if spec > len(devs):
            raise _oversubscribed(spec, len(devs), f"devices={spec}")
        return tuple(devs[:spec])
    if hasattr(spec, "platform") and hasattr(spec, "id"):
        return (spec,)
    devs = tuple(spec)
    if not devs:
        raise ValueError("devices= must name at least one device")
    return devs


def make_job_mesh(devices: Sequence[Any]):
    """1-D mesh over ``devices`` with the single axis ``"jobs"``.

    Same-program megabatches shard their leading (job) axis over this
    mesh; every other array axis is replicated.
    """
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices, dtype=object), ("jobs",))


def balance_units(
    units: Sequence[Any],
    n_devices: int,
    cost: Callable[[Any], float],
) -> list[list[Any]]:
    """Greedy least-loaded assignment of routing units to devices.

    Units are sorted by descending cost (LPT scheduling) and each is
    placed on the currently least-loaded device, so a heterogeneous mix
    spreads by the cost model's estimates rather than round-robin.
    Returns ``n_devices`` lists (some possibly empty).  Ties break on
    device index so the assignment is deterministic.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    lanes: list[list[Any]] = [[] for _ in range(n_devices)]
    if n_devices == 1:
        lanes[0].extend(units)
        return lanes
    load = [0.0] * n_devices
    order = sorted(range(len(units)), key=lambda i: -float(cost(units[i])))
    for i in order:
        k = min(range(n_devices), key=lambda d: (load[d], d))
        lanes[k].append(units[i])
        load[k] += float(cost(units[i]))
    # preserve submission order within each lane (drain order stability)
    index = {id(u): i for i, u in enumerate(units)}
    for lane in lanes:
        lane.sort(key=lambda u: index[id(u)])
    return lanes
