"""A NumPy reference executor for whole-program verification.

The JAX interpreter is the semantic ground truth, but it pays an XLA
compile per program — far too slow to run the analyzer's soundness
suite over hundreds of *generated* programs.  This module walks the same
static path with plain NumPy (the eGPU has no data-dependent branches,
so control flow is a host loop) and, unlike the JAX tiers, it *observes*
what the analyzer predicts:

* every LOD/STO effective address per pc (min/max over active threads,
  plus whether any active thread went out of bounds),
* peak predicate/loop/call stack depths and every underflow/overflow
  attempt,
* executed steps (to check the analyzer's static step count).

Data semantics mirror ``repro.core.semantics`` bit-for-bit for the
integer ISA (the differential test in ``tests/`` cross-checks whole
machine states against the interpreter); FP ops are implemented
best-effort with NumPy float32 and are exact for add/sub/mul/min/max on
the CPU backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import isa
from ..core.assembler import ProgramImage
from ..core.isa import Op, Typ

_U32 = np.uint32
_I32 = np.int32
_IF_SET = frozenset(int(o) for o in isa.IF_OPS)


def _f32(x):
    return x.view(np.float32)


def _bits(x):
    return np.asarray(x, np.float32).view(_U32)


def _sext16(x):
    v = (x & _U32(0xFFFF)).astype(np.int64)
    return np.where(v >= 1 << 15, v - (1 << 16), v)


def _sext24(x):
    v = (x & _U32(0xFFFFFF)).astype(np.int64)
    return np.where(v >= 1 << 23, v - (1 << 24), v)


def _bitrev32(x):
    x = ((x & _U32(0x55555555)) << _U32(1)) | ((x >> _U32(1)) & _U32(0x55555555))
    x = ((x & _U32(0x33333333)) << _U32(2)) | ((x >> _U32(2)) & _U32(0x33333333))
    x = ((x & _U32(0x0F0F0F0F)) << _U32(4)) | ((x >> _U32(4)) & _U32(0x0F0F0F0F))
    x = ((x & _U32(0x00FF00FF)) << _U32(8)) | ((x >> _U32(8)) & _U32(0x00FF00FF))
    return (x << _U32(16)) | (x >> _U32(16))


def _det_sum(v, num_sps: int):
    """The deterministic DOT/SUM reduction order (see semantics.det_sum)."""
    T = v.shape[-1]
    m = v.reshape(T // num_sps, num_sps)
    acc = m[0].copy()
    for i in range(1, T // num_sps):
        acc = acc + m[i]
    s = num_sps // 2
    while s >= 1:
        acc = acc[:s] + acc[s:2 * s]
        s //= 2
    return acc[0]


@dataclass
class ConcreteResult:
    """Everything the soundness tests compare against the analyzer."""

    halted: bool
    steps: int
    regs: np.ndarray                    # (T, R) uint32
    shared: np.ndarray                  # (S,) uint32
    #: pc -> (min, max) effective address over active threads
    observed_addr: dict[int, tuple[int, int]] = field(default_factory=dict)
    #: pcs where some active thread addressed outside [0, shared_words)
    oob_pcs: set[int] = field(default_factory=set)
    max_pred_depth: int = 0
    max_loop_depth: int = 0
    max_call_depth: int = 0
    #: attempted pushes beyond / pops below the configured stack limits
    stack_faults: set[str] = field(default_factory=set)
    executed_pcs: set[int] = field(default_factory=set)


def concrete_run(image: ProgramImage, threads: int | None = None, *,
                 tdx_dim: int = 16, shared_init: np.ndarray | None = None,
                 max_steps: int | None = None) -> ConcreteResult:
    cfg = image.cfg
    if threads is None:
        threads = image.threads_active or cfg.max_threads
    T, R, S = cfg.max_threads, cfg.regs_per_thread, cfg.shared_words
    LD, CD = cfg.max_loop_depth, cfg.max_call_depth
    D = max(1, cfg.predicate_levels)
    num_sps = cfg.num_sps
    w_rt = -(-threads // num_sps)
    wfs_by_depth = (1, w_rt, max(1, -(-w_rt // 2)), max(1, -(-w_rt // 4)))
    alu_mask = _U32((1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32
                    else 0xFFFFFFFF)
    amt_mask = _U32(cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)
    cap = cfg.max_steps if max_steps is None else max_steps

    regs = np.zeros((T, R), _U32)
    shared = np.zeros(S, _U32)
    if shared_init is not None:
        buf = np.asarray(shared_init)
        if buf.dtype != _U32:
            buf = buf.astype(np.float32).view(_U32) \
                if buf.dtype.kind == "f" else buf.astype(_U32)
        shared[:len(buf)] = buf[:S]
    pstack = np.zeros((T, D), bool)
    pdepth = np.zeros(T, _I32)
    lctr = np.zeros(LD, np.int64)
    cstack = np.zeros(CD, np.int64)
    lsp = csp = 0
    tid = np.arange(T)
    lvl = np.arange(D)

    res = ConcreteResult(halted=False, steps=0, regs=regs, shared=shared)
    n = image.n
    op_a, typ_a, rd_a = image.op, image.typ, image.rd
    ra_a, rb_a, imm_a, tsc_a = image.ra, image.rb, image.imm, image.tsc
    pc = steps = 0

    def gidx(i: int, m: int) -> int:
        if i < 0:
            i += m
        return min(max(i, 0), m - 1)

    with np.errstate(over="ignore", invalid="ignore", divide="ignore"):
        while 0 <= pc < n and steps < cap:
            op = int(op_a[pc])
            typ, rd = int(typ_a[pc]), int(rd_a[pc])
            ra, rb = int(ra_a[pc]), int(rb_a[pc])
            imm, tsc = int(imm_a[pc]), int(tsc_a[pc])
            res.executed_pcs.add(pc)
            lanes = isa.WIDTH_LANES[(tsc >> 2) & 3]
            wfs = wfs_by_depth[tsc & 3]
            tsc_mask = ((tid % num_sps < lanes) & (tid // num_sps < wfs)
                        & (tid < threads))
            pok = np.all(pstack | (lvl >= pdepth[:, None]), axis=-1)
            mask = tsc_mask & pok
            av, bv, dv = regs[:, ra], regs[:, rb], regs[:, rd]
            signed = typ == int(Typ.I32)
            steps += 1

            if op == int(Op.STOP):
                res.halted = True
                break
            if op == int(Op.JMP):
                pc = imm
                continue
            if op == int(Op.JSR):
                if csp >= CD:
                    res.stack_faults.add("call-overflow")
                else:
                    cstack[csp] = pc + 1
                csp += 1
                res.max_call_depth = max(res.max_call_depth, csp)
                pc = imm
                continue
            if op == int(Op.RTS):
                if csp <= 0:
                    res.stack_faults.add("call-underflow")
                pc = int(cstack[gidx(csp - 1, CD)])
                csp -= 1
                continue
            if op == int(Op.INIT):
                if lsp >= LD:
                    res.stack_faults.add("loop-overflow")
                else:
                    lctr[lsp] = imm
                lsp += 1
                res.max_loop_depth = max(res.max_loop_depth, lsp)
                pc += 1
                continue
            if op == int(Op.LOOP):
                if lsp <= 0:
                    res.stack_faults.add("loop-underflow")
                ltop = int(lctr[gidx(lsp - 1, LD)])
                if 0 <= lsp - 1 < LD:
                    lctr[lsp - 1] = ltop - 1
                if ltop > 0:
                    pc = imm
                else:
                    lsp -= 1
                    pc += 1
                continue
            if op == int(Op.NOP):
                pc += 1
                continue

            # ---- predicate ops
            if op in _IF_SET:
                cond = _if_cond(op, av, bv)
                oh = (lvl == pdepth[:, None]) & tsc_mask[:, None]
                pstack[:] = np.where(oh, cond[:, None], pstack)
                if np.any(tsc_mask & (pdepth >= D)):
                    res.stack_faults.add("pred-overflow")
                pdepth += np.where(tsc_mask & (pdepth < D), 1, 0)
                res.max_pred_depth = max(res.max_pred_depth,
                                         int(pdepth.max()))
                pc += 1
                continue
            if op == int(Op.ELSE):
                if np.any(tsc_mask & (pdepth == 0)):
                    res.stack_faults.add("pred-underflow")
                oh = (lvl == (pdepth[:, None] - 1)) & tsc_mask[:, None] \
                    & (pdepth[:, None] > 0)
                pstack[:] = pstack ^ oh
                pc += 1
                continue
            if op == int(Op.ENDIF):
                if np.any(tsc_mask & (pdepth == 0)):
                    res.stack_faults.add("pred-underflow")
                pdepth -= np.where(tsc_mask & (pdepth > 0), 1, 0)
                pc += 1
                continue

            # ---- memory
            if op in (int(Op.LOD), int(Op.STO)):
                addr = av.astype(_I32).astype(np.int64) + imm
                act = addr[mask]
                if len(act):
                    key = (int(act.min()), int(act.max()))
                    old = res.observed_addr.get(pc)
                    res.observed_addr[pc] = key if old is None else \
                        (min(old[0], key[0]), max(old[1], key[1]))
                    if key[0] < 0 or key[1] >= S:
                        res.oob_pcs.add(pc)
                if op == int(Op.LOD):
                    a = np.clip(addr, 0, S - 1)
                    val = shared[a]
                    regs[:, rd] = np.where(mask, val, dv)
                else:
                    ok = mask & (addr >= 0) & (addr < S)
                    shared[addr[ok]] = regs[ok, rd]
                pc += 1
                continue

            # ---- value ops
            val = _value(op, typ, signed, av, bv, imm, tid, tdx_dim,
                         mask, num_sps, alu_mask, amt_mask, cfg)
            if val is not None:
                wmask = mask & (tid == 0) \
                    if op in (int(Op.DOT), int(Op.SUM)) else mask
                regs[:, rd] = np.where(wmask, val, dv)
            pc += 1

    res.steps = steps
    if not res.halted and not (0 <= pc < n):
        res.halted = True      # fell into the padded STOP tail
    return res


def _if_cond(op: int, av, bv):
    fa, fb = _f32(av), _f32(bv)
    ia, ib = av.astype(_I32), bv.astype(_I32)
    table = {
        int(Op.IF_EQ): av == bv, int(Op.IF_NE): av != bv,
        int(Op.IF_LT): ia < ib, int(Op.IF_LO): av < bv,
        int(Op.IF_LE): ia <= ib, int(Op.IF_LS): av <= bv,
        int(Op.IF_GT): ia > ib, int(Op.IF_HI): av > bv,
        int(Op.IF_GE): ia >= ib, int(Op.IF_HS): av >= bv,
        int(Op.IF_FEQ): fa == fb, int(Op.IF_FNE): fa != fb,
        int(Op.IF_FLT): fa < fb, int(Op.IF_FLE): fa <= fb,
        int(Op.IF_FGT): fa > fb, int(Op.IF_FGE): fa >= fb,
        int(Op.IF_Z): av == 0, int(Op.IF_NZ): av != 0,
    }
    return table[op]


def _value(op, typ, signed, av, bv, imm, tid, tdx_dim, mask, num_sps,
           alu_mask, amt_mask, cfg):
    """Result vector of one value op, or None for non-writing ops."""
    def im(x):
        return x.astype(_U32) & alu_mask

    amt = (bv & amt_mask).astype(np.uint64)
    if op == int(Op.ADD):
        return im(av + bv)
    if op == int(Op.SUB):
        return im(av - bv)
    if op == int(Op.NEG):
        return im((-av.astype(_I32)).astype(_U32))
    if op == int(Op.ABS):
        return im(np.abs(av.astype(_I32)).astype(_U32))
    if op == int(Op.MUL16LO):
        p_s = _sext16(av) * _sext16(bv)
        p_u = (av & _U32(0xFFFF)).astype(np.uint64) * (bv & _U32(0xFFFF))
        return im((p_s if signed else p_u) & 0xFFFFFFFF)
    if op == int(Op.MUL16HI):
        p_s = (_sext16(av) * _sext16(bv)) >> 16
        p_u = (((av & _U32(0xFFFF)).astype(np.uint64)
                * (bv & _U32(0xFFFF))) & 0xFFFFFFFF) >> 16
        return im((p_s if signed else p_u.astype(np.int64)) & 0xFFFFFFFF)
    if op == int(Op.MUL24LO):
        p = (_sext24(av) * _sext24(bv)) if signed else \
            (av & _U32(0xFFFFFF)).astype(np.int64) * (bv & _U32(0xFFFFFF))
        return im(p & 0xFFFFFFFF)
    if op == int(Op.MUL24HI):
        if signed:
            return im(((_sext24(av) * _sext24(bv)) >> 24) & 0xFFFFFFFF)
        p = (av & _U32(0xFFFFFF)).astype(np.int64) * (bv & _U32(0xFFFFFF))
        return im(p >> 24)
    if op == int(Op.AND):
        return im(av & bv)
    if op == int(Op.OR):
        return im(av | bv)
    if op == int(Op.XOR):
        return im(av ^ bv)
    if op == int(Op.NOT):
        return im(~av)
    if op == int(Op.CNOT):
        return im(np.where(av == 0, _U32(1), _U32(0)))
    if op == int(Op.BVS):
        return im(_bitrev32(av))
    if op == int(Op.SHL):
        return im((av.astype(np.uint64) << amt) & 0xFFFFFFFF)
    if op == int(Op.SHR):
        if signed:
            return im((av.astype(_I32).astype(np.int64) >> amt.astype(
                np.int64)).astype(np.int64) & 0xFFFFFFFF)
        return im(av.astype(np.uint64) >> amt)
    if op == int(Op.POP):
        return im(np.array([bin(int(v)).count("1") for v in av],
                           np.uint32))
    if op == int(Op.MAX):
        return im(np.where(av.astype(_I32) > bv.astype(_I32), av, bv)
                  if signed else np.maximum(av, bv))
    if op == int(Op.MIN):
        return im(np.where(av.astype(_I32) < bv.astype(_I32), av, bv)
                  if signed else np.minimum(av, bv))
    if op == int(Op.LODI):
        return im(np.full(av.shape, np.int64(imm) & 0xFFFFFFFF,
                          np.uint64))
    if op == int(Op.TDX):
        return im((tid % max(1, tdx_dim)).astype(_U32))
    if op == int(Op.TDY):
        return im((tid // max(1, tdx_dim)).astype(_U32))
    if op == int(Op.FADD):
        return _bits(_f32(av) + _f32(bv))
    if op == int(Op.FSUB):
        return _bits(_f32(av) - _f32(bv))
    if op == int(Op.FNEG):
        return av ^ _U32(0x80000000)
    if op == int(Op.FABS):
        return av & _U32(0x7FFFFFFF)
    if op == int(Op.FMUL):
        return _bits(_f32(av) * _f32(bv))
    if op == int(Op.FMAX):
        return _bits(np.maximum(_f32(av), _f32(bv)))
    if op == int(Op.FMIN):
        return _bits(np.minimum(_f32(av), _f32(bv)))
    if op == int(Op.DOT):
        s = _det_sum(np.where(mask, _f32(av) * _f32(bv),
                              np.float32(0.0)), num_sps)
        return np.broadcast_to(_bits(s), av.shape)
    if op == int(Op.SUM):
        s = _det_sum(np.where(mask, _f32(av), np.float32(0.0)), num_sps)
        return np.broadcast_to(_bits(s), av.shape)
    if op == int(Op.INVSQR):
        return _bits(np.float32(1.0) / np.sqrt(_f32(av)))
    return None
