"""Admission lint CLI: ``python -m repro.analysis.lint``.

Runs every static pass over the in-repo benchmark suite (or a selected
subset) and renders the structured diagnostics.  Exit status is the
admission contract, so CI can gate on it:

* ``2`` — at least one ERROR-level finding (fleet admission would
  reject the program),
* ``1`` — WARN-level findings only,
* ``0`` — clean at the requested threshold.

``--optimize`` additionally runs the verified optimizer over each
program and reports the transform counts (fold/DCE/NOP deltas); the
differential verifier runs too, so a miscompile fails loudly.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core.config import EGPUConfig
from ..programs import (build_bitonic, build_fft, build_matmul,
                        build_reduction, build_transpose)
from .diagnostics import Severity
from .passes import analyze


def _default_config() -> EGPUConfig:
    """The benchmark instance: full ALU, predicates, both extension
    units — every suite program assembles on it."""
    return EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                      alu_bits=32, shift_bits=32, predicate_levels=4,
                      has_dot=True, has_invsqr=True)


def suite(cfg: EGPUConfig | None = None):
    """The paper-suite benches the lint (and CI) walk, name -> Bench."""
    cfg = cfg or _default_config()
    return [build_reduction(cfg, 32),
            build_reduction(cfg, 32, use_dot=True),
            build_reduction(cfg, 32, no_dynamic=True),
            build_transpose(cfg, 16), build_matmul(cfg, 8),
            build_bitonic(cfg, 16), build_bitonic(cfg, 32),
            build_fft(cfg, 16), build_fft(cfg, 32)]


_SEV = {"info": Severity.INFO, "warn": Severity.WARN,
        "error": Severity.ERROR}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="statically verify the in-repo benchmark suite")
    ap.add_argument("--bench", action="append", default=None,
                    help="lint only benches whose name contains this "
                         "substring (repeatable)")
    ap.add_argument("--min-severity", choices=_SEV, default="info",
                    help="hide findings below this level (default info)")
    ap.add_argument("--fail-on", choices=("error", "warn"), default="warn",
                    help="exit non-zero at this level (default warn)")
    ap.add_argument("--optimize", action="store_true",
                    help="also run the verified optimizer on each bench")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--tdx-dim", type=int, default=None,
                    help="override the TDX grid width (default: each "
                         "bench's own)")
    args = ap.parse_args(argv)

    benches = suite()
    if args.bench:
        benches = [b for b in benches
                   if any(s in b.name for s in args.bench)]
        if not benches:
            print(f"no bench matches {args.bench}", file=sys.stderr)
            return 2

    worst = None
    out = []
    for b in benches:
        tdx = args.tdx_dim if args.tdx_dim is not None else b.tdx_dim
        report = analyze(b.image, tdx_dim=tdx)
        sev = report.max_severity
        if sev is not None and (worst is None or sev > worst):
            worst = sev
        entry = {
            "bench": b.name,
            "instructions": int(b.image.n),
            "counts": report.counts(),
            "static_steps": report.facts.get("static_steps"),
            "proved_accesses": list(report.facts.get("proved_accesses",
                                                     ())),
            "diagnostics": [
                {"severity": d.severity.name, "code": d.code,
                 "pc": d.pc, "message": d.message,
                 "path": list(d.path)}
                for d in report.diagnostics
                if d.severity >= _SEV[args.min_severity]],
        }
        if args.optimize:
            from .optimizer import optimize_image
            r = optimize_image(b.image, tdx_dim=tdx)
            entry["optimizer"] = {
                "changed": r.changed, "rounds": r.rounds,
                "folds": r.folds, "dce_removed": r.dce_removed,
                "instrs": [r.instrs_before, r.instrs_after],
                "nops": [r.nops_before, r.nops_after],
                "reason": r.reason,
            }
        out.append(entry)
        if not args.as_json:
            c = entry["counts"]
            line = (f"== {b.name}: {entry['instructions']} instr, "
                    f"{c['errors']}E/{c['warnings']}W/{c['infos']}I")
            if entry["static_steps"] is not None:
                line += f", static_steps={entry['static_steps']}"
            print(line)
            rendered = report.render(min_severity=_SEV[args.min_severity])
            for ln in rendered.splitlines()[:-1]:
                print("   " + ln)
            if args.optimize:
                o = entry["optimizer"]
                print(f"   optimizer: changed={o['changed']} "
                      f"folds={o['folds']} dce={o['dce_removed']} "
                      f"instrs {o['instrs'][0]}->{o['instrs'][1]} "
                      f"nops {o['nops'][0]}->{o['nops'][1]}"
                      + (f" ({o['reason']})" if o["reason"] else ""))

    if args.as_json:
        print(json.dumps(out, indent=2))
    if worst == Severity.ERROR:
        return 2
    if worst == Severity.WARN and args.fail_on == "warn":
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
