"""CFG dataflow passes over the packed eGPU program image.

One forward worklist fixpoint carries three abstract domains at once —
they share the walk because they feed each other:

* **stacks** — concrete predicate depth, loop-counter stack (values +
  provenance of the INIT that pushed them) and call stack (return
  addresses).  The ISA pushes immediates only, so depths and return
  targets are usually *exactly* known; a join of conflicting depths
  degrades the stack to unknown and reports a balance conflict.
* **register coverage** (reaching definitions per thread-space
  personality) — per register, the set of maximal `(lanes, wavefronts)`
  rectangles definitely written on *every* path.  Thread spaces are
  origin-anchored rectangles in the (lane, wavefront) grid, so "read
  covered by prior writes" reduces to single-rectangle dominance.
* **register intervals** — `[lo, hi]` value ranges over the uint32
  register file, with exact constant evaluation when operands are
  singletons (shared with the optimizer's constant folder) and per-op
  interval rules otherwise.  A predicated or narrow-TSC write *joins*
  with the old value (threads outside the mask keep theirs) — only an
  unpredicated full-space write replaces.

After the fixpoint a single reporting walk over the stable entry states
emits :class:`Diagnostic` objects with path witnesses, then the
structural passes run: unreachable code, halt reachability, structured
trip-count / static step estimation, trace-budget prediction, and a
backward liveness pass for dead writes.
"""
from __future__ import annotations

import threading
from collections import Counter, OrderedDict, deque
from typing import Any

import numpy as np

from ..core import cfg as cfg_mod
from ..core import isa
from ..core.assembler import ProgramImage
from ..core.config import EGPUConfig
from ..core.executor import (_PF_IMM, _PF_OP, _PF_RA, _PF_RB, _PF_RD,
                             _PF_TSC, _PF_TYP)
from ..core.isa import NUM_OPCODES, Op
from .diagnostics import AnalysisReport, Diagnostic, Severity

_M32 = 0xFFFFFFFF

#: semantic read sets (the hazard sets in ``isa`` are conservative: SUM
#: is scheduled as two-source but only reads Ra)
_READS_RA = frozenset(int(o) for o in isa.READS_RA)
_READS_RB = frozenset(int(o) for o in isa.READS_RB if o != Op.SUM)
_READS_RD = frozenset(int(o) for o in isa.READS_RD)
_WRITES = frozenset(int(o) for o in isa.REG_WRITE_OPS)
_IF_OPS = frozenset(int(o) for o in isa.IF_OPS)

#: integer value ops with an exact Python evaluator (= the foldable set)
_INT_EVAL_OPS = frozenset(int(o) for o in (
    Op.ADD, Op.SUB, Op.NEG, Op.ABS, Op.MUL16LO, Op.MUL16HI,
    Op.MUL24LO, Op.MUL24HI, Op.AND, Op.OR, Op.XOR, Op.NOT, Op.CNOT,
    Op.BVS, Op.SHL, Op.SHR, Op.POP, Op.MAX, Op.MIN))

_WIDEN_AT = 8            # joins per block before interval widening
_MAX_BLOCK_EXECS = 20000  # fixpoint budget (blocks are re-run on change)
_WITNESS_CAP = 24


# ---------------------------------------------------------------------------
# Exact integer semantics (Python ints, mirrors ``semantics.build_spec``)
# ---------------------------------------------------------------------------

def _sext(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= 1 << (bits - 1) else v


def eval_int(op: int, typ: int, a: int, b: int, cfg: EGPUConfig) -> int | None:
    """Bit-exact result of one integer value op on uint32 operands, or
    ``None`` for ops without a pure integer evaluator (FP, LOD, ...).

    This is the single constant-evaluation routine shared by the
    interval analysis and the optimizer's constant folder, so a folded
    LODI is bit-identical to the instruction it replaces by
    construction."""
    if op not in _INT_EVAL_OPS:
        return None
    mask = (1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32 else _M32
    signed = typ == int(isa.Typ.I32)
    amt = b & (cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)
    if op == int(Op.ADD):
        r = (a + b) & _M32
    elif op == int(Op.SUB):
        r = (a - b) & _M32
    elif op == int(Op.NEG):
        r = (-_sext(a, 32)) & _M32
    elif op == int(Op.ABS):
        r = abs(_sext(a, 32)) & _M32
    elif op == int(Op.MUL16LO):
        r = ((_sext(a, 16) * _sext(b, 16)) if signed
             else (a & 0xFFFF) * (b & 0xFFFF)) & _M32
    elif op == int(Op.MUL16HI):
        if signed:
            r = ((_sext(a, 16) * _sext(b, 16)) >> 16) & _M32
        else:
            r = (((a & 0xFFFF) * (b & 0xFFFF)) & _M32) >> 16
    elif op == int(Op.MUL24LO):
        p = (_sext(a, 24) * _sext(b, 24)) if signed \
            else (a & 0xFFFFFF) * (b & 0xFFFFFF)
        r = p & _M32
    elif op == int(Op.MUL24HI):
        if signed:
            r = ((_sext(a, 24) * _sext(b, 24)) >> 24) & _M32
        else:
            r = ((a & 0xFFFFFF) * (b & 0xFFFFFF)) >> 24
    elif op == int(Op.AND):
        r = a & b
    elif op == int(Op.OR):
        r = a | b
    elif op == int(Op.XOR):
        r = a ^ b
    elif op == int(Op.NOT):
        r = (~a) & _M32
    elif op == int(Op.CNOT):
        r = 1 if a == 0 else 0
    elif op == int(Op.BVS):
        r = int(f"{a:032b}"[::-1], 2)
    elif op == int(Op.SHL):
        r = (a << amt) & _M32
    elif op == int(Op.SHR):
        r = (_sext(a, 32) >> amt) & _M32 if signed else a >> amt
    elif op == int(Op.POP):
        r = bin(a).count("1")
    elif op == int(Op.MAX):
        r = (a if _sext(a, 32) > _sext(b, 32) else b) if signed \
            else max(a, b)
    else:  # MIN
        r = (a if _sext(a, 32) < _sext(b, 32) else b) if signed \
            else min(a, b)
    return r & mask


# ---------------------------------------------------------------------------
# Interval domain (uint32; None == unknown == [0, 2**32))
# ---------------------------------------------------------------------------

def _iv_join(a, b):
    if a is None or b is None:
        return None
    return (min(a[0], b[0]), max(a[1], b[1]))


def _masked(iv, mask: int):
    """Post-ALU precision clip: a known hull survives only if masking is
    the identity on it; otherwise the mask itself is the bound."""
    if iv is not None and 0 <= iv[0] and iv[1] <= mask:
        return iv
    return (0, mask) if mask < _M32 else None


def _iv_signed(iv):
    """uint32 hull -> signed int32 hull, or None when it straddles."""
    if iv is None:
        return None
    lo, hi = iv
    if hi < 1 << 31:
        return (lo, hi)
    if lo >= 1 << 31:
        return (lo - (1 << 32), hi - (1 << 32))
    return None


def _iv_transfer(op: int, typ: int, a, b, imm: int, cfg: EGPUConfig,
                 threads: int, tdx_dim: int):
    """Per-op interval rule for non-constant operands."""
    mask = (1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32 else _M32
    signed = typ == int(isa.Typ.I32)

    if op == int(Op.LODI):
        v = (imm & _M32) & mask if imm >= 0 else (imm + (1 << 32)) & mask
        return (v, v)
    if op == int(Op.TDX):
        return _masked((0, max(0, min(tdx_dim, threads) - 1)), mask)
    if op == int(Op.TDY):
        return _masked((0, (threads - 1) // max(1, tdx_dim)), mask)
    if op == int(Op.CNOT):
        return (0, 1)
    if op == int(Op.POP):
        return _masked((0, 32), mask)
    unk = _masked(None, mask) if op in _INT_EVAL_OPS else None
    if a is None or (op in _READS_RB and b is None):
        return unk
    if op == int(Op.ADD) and b is not None:
        hi = a[1] + b[1]
        return _masked((a[0] + b[0], hi), mask) if hi <= _M32 else \
            _masked(None, mask)
    if op == int(Op.SUB) and b is not None:
        if a[0] - b[1] >= 0:
            return _masked((a[0] - b[1], a[1] - b[0]), mask)
        return _masked(None, mask)
    if op == int(Op.AND) and b is not None:
        return _masked((0, min(a[1], b[1])), mask)
    if op in (int(Op.OR), int(Op.XOR)) and b is not None:
        bits = max(a[1].bit_length(), b[1].bit_length())
        lo = max(a[0], b[0]) if op == int(Op.OR) else 0
        return _masked((lo, (1 << bits) - 1), mask)
    if op == int(Op.SHL) and b is not None and b[0] == b[1]:
        amt = b[0] & (cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)
        hi = a[1] << amt
        return _masked((a[0] << amt, hi), mask) if hi <= _M32 else \
            _masked(None, mask)
    if op == int(Op.SHR) and b is not None and b[0] == b[1]:
        if signed and a[1] >= 1 << 31:
            return _masked(None, mask)
        amt = b[0] & (cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)
        return _masked((a[0] >> amt, a[1] >> amt), mask)
    if op in (int(Op.MIN), int(Op.MAX)) and b is not None:
        if signed and (a[1] >= 1 << 31 or b[1] >= 1 << 31):
            return _masked(None, mask)
        f = min if op == int(Op.MIN) else max
        return _masked((f(a[0], b[0]), f(a[1], b[1])), mask)
    if op == int(Op.MUL16LO) and b is not None \
            and a[1] <= 0xFFFF and b[1] <= 0xFFFF \
            and (not signed or (a[1] <= 0x7FFF and b[1] <= 0x7FFF)):
        return _masked((a[0] * b[0], a[1] * b[1]), mask)
    if op == int(Op.MUL24LO) and b is not None \
            and a[1] <= 0xFFFFFF and b[1] <= 0xFFFFFF \
            and a[1] * b[1] <= _M32 \
            and (not signed or (a[1] <= 0x7FFFFF and b[1] <= 0x7FFFFF)):
        return _masked((a[0] * b[0], a[1] * b[1]), mask)
    if op == int(Op.ABS) and (not signed or a[1] < 1 << 31):
        return _masked(a, mask)
    if op in _INT_EVAL_OPS:
        return _masked(None, mask)
    return None          # FP / LOD / DOT / SUM / INVSQR: full uint32


# ---------------------------------------------------------------------------
# Coverage domain: maximal origin-anchored (lanes, wavefronts) rectangles
# ---------------------------------------------------------------------------

def _rects_max(rects) -> frozenset:
    out = set()
    for r in rects:
        if not any(o != r and o[0] >= r[0] and o[1] >= r[1] for o in rects):
            out.add(r)
    return frozenset(out)


def _cov_join(a: frozenset, b: frozenset) -> frozenset:
    """Intersection of the two covered sets (must-analysis join)."""
    if a == b:
        return a
    return _rects_max({(min(x[0], y[0]), min(x[1], y[1]))
                       for x in a for y in b})


def _cov_add(cov: frozenset, rect) -> frozenset:
    return cov if _covers(cov, rect) else _rects_max(set(cov) | {rect})


def _cov_union(a: frozenset, b: frozenset) -> frozenset:
    """Union of two covered sets (both writes are guaranteed)."""
    return _rects_max(set(a) | set(b))


def _covers(cov: frozenset, rect) -> bool:
    return any(l >= rect[0] and w >= rect[1] for l, w in cov)


# ---------------------------------------------------------------------------
# The abstract state
# ---------------------------------------------------------------------------
#
# state = (pred, loops, calls, regs)
#   pred  : int predicate depth | None (unknown/conflicting)
#   loops : tuple of (counter_value | None, init_pc | None) | None
#   calls : tuple of return pcs (int | None) | None
#   regs  : tuple per register of (interval, coverage, maybe_written)

_REG0 = ((0, 0), frozenset(), False)     # zero-initialised, never written


def _join_stacks(a, b, kind: str, conflicts: set):
    if a is None or b is None:
        return None
    if len(a) != len(b):
        conflicts.add(kind)
        return None
    if kind == "loops":
        return tuple((va if va == vb else None, pa if pa == pb else None)
                     for (va, pa), (vb, pb) in zip(a, b))
    return tuple(x if x == y else None for x, y in zip(a, b))


def _join_state(a, b, conflicts: set):
    if a is None:
        return b
    pa, la, ca, ra = a
    pb, lb, cb, rb = b
    if pa is None or pb is None:
        pred = None
    elif pa == pb:
        pred = pa
    else:
        conflicts.add("pred")
        pred = None
    loops = _join_stacks(la, lb, "loops", conflicts)
    calls = _join_stacks(ca, cb, "calls", conflicts)
    regs = tuple(
        (x if x == y else
         (_iv_join(x[0], y[0]), _cov_join(x[1], y[1]), x[2] or y[2]))
        for x, y in zip(ra, rb))
    return (pred, loops, calls, regs)


def _widen(new, old):
    """Drop intervals that are still moving (guarantees termination)."""
    if old is None:
        return new
    pred, loops, calls, regs = new
    regs = tuple(
        (None if (x[0] != y[0] and x[0] is not None) else x[0], x[1], x[2])
        for x, y in zip(regs, old[3]))
    return (pred, loops, calls, regs)


# ---------------------------------------------------------------------------
# The analyzer
# ---------------------------------------------------------------------------

class _Reporter:
    """Diagnostic sink for the post-fixpoint reporting walk."""

    def __init__(self):
        self.diags: list[Diagnostic] = []
        self._seen: set[tuple[str, int]] = set()
        self.access_verdicts: dict[int, str] = {}
        self.loop_trips: dict[int, Any] = {}
        self.fold_candidates: dict[int, int] = {}
        self.pred_at: dict[int, int | None] = {}
        self.max_depth = {"pred": 0, "loops": 0, "calls": 0}

    def diag(self, sev: Severity, code: str, pc: int, msg: str,
             path=()) -> None:
        if (code, pc) in self._seen:
            return
        self._seen.add((code, pc))
        self.diags.append(Diagnostic(sev, code, pc, msg, tuple(path)))


class _Analyzer:
    def __init__(self, image: ProgramImage, threads: int, tdx_dim: int):
        cfg = image.cfg
        self.cfg = cfg
        self.n = image.n
        self.threads = threads
        self.tdx_dim = max(1, int(tdx_dim))
        self.packed = np.stack(
            [image.op, image.typ, image.rd, image.ra, image.rb,
             image.imm, image.tsc], axis=1).astype(np.int64)
        self.g = cfg_mod.build_cfg(self.packed, self.n)
        w_rt = -(-threads // cfg.num_sps)
        self.w_rt = w_rt
        self.wfs_table = (1, w_rt, max(1, -(-w_rt // 2)),
                          max(1, -(-w_rt // 4)))
        self.D = max(1, cfg.predicate_levels)
        self.S = cfg.shared_words
        self.nregs = cfg.regs_per_thread
        self.states: dict[int, Any] = {}
        self.witness: dict[int, tuple] = {}
        self.conflicts: set = set()
        self.hard_faults: dict[tuple[str, int], str] = {}

    def _fault(self, rep, code: str, pc: int, msg: str, path=()) -> None:
        """Record a stack-discipline ERROR.  These must be captured even
        during the fixpoint pass (``rep is None``): the fault degrades the
        abstract stack to ``None``, and the CFG join can erase that
        evidence before the reporting replay ever sees a concrete stack
        at the faulting block again."""
        self.hard_faults.setdefault((code, pc), msg)
        if rep:
            rep.diag(Severity.ERROR, code, pc, msg, path)

    # ------------------------------------------------------------ fields
    def _ins(self, pc: int):
        row = self.packed[pc]
        return (int(row[_PF_OP]), int(row[_PF_TYP]), int(row[_PF_RD]),
                int(row[_PF_RA]), int(row[_PF_RB]), int(row[_PF_IMM]),
                int(row[_PF_TSC]))

    def _space(self, tsc: int):
        """(lanes, wavefronts, is_full) of one instruction's TSC."""
        lanes = isa.WIDTH_LANES[(tsc >> 2) & 3]
        wfs = self.wfs_table[tsc & 3]
        return lanes, wfs, (lanes == self.cfg.num_sps and wfs == self.w_rt)

    @staticmethod
    def _pers(lanes: int, wfs: int) -> str:
        return f"{lanes} lane(s) x {wfs} wavefront(s)"

    # ---------------------------------------------------------- transfer
    def _exec_block(self, st, bi: int, rep: _Reporter | None):
        """Run one block's transfer; returns ``{(succ_block, kind): state}``
        restricted to feasible edges."""
        cfg = self.cfg
        s, e = self.g.blocks[bi]
        pred, loops, calls, regs = st
        regs = list(regs)
        path = self.witness.get(bi, ()) + (s,) if rep else ()
        halt_rts = False
        # In-block IF/ELSE arm tracking: a register written in *both*
        # arms of a predicate region is, at the matching ENDIF, covered
        # by the intersection of the arm rectangles — the two masks are
        # complementary, so together the writes reach every thread the
        # enclosing context enables.  IF/ELSE/ENDIF are straight-line
        # ops here (not branches), so the whole region sits in one
        # block.  Frames: [state(0=then,1=else,2=dead), thn, els]; a
        # second ELSE flips the mask back, so it kills the frame.
        frames: list = []
        frames_ok = True
        for pc in range(s, e):
            op, typ, rd, ra, rb, imm, tsc = self._ins(pc)
            if op >= NUM_OPCODES:
                if rep:
                    rep.diag(Severity.ERROR, "bad-opcode", pc,
                             f"opcode {op} is not in the 61-op ISA", path)
                continue
            if (tsc >> 2) & 3 == 3 and rep:
                rep.diag(Severity.ERROR, "undefined-tsc-width", pc,
                         "TSC width coding '11' is undefined (Table 3)",
                         path)
            lanes, wfs, full = self._space(tsc)
            predicated = pred is None or pred > 0
            if rep:
                rep.pred_at[pc] = pred
                self._check_reads(rep, pc, op, ra, rb, rd, regs,
                                  lanes, wfs, path, frames, frames_ok)
            # ---- sequencer / predicate structure
            if op == int(Op.JSR):
                if calls is not None:
                    if len(calls) >= cfg.max_call_depth:
                        self._fault(rep, "call-overflow", pc,
                                    f"JSR beyond max_call_depth="
                                    f"{cfg.max_call_depth} drops the "
                                    f"return address", path)
                        calls = None
                    else:
                        calls = calls + (pc + 1,)
                        if rep:
                            rep.max_depth["calls"] = max(
                                rep.max_depth["calls"], len(calls))
            elif op == int(Op.RTS):
                if calls is not None and not calls:
                    self._fault(rep, "call-underflow", pc,
                                "RTS with an empty call stack jumps to "
                                "an undefined return address", path)
                    halt_rts = True
                elif calls is not None:
                    calls = calls[:-1]
            elif op == int(Op.INIT):
                if loops is not None:
                    if len(loops) >= cfg.max_loop_depth:
                        self._fault(rep, "loop-overflow", pc,
                                    f"INIT beyond max_loop_depth="
                                    f"{cfg.max_loop_depth} drops the "
                                    f"counter", path)
                        loops = None
                    else:
                        loops = loops + ((imm, pc),)
                        if rep:
                            rep.max_depth["loops"] = max(
                                rep.max_depth["loops"], len(loops))
            elif op == int(Op.LOOP):
                if loops is not None and not loops:
                    self._fault(rep, "loop-underflow", pc,
                                "LOOP with an empty loop stack reads an "
                                "undefined counter", path)
                    loops = None
            elif op in _IF_OPS:
                if cfg.predicate_levels == 0 and rep:
                    rep.diag(Severity.WARN, "no-predicate-hw", pc,
                             "IF.cc on a config with predicate_levels=0 "
                             "(runtime emulates a single level)", path)
                if not full:
                    # the push reaches only TSC-active threads: the
                    # per-thread depths diverge and the scalar model
                    # loses them
                    pred = None
                    frames_ok = False
                elif pred is not None:
                    if pred >= self.D:
                        self._fault(rep, "pred-overflow", pc,
                                    f"IF.cc beyond predicate_levels="
                                    f"{self.D} drops the push and "
                                    f"desynchronises ENDIF", path)
                        frames_ok = False
                    else:
                        pred += 1
                        frames.append([0, {}, {}])
                        if rep:
                            rep.max_depth["pred"] = max(
                                rep.max_depth["pred"], pred)
                else:
                    frames_ok = False
            elif op == int(Op.ELSE):
                if pred == 0:
                    self._fault(rep, "pred-underflow", pc,
                                "ELSE without an open IF", path)
                if not full:
                    frames_ok = False    # flips only a subset of threads
                if frames:
                    frames[-1][0] = min(frames[-1][0] + 1, 2)
            elif op == int(Op.ENDIF):
                if not full:
                    pred = None          # pops only a subset of threads
                    frames_ok = False
                elif pred == 0:
                    self._fault(rep, "pred-underflow", pc,
                                "ENDIF without an open IF", path)
                elif pred is not None:
                    pred -= 1
                    if frames:
                        fstate, thn, els = frames.pop()
                        if frames_ok and fstate == 1:
                            for r in thn.keys() & els.keys():
                                m = _cov_join(thn[r], els[r])
                                if frames:
                                    f = frames[-1]
                                    arm = f[2] if f[0] else f[1]
                                    arm[r] = _cov_union(
                                        arm.get(r, frozenset()), m)
                                elif pred == 0:
                                    iv, cov, _w = regs[r]
                                    regs[r] = (iv, _cov_union(cov, m),
                                               True)
                else:
                    frames_ok = False
            # ---- memory bounds
            if op in (int(Op.LOD), int(Op.STO)) and rep:
                self._check_access(rep, pc, op, regs[ra][0], imm,
                                   predicated, path)
            # ---- register writes
            if op in _WRITES:
                a_iv, b_iv = regs[ra][0], regs[rb][0]
                iv = None
                if a_iv is not None and a_iv[0] == a_iv[1] \
                        and op in _INT_EVAL_OPS \
                        and (op not in _READS_RB
                             or (b_iv is not None and b_iv[0] == b_iv[1])):
                    bval = b_iv[0] if b_iv is not None else 0
                    v = eval_int(op, typ, a_iv[0], bval, cfg)
                    if v is not None:
                        iv = (v, v)
                        if rep and not predicated:
                            rep.fold_candidates[pc] = v
                if iv is None:
                    iv = _iv_transfer(op, typ, a_iv, b_iv, imm, cfg,
                                      self.threads, self.tdx_dim)
                rect = (1, 1) if op in (int(Op.DOT), int(Op.SUM)) \
                    else (lanes, wfs)
                old = regs[rd]
                if full and not predicated \
                        and op not in (int(Op.DOT), int(Op.SUM)):
                    regs[rd] = (iv, _cov_add(old[1], rect), True)
                else:
                    cov = old[1] if predicated else _cov_add(old[1], rect)
                    regs[rd] = (_iv_join(old[0], iv), cov, True)
                    if frames_ok and frames and frames[-1][0] < 2 \
                            and pred is not None:
                        arm = frames[-1][1 + frames[-1][0]]
                        arm[rd] = _cov_union(arm.get(rd, frozenset()),
                                             frozenset((rect,)))
        # ------------------------------------------------------ edges
        out_state = (pred, loops, calls, tuple(regs))
        outs: dict[tuple[int, str], Any] = {}
        term_op = self._ins(e - 1)[0]
        for sb, kind in self.g.succs[bi]:
            if kind == "loop_back":
                if loops is None:
                    outs[(sb, kind)] = out_state
                elif loops:
                    v, ip = loops[-1]
                    if v is None or v > 0:
                        outs[(sb, kind)] = (pred, loops[:-1] + ((None, ip),),
                                            calls, tuple(regs))
            elif kind == "loop_exit":
                if loops is None:
                    outs[(sb, kind)] = out_state
                elif loops:
                    v, _ = loops[-1]
                    if v is None or v <= 0:
                        outs[(sb, kind)] = (pred, loops[:-1], calls,
                                            tuple(regs))
            elif kind == "return":
                if halt_rts:
                    continue
                if calls is None:
                    outs[(sb, kind)] = (pred, loops, None, tuple(regs))
                else:
                    ret = calls[-1] if calls else None
                    popped = (pred, loops, calls[:-1], tuple(regs))
                    if ret is None:
                        outs[(sb, kind)] = popped
                    elif self.g.block_of.get(ret) == sb:
                        outs[(sb, kind)] = popped
            else:
                outs[(sb, kind)] = out_state
        if rep and term_op == int(Op.LOOP) and loops not in (None, ()):
            v, ip = loops[-1]
            init_imm = self._ins(ip)[5] if ip is not None else None
            prev = rep.loop_trips.get(e - 1, "unset")
            trips = (max(init_imm, 0) + 1) if init_imm is not None else None
            rep.loop_trips[e - 1] = trips if prev in ("unset", trips) \
                else None
        return outs

    # -------------------------------------------------------- read checks
    def _check_reads(self, rep, pc, op, ra, rb, rd, regs, lanes, wfs, path,
                     frames=(), frames_ok=False):
        reads = []
        if op in _READS_RA:
            reads.append(("Ra", ra))
        if op in _READS_RB:
            reads.append(("Rb", rb))
        if op in _READS_RD:
            reads.append(("Rd", rd))
        for role, r in reads:
            iv, cov, maybe = regs[r]
            if _covers(cov, (lanes, wfs)):
                continue
            # a write earlier in a still-open predicate arm is seen by
            # exactly the threads that made it: a read under the same
            # (or deeper) mask chain is defined where it executes
            if frames_ok and any(
                    f[0] < 2 and _covers(f[1 + f[0]].get(r, frozenset()),
                                         (lanes, wfs))
                    for f in frames):
                continue
            if not cov and not maybe:
                rep.diag(Severity.WARN, "undefined-read", pc,
                         f"{Op(op).name} reads {role}=r{r} which no path "
                         f"writes first (reads as 0 here; undefined in "
                         f"hardware)", path)
            else:
                rep.diag(Severity.WARN, "partial-def-read", pc,
                         f"{Op(op).name} reads {role}=r{r} over "
                         f"{self._pers(lanes, wfs)} but definite writes "
                         f"cover a narrower thread space (or are "
                         f"predicate-gated)", path)

    def _check_access(self, rep, pc, op, ra_iv, imm, predicated, path):
        name = Op(op).name
        sv = _iv_signed(ra_iv)
        if sv is None:
            rep.access_verdicts[pc] = "unproven"
            rep.diag(Severity.INFO, "unproven-bounds", pc,
                     f"{name} address Ra{imm:+d} has unknown range "
                     f"(interval analysis lost it)", path)
            return
        lo, hi = sv[0] + imm, sv[1] + imm
        if hi < 0 or lo >= self.S:
            rep.access_verdicts[pc] = "oob"
            sev = Severity.WARN if predicated else Severity.ERROR
            code = "oob-access-predicated" if predicated else "oob-access"
            rep.diag(sev, code, pc,
                     f"{name} address in [{lo}, {hi}] is entirely outside "
                     f"shared memory [0, {self.S})"
                     + (" (predicate-gated)" if predicated else ""), path)
        elif lo >= 0 and hi < self.S:
            rep.access_verdicts[pc] = "proved"
        else:
            rep.access_verdicts[pc] = "unproven"
            rep.diag(Severity.INFO, "unproven-bounds", pc,
                     f"{name} address in [{lo}, {hi}] may straddle shared "
                     f"memory [0, {self.S})", path)

    # ----------------------------------------------------------- fixpoint
    def run(self) -> AnalysisReport:
        entry = (0, (), (), tuple([_REG0] * self.nregs))
        self.states[0] = entry
        self.witness[0] = ()
        visits: Counter = Counter()
        work = deque([0])
        budget = _MAX_BLOCK_EXECS
        clipped = False
        while work and budget:
            budget -= 1
            bi = work.popleft()
            outs = self._exec_block(self.states[bi], bi, None)
            for (sb, _kind), ost in outs.items():
                joined = _join_state(self.states.get(sb), ost,
                                     self.conflicts)
                if joined != self.states.get(sb):
                    visits[sb] += 1
                    if visits[sb] > _WIDEN_AT:
                        joined = _widen(joined, self.states.get(sb))
                    if joined != self.states.get(sb):
                        self.states[sb] = joined
                        self.witness[sb] = (self.witness.get(bi, ())
                                            + (self.g.blocks[bi][0],)
                                            )[-_WITNESS_CAP:]
                        if sb not in work:
                            work.append(sb)
        if work:
            clipped = True

        rep = _Reporter()
        for bi in sorted(self.states):
            self._exec_block(self.states[bi], bi, rep)
        self._structural(rep, clipped)
        facts = self._facts(rep, clipped)
        report = AnalysisReport(diagnostics=rep.diags, facts=facts)
        return report

    # --------------------------------------------------------- structural
    def _structural(self, rep: _Reporter, clipped: bool) -> None:
        g = self.g
        if clipped:
            rep.diag(Severity.INFO, "analysis-budget", -1,
                     "fixpoint budget exhausted; remaining findings are "
                     "best-effort")
        for pc, op, tgt in g.bad_targets:
            rep.diag(Severity.ERROR, "bad-branch-target", pc,
                     f"{Op(op).name} target {tgt} is outside the "
                     f"{self.n}-instruction image")
        for kind in sorted(self.conflicts):
            rep.diag(Severity.ERROR, "stack-conflict", -1,
                     f"conflicting {kind.rstrip('s')} stack depths meet at "
                     f"a CFG join (unbalanced push/pop across paths, or "
                     f"recursion)")
        # stack faults seen only on fixpoint paths (the fault poisons the
        # abstract stack to None, and the join can erase the evidence
        # before the reporting replay runs)
        seen = {(d.code, d.pc) for d in rep.diags}
        for (code, pc), msg in sorted(self.hard_faults.items(),
                                      key=lambda kv: kv[0][1]):
            if (code, pc) not in seen:
                rep.diag(Severity.ERROR, code, pc,
                         msg + " (reached along a fixpoint path whose "
                               "stack state was later lost at a join)")
        # unreachable code (skip the assembler's auto-appended final STOP)
        for bi, (s, e) in enumerate(g.blocks):
            if bi in self.states:
                continue
            if s == self.n - 1 and self._ins(s)[0] == int(Op.STOP):
                continue
            rep.diag(Severity.WARN, "unreachable-code", s,
                     f"block [{s}, {e}) is unreachable from entry")
        # halt reachability
        can_halt = False
        for bi in self.states:
            s, e = g.blocks[bi]
            term = self._ins(e - 1)[0]
            if term == int(Op.STOP):
                can_halt = True
            elif not g.succs[bi] and term != int(Op.RTS):
                can_halt = True     # falls off the image into padded STOP
        if not can_halt:
            rep.diag(Severity.ERROR, "no-halt", -1,
                     "no reachable path reaches STOP or leaves the image "
                     "(the program cannot halt)")
        for pc, trips in sorted(rep.loop_trips.items()):
            if trips is None:
                rep.diag(Severity.INFO, "trip-unknown", pc,
                         "loop trip count is not statically determined "
                         "(counter or INIT provenance lost at a join)")
        self._dead_writes(rep)

    def _dead_writes(self, rep: _Reporter) -> None:
        """Backward liveness over the reached blocks; INFO per dead def."""
        g = self.g
        reached = sorted(self.states)
        live_in: dict[int, int] = {bi: 0 for bi in reached}
        preds: dict[int, list[int]] = {bi: [] for bi in reached}
        for bi in reached:
            for sb, _k in g.succs[bi]:
                if sb in preds:
                    preds[sb].append(bi)

        def back(bi: int, live: int, sink: list | None) -> int:
            s, e = g.blocks[bi]
            for pc in range(e - 1, s - 1, -1):
                op, typ, rd, ra, rb, imm, tsc = self._ins(pc)
                if op >= NUM_OPCODES:
                    continue
                if op in _WRITES:
                    if sink is not None and not (live >> rd) & 1:
                        sink.append(pc)
                    lanes, wfs, full = self._space(tsc)
                    strong = (full and rep.pred_at.get(pc) == 0
                              and op not in (int(Op.DOT), int(Op.SUM)))
                    if strong:
                        live &= ~(1 << rd)
                if op in _READS_RA:
                    live |= 1 << ra
                if op in _READS_RB:
                    live |= 1 << rb
                if op in _READS_RD:
                    live |= 1 << rd
            return live

        work = deque(reached)
        while work:
            bi = work.popleft()
            out = 0
            for sb, _k in g.succs[bi]:
                out |= live_in.get(sb, 0)
            new_in = back(bi, out, None)
            if new_in != live_in[bi]:
                live_in[bi] = new_in
                for pb in preds[bi]:
                    if pb not in work:
                        work.append(pb)
        dead: list[int] = []
        for bi in reached:
            out = 0
            for sb, _k in g.succs[bi]:
                out |= live_in.get(sb, 0)
            back(bi, out, dead)
        for pc in sorted(dead)[:16]:
            op = self._ins(pc)[0]
            rd = self._ins(pc)[2]
            rep.diag(Severity.INFO, "dead-write", pc,
                     f"{Op(op).name} writes r{rd} which nothing reads "
                     f"before the program halts")

    # -------------------------------------------------------------- facts
    def _facts(self, rep: _Reporter, clipped: bool) -> dict:
        reached = sorted(self.states)
        distinct = sum(e - s for bi, (s, e) in enumerate(self.g.blocks)
                       if bi in self.states)
        static_steps = self._static_steps(rep)
        facts = {
            "threads": self.threads,
            "tdx_dim": self.tdx_dim,
            "n_blocks": len(self.g.blocks),
            "reached_blocks": len(reached),
            "distinct_reachable_instrs": distinct,
            "predicted_superblock_eligible": distinct <= cfg_mod.MAX_TRACE,
            "loop_trips": dict(rep.loop_trips),
            "static_steps": static_steps,
            "access_verdicts": dict(rep.access_verdicts),
            "proved_accesses": tuple(
                pc for pc, v in sorted(rep.access_verdicts.items())
                if v == "proved"),
            "max_pred_depth": rep.max_depth["pred"],
            "max_loop_depth": rep.max_depth["loops"],
            "max_call_depth": rep.max_depth["calls"],
            "fold_candidates": dict(rep.fold_candidates),
            "pred_at": dict(rep.pred_at),
            "analysis_clipped": clipped,
        }
        if distinct > cfg_mod.MAX_TRACE:
            rep.diag(Severity.INFO, "trace-budget", -1,
                     f"{distinct} distinct reachable instructions exceed "
                     f"the {cfg_mod.MAX_TRACE}-instruction superblock "
                     f"trace budget; the runner will fall back to the "
                     f"blocks tier")
        if static_steps is not None and static_steps > self.cfg.max_steps:
            rep.diag(Severity.ERROR, "steps-exceeded", -1,
                     f"statically determined execution length "
                     f"{static_steps} exceeds max_steps="
                     f"{self.cfg.max_steps} (the interpreter would stop "
                     f"mid-flight)")
        return facts

    def _static_steps(self, rep: _Reporter) -> int | None:
        """Exact executed-instruction count for *structured* programs: no
        JMP/JSR/RTS, every LOOP a known-trip backward branch, loop bodies
        laminar (properly nested).  Matches the path simulator's ``steps``
        bit-for-bit when it returns a value (tested)."""
        ops = self.packed[:self.n, _PF_OP]
        imms = self.packed[:self.n, _PF_IMM]
        for bad in (Op.JMP, Op.JSR, Op.RTS):
            if np.any(ops == int(bad)):
                return None
        stops = np.flatnonzero(ops == int(Op.STOP))
        if not len(stops):
            return None
        s0 = int(stops[0])
        loops = []
        for pc in np.flatnonzero(ops == int(Op.LOOP)):
            pc = int(pc)
            if pc > s0:
                continue
            t = int(imms[pc])
            trips = rep.loop_trips.get(pc)
            if trips is None or not 0 <= t < pc:
                return None
            loops.append((t, pc, trips))
        for (a1, b1, _t1) in loops:          # laminar check
            for (a2, b2, _t2) in loops:
                if a1 < a2 <= b1 < b2:
                    return None
        total = 0
        for pc in range(s0 + 1):
            mult = 1
            for (a, b, trips) in loops:
                if a <= pc <= b:
                    mult *= trips
            total += mult
        return total


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def analyze(image: ProgramImage, threads: int | None = None, *,
            tdx_dim: int = 16) -> AnalysisReport:
    """Run every static pass over one assembled program.

    ``threads``/``tdx_dim`` fix the thread-space geometry the analysis
    is exact for (wavefront counts, TDX/TDY ranges); they default to the
    image's ``threads_active`` (falling back to the config maximum) and
    the conventional 16-wide thread grid.
    """
    cfg = image.cfg
    if threads is None:
        threads = image.threads_active or cfg.max_threads
    if threads < 1 or threads > cfg.max_threads:
        raise ValueError(f"threads {threads} invalid for max "
                         f"{cfg.max_threads}")
    return _Analyzer(image, threads, tdx_dim).run()


_CACHE: OrderedDict = OrderedDict()
_CACHE_LOCK = threading.Lock()
_CACHE_MAX = 256


def analyze_cached(image: ProgramImage, threads: int | None = None, *,
                   tdx_dim: int = 16) -> AnalysisReport:
    """LRU-cached :func:`analyze` keyed on (config, program bits,
    threads, tdx_dim) — the admission path calls this per submit, so
    repeated submits of the same program cost one dict lookup."""
    cfg = image.cfg
    t = threads if threads is not None \
        else (image.threads_active or cfg.max_threads)
    key = (cfg, image.words.tobytes(), t, tdx_dim)
    with _CACHE_LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _CACHE.move_to_end(key)
            return hit
    report = analyze(image, threads, tdx_dim=tdx_dim)
    with _CACHE_LOCK:
        _CACHE[key] = report
        while len(_CACHE) > _CACHE_MAX:
            _CACHE.popitem(last=False)
    return report
