"""Verified pre-compile optimizer: constant folding + DCE over the CFG.

The ISA has no data-dependent control flow, so every transform here is
justified by *input-independent* static facts:

* **NOP strip + re-schedule** — hand-written or previously scheduled
  hazard NOPs are removed and the assembler's exact per-wavefront
  scheduler re-derives the minimal set for the transformed program
  (removing instructions can both remove *and create* hazards).
* **Constant folding** — an instruction whose result the interval
  analysis proved to be a single constant for every active thread on
  every path (and which issues unpredicated) is replaced by a ``LODI``
  with the same destination and thread-space coding, when the value is
  representable as a sign-extended 16-bit immediate under the config's
  ALU mask.  The constant comes from :func:`repro.analysis.passes.eval_int`
  — the same evaluator the analysis uses — so the replacement is
  bit-identical by construction.
* **Dead-code elimination** — register writes that are overwritten by a
  statically unpredicated full-thread-space write on *every* path
  before any read are dropped.  Liveness treats program exit as
  all-registers-live, so the final architectural register file (not
  just shared memory) is preserved bit-for-bit.

The contract is full bit-identity of the architectural end state
(register file, shared memory, halt flag) for any shared-memory input.
``optimize_image`` enforces it twice: the optimized image is re-analyzed
(no new ERROR diagnostics allowed) and, with ``verify=True`` (default),
differentially executed against the original on a deterministic
non-trivial shared-memory pattern via the numpy reference executor.  On
any doubt the original image is returned unchanged.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict, deque
from typing import Sequence

import numpy as np

from ..core import cfg as cfg_mod
from ..core import isa
from ..core.assembler import Asm, Label, ProgramImage
from ..core.config import EGPUConfig
from ..core.isa import NUM_OPCODES, Instr, Op, Typ
from .diagnostics import AnalysisReport
from .passes import analyze

_M32 = 0xFFFFFFFF
_TARGETS = frozenset(int(o) for o in cfg_mod.TARGET_OPS)
_WRITES = frozenset(int(o) for o in isa.REG_WRITE_OPS)
_READS_RA = frozenset(int(o) for o in isa.READS_RA)
_READS_RB = frozenset(int(o) for o in isa.READS_RB if o != Op.SUM)
_READS_RD = frozenset(int(o) for o in isa.READS_RD)
_NOP = int(Op.NOP)


class OptimizationError(RuntimeError):
    """The optimized program failed differential verification.

    This is a bug in the optimizer, never in the input program — it is
    raised instead of silently shipping a miscompile."""


@dataclasses.dataclass
class OptResult:
    """Outcome of :func:`optimize_image`."""

    image: ProgramImage           # optimized (== original when unchanged)
    original: ProgramImage
    changed: bool
    rounds: int
    folds: int                    # instructions replaced by LODI
    dce_removed: int              # dead register writes dropped
    nops_before: int              # NOP count in the input image
    nops_after: int               # NOP count after re-scheduling
    report: AnalysisReport | None  # analysis of the final image
    reason: str = ""              # why unchanged, when bailing out

    @property
    def instrs_before(self) -> int:
        return self.original.n

    @property
    def instrs_after(self) -> int:
        return self.image.n


def _instrs(image: ProgramImage) -> list[Instr]:
    return [Instr(op=int(image.op[i]), typ=int(image.typ[i]),
                  rd=int(image.rd[i]), ra=int(image.ra[i]),
                  rb=int(image.rb[i]), imm=int(image.imm[i]),
                  tsc=int(image.tsc[i]))
            for i in range(image.n)]


def _reassemble(instrs: Sequence[Instr], cfg: EGPUConfig,
                threads_active: int | None, *,
                drop: frozenset = frozenset(),
                repl: dict | None = None,
                schedule_nops: bool) -> ProgramImage:
    """Rebuild an image from ``instrs`` with branch targets re-expressed
    as labels, so dropping NOPs / dead writes (and the scheduler adding
    NOPs back) retargets every JMP/JSR/LOOP automatically.  A label on a
    dropped instruction floats to the next retained one."""
    repl = repl or {}
    n = len(instrs)
    targets = {int(i.imm) for i in instrs
               if int(i.op) in _TARGETS and 0 <= int(i.imm) <= n}
    a = Asm(cfg)
    for pc, ins in enumerate(instrs):
        if pc in targets:
            a.items.append(Label(f"_T{pc}"))
        if int(ins.op) == _NOP or pc in drop:
            continue
        ins = repl.get(pc, ins)
        if int(ins.op) in _TARGETS and int(ins.imm) in targets:
            ins = ins._replace(imm=f"_T{int(ins.imm)}")
        a.items.append(ins)
    if n in targets:
        a.items.append(Label(f"_T{n}"))
    # a trailing label must resolve inside the image: anchor it on an
    # explicit STOP (assemble() only auto-appends after label resolution
    # when the last instruction is not already a STOP)
    if a.items and isinstance(a.items[-1], Label):
        a.items.append(Instr(op=int(Op.STOP)))
    return a.assemble(threads_active, schedule_nops=schedule_nops)


def _lodi_imm(value: int, cfg: EGPUConfig) -> int | None:
    """The 16-bit immediate whose LODI result equals ``value`` under the
    config's ALU mask, or None when not representable."""
    mask = (1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32 else _M32
    for cand in (value, value - (mask + 1 if mask < _M32 else 1 << 32)):
        if -32768 <= cand <= 32767 and (cand & mask) == value:
            return cand
    return None


def _fold_replacements(instrs: Sequence[Instr], cfg: EGPUConfig,
                       report: AnalysisReport) -> dict[int, Instr]:
    repl: dict[int, Instr] = {}
    for pc, value in report.facts.get("fold_candidates", {}).items():
        if not 0 <= pc < len(instrs):
            continue
        ins = instrs[pc]
        op = int(ins.op)
        if op not in _WRITES or op in (int(Op.DOT), int(Op.SUM)):
            continue
        imm = _lodi_imm(int(value), cfg)
        if imm is None:
            continue
        if op == int(Op.LODI) and int(ins.imm) == imm:
            continue                      # already canonical
        repl[pc] = Instr(op=int(Op.LODI), typ=int(Typ.U32), rd=int(ins.rd),
                         ra=0, rb=0, imm=imm, tsc=int(ins.tsc))
    return repl


def _dead_pcs(image: ProgramImage, report: AnalysisReport,
              threads: int) -> frozenset:
    """Register writes safe to drop: on every path to exit the value is
    strongly overwritten (unpredicated, full thread space) before any
    read.  Exit live-set is *all registers* — the final register file is
    part of the preserved state."""
    cfg = image.cfg
    n = image.n
    packed = np.stack([image.op, image.typ, image.rd, image.ra,
                       image.rb, image.imm, image.tsc],
                      axis=1).astype(np.int64)
    g = cfg_mod.build_cfg(packed, n)
    pred_at = report.facts.get("pred_at", {})
    nregs = cfg.regs_per_thread
    all_live = (1 << nregs) - 1
    w_rt = max(1, -(-threads // cfg.num_sps))
    wfs_table = (1, w_rt, max(1, -(-w_rt // 2)), max(1, -(-w_rt // 4)))

    def full_space(tsc: int) -> bool:
        lanes = isa.WIDTH_LANES[(tsc >> 2) & 3]
        return lanes == cfg.num_sps and wfs_table[tsc & 3] == w_rt

    def back(bi: int, live: int, sink: list | None) -> int:
        s, e = g.blocks[bi]
        for pc in range(e - 1, s - 1, -1):
            ins = packed[pc]
            op, rd, ra, rb, tsc = (int(ins[0]), int(ins[2]), int(ins[3]),
                                   int(ins[4]), int(ins[6]))
            if op >= NUM_OPCODES:
                continue
            if op in _WRITES:
                if sink is not None and not (live >> rd) & 1:
                    sink.append(pc)
                if (full_space(tsc) and pred_at.get(pc) == 0
                        and op not in (int(Op.DOT), int(Op.SUM))):
                    live &= ~(1 << rd)
            if op in _READS_RA:
                live |= 1 << ra
            if op in _READS_RB:
                live |= 1 << rb
            if op in _READS_RD:
                live |= 1 << rd
        return live

    def live_out_base(bi: int) -> int:
        term = int(packed[g.blocks[bi][1] - 1][0])
        if term in (int(Op.STOP), int(Op.RTS)) or not g.succs[bi]:
            return all_live           # exit (RTS may underflow-halt)
        return 0

    nb = len(g.blocks)
    live_in = {bi: 0 for bi in range(nb)}
    preds: dict[int, list[int]] = {bi: [] for bi in range(nb)}
    for bi in range(nb):
        for sb, _k in g.succs[bi]:
            preds[sb].append(bi)
    work = deque(range(nb))
    while work:
        bi = work.popleft()
        out = live_out_base(bi)
        for sb, _k in g.succs[bi]:
            out |= live_in[sb]
        new_in = back(bi, out, None)
        if new_in != live_in[bi]:
            live_in[bi] = new_in
            for pb in preds[bi]:
                if pb not in work:
                    work.append(pb)
    dead: list[int] = []
    for bi in range(nb):
        out = live_out_base(bi)
        for sb, _k in g.succs[bi]:
            out |= live_in[sb]
        back(bi, out, dead)
    return frozenset(dead)


def _verify_pattern(n_words: int) -> np.ndarray:
    """Deterministic, non-trivial shared-memory image for differential
    runs: a Knuth-multiplicative scramble of the address."""
    a = np.arange(n_words, dtype=np.uint64) * np.uint64(2654435761)
    return (a & np.uint64(_M32)).astype(np.uint32)


def optimize_image(image: ProgramImage, threads: int | None = None, *,
                   tdx_dim: int = 16, max_rounds: int = 8,
                   verify: bool = True) -> OptResult:
    """Optimize one assembled program; see the module docstring for the
    transforms and the equivalence contract.

    Never degrades: on analysis ERRORs in the *input*, or when a round
    fails re-verification, the original image is returned with
    ``changed=False`` and a ``reason``.  A differential mismatch under
    ``verify=True`` raises :class:`OptimizationError` (optimizer bug).
    """
    cfg = image.cfg
    if threads is None:
        threads = image.threads_active or cfg.max_threads
    orig_instrs = _instrs(image)
    nops_before = sum(1 for i in orig_instrs if int(i.op) == _NOP)

    def bail(reason: str, report=None) -> OptResult:
        return OptResult(image=image, original=image, changed=False,
                         rounds=0, folds=0, dce_removed=0,
                         nops_before=nops_before, nops_after=nops_before,
                         report=report, reason=reason)

    report = analyze(image, threads, tdx_dim=tdx_dim)
    if not report.ok:
        return bail("input-has-errors", report)
    if report.facts.get("analysis_clipped"):
        return bail("analysis-budget", report)

    # ---- iterate fold / DCE on a NOP-free image ------------------------
    tight = _reassemble(orig_instrs, cfg, image.threads_active,
                        schedule_nops=False)
    folds = dce = rounds = 0
    rep_t = analyze(tight, threads, tdx_dim=tdx_dim)
    while rounds < max_rounds:
        if not rep_t.ok:                 # a transform introduced an ERROR
            return bail("round-verification-failed", rep_t)
        instrs = _instrs(tight)
        repl = _fold_replacements(instrs, cfg, rep_t)
        drop = frozenset() if repl else _dead_pcs(tight, rep_t, threads)
        if not repl and not drop:
            break
        rounds += 1
        folds += len(repl)
        dce += len(drop)
        tight = _reassemble(instrs, cfg, image.threads_active,
                            drop=drop, repl=repl, schedule_nops=False)
        rep_t = analyze(tight, threads, tdx_dim=tdx_dim)

    # ---- re-derive hazard NOPs and verify ------------------------------
    final = _reassemble(_instrs(tight), cfg, image.threads_active,
                        schedule_nops=True)
    final_report = analyze(final, threads, tdx_dim=tdx_dim)
    if not final_report.ok:
        return bail("final-verification-failed", final_report)
    changed = final.words.tobytes() != image.words.tobytes()
    if changed and verify:
        from .concrete import concrete_run
        shared = _verify_pattern(cfg.shared_words)
        a = concrete_run(image, threads, tdx_dim=tdx_dim, shared_init=shared)
        b = concrete_run(final, threads, tdx_dim=tdx_dim, shared_init=shared)
        if (a.halted != b.halted
                or not np.array_equal(a.regs, b.regs)
                or not np.array_equal(a.shared, b.shared)):
            raise OptimizationError(
                f"optimized program diverges from the original "
                f"(halted {a.halted}->{b.halted}; "
                f"regs equal: {np.array_equal(a.regs, b.regs)}; "
                f"shared equal: {np.array_equal(a.shared, b.shared)})")
    nops_after = int(np.sum(final.op == _NOP))
    return OptResult(image=final if changed else image, original=image,
                     changed=changed, rounds=rounds, folds=folds,
                     dce_removed=dce, nops_before=nops_before,
                     nops_after=nops_after if changed else nops_before,
                     report=final_report)


_CACHE: OrderedDict = OrderedDict()
_CACHE_LOCK = threading.Lock()
_CACHE_MAX = 128


def optimize_image_cached(image: ProgramImage, threads: int | None = None,
                          *, tdx_dim: int = 16,
                          verify: bool = True) -> OptResult:
    """LRU-cached :func:`optimize_image` keyed on (config, program bits,
    threads, tdx_dim) — the ``compile_program(optimize=True)`` path calls
    this, so a hot program pays the optimizer once."""
    cfg = image.cfg
    t = threads if threads is not None \
        else (image.threads_active or cfg.max_threads)
    key = (cfg, image.words.tobytes(), t, tdx_dim, verify)
    with _CACHE_LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _CACHE.move_to_end(key)
            return hit
    res = optimize_image(image, threads, tdx_dim=tdx_dim, verify=verify)
    with _CACHE_LOCK:
        _CACHE[key] = res
        while len(_CACHE) > _CACHE_MAX:
            _CACHE.popitem(last=False)
    return res
