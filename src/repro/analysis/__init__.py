"""Whole-program static analysis over the eGPU ISA control-flow graph.

The ISA has no data-dependent branches — every JMP/JSR/LOOP target and
every INIT trip count is an immediate — so a program's control behaviour
is fully decidable at submit time.  This package exploits that:

* :func:`analyze` — CFG dataflow passes (reaching definitions per
  thread-space personality, stack balance for the predicate/loop/call
  stacks, interval-based shared-memory bounds, static trip-count and
  trace-budget prediction, dead/unreachable code), producing structured
  :class:`Diagnostic` objects with severities and path witnesses.
* :func:`optimize_image` — a verified pre-compile optimizer (constant
  folding + dead-code elimination over the CFG, hazard NOPs re-derived
  by the assembler's scheduler).
* ``python -m repro.analysis.lint`` — renders diagnostics for one
  program or the whole in-repo suite.

The fleet admission path (`repro.fleet.scheduler.check_job`) rejects
ERROR-level programs before any compile.
"""
from .diagnostics import (AnalysisReport, Diagnostic,  # noqa: F401
                          ProgramVerificationError, Severity)
from .passes import analyze, analyze_cached            # noqa: F401
from .optimizer import OptResult, optimize_image       # noqa: F401
from .concrete import ConcreteResult, concrete_run     # noqa: F401
