"""Whole-program static analysis over the eGPU ISA control-flow graph.

The ISA has no data-dependent branches — every JMP/JSR/LOOP target and
every INIT trip count is an immediate — so a program's control behaviour
is fully decidable at submit time.  This package exploits that:

* :func:`analyze` — CFG dataflow passes (reaching definitions per
  thread-space personality, stack balance for the predicate/loop/call
  stacks, interval-based shared-memory bounds, static trip-count and
  trace-budget prediction, dead/unreachable code), producing structured
  :class:`Diagnostic` objects with severities and path witnesses.
* :func:`optimize_image` — a verified pre-compile optimizer (constant
  folding + dead-code elimination over the CFG, hazard NOPs re-derived
  by the assembler's scheduler).
* ``python -m repro.analysis.lint`` — renders diagnostics for one
  program or the whole in-repo suite.

Invariants the rest of the stack builds on (see
``docs/architecture.md``):

* **ERROR rejects pre-compile** — ``Fleet.submit`` /
  ``FleetService.submit`` run :func:`analyze_cached` and raise
  (:class:`ProgramVerificationError` / ``JobError(kind="rejected")``)
  before any compile, queue slot or device work is spent; WARN/INFO
  admit;
* **soundness over completeness** — the analyzer never calls a
  faulting program safe (swept against the NumPy reference executor
  in ``tests/test_analysis_soundness.py``); unprovable cases degrade
  to WARN/INFO, never to silence;
* **optimizer changes nothing observable** — every
  :func:`optimize_image` transform is differentially verified
  bit-identical in architectural end state across all three execution
  tiers, and bails (input returned unchanged) on programs that carry
  ERROR findings.
"""
from .diagnostics import (AnalysisReport, Diagnostic,  # noqa: F401
                          ProgramVerificationError, Severity)
from .passes import analyze, analyze_cached            # noqa: F401
from .optimizer import OptResult, optimize_image       # noqa: F401
from .concrete import ConcreteResult, concrete_run     # noqa: F401
