"""Structured diagnostics produced by the static analyzer.

Severity semantics (the admission contract):

* ``ERROR`` — the program is structurally broken: out-of-image branch
  target, undefined TSC width coding, stack underflow/overflow against
  the configured limits, a shared-memory access *proven* out of bounds
  on an unpredicated path, or a program that can never halt / must
  exceed ``max_steps``.  Fleet admission rejects these before compile.
* ``WARN`` — almost certainly a bug but with defined behaviour in this
  implementation: reads of registers never (or only partially) written
  — the register file is zero-initialised here but undefined in
  hardware — unreachable code, predicate ops on a predicate-less
  config, or a proven-OOB access that is predicate-gated.
* ``INFO`` — facts, not defects: bounds the interval analysis could not
  prove either way, dead register writes, unknown trip counts, and
  tier predictions (e.g. the trace budget says the superblock runner
  will fall back).

Every diagnostic carries the pc it anchors to and, where the dataflow
derived it, a *path witness*: the basic-block entry pcs of one CFG path
that reaches the offending instruction.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.IntEnum):
    INFO = 0
    WARN = 1
    ERROR = 2


@dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding, anchored to a pc."""

    severity: Severity
    code: str                 # stable kebab-case id, e.g. "oob-access"
    pc: int                   # -1 for whole-program findings
    message: str
    #: basic-block start pcs of one path from entry to ``pc`` (may be
    #: elided in the middle for very deep paths); () when structural
    path: tuple[int, ...] = ()

    def render(self) -> str:
        loc = f"pc {self.pc:4d}" if self.pc >= 0 else "program"
        s = f"{self.severity.name:5s} {loc} [{self.code}] {self.message}"
        if self.path:
            s += f"  (path: {' -> '.join(str(p) for p in self.path)})"
        return s


@dataclass
class AnalysisReport:
    """All diagnostics plus the facts the passes proved along the way."""

    diagnostics: list[Diagnostic] = field(default_factory=list)
    #: machine-readable facts: static_steps, loop_trips, proved/unproven
    #: access counts, distinct_reachable_instrs, max stack depths, ...
    facts: dict = field(default_factory=dict)

    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == Severity.ERROR]

    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == Severity.WARN]

    @property
    def ok(self) -> bool:
        """No ERROR-level findings (the admission gate)."""
        return not self.errors()

    @property
    def max_severity(self) -> Severity | None:
        return max((d.severity for d in self.diagnostics), default=None)

    def by_code(self, code: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def counts(self) -> dict[str, int]:
        return {"errors": len(self.errors()),
                "warnings": len(self.warnings()),
                "infos": len([d for d in self.diagnostics
                              if d.severity == Severity.INFO])}

    def render(self, *, min_severity: Severity = Severity.INFO) -> str:
        lines = [d.render() for d in
                 sorted(self.diagnostics,
                        key=lambda d: (-int(d.severity), d.pc))
                 if d.severity >= min_severity]
        c = self.counts()
        lines.append(f"{c['errors']} error(s), {c['warnings']} warning(s), "
                     f"{c['infos']} info(s)")
        return "\n".join(lines)


class ProgramVerificationError(ValueError):
    """Raised at admission for programs with ERROR-level diagnostics.

    Subclasses ``ValueError`` so existing fail-fast submit paths (which
    surface ``ValueError`` synchronously) keep working unchanged; the
    structured findings ride along as ``.diagnostics``.
    """

    def __init__(self, report: AnalysisReport):
        self.report = report
        self.diagnostics = report.errors()
        head = "; ".join(d.render() for d in self.diagnostics[:3])
        more = len(self.diagnostics) - 3
        if more > 0:
            head += f"; (+{more} more)"
        super().__init__(f"program rejected by static verifier: {head}")
