"""eGPU instruction set architecture.

Faithful to Table 2 / Figure 3 of "A Statically and Dynamically Scalable
Soft GPGPU" (Langhammer & Constantinides, 2024).

The ISA has exactly 61 instructions, including 18 conditional (IF.cc)
cases.  The instruction word (IW) is parameterised by the number of
registers per thread (Fig. 3 shows the 43-bit / 32-register form):

    [tsc:4][opcode:6][type:2][rd:RB][ra:RB][rb:RB][imm:16]

where RB = ceil(log2(regs_per_thread)).  The 4-bit thread-space control
(TSC) field encodes the dynamic wavefront width/depth per Table 3.
"""
from __future__ import annotations

import enum
from typing import NamedTuple


class Op(enum.IntEnum):
    """The 61 eGPU opcodes (dense encoding, 6-bit field)."""

    # -- Integer arithmetic (4) ------------------------------------------
    ADD = 0
    SUB = 1
    NEG = 2
    ABS = 3
    # -- Integer multiply (4) --------------------------------------------
    MUL16LO = 4
    MUL16HI = 5
    MUL24LO = 6
    MUL24HI = 7
    # -- Integer logic (6) -----------------------------------------------
    AND = 8
    OR = 9
    XOR = 10
    NOT = 11
    CNOT = 12   # Rd = (Ra == 0) ? 1 : 0
    BVS = 13    # Rd = bit_reverse(Ra)
    # -- Integer shift (2) -----------------------------------------------
    SHL = 14
    SHR = 15
    # -- Integer other (3) -----------------------------------------------
    POP = 16    # population count
    MAX = 17
    MIN = 18
    # -- FP ALU (7) --------------------------------------------------------
    FADD = 19
    FSUB = 20
    FNEG = 21
    FABS = 22
    FMUL = 23
    FMAX = 24
    FMIN = 25
    # -- Memory (2) --------------------------------------------------------
    LOD = 26    # Rd = shared[Ra + offset]
    STO = 27    # shared[Ra + offset] = Rd
    # -- Immediate (1) -----------------------------------------------------
    LODI = 28   # Rd = imm (sign-extended 16-bit)
    # -- Thread id (2) -----------------------------------------------------
    TDX = 29
    TDY = 30
    # -- Extension units (3) -------------------------------------------------
    DOT = 31     # Rd[thread0] = <Ra, Rb> over active thread space
    SUM = 32     # Rd[thread0] = sum(Ra) over active thread space
    INVSQR = 33  # Rd = 1/sqrt(Ra)
    # -- Control (7) ---------------------------------------------------------
    JMP = 34
    JSR = 35
    RTS = 36
    LOOP = 37   # dec loop ctr; jump if != 0 else pop
    INIT = 38   # push loop ctr = imm
    STOP = 39
    NOP = 40
    # -- Conditionals: 18 IF.cc cases + ELSE + ENDIF (20) ---------------------
    IF_EQ = 41
    IF_NE = 42
    IF_LT = 43   # signed <
    IF_LO = 44   # unsigned <
    IF_LE = 45   # signed <=
    IF_LS = 46   # unsigned <=
    IF_GT = 47   # signed >
    IF_HI = 48   # unsigned >
    IF_GE = 49   # signed >=
    IF_HS = 50   # unsigned >=
    IF_FEQ = 51
    IF_FNE = 52
    IF_FLT = 53
    IF_FLE = 54
    IF_FGT = 55
    IF_FGE = 56
    IF_Z = 57    # Ra == 0
    IF_NZ = 58   # Ra != 0
    ELSE = 59
    ENDIF = 60


NUM_OPCODES = len(Op)
assert NUM_OPCODES == 61, NUM_OPCODES

_IF_OPS = tuple(op for op in Op if op.name.startswith("IF_"))
assert len(_IF_OPS) == 18  # "including 18 conditional cases"

#: The 18 IF.cc comparison opcodes (they push one predicate level).
IF_OPS = frozenset(_IF_OPS)

#: Ops that modify the per-thread predicate state: every IF.cc pushes a
#: level, ELSE flips the top, ENDIF pops.  Hazard tracking (executor,
#: assembler) keys the virtual predicate slot off this set — NOT off the
#: opcode ordering — so growing the enum past ENDIF cannot silently tag
#: new sequencer ops as predicate writers.
PRED_WRITE_OPS = frozenset(_IF_OPS) | {Op.ELSE, Op.ENDIF}


class Typ(enum.IntEnum):
    """2-bit representation field (Fig. 3)."""

    U32 = 0
    I32 = 1
    F32 = 2


# ---------------------------------------------------------------------------
# Thread-space control (Table 3).
#
#   width  [4:3]: 00 = all 16 SPs, 01 = first 4 SPs, 10 = SP0 only,
#                 11 = undefined (we reject it at assembly time)
#   depth  [2:1]: 00 = wavefront 0 only, 01 = all wavefronts,
#                 10 = first 1/2 wavefronts, 11 = first 1/4 wavefronts
# ---------------------------------------------------------------------------

WIDTH_ALL, WIDTH_QUARTER, WIDTH_ONE = 0, 1, 2
DEPTH_WF0, DEPTH_ALL, DEPTH_HALF, DEPTH_QUARTER = 0, 1, 2, 3

#: lanes enabled for each width code (index 3 is the undefined coding;
#: hardware behaviour is unspecified — we treat it as full width but the
#: assembler refuses to emit it).
WIDTH_LANES = (16, 4, 1, 16)


def tsc_encode(width: int, depth: int) -> int:
    if width == 3:
        raise ValueError("TSC width coding '11' is undefined (Table 3)")
    return ((width & 0x3) << 2) | (depth & 0x3)


def tsc_width(tsc: int) -> int:
    return (tsc >> 2) & 0x3


def tsc_depth(tsc: int) -> int:
    return tsc & 0x3


# Common "personalities" (paper §3.1): full SIMT, multithreaded CPU, MCU.
TSC_FULL = tsc_encode(WIDTH_ALL, DEPTH_ALL)          # standard SIMT
TSC_WF0 = tsc_encode(WIDTH_ALL, DEPTH_WF0)           # one wavefront
TSC_CPU = tsc_encode(WIDTH_ONE, DEPTH_ALL)           # multithreaded CPU (SP0)
TSC_MCU = tsc_encode(WIDTH_ONE, DEPTH_WF0)           # single thread 0
TSC_QUARTER = tsc_encode(WIDTH_QUARTER, DEPTH_ALL)   # first 4 SPs
TSC_HALF_DEPTH = tsc_encode(WIDTH_ALL, DEPTH_HALF)
TSC_QUARTER_DEPTH = tsc_encode(WIDTH_ALL, DEPTH_QUARTER)

PERSONALITIES = {
    "full": TSC_FULL,
    "wf0": TSC_WF0,
    "cpu": TSC_CPU,
    "mcu": TSC_MCU,
    "quarter": TSC_QUARTER,
    "half_depth": TSC_HALF_DEPTH,
    "quarter_depth": TSC_QUARTER_DEPTH,
}


# ---------------------------------------------------------------------------
# Instruction classes — used for cost accounting and the Fig. 6 profile.
# ---------------------------------------------------------------------------

class OpClass(enum.IntEnum):
    NOPC = 0       # NOPs (incl. hazard padding)
    INT = 1        # integer ALU (arith/mul/logic/shift/other)
    FP = 2         # FP ALU
    MEM_RD = 3     # shared-memory reads
    MEM_WR = 4     # shared-memory writes
    BRANCH = 5     # control flow (JMP/JSR/RTS/LOOP/INIT/STOP)
    THREAD = 6     # thread-id / immediate loads
    EXT = 7        # extension units (DOT/SUM/INVSQR)
    COND = 8       # predicates (IF/ELSE/ENDIF)


NUM_OP_CLASSES = len(OpClass)


def _opclass(op: Op) -> OpClass:
    if op == Op.NOP:
        return OpClass.NOPC
    if op in (Op.FADD, Op.FSUB, Op.FNEG, Op.FABS, Op.FMUL, Op.FMAX, Op.FMIN):
        return OpClass.FP
    if op == Op.LOD:
        return OpClass.MEM_RD
    if op == Op.STO:
        return OpClass.MEM_WR
    if op in (Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP):
        return OpClass.BRANCH
    if op in (Op.TDX, Op.TDY, Op.LODI):
        return OpClass.THREAD
    if op in (Op.DOT, Op.SUM, Op.INVSQR):
        return OpClass.EXT
    if op.value >= Op.IF_EQ:
        return OpClass.COND
    return OpClass.INT


OP_CLASS = tuple(_opclass(op) for op in Op)

#: Vector ops run over the thread space (charged per active wavefront);
#: scalar ops are sequencer-only and cost one cycle.
SCALAR_OPS = frozenset(
    {Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP, Op.NOP}
)

#: Ops that write a destination register (per-thread, mask-gated).
REG_WRITE_OPS = frozenset(
    op for op in Op
    if op not in SCALAR_OPS
    and op not in (Op.STO, Op.ELSE, Op.ENDIF)
    and not op.name.startswith("IF_")
)

#: Ops reading Ra / Rb (for hazard scheduling).
READS_RA = frozenset(
    op for op in Op
    if op not in SCALAR_OPS and op not in (Op.LODI, Op.TDX, Op.TDY, Op.ELSE, Op.ENDIF)
)
_TWO_SRC = {
    Op.ADD, Op.SUB, Op.MUL16LO, Op.MUL16HI, Op.MUL24LO, Op.MUL24HI,
    Op.AND, Op.OR, Op.XOR, Op.SHL, Op.SHR, Op.MAX, Op.MIN,
    Op.FADD, Op.FSUB, Op.FMUL, Op.FMAX, Op.FMIN, Op.DOT, Op.SUM,
}
READS_RB = frozenset(_TWO_SRC | {op for op in _IF_OPS if op not in (Op.IF_Z, Op.IF_NZ)})
#: STO reads Rd (the value being stored).
READS_RD = frozenset({Op.STO})


class Instr(NamedTuple):
    """A decoded instruction. ``imm`` is a signed 16-bit value."""

    op: int
    typ: int = Typ.U32
    rd: int = 0
    ra: int = 0
    rb: int = 0
    imm: int = 0
    tsc: int = TSC_FULL

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"{Op(self.op).name}.{Typ(self.typ).name} rd={self.rd} ra={self.ra} "
            f"rb={self.rb} imm={self.imm} tsc={self.tsc:04b}"
        )


# ---------------------------------------------------------------------------
# Instruction-word packing (Fig. 3), parameterised by register-field width.
# ---------------------------------------------------------------------------

def reg_bits(regs_per_thread: int) -> int:
    return max(1, (regs_per_thread - 1).bit_length())


def iw_bits(regs_per_thread: int) -> int:
    """Total IW width: 4 + 6 + 2 + 3*RB + 16 — 40/43/46 bits for 16/32/64
    registers per thread (§5.4).  Bit 0 of Fig. 3 is spare and not counted."""
    return 4 + 6 + 2 + 3 * reg_bits(regs_per_thread) + 16


def encode_word(ins: Instr, regs_per_thread: int) -> int:
    rb_ = reg_bits(regs_per_thread)
    for r in (ins.rd, ins.ra, ins.rb):
        if not 0 <= r < (1 << rb_):
            raise ValueError(f"register {r} out of range for {regs_per_thread} regs")
    imm = ins.imm & 0xFFFF
    w = imm << 1
    pos = 17
    w |= (ins.rb & ((1 << rb_) - 1)) << pos
    pos += rb_
    w |= (ins.ra & ((1 << rb_) - 1)) << pos
    pos += rb_
    w |= (ins.rd & ((1 << rb_) - 1)) << pos
    pos += rb_
    w |= (ins.typ & 0x3) << pos
    pos += 2
    w |= (ins.op & 0x3F) << pos
    pos += 6
    w |= (ins.tsc & 0xF) << pos
    return w


def decode_word(word: int, regs_per_thread: int) -> Instr:
    rb_ = reg_bits(regs_per_thread)
    imm = (word >> 1) & 0xFFFF
    if imm & 0x8000:  # sign-extend
        imm -= 0x10000
    pos = 17
    rbv = (word >> pos) & ((1 << rb_) - 1)
    pos += rb_
    rav = (word >> pos) & ((1 << rb_) - 1)
    pos += rb_
    rdv = (word >> pos) & ((1 << rb_) - 1)
    pos += rb_
    typ = (word >> pos) & 0x3
    pos += 2
    op = (word >> pos) & 0x3F
    pos += 6
    tsc = (word >> pos) & 0xF
    return Instr(op=op, typ=typ, rd=rdv, ra=rav, rb=rbv, imm=imm, tsc=tsc)
