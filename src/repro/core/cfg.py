"""Shared control-flow-graph decomposition over the packed ISA image.

Every tier of the stack needs the same structural view of a program:

* the basic-block compiler (``blockc``) drives a ``while_loop``+``switch``
  over the blocks,
* the superblock path simulator folds the executed block sequence,
* the static analyzer (``repro.analysis``) runs dataflow over the block
  graph.

The decomposition used to live privately in ``blockc._decompose``; it is
extracted here so the analyzer and the compiler agree bit-for-bit on
block boundaries.  The eGPU ISA has *no data-dependent branches* — every
JMP/JSR/LOOP target and every INIT trip count is an immediate — so this
graph is exact, not an approximation: the runtime path is one walk of it.

Edge kinds
----------
``fall``       straight-line fall-through (including artificial
               ``MAX_BLOCK`` splits and the not-taken LOOP exit)
``jump``       unconditional JMP
``call``       JSR to its (immediate) target
``return``     RTS to a return site (the instruction after some JSR);
               when the analyzer cannot prove which, every return site
               is a conservative successor
``loop_back``  LOOP back-edge to its (immediate) target
``loop_exit``  LOOP fall-through when the hardware loop counter hits 0

A pc leaving ``[0, n)`` halts the machine (the padded image tail is all
STOP), so blocks with no successors are genuine exits, and an
out-of-image branch target is a structural defect recorded in
``ProgramCFG.bad_targets`` rather than an edge.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .executor import _PF_IMM, _PF_OP
from .isa import Op

#: trace-size bound: longer straight-line runs are split with an
#: artificial fall-through (keeps per-block XLA compiles bounded)
MAX_BLOCK = 192

#: superblock trace budget — total instructions traced per compile
#: (straight-line runs plus each repeat body once); the generalization
#: of the per-block ``MAX_BLOCK`` bound to whole-path traces.  Programs
#: over budget fall back to the basic-block driver.
MAX_TRACE = 4096

#: sequencer ops that end a basic block (IF/ELSE/ENDIF are *predicate*
#: ops — they mask threads but never move the PC, so they trace inline)
SEQ_TERM = (int(Op.JMP), int(Op.JSR), int(Op.RTS), int(Op.LOOP),
            int(Op.STOP))

#: branch ops whose immediate is a program-counter target
TARGET_OPS = (int(Op.JMP), int(Op.JSR), int(Op.LOOP))


def decompose(packed: np.ndarray, n: int,
              max_block: int = MAX_BLOCK) -> list[tuple[int, int]]:
    """Split ``[0, n)`` into basic blocks ``(start, end)`` (end exclusive,
    terminator included).  Leaders: instruction 0, every in-range
    JMP/JSR/LOOP target, and every instruction after a sequencer op
    (fall-throughs and JSR return addresses)."""
    ops = packed[:n, _PF_OP]
    imms = packed[:n, _PF_IMM]
    leaders = {0}
    for i in range(n):
        o = int(ops[i])
        if o in TARGET_OPS:
            t = int(imms[i])
            if 0 <= t < n:
                leaders.add(t)
        if o in SEQ_TERM and i + 1 < n:
            leaders.add(i + 1)
    starts = sorted(leaders)
    blocks: list[tuple[int, int]] = []
    for s, e in zip(starts, starts[1:] + [n]):
        while e - s > max_block:
            blocks.append((s, s + max_block))
            s += max_block
        blocks.append((s, e))
    return blocks


@dataclass
class ProgramCFG:
    """Basic blocks plus typed edges over a packed program image."""

    n: int
    blocks: list[tuple[int, int]]
    #: per-block list of ``(successor_block_index, edge_kind)``
    succs: list[list[tuple[int, str]]]
    #: per-block predecessor block indices (kind-blind)
    preds: list[list[int]]
    #: pc -> index of the block containing it
    block_of: dict[int, int] = field(repr=False)
    #: pcs immediately after a JSR (conservative RTS successors)
    return_sites: list[int]
    #: ``(pc, op, target)`` for branch immediates outside ``[0, n)``
    bad_targets: list[tuple[int, int, int]]

    def reachable(self, entry: int = 0) -> set[int]:
        """Block indices reachable from the block containing ``entry``."""
        seen: set[int] = set()
        work = [self.block_of[entry]] if entry in self.block_of else []
        while work:
            b = work.pop()
            if b in seen:
                continue
            seen.add(b)
            work.extend(s for s, _ in self.succs[b] if s not in seen)
        return seen


def build_cfg(packed: np.ndarray, n: int,
              max_block: int = MAX_BLOCK) -> ProgramCFG:
    """Build the typed block graph for ``packed[:n]``.

    RTS blocks get a ``return`` edge to *every* return site — callers
    that can prove the return address (the analyzer's call-stack
    dataflow usually can) refine this themselves.
    """
    blocks = decompose(packed, n, max_block)
    block_of = {}
    for bi, (s, e) in enumerate(blocks):
        for pc in range(s, e):
            block_of[pc] = bi
    ops = packed[:n, _PF_OP]
    imms = packed[:n, _PF_IMM]
    return_sites = [i + 1 for i in range(n)
                    if int(ops[i]) == int(Op.JSR) and i + 1 < n]
    bad_targets = []
    succs: list[list[tuple[int, str]]] = []
    for bi, (s, e) in enumerate(blocks):
        out: list[tuple[int, str]] = []
        term = int(ops[e - 1])
        tgt = int(imms[e - 1])
        if term == int(Op.STOP):
            pass                                   # halt: no successors
        elif term == int(Op.JMP):
            if 0 <= tgt < n:
                out.append((block_of[tgt], "jump"))
            else:
                bad_targets.append((e - 1, term, tgt))
        elif term == int(Op.JSR):
            if 0 <= tgt < n:
                out.append((block_of[tgt], "call"))
            else:
                bad_targets.append((e - 1, term, tgt))
        elif term == int(Op.RTS):
            out.extend((block_of[r], "return") for r in return_sites)
        elif term == int(Op.LOOP):
            if 0 <= tgt < n:
                out.append((block_of[tgt], "loop_back"))
            else:
                bad_targets.append((e - 1, term, tgt))
            if e < n:
                out.append((block_of[e], "loop_exit"))
        else:                                      # plain fall-through
            if e < n:
                out.append((block_of[e], "fall"))
        succs.append(out)
    preds: list[list[int]] = [[] for _ in blocks]
    for bi, out in enumerate(succs):
        for sb, _ in out:
            if bi not in preds[sb]:
                preds[sb].append(bi)
    return ProgramCFG(n=n, blocks=blocks, succs=succs, preds=preds,
                      block_of=block_of, return_sites=return_sites,
                      bad_targets=bad_targets)


def summary(packed: np.ndarray, n: int) -> dict[str, float]:
    """Cheap structural facts for ``TierPolicy`` static features.

    Pure graph shape — no dataflow — so it is safe to compute on the
    compile path for every program."""
    g = build_cfg(packed, n)
    ops = packed[:n, _PF_OP]
    n_loops = int(np.sum(ops == int(Op.LOOP)))
    n_calls = int(np.sum(ops == int(Op.JSR)))
    reach = g.reachable(0)
    n_edges = sum(len(s) for s in g.succs)
    return {
        "cfg_blocks": float(len(g.blocks)),
        "cfg_edges": float(n_edges),
        "cfg_loops": float(n_loops),
        "cfg_calls": float(n_calls),
        "cfg_reachable_frac": float(len(reach) / max(1, len(g.blocks))),
        "cfg_straightline": float(n_loops == 0 and n_calls == 0
                                  and int(np.sum(ops == int(Op.JMP))) == 0),
    }
