"""Per-opcode semantics of the eGPU ISA, shared by every execution tier.

The interpreter (:mod:`repro.core.executor`), the basic-block compiler
(:mod:`repro.core.blockc`) and the vmapped fleet engine all execute the
same instruction semantics; this module is the single definition they
share.  It has two layers:

* :func:`build_spec` — the per-opcode *value/condition* functions.
  ``spec[op] = (value_fn | None, cond_fn | None)``: the register value an
  instruction produces, and (for IF.cc) the predicate condition it
  pushes.  The functions close over an :class:`OpEnv` whose fields may be
  **traced** scalars (the interpreter gathers ``op/typ/imm/...`` from the
  program image at run time) or **Python constants** (the block compiler
  bakes the static program in at trace time, so e.g. ``signed`` folds and
  the dead branch disappears).  Thread-space arrays carry an optional
  leading batch axis — every function is written against the *last*
  axes, so the same code serves one core ``(T,)`` and a fleet ``(B, T)``.

* structural-update helpers — predicate stacks, call/loop stacks, the
  deterministic DOT/SUM reduction.  Each takes an ``en`` gate that may be
  the Python constant ``True`` (compiler: the update statically applies)
  or a traced bool (interpreter: mask-gated select).

Bit-exactness is the contract: all integer results live in a uint32
register file, FP32 values are bitcast in and out of the FP units, and
the DOT/SUM reduction order is fixed (sequential over wavefronts,
pairwise tree within the 16-lane wavefront) so every tier produces
identical bits.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np
from jax import lax

from .config import EGPUConfig
from .isa import NUM_OPCODES, Op

_I32 = jnp.int32
_U32 = jnp.uint32


# ---------------------------------------------------------------------------
# Bit-exact integer/FP helpers (uint32 register file)
# ---------------------------------------------------------------------------

def _i(x):
    return x.astype(jnp.int32)


def _u(x):
    return x.astype(_U32)


def _f(x):
    return lax.bitcast_convert_type(x, jnp.float32)


def _bits(x):
    return lax.bitcast_convert_type(x.astype(jnp.float32), _U32)


def _sext16(x_u32):
    """Sign-extend the low 16 bits."""
    x = _i(x_u32 & _U32(0xFFFF))
    return jnp.where(x >= 1 << 15, x - (1 << 16), x)


def _sext24(x_u32):
    x = _i(x_u32 & _U32(0xFFFFFF))
    return jnp.where(x >= 1 << 23, x - (1 << 24), x)


def _bit_reverse32(x):
    x = ((x & _U32(0x55555555)) << 1) | ((x >> 1) & _U32(0x55555555))
    x = ((x & _U32(0x33333333)) << 2) | ((x >> 2) & _U32(0x33333333))
    x = ((x & _U32(0x0F0F0F0F)) << 4) | ((x >> 4) & _U32(0x0F0F0F0F))
    x = ((x & _U32(0x00FF00FF)) << 8) | ((x >> 8) & _U32(0x00FF00FF))
    x = (x << 16) | (x >> 16)
    return x


def _mul24(a_u32, b_u32, signed):
    """24x24 -> 48-bit product as (hi24, lo24) uint32 limb pair.

    Implemented in 32-bit limbs (the container runs with x64 disabled,
    and the hardware is a 24-bit multiplier anyway).
    """
    if signed:
        sa = _sext24(a_u32)
        sb = _sext24(b_u32)
        neg = (sa < 0) ^ (sb < 0)
        a = _u(jnp.abs(sa))
        b = _u(jnp.abs(sb))
    else:
        neg = jnp.zeros(a_u32.shape, jnp.bool_)
        a = a_u32 & _U32(0xFFFFFF)
        b = b_u32 & _U32(0xFFFFFF)
    m12 = _U32((1 << 12) - 1)
    m24 = _U32((1 << 24) - 1)
    ah, al = a >> 12, a & m12
    bh, bl = b >> 12, b & m12
    low = al * bl                       # < 2^24
    mid = ah * bl + al * bh             # < 2^25
    t = mid + (low >> 12)               # < 2^26
    hi = ah * bh + (t >> 12)            # bits [47:24]
    lo = ((t & m12) << 12) | (low & m12)  # bits [23:0]
    # two's-complement negate the 48-bit (hi, lo) pair where requested
    nlo = (-lo) & m24
    borrow = (lo != 0).astype(_U32)
    nhi = ((~hi) & m24) + _U32(1) - borrow
    nhi = nhi & m24
    hi = jnp.where(neg, nhi, hi)
    lo = jnp.where(neg, nlo, lo)
    return hi, lo, neg


def _sel(c, a, b):
    """``jnp.where`` that folds when the predicate is a Python constant.

    The block compiler bakes ``typ`` in, so ``signed`` is a plain bool and
    the dead branch never enters the jaxpr; the interpreter passes a
    traced bool and gets the usual select.
    """
    if isinstance(c, (bool, np.bool_)):
        return a if c else b
    return jnp.where(c, a, b)


def det_sum(v, num_sps: int = 16):
    """Deterministic thread-space reduction (DOT/SUM extension unit).

    Sequential over wavefronts, pairwise tree within the 16-lane
    wavefront, like the hardware's accumulator — so the interpreter, the
    block compiler and the vmapped fleet produce bit-identical sums
    (``jnp.sum`` may associate differently under vmap/batching).  ``v``
    is ``(..., T)``; returns ``(...)``.
    """
    T = v.shape[-1]
    m = v.reshape(v.shape[:-1] + (T // num_sps, num_sps))
    acc = m[..., 0, :]
    for i in range(1, T // num_sps):
        acc = acc + m[..., i, :]
    s = num_sps // 2
    while s >= 1:
        acc = acc[..., :s] + acc[..., s:2 * s]
        s //= 2
    return acc[..., 0]


# ---------------------------------------------------------------------------
# The operand environment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpEnv:
    """Everything an opcode's value function reads.

    ``rav/rbv/rdv`` are the Ra/Rb/Rd operand columns ``(..., T)`` uint32;
    ``signed``/``imm`` are the decoded type/immediate fields — traced
    scalars under the interpreter, Python constants under the block
    compiler; ``mask`` is the active-thread mask (TSC x predicates) that
    gates the DOT/SUM reduction; ``shared`` is ``(..., S)`` and
    ``tdx_dim`` a scalar or ``(...,)`` per-core vector.
    """

    cfg: EGPUConfig
    rav: Any
    rbv: Any
    rdv: Any
    signed: Any               # traced bool or Python bool
    imm: Any                  # traced int32 or Python int
    mask: Any                 # (..., T) bool
    tid: Any                  # (T,) int32
    shared: Any               # (..., S)
    tdx_dim: Any              # scalar or (...,) int32

    @property
    def alu_mask(self):
        bits = self.cfg.alu_bits
        return _U32((1 << bits) - 1 if bits < 32 else 0xFFFFFFFF)

    def imask(self, v):
        """Integer ALU precision (16-bit ALU configs clip to alu_bits)."""
        return v.astype(_U32) & self.alu_mask

    @property
    def addr(self):
        """LOD/STO effective address: Ra + offset, per thread."""
        return _i(self.rav) + self.imm

    def load(self, addr):
        """Shared-memory gather with the hardware's address clamp."""
        S = self.shared.shape[-1]
        a = jnp.clip(addr, 0, S - 1)
        if self.shared.ndim == 1:
            return self.shared[a]
        return jnp.take_along_axis(self.shared, a, axis=-1)


def store(shared, sidx, val):
    """The one true scatter: STO to shared memory.

    ``sidx`` is the per-thread target index with inactive/out-of-range
    threads already pointed at ``S`` (dropped).  Batched shared memory
    ``(B, S)`` is written as a single flattened scatter — a per-core
    batched scatter is the slowest op on the CPU backend by an order of
    magnitude.
    """
    S = shared.shape[-1]
    if shared.ndim == 1:
        return shared.at[sidx].set(val, mode="drop")
    n = shared.shape[0]
    core = jnp.arange(n, dtype=_I32).reshape((n,) + (1,) * (sidx.ndim - 1))
    flat = jnp.where(sidx < S, core * S + sidx, n * S).ravel()
    return shared.ravel().at[flat].set(val.ravel(),
                                       mode="drop").reshape(shared.shape)


# ---------------------------------------------------------------------------
# Per-opcode value / condition functions
# ---------------------------------------------------------------------------

def build_spec(env: OpEnv) -> list:
    """``spec[op] = (value_fn | None, cond_fn | None)`` over all opcodes.

    Control ops carry no value function (their register write is gated
    off by the ``writes_rd`` table / never emitted by the compiler).
    """
    cfg = env.cfg
    rav, rbv = env.rav, env.rbv
    signed = env.signed
    imask = env.imask

    def shift_amt():
        return rbv & _U32(cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)

    def f_add(): return imask(rav + rbv)
    def f_sub(): return imask(rav - rbv)
    def f_negi(): return imask(_u(-_i(rav)))
    def f_absi(): return imask(_u(jnp.abs(_i(rav))))

    def f_mul16lo():
        p_s = _sext16(rav) * _sext16(rbv)
        p_u = _i((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF)))
        return imask(_u(_sel(signed, p_s, p_u)))

    def f_mul16hi():
        p_s = (_sext16(rav) * _sext16(rbv)) >> 16
        p_u = _u((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF))) >> 16
        return imask(_sel(signed, _u(p_s), p_u))

    def f_mul24lo():
        hi, lo, _ = _mul24(rav, rbv, False)
        hi_s, lo_s, _ = _mul24(rav, rbv, True)
        # low 32 bits of the 48-bit product
        u = (lo | (hi << 24))
        s = (lo_s | (hi_s << 24))
        return imask(_sel(signed, s, u))

    def f_mul24hi():
        hi, lo, _ = _mul24(rav, rbv, False)
        hi_s, lo_s, neg = _mul24(rav, rbv, True)
        # arithmetic >>24 of the 48-bit product: extend from bit 47
        # (== bit 23 of hi24) — NOT from the sign flag, which is also
        # set for zero products of opposite-signed operands
        s = jnp.where((hi_s & _U32(0x800000)) != 0,
                      hi_s | _U32(0xFF000000), hi_s)
        return imask(_sel(signed, s, hi))

    def f_and(): return imask(rav & rbv)
    def f_or(): return imask(rav | rbv)
    def f_xor(): return imask(rav ^ rbv)
    def f_not(): return imask(~rav)
    def f_cnot(): return imask(jnp.where(rav == 0, _U32(1), _U32(0)))
    def f_bvs(): return imask(_bit_reverse32(rav))

    def f_shl(): return imask(rav << shift_amt())

    def f_shr():
        log = rav >> shift_amt()
        ari = _u(_i(rav) >> _i(shift_amt()))
        return imask(_sel(signed, ari, log))

    def f_pop(): return imask(lax.population_count(rav))

    def f_max():
        s = jnp.where(_i(rav) > _i(rbv), rav, rbv)
        u = jnp.where(rav > rbv, rav, rbv)
        return imask(_sel(signed, s, u))

    def f_min():
        s = jnp.where(_i(rav) < _i(rbv), rav, rbv)
        u = jnp.where(rav < rbv, rav, rbv)
        return imask(_sel(signed, s, u))

    # FP (bitcast through the uint32 register file)
    def f_fadd(): return _bits(_f(rav) + _f(rbv))
    def f_fsub(): return _bits(_f(rav) - _f(rbv))
    def f_fneg(): return rav ^ _U32(0x80000000)
    def f_fabs(): return rav & _U32(0x7FFFFFFF)
    def f_fmul(): return _bits(_f(rav) * _f(rbv))
    def f_fmax(): return _bits(jnp.maximum(_f(rav), _f(rbv)))
    def f_fmin(): return _bits(jnp.minimum(_f(rav), _f(rbv)))

    # memory / immediates / thread ids.  LODI/TDX/TDY results are
    # produced by the integer datapath, so a 16-bit ALU clips them to
    # ``alu_bits`` like any other integer result; LOD is *not* masked
    # (the shared memory is a full 32-bit datapath) and neither are the
    # FP units (bitcast results bypass the integer ALU entirely).
    def f_lod():
        return env.load(env.addr)

    def f_lodi():
        return imask(jnp.broadcast_to(_u(jnp.int32(env.imm)), rav.shape))

    def f_tdx():
        d = jnp.asarray(env.tdx_dim, _I32)
        return imask(_u(jnp.broadcast_to(env.tid % d[..., None], rav.shape)))

    def f_tdy():
        d = jnp.asarray(env.tdx_dim, _I32)
        return imask(_u(jnp.broadcast_to(env.tid // d[..., None], rav.shape)))

    # extension units: DOT/SUM land in thread 0's Rd.
    def f_dot():
        s = det_sum(jnp.where(env.mask, _f(rav) * _f(rbv), 0.0),
                    cfg.num_sps)
        return jnp.broadcast_to(_bits(s)[..., None], rav.shape)

    def f_sum():
        s = det_sum(jnp.where(env.mask, _f(rav), 0.0), cfg.num_sps)
        return jnp.broadcast_to(_bits(s)[..., None], rav.shape)

    def f_invsqr(): return _bits(lax.rsqrt(_f(rav)))

    fa, fb = _f(rav), _f(rbv)
    spec: list = [None] * NUM_OPCODES
    for o, f in [(Op.ADD, f_add), (Op.SUB, f_sub), (Op.NEG, f_negi),
                 (Op.ABS, f_absi), (Op.MUL16LO, f_mul16lo),
                 (Op.MUL16HI, f_mul16hi), (Op.MUL24LO, f_mul24lo),
                 (Op.MUL24HI, f_mul24hi), (Op.AND, f_and), (Op.OR, f_or),
                 (Op.XOR, f_xor), (Op.NOT, f_not), (Op.CNOT, f_cnot),
                 (Op.BVS, f_bvs), (Op.SHL, f_shl), (Op.SHR, f_shr),
                 (Op.POP, f_pop), (Op.MAX, f_max), (Op.MIN, f_min),
                 (Op.FADD, f_fadd), (Op.FSUB, f_fsub), (Op.FNEG, f_fneg),
                 (Op.FABS, f_fabs), (Op.FMUL, f_fmul), (Op.FMAX, f_fmax),
                 (Op.FMIN, f_fmin), (Op.LOD, f_lod), (Op.LODI, f_lodi),
                 (Op.TDX, f_tdx), (Op.TDY, f_tdy), (Op.DOT, f_dot),
                 (Op.SUM, f_sum), (Op.INVSQR, f_invsqr)]:
        spec[o] = (f, None)
    for o, f in [(Op.IF_EQ, lambda: rav == rbv),
                 (Op.IF_NE, lambda: rav != rbv),
                 (Op.IF_LT, lambda: _i(rav) < _i(rbv)),
                 (Op.IF_LO, lambda: rav < rbv),
                 (Op.IF_LE, lambda: _i(rav) <= _i(rbv)),
                 (Op.IF_LS, lambda: rav <= rbv),
                 (Op.IF_GT, lambda: _i(rav) > _i(rbv)),
                 (Op.IF_HI, lambda: rav > rbv),
                 (Op.IF_GE, lambda: _i(rav) >= _i(rbv)),
                 (Op.IF_HS, lambda: rav >= rbv),
                 (Op.IF_FEQ, lambda: fa == fb),
                 (Op.IF_FNE, lambda: fa != fb),
                 (Op.IF_FLT, lambda: fa < fb),
                 (Op.IF_FLE, lambda: fa <= fb),
                 (Op.IF_FGT, lambda: fa > fb),
                 (Op.IF_FGE, lambda: fa >= fb),
                 (Op.IF_Z, lambda: rav == 0),
                 (Op.IF_NZ, lambda: rav != 0)]:
        spec[o] = (None, f)
    return spec


# ---------------------------------------------------------------------------
# Structural updates: predicate stacks (divergence, Fig. 2)
# ---------------------------------------------------------------------------
#
# ``pstack`` is (..., T, D) bool, ``pdepth`` (T,) or (..., T) int32.  The
# ``en`` gate may be the Python constant True (block compiler: the op
# statically executes) or a traced bool (interpreter: mask-gated).

def pred_ok(pstack, pdepth, D: int):
    """Threads whose every pushed predicate level is True: ``(..., T)``."""
    lvl = jnp.arange(D, dtype=_I32)
    return jnp.all(pstack | (lvl >= pdepth[..., :, None]), axis=-1)


def pred_push(pstack, pdepth, cond, tsc_mask, D: int, en=True):
    """IF.cc: push ``cond`` at the current depth for TSC-active threads."""
    lvl = jnp.arange(D, dtype=_I32)
    oh = (lvl == pdepth[..., :, None]) & tsc_mask[..., :, None] & en
    ps = jnp.where(oh, cond[..., :, None], pstack)
    pd = pdepth + jnp.where(tsc_mask & (pdepth < D) & en, 1, 0)
    return ps, pd


def pred_else(pstack, pdepth, tsc_mask, D: int, en=True):
    """ELSE: flip the top predicate level of TSC-active threads."""
    lvl = jnp.arange(D, dtype=_I32)
    oh = (lvl == (pdepth[..., :, None] - 1)) & tsc_mask[..., :, None] \
        & (pdepth[..., :, None] > 0) & en
    return pstack ^ oh


def pred_pop(pdepth, tsc_mask, en=True):
    """ENDIF: pop one predicate level from TSC-active threads."""
    return pdepth - jnp.where(tsc_mask & (pdepth > 0) & en, 1, 0)


# ---------------------------------------------------------------------------
# Structural updates: sequencer (call/loop stacks)
# ---------------------------------------------------------------------------

def call_push(cstack, csp, ret_pc, en=True):
    """JSR: push the return address (write dropped when the stack is
    full; the pointer still moves, mirroring the one-hot select)."""
    idx = jnp.arange(cstack.shape[-1], dtype=_I32)
    cm = (idx == csp) & en
    return jnp.where(cm, ret_pc, cstack), csp + jnp.where(en, 1, 0)


def call_top(cstack, csp):
    """RTS target: the last pushed return address.

    The index follows JAX dynamic-gather semantics exactly (negative
    wraps once, then clamps) so an unbalanced RTS reads the same slot in
    every execution tier.
    """
    return cstack[csp - 1]


def loop_init(lctr, lsp, count, en=True):
    """INIT: push a loop counter (write dropped when out of range; the
    pointer still moves)."""
    idx = jnp.arange(lctr.shape[-1], dtype=_I32)
    lm = (idx == lsp) & en
    return jnp.where(lm, count, lctr), lsp + jnp.where(en, 1, 0)


def loop_top(lctr, lsp):
    """The counter LOOP tests: top of the loop stack (JAX dynamic-gather
    index semantics, like :func:`call_top`)."""
    return lctr[lsp - 1]


def loop_step(lctr, lsp, en=True):
    """LOOP: decrement the top counter; returns (lctr', taken, lsp_pop)
    where ``lsp_pop`` is the stack pointer after a not-taken pop."""
    lsp1 = lsp - 1
    ltop = loop_top(lctr, lsp)
    taken = ltop > 0
    idx = jnp.arange(lctr.shape[-1], dtype=_I32)
    lctr2 = jnp.where((idx == lsp1) & en, ltop - 1, lctr)
    return lctr2, taken, lsp1
