"""The eGPU SIMT executor: a jitted ``lax.while_loop`` interpreter.

One ``while_loop`` iteration = one instruction.  All threads execute the
instruction *vectorised* (the hardware issues one 16-lane wavefront per
cycle; we charge cycles through the cost model rather than looping), with
the active-thread mask derived from

  * the instruction's 4-bit thread-space control field (dynamic
    scalability, Table 3),
  * the runtime thread count (static scalability),
  * the per-thread predicate stacks (divergence, Fig. 2).

Cycle accounting matches :mod:`repro.core.cost` exactly, and a built-in
hazard checker counts read-after-write violations (the eGPU has no hazard
hardware; a correct program — i.e. one produced by the assembler's
scheduler — must report zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import isa
from .assembler import ProgramImage
from .config import EGPUConfig
from .isa import Op, Typ
from .machine import MachineState, init_state

_I32 = jnp.int32
_U32 = jnp.uint32

# virtual hazard slots
_HZ_MEM = -2
_HZ_PRED = -1


# ---------------------------------------------------------------------------
# Constant per-opcode tables (built once per config, baked into the jaxpr).
#
# All per-opcode metadata lives in ONE (NUM_OPCODES, 11) int32 table so the
# step function fetches it with a single dynamic row gather — under the
# vmapped fleet every separate gather is a separate (batched) HLO op, and
# the step is op-dispatch bound on CPU, not FLOP bound.
# ---------------------------------------------------------------------------

# table columns
(_TC_SCALAR, _TC_READS_RA, _TC_READS_RB, _TC_READS_RD, _TC_WRITES_RD,
 _TC_LAT, _TC_CLS, _TC_PER_WF0) = range(8)          # per_wf spans cols 7..10

# program-image columns (see pad_image)
_PF_OP, _PF_TYP, _PF_RD, _PF_RA, _PF_RB, _PF_IMM, _PF_TSC = range(7)
PROG_FIELDS = ("op", "typ", "rd", "ra", "rb", "imm", "tsc")


def _tables(cfg: EGPUConfig):
    n = isa.NUM_OPCODES
    t = np.zeros((n, 11), np.int32)
    t[:, _TC_PER_WF0:] = 1
    from . import cost as _cost

    for op in Op:
        t[op, _TC_SCALAR] = op in isa.SCALAR_OPS
        t[op, _TC_READS_RA] = op in isa.READS_RA
        t[op, _TC_READS_RB] = op in isa.READS_RB
        t[op, _TC_READS_RD] = op in isa.READS_RD
        t[op, _TC_WRITES_RD] = op in isa.REG_WRITE_OPS
        t[op, _TC_LAT] = _cost.result_latency(op, cfg)
        t[op, _TC_CLS] = isa.OP_CLASS[op]
        for wc in range(4):
            width = isa.WIDTH_LANES[wc]
            if op == Op.LOD:
                t[op, _TC_PER_WF0 + wc] = -(-width // cfg.cost.sp_read_ports)
            elif op == Op.STO:
                t[op, _TC_PER_WF0 + wc] = -(-width // cfg.write_ports)
    return jnp.asarray(t)


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Integer helpers (bit-exact, uint32 register file)
# ---------------------------------------------------------------------------

def _i(x):
    return x.astype(jnp.int32)


def _u(x):
    return x.astype(_U32)


def _f(x):
    return lax.bitcast_convert_type(x, jnp.float32)


def _bits(x):
    return lax.bitcast_convert_type(x.astype(jnp.float32), _U32)


def _sext16(x_u32):
    """Sign-extend the low 16 bits."""
    x = _i(x_u32 & _U32(0xFFFF))
    return jnp.where(x >= 1 << 15, x - (1 << 16), x)


def _sext24(x_u32):
    x = _i(x_u32 & _U32(0xFFFFFF))
    return jnp.where(x >= 1 << 23, x - (1 << 24), x)


def _bit_reverse32(x):
    x = ((x & _U32(0x55555555)) << 1) | ((x >> 1) & _U32(0x55555555))
    x = ((x & _U32(0x33333333)) << 2) | ((x >> 2) & _U32(0x33333333))
    x = ((x & _U32(0x0F0F0F0F)) << 4) | ((x >> 4) & _U32(0x0F0F0F0F))
    x = ((x & _U32(0x00FF00FF)) << 8) | ((x >> 8) & _U32(0x00FF00FF))
    x = (x << 16) | (x >> 16)
    return x


def _mul24(a_u32, b_u32, signed):
    """24x24 -> 48-bit product as (hi24, lo24) uint32 limb pair.

    Implemented in 32-bit limbs (the container runs with x64 disabled,
    and the hardware is a 24-bit multiplier anyway).
    """
    if signed:
        sa = _sext24(a_u32)
        sb = _sext24(b_u32)
        neg = (sa < 0) ^ (sb < 0)
        a = _u(jnp.abs(sa))
        b = _u(jnp.abs(sb))
    else:
        neg = jnp.zeros(a_u32.shape, jnp.bool_)
        a = a_u32 & _U32(0xFFFFFF)
        b = b_u32 & _U32(0xFFFFFF)
    m12 = _U32((1 << 12) - 1)
    m24 = _U32((1 << 24) - 1)
    ah, al = a >> 12, a & m12
    bh, bl = b >> 12, b & m12
    low = al * bl                       # < 2^24
    mid = ah * bl + al * bh             # < 2^25
    t = mid + (low >> 12)               # < 2^26
    hi = ah * bh + (t >> 12)            # bits [47:24]
    lo = ((t & m12) << 12) | (low & m12)  # bits [23:0]
    # two's-complement negate the 48-bit (hi, lo) pair where requested
    nlo = (-lo) & m24
    borrow = (lo != 0).astype(_U32)
    nhi = ((~hi) & m24) + _U32(1) - borrow
    nhi = nhi & m24
    hi = jnp.where(neg, nhi, hi)
    lo = jnp.where(neg, nlo, lo)
    return hi, lo, neg


# ---------------------------------------------------------------------------
# Step function
# ---------------------------------------------------------------------------

_PAD = 64  # programs are padded to a multiple of this to share compiles


@functools.lru_cache(maxsize=64)
def make_step(cfg: EGPUConfig, prog_len: int,
              ops_subset: frozenset | None = None, *,
              flat_dispatch: bool = False, check_hazards: bool = True,
              collect_stats: bool = True):
    """Build the per-instruction semantics for one eGPU core.

    Returns ``(step, running)``: ``step(state, prog, act=None) ->
    (state, sto_idx, sto_val)`` executes exactly one instruction
    (``prog`` is the packed ``(prog_len, 7)`` image from
    :func:`pad_image`), and ``running(state) -> bool`` is the continue
    predicate.  The split from the ``while_loop`` driver is what lets the
    same semantics power both :func:`run_program` (single core) and the
    vmapped fleet engine (:mod:`repro.fleet.engine`).

    The state update is *flat*: the per-opcode ``lax.switch`` only selects
    the value an instruction produces (a ``(T,)`` vector plus an IF.cc
    condition), and every architectural structure — register file,
    predicate/loop/call stacks, PC — is then updated exactly once with
    mask-gated one-hot selects.  Under ``jax.vmap`` a switch over a
    batched opcode lowers to "execute every branch, select one", and a
    batched scatter is pathologically slow on the CPU backend, so the
    step avoids scatters entirely:

    * small structures (hazard rows, stacks, stat counters) use one-hot
      ``where`` selects, which fuse;
    * the one real scatter — the STO write to shared memory — is
      *deferred*: ``step`` returns ``(state, sto_idx, sto_val)`` and the
      driver applies it (the fleet driver as a single flattened scatter
      for the whole batch, gated on "any core is storing this cycle").

    ``act`` (bool, default True) gates every write, so a halted core
    no-ops without a second freeze pass over the state.

    ``ops_subset`` (a frozenset of opcode ints) specializes the dispatch to
    the instruction working set of the program(s) actually being run —
    opcodes outside the subset map to a dummy branch.  The fleet packs the
    union of its batch's opcodes here, shrinking the vmapped
    all-branches dispatch several-fold.

    ``flat_dispatch`` replaces the ``lax.switch`` with a nested-``where``
    chain: correct in both drivers, but chosen per driver for speed — the
    switch wins single-core (one branch executes), the chain wins vmapped
    (everything fuses into a few kernels instead of per-branch launches).

    ``check_hazards=False`` / ``collect_stats=False`` drop the RAW hazard
    checker / the Fig. 6 instruction-mix counters from the compiled step.
    Neither affects the architectural results (registers, shared memory,
    cycles, PC trace) — the real eGPU has no hazard hardware or counters —
    so throughput-oriented fleet runs can shed their cost.
    """
    T = cfg.max_threads
    R = cfg.regs_per_thread
    S = cfg.shared_words
    D = max(1, cfg.predicate_levels)
    tables = _tables(cfg)
    tid = jnp.arange(T, dtype=_I32)
    lane = tid % cfg.num_sps
    wf = tid // cfg.num_sps
    width_lanes = jnp.asarray(isa.WIDTH_LANES, _I32)

    branch_ops = sorted(ops_subset) if ops_subset is not None \
        else list(range(isa.NUM_OPCODES))
    remap_np = np.full((isa.NUM_OPCODES,), len(branch_ops), np.int32)
    for i, o in enumerate(branch_ops):
        remap_np[o] = i
    remap = jnp.asarray(remap_np)

    def step(st: MachineState, prog, act=None):
        gate = jnp.bool_(True) if act is None else act
        pc = st.pc
        row = prog[pc]                   # one gather for all seven fields
        op = row[_PF_OP]
        typ = row[_PF_TYP]
        rd = row[_PF_RD]
        ra = row[_PF_RA]
        rb = row[_PF_RB]
        imm = row[_PF_IMM]
        tsc = row[_PF_TSC]
        trow = tables[op]                # one gather for all opcode metadata

        width_code = (tsc >> 2) & 3
        depth_code = tsc & 3
        w_rt = _cdiv(st.threads_active, cfg.num_sps)
        wfs = jnp.stack([_I32(1), w_rt, jnp.maximum(1, _cdiv(w_rt, 2)),
                         jnp.maximum(1, _cdiv(w_rt, 4))])[depth_code]
        lanes = width_lanes[width_code]
        per_wf_c = trow[_TC_PER_WF0 + width_code]
        is_scalar = trow[_TC_SCALAR] == 1
        writes_rd = trow[_TC_WRITES_RD] == 1
        issue = jnp.where(is_scalar, _I32(1), per_wf_c * wfs)

        # --- active masks ------------------------------------------------
        tsc_mask = (lane < lanes) & (wf < wfs) & (tid < st.threads_active)
        lvl = jnp.arange(D, dtype=_I32)
        pred_ok = jnp.all(st.pstack | (lvl[None, :] >= st.pdepth[:, None]),
                          axis=1)
        mask = tsc_mask & pred_ok

        # --- operand reads (one gather) ----------------------------------
        srcs = jnp.stack([ra, rb, rd])
        vals = st.regs[:, srcs]          # (T, 3)
        rav, rbv, rdv = vals[:, 0], vals[:, 1], vals[:, 2]

        # --- hazard checker (RAW), vectorised over the five read slots ---
        hz = st.hazard
        violated = jnp.bool_(False)
        if check_hazards:
            rows = jnp.concatenate([hz[srcs], hz[R:R + 2]])  # ra/rb/rd/mem/pred
            p_start, p_per_wf = rows[:, 0], rows[:, 1]
            p_wfs, p_lat = rows[:, 2], rows[:, 3]
            k_max = jnp.minimum(p_wfs, wfs) - 1
            k = jnp.where(p_per_wf > per_wf_c, k_max, 0)
            cons = p_start + p_per_wf * (k + 1) - 1 + p_lat - per_wf_c * k
            pred_reads = (~is_scalar) if cfg.has_predicates \
                else jnp.bool_(False)
            flags = jnp.stack([trow[_TC_READS_RA] == 1,
                               trow[_TC_READS_RB] == 1,
                               trow[_TC_READS_RD] == 1, op == Op.LOD,
                               pred_reads])
            neg_inf = _I32(-(1 << 30))
            need = jnp.max(jnp.where(flags, cons, neg_inf))
            violated = (~is_scalar | (op == Op.LOD)) & (need > st.cycles)

            # writer bookkeeping: rd / shared-memory / predicate rows as one
            # fused one-hot select (scatters are slow on the vmapped path)
            new_row = jnp.stack([st.cycles, per_wf_c, wfs, trow[_TC_LAT]])
            none = _I32(-9)
            ridx = jnp.arange(R + 2, dtype=_I32)
            hrow = ((ridx == jnp.where(writes_rd, rd, none)) |
                    (ridx == jnp.where(op == Op.STO, _I32(R + 2 + _HZ_MEM),
                                       none)) |
                    (ridx == jnp.where(op >= Op.IF_EQ,
                                       _I32(R + 2 + _HZ_PRED), none))) & gate
            hz = jnp.where(hrow[:, None], new_row[None, :], hz)

        # --- semantic helpers ---------------------------------------------
        alu_mask = _U32((1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32
                        else 0xFFFFFFFF)

        def imask(v):  # integer ALU precision (16-bit ALU configs)
            return v.astype(_U32) & alu_mask

        signed = typ == Typ.I32

        # --- per-opcode value functions ------------------------------------
        def shift_amt():
            return rbv & _U32(cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)

        def f_add(): return imask(rav + rbv)
        def f_sub(): return imask(rav - rbv)
        def f_negi(): return imask(_u(-_i(rav)))
        def f_absi(): return imask(_u(jnp.abs(_i(rav))))

        def f_mul16lo():
            p_s = _sext16(rav) * _sext16(rbv)
            p_u = _i((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF)))
            return imask(_u(jnp.where(signed, p_s, p_u)))

        def f_mul16hi():
            p_s = (_sext16(rav) * _sext16(rbv)) >> 16
            p_u = _u((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF))) >> 16
            return imask(jnp.where(signed, _u(p_s), p_u))

        def f_mul24lo():
            hi, lo, _ = _mul24(rav, rbv, False)
            hi_s, lo_s, _ = _mul24(rav, rbv, True)
            # low 32 bits of the 48-bit product
            u = (lo | (hi << 24))
            s = (lo_s | (hi_s << 24))
            return imask(jnp.where(signed, s, u))

        def f_mul24hi():
            hi, lo, _ = _mul24(rav, rbv, False)
            hi_s, lo_s, neg = _mul24(rav, rbv, True)
            # arithmetic >>24 of the 48-bit product: extend from bit 47
            # (== bit 23 of hi24) — NOT from the sign flag, which is also
            # set for zero products of opposite-signed operands
            s = jnp.where((hi_s & _U32(0x800000)) != 0,
                          hi_s | _U32(0xFF000000), hi_s)
            return imask(jnp.where(signed, s, hi))

        def f_and(): return imask(rav & rbv)
        def f_or(): return imask(rav | rbv)
        def f_xor(): return imask(rav ^ rbv)
        def f_not(): return imask(~rav)
        def f_cnot(): return imask(jnp.where(rav == 0, _U32(1), _U32(0)))
        def f_bvs(): return imask(_bit_reverse32(rav))

        def f_shl(): return imask(rav << shift_amt())

        def f_shr():
            log = rav >> shift_amt()
            ari = _u(_i(rav) >> _i(shift_amt()))
            return imask(jnp.where(signed, ari, log))

        def f_pop(): return imask(lax.population_count(rav))

        def f_max():
            s = jnp.where(_i(rav) > _i(rbv), rav, rbv)
            u = jnp.where(rav > rbv, rav, rbv)
            return imask(jnp.where(signed, s, u))

        def f_min():
            s = jnp.where(_i(rav) < _i(rbv), rav, rbv)
            u = jnp.where(rav < rbv, rav, rbv)
            return imask(jnp.where(signed, s, u))

        # FP (bitcast through the uint32 register file)
        def f_fadd(): return _bits(_f(rav) + _f(rbv))
        def f_fsub(): return _bits(_f(rav) - _f(rbv))
        def f_fneg(): return rav ^ _U32(0x80000000)
        def f_fabs(): return rav & _U32(0x7FFFFFFF)
        def f_fmul(): return _bits(_f(rav) * _f(rbv))
        def f_fmax(): return _bits(jnp.maximum(_f(rav), _f(rbv)))
        def f_fmin(): return _bits(jnp.minimum(_f(rav), _f(rbv)))

        # memory / immediates / thread ids.  LODI/TDX/TDY results are
        # produced by the integer datapath, so a 16-bit ALU clips them to
        # ``alu_bits`` like any other integer result; LOD is *not* masked
        # (the shared memory is a full 32-bit datapath) and neither are the
        # FP units (bitcast results bypass the integer ALU entirely).
        addr = _i(rav) + imm

        def f_lod():
            return st.shared[jnp.clip(addr, 0, S - 1)]

        def f_lodi():
            return imask(jnp.broadcast_to(_u(imm), (T,)))

        def f_tdx(): return imask(_u(tid % st.tdx_dim))
        def f_tdy(): return imask(_u(tid // st.tdx_dim))

        # extension units: DOT/SUM land in thread 0's Rd.  The reduction
        # order is fixed (sequential over wavefronts, pairwise tree within
        # the 16-lane wavefront, like the hardware's accumulator) so the
        # single-core and vmapped fleet paths produce bit-identical sums —
        # ``jnp.sum`` may associate differently under vmap.
        def _det_sum(v):
            m = v.reshape(T // 16, 16)
            acc = m[0]
            for i in range(1, T // 16):
                acc = acc + m[i]
            for s in (8, 4, 2, 1):
                acc = acc[:s] + acc[s:2 * s]
            return acc[0]

        def f_dot():
            s = _det_sum(jnp.where(mask, _f(rav) * _f(rbv), 0.0))
            return jnp.broadcast_to(_bits(s), (T,))

        def f_sum():
            s = _det_sum(jnp.where(mask, _f(rav), 0.0))
            return jnp.broadcast_to(_bits(s), (T,))

        def f_invsqr(): return _bits(lax.rsqrt(_f(rav)))

        # --- the opcode dispatch -------------------------------------------
        # ``spec[op] = (value_fn | None, cond_fn | None)``: the write value
        # an instruction produces and (for IF.cc) its condition.  Control
        # ops carry no value function (their register write is gated off by
        # the ``writes_rd`` table anyway).
        fa, fb = _f(rav), _f(rbv)
        no_cond = jnp.zeros((T,), jnp.bool_)
        spec: list = [None] * isa.NUM_OPCODES
        for o, f in [(Op.ADD, f_add), (Op.SUB, f_sub), (Op.NEG, f_negi),
                     (Op.ABS, f_absi), (Op.MUL16LO, f_mul16lo),
                     (Op.MUL16HI, f_mul16hi), (Op.MUL24LO, f_mul24lo),
                     (Op.MUL24HI, f_mul24hi), (Op.AND, f_and), (Op.OR, f_or),
                     (Op.XOR, f_xor), (Op.NOT, f_not), (Op.CNOT, f_cnot),
                     (Op.BVS, f_bvs), (Op.SHL, f_shl), (Op.SHR, f_shr),
                     (Op.POP, f_pop), (Op.MAX, f_max), (Op.MIN, f_min),
                     (Op.FADD, f_fadd), (Op.FSUB, f_fsub), (Op.FNEG, f_fneg),
                     (Op.FABS, f_fabs), (Op.FMUL, f_fmul), (Op.FMAX, f_fmax),
                     (Op.FMIN, f_fmin), (Op.LOD, f_lod), (Op.LODI, f_lodi),
                     (Op.TDX, f_tdx), (Op.TDY, f_tdy), (Op.DOT, f_dot),
                     (Op.SUM, f_sum), (Op.INVSQR, f_invsqr)]:
            spec[o] = (f, None)
        for o, f in [(Op.IF_EQ, lambda: rav == rbv),
                     (Op.IF_NE, lambda: rav != rbv),
                     (Op.IF_LT, lambda: _i(rav) < _i(rbv)),
                     (Op.IF_LO, lambda: rav < rbv),
                     (Op.IF_LE, lambda: _i(rav) <= _i(rbv)),
                     (Op.IF_LS, lambda: rav <= rbv),
                     (Op.IF_GT, lambda: _i(rav) > _i(rbv)),
                     (Op.IF_HI, lambda: rav > rbv),
                     (Op.IF_GE, lambda: _i(rav) >= _i(rbv)),
                     (Op.IF_HS, lambda: rav >= rbv),
                     (Op.IF_FEQ, lambda: fa == fb),
                     (Op.IF_FNE, lambda: fa != fb),
                     (Op.IF_FLT, lambda: fa < fb),
                     (Op.IF_FLE, lambda: fa <= fb),
                     (Op.IF_FGT, lambda: fa > fb),
                     (Op.IF_FGE, lambda: fa >= fb),
                     (Op.IF_Z, lambda: rav == 0),
                     (Op.IF_NZ, lambda: rav != 0)]:
            spec[o] = (None, f)

        if flat_dispatch:
            # nested-where chain over the working set: every elementwise
            # value fuses into a handful of kernels.  A vmapped lax.switch
            # executes all branches anyway (batched opcodes), but as
            # separate computations + select_n — many more kernel launches.
            value, ifcond = rav, no_cond
            for o in branch_ops:
                if spec[o] is None:
                    continue
                vf, cf = spec[o]
                if vf is not None:
                    value = jnp.where(op == o, vf().astype(_U32), value)
                if cf is not None:
                    ifcond = jnp.where(op == o, cf(), ifcond)
        else:
            # real control flow: one branch executes per instruction
            def to_branch(entry):
                if entry is None or entry[0] is None and entry[1] is None:
                    return lambda _: (rav, no_cond)
                vf, cf = entry
                if vf is not None:
                    return lambda _: (vf().astype(_U32), no_cond)
                return lambda _: (rav, cf())

            active = [to_branch(spec[o]) for o in branch_ops] \
                + [to_branch(None)]
            value, ifcond = lax.switch(remap[op], active, _I32(0))

        # --- register writeback (one column update, mask-gated; a batched
        # dynamic_update_slice lowers to an in-place column write) ----------
        ext0 = (op == Op.DOT) | (op == Op.SUM)   # write thread 0 only
        wmask = jnp.where(ext0, tid == 0, mask) & writes_rd & gate
        col = jnp.where(wmask, value, rdv)
        regs = lax.dynamic_update_slice(st.regs, col[:, None],
                                        (jnp.int32(0), rd))

        # --- shared-memory write (STO): deferred to the driver -------------
        sto_ok = (op == Op.STO) & mask & (addr >= 0) & (addr < S) & gate
        sidx = jnp.where(sto_ok, addr, S)   # out-of-range/inactive -> dropped

        # --- predicate stacks ----------------------------------------------
        is_if = ((op >= Op.IF_EQ) & (op <= Op.IF_NZ)) & gate
        is_else = (op == Op.ELSE) & gate
        is_endif = (op == Op.ENDIF) & gate
        oh_push = (lvl[None, :] == st.pdepth[:, None]) & tsc_mask[:, None]
        ps_push = jnp.where(oh_push, ifcond[:, None], st.pstack)
        pd_push = st.pdepth + jnp.where(tsc_mask & (st.pdepth < D), 1, 0)
        oh_else = (lvl[None, :] == (st.pdepth[:, None] - 1)) \
            & tsc_mask[:, None] & (st.pdepth[:, None] > 0)
        pd_pop = st.pdepth - jnp.where(tsc_mask & (st.pdepth > 0), 1, 0)
        pstack = jnp.where(is_if, ps_push,
                           jnp.where(is_else, st.pstack ^ oh_else, st.pstack))
        pdepth = jnp.where(is_if, pd_push,
                           jnp.where(is_endif, pd_pop, st.pdepth))

        # --- sequencer: call/loop stacks and PC ----------------------------
        is_jmp = op == Op.JMP
        is_jsr = (op == Op.JSR) & gate
        is_rts = (op == Op.RTS) & gate
        is_loop = (op == Op.LOOP) & gate
        is_init = (op == Op.INIT) & gate
        is_stop = (op == Op.STOP) & gate

        cm = (jnp.arange(st.cstack.shape[0], dtype=_I32) == st.csp) & is_jsr
        cstack = jnp.where(cm, pc + 1, st.cstack)
        csp = st.csp + jnp.where(is_jsr, 1, 0) - jnp.where(is_rts, 1, 0)
        rts_pc = st.cstack[st.csp - 1]

        lsp1 = st.lsp - 1
        ltop = st.lctr[lsp1]
        taken = ltop > 0
        lidx = jnp.arange(st.lctr.shape[0], dtype=_I32)
        lctr = jnp.where((lidx == st.lsp) & is_init, imm,
                         jnp.where((lidx == lsp1) & is_loop, ltop - 1,
                                   st.lctr))
        lsp = jnp.where(is_init, st.lsp + 1,
                        jnp.where(is_loop & ~taken, lsp1, st.lsp))

        pc1 = jnp.where(gate, pc + 1, pc)
        pc_next = jnp.where(
            (is_jmp & gate) | is_jsr, imm,
            jnp.where(is_rts, rts_pc,
                      jnp.where(is_loop & taken, imm, pc1)))

        stat_cycles, stat_instrs = st.stat_cycles, st.stat_instrs
        if collect_stats:
            cls = trow[_TC_CLS]
            sm = (jnp.arange(isa.NUM_OP_CLASSES, dtype=_I32) == cls) & gate
            stat_cycles = st.stat_cycles + jnp.where(sm, issue, 0)
            stat_instrs = st.stat_instrs + jnp.where(sm, 1, 0)

        st2 = st._replace(
            regs=regs, pstack=pstack, pdepth=pdepth,
            lctr=lctr, lsp=lsp, cstack=cstack, csp=csp,
            pc=pc_next,
            cycles=st.cycles + jnp.where(gate, issue, 0),
            steps=st.steps + jnp.where(gate, 1, 0),
            halted=st.halted | is_stop,
            hazard=hz,
            hazard_violations=st.hazard_violations
            + (violated & gate).astype(_I32),
            stat_cycles=stat_cycles, stat_instrs=stat_instrs,
        )
        return st2, sidx, rdv

    def running(st: MachineState):
        return (~st.halted) & (st.steps < cfg.max_steps) & \
            (st.pc >= 0) & (st.pc < prog_len)

    return step, running


# ---------------------------------------------------------------------------
# Single-core driver
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _make_runner(cfg: EGPUConfig, prog_len: int):
    step, running = make_step(cfg, prog_len)

    def body(carry):
        st, prog = carry
        st2, sidx, rdv = step(st, prog)
        shared = st2.shared.at[sidx].set(rdv, mode="drop")
        return (st2._replace(shared=shared), prog)

    def cond(carry):
        return running(carry[0])

    @jax.jit
    def run(prog, st):
        final, _ = lax.while_loop(cond, body, (st, prog))
        return final

    return run


def padded_length(n: int) -> int:
    """Instruction count rounded up to the shared ``_PAD`` compile grid."""
    return n + (-n) % _PAD


def pad_image(image: ProgramImage, prog_len: int | None = None):
    """Pack a program into a ``(padded_len, 7)`` int32 array of decoded
    fields (column order :data:`PROG_FIELDS`), padded with STOP rows.

    Returns ``(packed, padded_len)``; ``padded_len`` is ``prog_len`` if
    given, else the next multiple of ``_PAD`` — the executor/fleet compile
    cache is keyed on that length, so padding to the shared grid reuses
    compiles.
    """
    n = image.n
    length = prog_len if prog_len is not None else padded_length(n)
    if length < n:
        raise ValueError(f"prog_len {length} < program length {n}")
    packed = np.zeros((length, 7), np.int32)
    packed[n:, _PF_OP] = int(Op.STOP)
    for col, field in enumerate(PROG_FIELDS):
        packed[:n, col] = getattr(image, field)
    return packed, length


def run_program(image: ProgramImage, state: MachineState | None = None,
                **init_kw) -> MachineState:
    """Execute an assembled program to completion."""
    cfg = image.cfg
    if state is None:
        state = init_state(cfg, threads=image.threads_active, **init_kw)
    packed, length = pad_image(image)
    runner = _make_runner(cfg, length)
    out = runner(jnp.asarray(packed), state)
    out.cycles.block_until_ready()
    return out
