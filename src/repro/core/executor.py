"""The eGPU SIMT executor: a jitted ``lax.while_loop`` interpreter.

One ``while_loop`` iteration = one instruction.  All threads execute the
instruction *vectorised* (the hardware issues one 16-lane wavefront per
cycle; we charge cycles through the cost model rather than looping), with
the active-thread mask derived from

  * the instruction's 4-bit thread-space control field (dynamic
    scalability, Table 3),
  * the runtime thread count (static scalability),
  * the per-thread predicate stacks (divergence, Fig. 2).

Cycle accounting matches :mod:`repro.core.cost` exactly, and a built-in
hazard checker counts read-after-write violations (the eGPU has no hazard
hardware; a correct program — i.e. one produced by the assembler's
scheduler — must report zero).

The per-opcode *semantics* (value/condition functions, predicate and
sequencer stack updates) live in :mod:`repro.core.semantics`, shared
with the basic-block compiler (:mod:`repro.core.blockc`) — this module
contributes the per-instruction *dispatch*: gather the instruction from
the program image, select the value through a switch/where-chain, and
apply every architectural update exactly once with mask-gated selects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import isa, semantics
from .assembler import ProgramImage
from .config import EGPUConfig
from .isa import Op, Typ
from .machine import MachineState, init_state
from ..obs import trace as obs_trace

_I32 = jnp.int32
_U32 = jnp.uint32

# virtual hazard slots
_HZ_MEM = -2
_HZ_PRED = -1


# ---------------------------------------------------------------------------
# Constant per-opcode tables (built once per config, baked into the jaxpr).
#
# All per-opcode metadata lives in ONE (NUM_OPCODES, 12) int32 table so the
# step function fetches it with a single dynamic row gather — under the
# vmapped fleet every separate gather is a separate (batched) HLO op, and
# the step is op-dispatch bound on CPU, not FLOP bound.
# ---------------------------------------------------------------------------

# table columns
(_TC_SCALAR, _TC_READS_RA, _TC_READS_RB, _TC_READS_RD, _TC_WRITES_RD,
 _TC_LAT, _TC_CLS, _TC_PER_WF0) = range(8)          # per_wf spans cols 7..10
_TC_WRITES_PRED = 11

# program-image columns (see pad_image)
_PF_OP, _PF_TYP, _PF_RD, _PF_RA, _PF_RB, _PF_IMM, _PF_TSC = range(7)
PROG_FIELDS = ("op", "typ", "rd", "ra", "rb", "imm", "tsc")


def tables_np(cfg: EGPUConfig) -> np.ndarray:
    """The per-opcode metadata table as NumPy (shared with the static
    path simulator in :mod:`repro.core.blockc`)."""
    n = isa.NUM_OPCODES
    t = np.zeros((n, 12), np.int32)
    t[:, _TC_PER_WF0:_TC_PER_WF0 + 4] = 1
    from . import cost as _cost

    for op in Op:
        t[op, _TC_SCALAR] = op in isa.SCALAR_OPS
        t[op, _TC_READS_RA] = op in isa.READS_RA
        t[op, _TC_READS_RB] = op in isa.READS_RB
        t[op, _TC_READS_RD] = op in isa.READS_RD
        t[op, _TC_WRITES_RD] = op in isa.REG_WRITE_OPS
        t[op, _TC_LAT] = _cost.result_latency(op, cfg)
        t[op, _TC_CLS] = isa.OP_CLASS[op]
        t[op, _TC_WRITES_PRED] = op in isa.PRED_WRITE_OPS
        for wc in range(4):
            width = isa.WIDTH_LANES[wc]
            if op == Op.LOD:
                t[op, _TC_PER_WF0 + wc] = -(-width // cfg.cost.sp_read_ports)
            elif op == Op.STO:
                t[op, _TC_PER_WF0 + wc] = -(-width // cfg.write_ports)
    return t


def _tables(cfg: EGPUConfig):
    return jnp.asarray(tables_np(cfg))


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Step function
# ---------------------------------------------------------------------------

_PAD = 64  # programs are padded to a multiple of this to share compiles


@functools.lru_cache(maxsize=64)
def make_step(cfg: EGPUConfig, prog_len: int,
              ops_subset: frozenset | None = None, *,
              flat_dispatch: bool = False, check_hazards: bool = True,
              collect_stats: bool = True):
    """Build the per-instruction semantics for one eGPU core.

    Returns ``(step, running)``: ``step(state, prog, act=None) ->
    (state, sto_idx, sto_val)`` executes exactly one instruction
    (``prog`` is the packed ``(prog_len, 7)`` image from
    :func:`pad_image`), and ``running(state) -> bool`` is the continue
    predicate.  The split from the ``while_loop`` driver is what lets the
    same semantics power both :func:`run_program` (single core) and the
    vmapped fleet engine (:mod:`repro.fleet.engine`).

    The state update is *flat*: the per-opcode ``lax.switch`` only selects
    the value an instruction produces (a ``(T,)`` vector plus an IF.cc
    condition), and every architectural structure — register file,
    predicate/loop/call stacks, PC — is then updated exactly once with
    mask-gated one-hot selects.  Under ``jax.vmap`` a switch over a
    batched opcode lowers to "execute every branch, select one", and a
    batched scatter is pathologically slow on the CPU backend, so the
    step avoids scatters entirely:

    * small structures (hazard rows, stacks, stat counters) use one-hot
      ``where`` selects, which fuse;
    * the one real scatter — the STO write to shared memory — is
      *deferred*: ``step`` returns ``(state, sto_idx, sto_val)`` and the
      driver applies it (the fleet driver as a single flattened scatter
      for the whole batch, gated on "any core is storing this cycle").

    ``act`` (bool, default True) gates every write, so a halted core
    no-ops without a second freeze pass over the state.

    ``ops_subset`` (a frozenset of opcode ints) specializes the dispatch to
    the instruction working set of the program(s) actually being run —
    opcodes outside the subset map to a dummy branch.  The fleet packs the
    union of its batch's opcodes here, shrinking the vmapped
    all-branches dispatch several-fold.

    ``flat_dispatch`` replaces the ``lax.switch`` with a nested-``where``
    chain: correct in both drivers, but chosen per driver for speed — the
    switch wins single-core (one branch executes), the chain wins vmapped
    (everything fuses into a few kernels instead of per-branch launches).

    ``check_hazards=False`` / ``collect_stats=False`` drop the RAW hazard
    checker / the Fig. 6 instruction-mix counters from the compiled step.
    Neither affects the architectural results (registers, shared memory,
    cycles, PC trace) — the real eGPU has no hazard hardware or counters —
    so throughput-oriented fleet runs can shed their cost.
    """
    T = cfg.max_threads
    R = cfg.regs_per_thread
    S = cfg.shared_words
    D = max(1, cfg.predicate_levels)
    tables = _tables(cfg)
    tid = jnp.arange(T, dtype=_I32)
    lane = tid % cfg.num_sps
    wf = tid // cfg.num_sps
    width_lanes = jnp.asarray(isa.WIDTH_LANES, _I32)

    branch_ops = sorted(ops_subset) if ops_subset is not None \
        else list(range(isa.NUM_OPCODES))
    remap_np = np.full((isa.NUM_OPCODES,), len(branch_ops), np.int32)
    for i, o in enumerate(branch_ops):
        remap_np[o] = i
    remap = jnp.asarray(remap_np)

    def step(st: MachineState, prog, act=None):
        gate = jnp.bool_(True) if act is None else act
        pc = st.pc
        row = prog[pc]                   # one gather for all seven fields
        op = row[_PF_OP]
        typ = row[_PF_TYP]
        rd = row[_PF_RD]
        ra = row[_PF_RA]
        rb = row[_PF_RB]
        imm = row[_PF_IMM]
        tsc = row[_PF_TSC]
        trow = tables[op]                # one gather for all opcode metadata

        width_code = (tsc >> 2) & 3
        depth_code = tsc & 3
        w_rt = _cdiv(st.threads_active, cfg.num_sps)
        wfs = jnp.stack([_I32(1), w_rt, jnp.maximum(1, _cdiv(w_rt, 2)),
                         jnp.maximum(1, _cdiv(w_rt, 4))])[depth_code]
        lanes = width_lanes[width_code]
        per_wf_c = trow[_TC_PER_WF0 + width_code]
        is_scalar = trow[_TC_SCALAR] == 1
        writes_rd = trow[_TC_WRITES_RD] == 1
        issue = jnp.where(is_scalar, _I32(1), per_wf_c * wfs)

        # --- active masks ------------------------------------------------
        tsc_mask = (lane < lanes) & (wf < wfs) & (tid < st.threads_active)
        pred = semantics.pred_ok(st.pstack, st.pdepth, D)
        mask = tsc_mask & pred

        # --- operand reads (one gather) ----------------------------------
        srcs = jnp.stack([ra, rb, rd])
        vals = st.regs[:, srcs]          # (T, 3)
        rav, rbv, rdv = vals[:, 0], vals[:, 1], vals[:, 2]

        # --- hazard checker (RAW), vectorised over the five read slots ---
        hz = st.hazard
        violated = jnp.bool_(False)
        if check_hazards:
            rows = jnp.concatenate([hz[srcs], hz[R:R + 2]])  # ra/rb/rd/mem/pred
            p_start, p_per_wf = rows[:, 0], rows[:, 1]
            p_wfs, p_lat = rows[:, 2], rows[:, 3]
            k_max = jnp.minimum(p_wfs, wfs) - 1
            k = jnp.where(p_per_wf > per_wf_c, k_max, 0)
            cons = p_start + p_per_wf * (k + 1) - 1 + p_lat - per_wf_c * k
            pred_reads = (~is_scalar) if cfg.has_predicates \
                else jnp.bool_(False)
            flags = jnp.stack([trow[_TC_READS_RA] == 1,
                               trow[_TC_READS_RB] == 1,
                               trow[_TC_READS_RD] == 1, op == Op.LOD,
                               pred_reads])
            neg_inf = _I32(-(1 << 30))
            need = jnp.max(jnp.where(flags, cons, neg_inf))
            violated = (~is_scalar | (op == Op.LOD)) & (need > st.cycles)

            # writer bookkeeping: rd / shared-memory / predicate rows as one
            # fused one-hot select (scatters are slow on the vmapped path)
            new_row = jnp.stack([st.cycles, per_wf_c, wfs, trow[_TC_LAT]])
            none = _I32(-9)
            ridx = jnp.arange(R + 2, dtype=_I32)
            hrow = ((ridx == jnp.where(writes_rd, rd, none)) |
                    (ridx == jnp.where(op == Op.STO, _I32(R + 2 + _HZ_MEM),
                                       none)) |
                    (ridx == jnp.where(trow[_TC_WRITES_PRED] == 1,
                                       _I32(R + 2 + _HZ_PRED), none))) & gate
            hz = jnp.where(hrow[:, None], new_row[None, :], hz)

        # --- per-opcode value/condition functions (shared semantics) -----
        env = semantics.OpEnv(cfg=cfg, rav=rav, rbv=rbv, rdv=rdv,
                              signed=typ == Typ.I32, imm=imm, mask=mask,
                              tid=tid, shared=st.shared,
                              tdx_dim=st.tdx_dim)
        spec = semantics.build_spec(env)
        addr = env.addr
        no_cond = jnp.zeros((T,), jnp.bool_)

        if flat_dispatch:
            # nested-where chain over the working set: every elementwise
            # value fuses into a handful of kernels.  A vmapped lax.switch
            # executes all branches anyway (batched opcodes), but as
            # separate computations + select_n — many more kernel launches.
            value, ifcond = rav, no_cond
            for o in branch_ops:
                if spec[o] is None:
                    continue
                vf, cf = spec[o]
                if vf is not None:
                    value = jnp.where(op == o, vf().astype(_U32), value)
                if cf is not None:
                    ifcond = jnp.where(op == o, cf(), ifcond)
        else:
            # real control flow: one branch executes per instruction
            def to_branch(entry):
                if entry is None or entry[0] is None and entry[1] is None:
                    return lambda _: (rav, no_cond)
                vf, cf = entry
                if vf is not None:
                    return lambda _: (vf().astype(_U32), no_cond)
                return lambda _: (rav, cf())

            active = [to_branch(spec[o]) for o in branch_ops] \
                + [to_branch(None)]
            value, ifcond = lax.switch(remap[op], active, _I32(0))

        # --- register writeback (one column update, mask-gated; a batched
        # dynamic_update_slice lowers to an in-place column write) ----------
        ext0 = (op == Op.DOT) | (op == Op.SUM)   # write thread 0 only
        wmask = jnp.where(ext0, tid == 0, mask) & writes_rd & gate
        col = jnp.where(wmask, value, rdv)
        regs = lax.dynamic_update_slice(st.regs, col[:, None],
                                        (jnp.int32(0), rd))

        # --- shared-memory write (STO): deferred to the driver -------------
        sto_ok = (op == Op.STO) & mask & (addr >= 0) & (addr < S) & gate
        sidx = jnp.where(sto_ok, addr, S)   # out-of-range/inactive -> dropped

        # --- predicate stacks ----------------------------------------------
        is_if = ((op >= Op.IF_EQ) & (op <= Op.IF_NZ)) & gate
        is_else = (op == Op.ELSE) & gate
        is_endif = (op == Op.ENDIF) & gate
        ps_push, pd_push = semantics.pred_push(st.pstack, st.pdepth, ifcond,
                                               tsc_mask, D)
        ps_else = semantics.pred_else(st.pstack, st.pdepth, tsc_mask, D)
        pd_pop = semantics.pred_pop(st.pdepth, tsc_mask)
        pstack = jnp.where(is_if, ps_push,
                           jnp.where(is_else, ps_else, st.pstack))
        pdepth = jnp.where(is_if, pd_push,
                           jnp.where(is_endif, pd_pop, st.pdepth))

        # --- sequencer: call/loop stacks and PC ----------------------------
        is_jmp = op == Op.JMP
        is_jsr = (op == Op.JSR) & gate
        is_rts = (op == Op.RTS) & gate
        is_loop = (op == Op.LOOP) & gate
        is_init = (op == Op.INIT) & gate
        is_stop = (op == Op.STOP) & gate

        cstack, csp = semantics.call_push(st.cstack, st.csp, pc + 1,
                                          en=is_jsr)
        csp = csp - jnp.where(is_rts, 1, 0)
        rts_pc = semantics.call_top(st.cstack, st.csp)

        lctr, lsp = semantics.loop_init(st.lctr, st.lsp, imm, en=is_init)
        lctr, taken, lsp_pop = semantics.loop_step(lctr, st.lsp, en=is_loop)
        lsp = jnp.where(is_loop & ~taken, lsp_pop, lsp)

        pc1 = jnp.where(gate, pc + 1, pc)
        pc_next = jnp.where(
            (is_jmp & gate) | is_jsr, imm,
            jnp.where(is_rts, rts_pc,
                      jnp.where(is_loop & taken, imm, pc1)))

        stat_cycles, stat_instrs = st.stat_cycles, st.stat_instrs
        if collect_stats:
            cls = trow[_TC_CLS]
            sm = (jnp.arange(isa.NUM_OP_CLASSES, dtype=_I32) == cls) & gate
            stat_cycles = st.stat_cycles + jnp.where(sm, issue, 0)
            stat_instrs = st.stat_instrs + jnp.where(sm, 1, 0)

        st2 = st._replace(
            regs=regs, pstack=pstack, pdepth=pdepth,
            lctr=lctr, lsp=lsp, cstack=cstack, csp=csp,
            pc=pc_next,
            cycles=st.cycles + jnp.where(gate, issue, 0),
            steps=st.steps + jnp.where(gate, 1, 0),
            halted=st.halted | is_stop,
            hazard=hz,
            hazard_violations=st.hazard_violations
            + (violated & gate).astype(_I32),
            stat_cycles=stat_cycles, stat_instrs=stat_instrs,
        )
        return st2, sidx, rdv

    def running(st: MachineState):
        return (~st.halted) & (st.steps < cfg.max_steps) & \
            (st.pc >= 0) & (st.pc < prog_len)

    return step, running


# ---------------------------------------------------------------------------
# Single-core driver
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _make_runner(cfg: EGPUConfig, prog_len: int,
                 ops_subset: frozenset | None = None,
                 validate: bool = True):
    step, running = make_step(cfg, prog_len, ops_subset,
                              check_hazards=validate,
                              collect_stats=validate)

    def body(carry):
        st, prog = carry
        st2, sidx, rdv = step(st, prog)
        shared = st2.shared.at[sidx].set(rdv, mode="drop")
        return (st2._replace(shared=shared), prog)

    def cond(carry):
        return running(carry[0])

    # the carried machine state is donated: XLA reuses its buffers
    # in-place instead of copying the register file / shared memory on
    # every dispatch (callers get a fresh state back)
    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(prog, st):
        final, _ = lax.while_loop(cond, body, (st, prog))
        return final

    return run


def padded_length(n: int) -> int:
    """Instruction count rounded up to the shared ``_PAD`` compile grid."""
    return n + (-n) % _PAD


def pad_image(image: ProgramImage, prog_len: int | None = None):
    """Pack a program into a ``(padded_len, 7)`` int32 array of decoded
    fields (column order :data:`PROG_FIELDS`), padded with STOP rows.

    Returns ``(packed, padded_len)``; ``padded_len`` is ``prog_len`` if
    given, else the next multiple of ``_PAD`` — the executor/fleet compile
    cache is keyed on that length, so padding to the shared grid reuses
    compiles.
    """
    n = image.n
    length = prog_len if prog_len is not None else padded_length(n)
    if length < n:
        raise ValueError(f"prog_len {length} < program length {n}")
    packed = np.zeros((length, 7), np.int32)
    packed[n:, _PF_OP] = int(Op.STOP)
    for col, field in enumerate(PROG_FIELDS):
        packed[:n, col] = getattr(image, field)
    return packed, length


def image_ops(image: ProgramImage) -> frozenset:
    """The program's instruction working set (incl. the STOP padding),
    used to specialize the interpreter dispatch to the opcodes that can
    actually occur."""
    return frozenset(int(o) for o in np.unique(image.op)) | {int(Op.STOP)}


def run_program(image: ProgramImage, state: MachineState | None = None, *,
                validate: bool = True, **init_kw) -> MachineState:
    """Execute an assembled program to completion (interpreter tier).

    The step is specialized to the program's opcode working set (the
    same specialization the fleet fast path uses), and ``validate=False``
    additionally drops the hazard checker and the Fig. 6 instruction-mix
    counters — architectural results (registers, shared memory, cycles,
    PC) are unchanged either way.

    The initial state's buffers are donated to the dispatch; if you pass
    ``state`` explicitly, treat it as consumed and use the returned one.
    """
    cfg = image.cfg
    if state is None:
        init_kw.setdefault("threads", image.threads_active)
        state = init_state(cfg, **init_kw)
    packed, length = pad_image(image)
    runner = _make_runner(cfg, length, image_ops(image), validate)
    with obs_trace.span("interpret", prog_len=length):
        out = runner(jnp.asarray(packed), state)
        out.cycles.block_until_ready()
    return out
