"""The eGPU SIMT executor: a jitted ``lax.while_loop`` interpreter.

One ``while_loop`` iteration = one instruction.  All threads execute the
instruction *vectorised* (the hardware issues one 16-lane wavefront per
cycle; we charge cycles through the cost model rather than looping), with
the active-thread mask derived from

  * the instruction's 4-bit thread-space control field (dynamic
    scalability, Table 3),
  * the runtime thread count (static scalability),
  * the per-thread predicate stacks (divergence, Fig. 2).

Cycle accounting matches :mod:`repro.core.cost` exactly, and a built-in
hazard checker counts read-after-write violations (the eGPU has no hazard
hardware; a correct program — i.e. one produced by the assembler's
scheduler — must report zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import isa
from .assembler import ProgramImage
from .config import EGPUConfig
from .isa import Op, Typ
from .machine import MachineState, init_state

_I32 = jnp.int32
_U32 = jnp.uint32

# virtual hazard slots
_HZ_MEM = -2
_HZ_PRED = -1


# ---------------------------------------------------------------------------
# Constant per-opcode tables (built once per config, baked into the jaxpr).
# ---------------------------------------------------------------------------

def _tables(cfg: EGPUConfig):
    n = isa.NUM_OPCODES
    scalar = np.zeros((n,), np.bool_)
    reads_ra = np.zeros((n,), np.bool_)
    reads_rb = np.zeros((n,), np.bool_)
    reads_rd = np.zeros((n,), np.bool_)
    writes_rd = np.zeros((n,), np.bool_)
    latency = np.zeros((n,), np.int32)
    opclass = np.zeros((n,), np.int32)
    per_wf = np.ones((n, 4), np.int32)  # [op, width_code] issue cycles per wf
    from . import cost as _cost

    for op in Op:
        scalar[op] = op in isa.SCALAR_OPS
        reads_ra[op] = op in isa.READS_RA
        reads_rb[op] = op in isa.READS_RB
        reads_rd[op] = op in isa.READS_RD
        writes_rd[op] = op in isa.REG_WRITE_OPS
        latency[op] = _cost.result_latency(op, cfg)
        opclass[op] = isa.OP_CLASS[op]
        for wc in range(4):
            width = isa.WIDTH_LANES[wc]
            if op == Op.LOD:
                per_wf[op, wc] = -(-width // cfg.cost.sp_read_ports)
            elif op == Op.STO:
                per_wf[op, wc] = -(-width // cfg.write_ports)
    return dict(scalar=jnp.asarray(scalar), reads_ra=jnp.asarray(reads_ra),
                reads_rb=jnp.asarray(reads_rb), reads_rd=jnp.asarray(reads_rd),
                writes_rd=jnp.asarray(writes_rd), latency=jnp.asarray(latency),
                opclass=jnp.asarray(opclass), per_wf=jnp.asarray(per_wf))


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# Integer helpers (bit-exact, uint32 register file)
# ---------------------------------------------------------------------------

def _i(x):
    return x.astype(jnp.int32)


def _u(x):
    return x.astype(_U32)


def _f(x):
    return lax.bitcast_convert_type(x, jnp.float32)


def _bits(x):
    return lax.bitcast_convert_type(x.astype(jnp.float32), _U32)


def _sext16(x_u32):
    """Sign-extend the low 16 bits."""
    x = _i(x_u32 & _U32(0xFFFF))
    return jnp.where(x >= 1 << 15, x - (1 << 16), x)


def _sext24(x_u32):
    x = _i(x_u32 & _U32(0xFFFFFF))
    return jnp.where(x >= 1 << 23, x - (1 << 24), x)


def _bit_reverse32(x):
    x = ((x & _U32(0x55555555)) << 1) | ((x >> 1) & _U32(0x55555555))
    x = ((x & _U32(0x33333333)) << 2) | ((x >> 2) & _U32(0x33333333))
    x = ((x & _U32(0x0F0F0F0F)) << 4) | ((x >> 4) & _U32(0x0F0F0F0F))
    x = ((x & _U32(0x00FF00FF)) << 8) | ((x >> 8) & _U32(0x00FF00FF))
    x = (x << 16) | (x >> 16)
    return x


def _mul24(a_u32, b_u32, signed):
    """24x24 -> 48-bit product as (hi24, lo24) uint32 limb pair.

    Implemented in 32-bit limbs (the container runs with x64 disabled,
    and the hardware is a 24-bit multiplier anyway).
    """
    if signed:
        sa = _sext24(a_u32)
        sb = _sext24(b_u32)
        neg = (sa < 0) ^ (sb < 0)
        a = _u(jnp.abs(sa))
        b = _u(jnp.abs(sb))
    else:
        neg = jnp.zeros(a_u32.shape, jnp.bool_)
        a = a_u32 & _U32(0xFFFFFF)
        b = b_u32 & _U32(0xFFFFFF)
    m12 = _U32((1 << 12) - 1)
    m24 = _U32((1 << 24) - 1)
    ah, al = a >> 12, a & m12
    bh, bl = b >> 12, b & m12
    low = al * bl                       # < 2^24
    mid = ah * bl + al * bh             # < 2^25
    t = mid + (low >> 12)               # < 2^26
    hi = ah * bh + (t >> 12)            # bits [47:24]
    lo = ((t & m12) << 12) | (low & m12)  # bits [23:0]
    # two's-complement negate the 48-bit (hi, lo) pair where requested
    nlo = (-lo) & m24
    borrow = (lo != 0).astype(_U32)
    nhi = ((~hi) & m24) + _U32(1) - borrow
    nhi = nhi & m24
    hi = jnp.where(neg, nhi, hi)
    lo = jnp.where(neg, nlo, lo)
    return hi, lo, neg


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

_PAD = 64  # programs are padded to a multiple of this to share compiles


@functools.lru_cache(maxsize=32)
def _make_runner(cfg: EGPUConfig, prog_len: int):
    T = cfg.max_threads
    R = cfg.regs_per_thread
    S = cfg.shared_words
    D = max(1, cfg.predicate_levels)
    tables = _tables(cfg)
    tid = jnp.arange(T, dtype=_I32)
    lane = tid % cfg.num_sps
    wf = tid // cfg.num_sps
    width_lanes = jnp.asarray(isa.WIDTH_LANES, _I32)

    def body(carry):
        st: MachineState = carry[0]
        prog = carry[1]
        pc = st.pc
        op = prog["op"][pc]
        typ = prog["typ"][pc]
        rd = prog["rd"][pc]
        ra = prog["ra"][pc]
        rb = prog["rb"][pc]
        imm = prog["imm"][pc]
        tsc = prog["tsc"][pc]

        width_code = (tsc >> 2) & 3
        depth_code = tsc & 3
        w_rt = _cdiv(st.threads_active, cfg.num_sps)
        wfs = jnp.stack([_I32(1), w_rt, jnp.maximum(1, _cdiv(w_rt, 2)),
                         jnp.maximum(1, _cdiv(w_rt, 4))])[depth_code]
        lanes = width_lanes[width_code]
        per_wf_c = tables["per_wf"][op, width_code]
        is_scalar = tables["scalar"][op]
        issue = jnp.where(is_scalar, _I32(1), per_wf_c * wfs)

        # --- active masks ------------------------------------------------
        tsc_mask = (lane < lanes) & (wf < wfs) & (tid < st.threads_active)
        lvl = jnp.arange(D, dtype=_I32)
        pred_ok = jnp.all(st.pstack | (lvl[None, :] >= st.pdepth[:, None]),
                          axis=1)
        mask = tsc_mask & pred_ok

        # --- operand reads --------------------------------------------------
        rav = lax.dynamic_index_in_dim(st.regs, ra, axis=1, keepdims=False)
        rbv = lax.dynamic_index_in_dim(st.regs, rb, axis=1, keepdims=False)
        rdv = lax.dynamic_index_in_dim(st.regs, rd, axis=1, keepdims=False)

        # --- hazard checker (RAW) ---------------------------------------
        def constraint(row):
            p_start, p_per_wf, p_wfs, p_lat = row[0], row[1], row[2], row[3]
            k_max = jnp.minimum(p_wfs, wfs) - 1
            k = jnp.where(p_per_wf > per_wf_c, k_max, 0)
            return p_start + p_per_wf * (k + 1) - 1 + p_lat - per_wf_c * k

        hz = st.hazard
        neg_inf = _I32(-(1 << 30))
        need = neg_inf
        need = jnp.maximum(need, jnp.where(tables["reads_ra"][op],
                                           constraint(hz[ra]), neg_inf))
        need = jnp.maximum(need, jnp.where(tables["reads_rb"][op],
                                           constraint(hz[rb]), neg_inf))
        need = jnp.maximum(need, jnp.where(tables["reads_rd"][op],
                                           constraint(hz[rd]), neg_inf))
        need = jnp.maximum(need, jnp.where(op == Op.LOD,
                                           constraint(hz[_HZ_MEM]), neg_inf))
        if cfg.has_predicates:
            need = jnp.maximum(
                need, jnp.where(~is_scalar, constraint(hz[_HZ_PRED]), neg_inf))
        violated = (~is_scalar | (op == Op.LOD)) & (need > st.cycles)

        new_row = jnp.stack([st.cycles, per_wf_c, wfs, tables["latency"][op]])
        hz = jnp.where(tables["writes_rd"][op],
                       hz.at[rd].set(new_row), hz)
        hz = jnp.where(op == Op.STO, hz.at[_HZ_MEM].set(new_row), hz)
        hz = jnp.where(op >= Op.IF_EQ, hz.at[_HZ_PRED].set(new_row), hz)

        # --- semantic helpers ---------------------------------------------
        alu_mask = _U32((1 << cfg.alu_bits) - 1 if cfg.alu_bits < 32
                        else 0xFFFFFFFF)

        def wr(st_, val, m=None):
            m = mask if m is None else m
            val = val.astype(_U32)
            if cfg.alu_bits < 32:
                pass  # masking applied by int ops individually
            old = lax.dynamic_index_in_dim(st_.regs, rd, axis=1,
                                           keepdims=False)
            col = jnp.where(m, val, old)
            return st_._replace(regs=lax.dynamic_update_slice(
                st_.regs, col[:, None], (jnp.int32(0), rd)))

        def imask(v):  # integer ALU precision (16-bit ALU configs)
            return v.astype(_U32) & alu_mask

        def adv(st_):
            return st_._replace(pc=st_.pc + 1)

        signed = typ == Typ.I32

        # --- branch functions (one per opcode) -----------------------------
        def b_alu(f):
            def g(st_):
                return adv(wr(st_, f()))
            return g

        def shift_amt():
            return rbv & _U32(cfg.alu_bits - 1 if cfg.shift_bits > 1 else 1)

        def f_add(): return imask(rav + rbv)
        def f_sub(): return imask(rav - rbv)
        def f_negi(): return imask(_u(-_i(rav)))
        def f_absi(): return imask(_u(jnp.abs(_i(rav))))

        def f_mul16lo():
            p_s = _sext16(rav) * _sext16(rbv)
            p_u = _i((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF)))
            return imask(_u(jnp.where(signed, p_s, p_u)))

        def f_mul16hi():
            p_s = (_sext16(rav) * _sext16(rbv)) >> 16
            p_u = _u((rav & _U32(0xFFFF)) * (rbv & _U32(0xFFFF))) >> 16
            return imask(jnp.where(signed, _u(p_s), p_u))

        def f_mul24lo():
            hi, lo, _ = _mul24(rav, rbv, False)
            hi_s, lo_s, _ = _mul24(rav, rbv, True)
            # low 32 bits of the 48-bit product
            u = (lo | (hi << 24))
            s = (lo_s | (hi_s << 24))
            return imask(jnp.where(signed, s, u))

        def f_mul24hi():
            hi, lo, _ = _mul24(rav, rbv, False)
            hi_s, lo_s, neg = _mul24(rav, rbv, True)
            # arithmetic >>24 of the 48-bit product: extend from bit 47
            # (== bit 23 of hi24) — NOT from the sign flag, which is also
            # set for zero products of opposite-signed operands
            s = jnp.where((hi_s & _U32(0x800000)) != 0,
                          hi_s | _U32(0xFF000000), hi_s)
            return imask(jnp.where(signed, s, hi))

        def f_and(): return imask(rav & rbv)
        def f_or(): return imask(rav | rbv)
        def f_xor(): return imask(rav ^ rbv)
        def f_not(): return imask(~rav)
        def f_cnot(): return imask(jnp.where(rav == 0, _U32(1), _U32(0)))
        def f_bvs(): return imask(_bit_reverse32(rav))

        def f_shl(): return imask(rav << shift_amt())

        def f_shr():
            log = rav >> shift_amt()
            ari = _u(_i(rav) >> _i(shift_amt()))
            return imask(jnp.where(signed, ari, log))

        def f_pop(): return imask(lax.population_count(rav))

        def f_max():
            s = jnp.where(_i(rav) > _i(rbv), rav, rbv)
            u = jnp.where(rav > rbv, rav, rbv)
            return imask(jnp.where(signed, s, u))

        def f_min():
            s = jnp.where(_i(rav) < _i(rbv), rav, rbv)
            u = jnp.where(rav < rbv, rav, rbv)
            return imask(jnp.where(signed, s, u))

        # FP (bitcast through the uint32 register file)
        def f_fadd(): return _bits(_f(rav) + _f(rbv))
        def f_fsub(): return _bits(_f(rav) - _f(rbv))
        def f_fneg(): return rav ^ _U32(0x80000000)
        def f_fabs(): return rav & _U32(0x7FFFFFFF)
        def f_fmul(): return _bits(_f(rav) * _f(rbv))
        def f_fmax(): return _bits(jnp.maximum(_f(rav), _f(rbv)))
        def f_fmin(): return _bits(jnp.minimum(_f(rav), _f(rbv)))

        # memory
        def b_lod(st_):
            addr = _i(rav) + imm
            safe = jnp.clip(addr, 0, S - 1)
            vals = st_.shared[safe]
            return adv(wr(st_, vals))

        def b_sto(st_):
            addr = _i(rav) + imm
            ok = mask & (addr >= 0) & (addr < S)
            idx = jnp.where(ok, addr, S)  # out-of-range -> dropped
            shared = st_.shared.at[idx].set(rdv, mode="drop")
            return adv(st_._replace(shared=shared))

        def b_lodi(st_):
            return adv(wr(st_, jnp.broadcast_to(_u(imm), (T,))))

        def b_tdx(st_):
            return adv(wr(st_, _u(tid % st_.tdx_dim)))

        def b_tdy(st_):
            return adv(wr(st_, _u(tid // st_.tdx_dim)))

        # extension units: result lands in thread 0's Rd
        def _scalar_wr(st_, value_f32):
            m0 = tid == 0
            return adv(wr(st_, jnp.broadcast_to(_bits(value_f32), (T,)), m0))

        def b_dot(st_):
            s = jnp.sum(jnp.where(mask, _f(rav) * _f(rbv), 0.0))
            return _scalar_wr(st_, s)

        def b_sum(st_):
            s = jnp.sum(jnp.where(mask, _f(rav), 0.0))
            return _scalar_wr(st_, s)

        def b_invsqr(st_):
            return adv(wr(st_, _bits(lax.rsqrt(_f(rav)))))

        # control
        def b_jmp(st_): return st_._replace(pc=imm)

        def b_jsr(st_):
            cs = st_.cstack.at[st_.csp].set(st_.pc + 1, mode="drop")
            return st_._replace(cstack=cs, csp=st_.csp + 1, pc=imm)

        def b_rts(st_):
            sp = st_.csp - 1
            return st_._replace(csp=sp, pc=st_.cstack[sp])

        def b_init(st_):
            lc = st_.lctr.at[st_.lsp].set(imm, mode="drop")
            return st_._replace(lctr=lc, lsp=st_.lsp + 1, pc=st_.pc + 1)

        def b_loop(st_):
            sp = st_.lsp - 1
            c = st_.lctr[sp]
            taken = c > 0
            lc = st_.lctr.at[sp].set(c - 1)
            return st_._replace(
                lctr=lc,
                lsp=jnp.where(taken, st_.lsp, sp),
                pc=jnp.where(taken, _I32(imm), st_.pc + 1))

        def b_stop(st_):
            return st_._replace(halted=jnp.bool_(True), pc=st_.pc + 1)

        def b_nop(st_): return adv(st_)

        # predicates
        def _push(st_, cond):
            oh = (lvl[None, :] == st_.pdepth[:, None]) & tsc_mask[:, None]
            ps = jnp.where(oh, cond[:, None], st_.pstack)
            pd = st_.pdepth + jnp.where(tsc_mask & (st_.pdepth < D), 1, 0)
            return adv(st_._replace(pstack=ps, pdepth=pd))

        def b_if(cond_fn):
            def g(st_):
                return _push(st_, cond_fn())
            return g

        def c_int(cmp_s, cmp_u):
            return jnp.where(signed, cmp_s(_i(rav), _i(rbv)),
                             cmp_u(rav, rbv))

        def b_else(st_):
            oh = (lvl[None, :] == (st_.pdepth[:, None] - 1)) \
                & tsc_mask[:, None] & (st_.pdepth[:, None] > 0)
            return adv(st_._replace(pstack=st_.pstack ^ oh))

        def b_endif(st_):
            pd = st_.pdepth - jnp.where(tsc_mask & (st_.pdepth > 0), 1, 0)
            return adv(st_._replace(pdepth=pd))

        fa, fb = _f(rav), _f(rbv)
        branches = [
            b_alu(f_add), b_alu(f_sub), b_alu(f_negi), b_alu(f_absi),
            b_alu(f_mul16lo), b_alu(f_mul16hi), b_alu(f_mul24lo),
            b_alu(f_mul24hi),
            b_alu(f_and), b_alu(f_or), b_alu(f_xor), b_alu(f_not),
            b_alu(f_cnot), b_alu(f_bvs),
            b_alu(f_shl), b_alu(f_shr),
            b_alu(f_pop), b_alu(f_max), b_alu(f_min),
            b_alu(f_fadd), b_alu(f_fsub), b_alu(f_fneg), b_alu(f_fabs),
            b_alu(f_fmul), b_alu(f_fmax), b_alu(f_fmin),
            b_lod, b_sto, b_lodi, b_tdx, b_tdy,
            b_dot, b_sum, b_invsqr,
            b_jmp, b_jsr, b_rts, b_loop, b_init, b_stop, b_nop,
            b_if(lambda: rav == rbv),                       # IF_EQ
            b_if(lambda: rav != rbv),                       # IF_NE
            b_if(lambda: _i(rav) < _i(rbv)),                # IF_LT
            b_if(lambda: rav < rbv),                        # IF_LO
            b_if(lambda: _i(rav) <= _i(rbv)),               # IF_LE
            b_if(lambda: rav <= rbv),                       # IF_LS
            b_if(lambda: _i(rav) > _i(rbv)),                # IF_GT
            b_if(lambda: rav > rbv),                        # IF_HI
            b_if(lambda: _i(rav) >= _i(rbv)),               # IF_GE
            b_if(lambda: rav >= rbv),                       # IF_HS
            b_if(lambda: fa == fb),                         # IF_FEQ
            b_if(lambda: fa != fb),                         # IF_FNE
            b_if(lambda: fa < fb),                          # IF_FLT
            b_if(lambda: fa <= fb),                         # IF_FLE
            b_if(lambda: fa > fb),                          # IF_FGT
            b_if(lambda: fa >= fb),                         # IF_FGE
            b_if(lambda: rav == 0),                         # IF_Z
            b_if(lambda: rav != 0),                         # IF_NZ
            b_else, b_endif,
        ]
        assert len(branches) == isa.NUM_OPCODES

        st2 = lax.switch(op, branches, st)
        cls = tables["opclass"][op]
        st2 = st2._replace(
            cycles=st.cycles + issue,
            steps=st.steps + 1,
            hazard=hz,
            hazard_violations=st.hazard_violations + violated.astype(_I32),
            stat_cycles=st.stat_cycles.at[cls].add(issue),
            stat_instrs=st.stat_instrs.at[cls].add(1),
        )
        return (st2, prog)

    def cond(carry):
        st = carry[0]
        return (~st.halted) & (st.steps < cfg.max_steps) & \
            (st.pc >= 0) & (st.pc < prog_len)

    @jax.jit
    def run(prog, st):
        final, _ = lax.while_loop(cond, body, (st, prog))
        return final

    return run


def run_program(image: ProgramImage, state: MachineState | None = None,
                **init_kw) -> MachineState:
    """Execute an assembled program to completion."""
    cfg = image.cfg
    if state is None:
        state = init_state(cfg, threads=image.threads_active, **init_kw)
    n = image.n
    pad = (-n) % _PAD
    stop_row = np.full((pad,), int(Op.STOP), np.int32)
    zeros = np.zeros((pad,), np.int32)
    prog = {
        "op": jnp.asarray(np.concatenate([image.op, stop_row])),
        "typ": jnp.asarray(np.concatenate([image.typ, zeros])),
        "rd": jnp.asarray(np.concatenate([image.rd, zeros])),
        "ra": jnp.asarray(np.concatenate([image.ra, zeros])),
        "rb": jnp.asarray(np.concatenate([image.rb, zeros])),
        "imm": jnp.asarray(np.concatenate([image.imm, zeros])),
        "tsc": jnp.asarray(np.concatenate([image.tsc, zeros])),
    }
    runner = _make_runner(cfg, n + pad)
    out = runner(prog, state)
    out.cycles.block_until_ready()
    return out
