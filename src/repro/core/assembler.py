"""eGPU assembler: a Python-embedded builder, plus the NOP scheduler.

The paper's benchmarks "were written in assembly code (we have not written
our compiler yet)" — this module is that assembler.  It provides:

* a builder API with one method per mnemonic, labels, and structured
  ``loop``/``if`` helpers that lower to the sequencer's INIT/LOOP and the
  predicate IF/ELSE/ENDIF instructions;
* per-instruction thread-space control (the paper's dynamic scalability):
  every emit accepts ``tsc=`` as a personality name (``"full"``, ``"wf0"``,
  ``"cpu"``, ``"mcu"``, ...), an ``(width, depth)`` tuple, or a raw 4-bit
  coding;
* :func:`schedule` — the hazard pass.  The eGPU has an 8-stage pipeline
  and **no hazard hardware**, so read-after-write distances shorter than
  the producer's latency must be covered with NOPs.  The scheduler models
  per-wavefront issue skew exactly (see ``_ready_constraint``) so that
  e.g. a full-depth chain needs no padding (issue occupancy hides the
  pipe) while a ``wf0``-only chain gets 7 NOPs — reproducing the NOP
  profiles of Fig. 6.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from . import cost, isa
from .config import EGPUConfig
from .isa import Instr, Op, Typ


def _resolve_tsc(tsc) -> int:
    if isinstance(tsc, str):
        return isa.PERSONALITIES[tsc]
    if isinstance(tsc, tuple):
        return isa.tsc_encode(*tsc)
    return int(tsc)


@dataclasses.dataclass
class Label:
    name: str


@dataclasses.dataclass
class ProgramImage:
    """An assembled program: decoded field arrays + encoded words."""

    cfg: EGPUConfig
    op: np.ndarray
    typ: np.ndarray
    rd: np.ndarray
    ra: np.ndarray
    rb: np.ndarray
    imm: np.ndarray
    tsc: np.ndarray
    words: np.ndarray       # bit-packed IWs (uint64)
    listing: list[str]
    threads_active: int     # thread count the schedule was built for

    @property
    def n(self) -> int:
        return int(self.op.shape[0])

    def static_cycle_estimate(self) -> int:
        """Straight-line issue-cycle count (no branches taken)."""
        wfs = max(1, -(-self.threads_active // self.cfg.num_sps))
        return int(sum(
            cost.issue_cycles(int(o), int(t), wfs, self.cfg)
            for o, t in zip(self.op, self.tsc)
        ))


class Asm:
    """Two-pass assembler with symbolic labels."""

    #: virtual register slots for hazard tracking (beyond architectural regs)
    _VPRED = "pred"   # predicate stack state
    _VMEM = "mem"     # shared memory RAW-through-memory

    def __init__(self, cfg: EGPUConfig):
        self.cfg = cfg
        self.items: list = []        # Instr (imm may be a str label) | ("label", name)
        self._auto = 0

    # ------------------------------------------------------------------ emit
    def label(self, name: str | None = None) -> str:
        if name is None:
            name = f"_L{self._auto}"
            self._auto += 1
        self.items.append(Label(name))
        return name

    def emit(self, op: Op, *, typ=Typ.U32, rd=0, ra=0, rb=0, imm=0,
             tsc="full") -> None:
        t = _resolve_tsc(tsc)
        if isa.tsc_width(t) == 3:
            raise ValueError("TSC width '11' is undefined")
        if op == Op.SHL or op == Op.SHR:
            if self.cfg.shift_bits == 1:
                # min-ALU configs support single-bit shifts only; the shift
                # amount register is still read but must hold 1.
                pass
        self.items.append(Instr(op=int(op), typ=int(typ), rd=rd, ra=ra,
                                rb=rb, imm=imm, tsc=t))

    # --- integer -----------------------------------------------------------
    def add(s, rd, ra, rb, typ=Typ.I32, tsc="full"): s.emit(Op.ADD, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def sub(s, rd, ra, rb, typ=Typ.I32, tsc="full"): s.emit(Op.SUB, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def neg(s, rd, ra, typ=Typ.I32, tsc="full"): s.emit(Op.NEG, rd=rd, ra=ra, typ=typ, tsc=tsc)
    def abs_(s, rd, ra, typ=Typ.I32, tsc="full"): s.emit(Op.ABS, rd=rd, ra=ra, typ=typ, tsc=tsc)
    def mul16lo(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.MUL16LO, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def mul16hi(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.MUL16HI, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def mul24lo(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.MUL24LO, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def mul24hi(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.MUL24HI, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def and_(s, rd, ra, rb, tsc="full"): s.emit(Op.AND, rd=rd, ra=ra, rb=rb, tsc=tsc)
    def or_(s, rd, ra, rb, tsc="full"): s.emit(Op.OR, rd=rd, ra=ra, rb=rb, tsc=tsc)
    def xor(s, rd, ra, rb, tsc="full"): s.emit(Op.XOR, rd=rd, ra=ra, rb=rb, tsc=tsc)
    def not_(s, rd, ra, tsc="full"): s.emit(Op.NOT, rd=rd, ra=ra, tsc=tsc)
    def cnot(s, rd, ra, tsc="full"): s.emit(Op.CNOT, rd=rd, ra=ra, tsc=tsc)
    def bvs(s, rd, ra, tsc="full"): s.emit(Op.BVS, rd=rd, ra=ra, tsc=tsc)
    def shl(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.SHL, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def shr(s, rd, ra, rb, typ=Typ.U32, tsc="full"): s.emit(Op.SHR, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def pop(s, rd, ra, tsc="full"): s.emit(Op.POP, rd=rd, ra=ra, tsc=tsc)
    def max_(s, rd, ra, rb, typ=Typ.I32, tsc="full"): s.emit(Op.MAX, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def min_(s, rd, ra, rb, typ=Typ.I32, tsc="full"): s.emit(Op.MIN, rd=rd, ra=ra, rb=rb, typ=typ, tsc=tsc)

    # --- FP ------------------------------------------------------------------
    def fadd(s, rd, ra, rb, tsc="full"): s.emit(Op.FADD, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def fsub(s, rd, ra, rb, tsc="full"): s.emit(Op.FSUB, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def fneg(s, rd, ra, tsc="full"): s.emit(Op.FNEG, rd=rd, ra=ra, typ=Typ.F32, tsc=tsc)
    def fabs(s, rd, ra, tsc="full"): s.emit(Op.FABS, rd=rd, ra=ra, typ=Typ.F32, tsc=tsc)
    def fmul(s, rd, ra, rb, tsc="full"): s.emit(Op.FMUL, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def fmax(s, rd, ra, rb, tsc="full"): s.emit(Op.FMAX, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def fmin(s, rd, ra, rb, tsc="full"): s.emit(Op.FMIN, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)

    # --- memory / immediates / thread ids ---------------------------------
    def lod(s, rd, ra, offset=0, tsc="full"): s.emit(Op.LOD, rd=rd, ra=ra, imm=offset, tsc=tsc)
    def sto(s, rd, ra, offset=0, tsc="full"): s.emit(Op.STO, rd=rd, ra=ra, imm=offset, tsc=tsc)
    def lodi(s, rd, imm, tsc="full"):
        if not -32768 <= imm <= 65535:
            raise ValueError("LODI immediate out of 16-bit range")
        if imm > 32767:
            imm -= 0x10000
        s.emit(Op.LODI, rd=rd, imm=imm, tsc=tsc)
    def tdx(s, rd, tsc="full"): s.emit(Op.TDX, rd=rd, tsc=tsc)
    def tdy(s, rd, tsc="full"): s.emit(Op.TDY, rd=rd, tsc=tsc)

    def lodi32(self, rd: int, value: int, s1: int, s2: int, tsc="full") -> None:
        """Load a full 32-bit constant.

        Paper-faithful lowering: LODI sign-extends a 16-bit immediate and
        SHL takes a *register* shift amount (Table 2), so two scratch
        registers are needed.  SHL-by-16 discards the hi half's sign
        extension; a logical SHL/SHR pair zero-extends the low half.
        """
        value &= 0xFFFFFFFF
        hi, lo = value >> 16, value & 0xFFFF
        if hi == 0 and lo < 0x8000:
            self.lodi(rd, lo, tsc=tsc)
            return
        self.lodi(s1, 16, tsc=tsc)
        self.lodi(rd, hi if hi < 0x8000 else hi - 0x10000, tsc=tsc)
        self.shl(rd, rd, s1, typ=Typ.U32, tsc=tsc)
        self.lodi(s2, lo if lo < 0x8000 else lo - 0x10000, tsc=tsc)
        if lo & 0x8000:  # zero-extend the low half
            self.shl(s2, s2, s1, typ=Typ.U32, tsc=tsc)
            self.shr(s2, s2, s1, typ=Typ.U32, tsc=tsc)
        self.or_(rd, rd, s2, tsc=tsc)

    def fconst(self, rd: int, value: float, s1: int, s2: int, tsc="full") -> None:
        bits = int(np.float32(value).view(np.uint32))
        self.lodi32(rd, bits, s1, s2, tsc=tsc)

    # --- extension ---------------------------------------------------------
    def dot(s, rd, ra, rb, tsc="full"):
        if not s.cfg.has_dot:
            raise ValueError("this eGPU configuration has no dot-product core")
        s.emit(Op.DOT, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def sum_(s, rd, ra, rb=0, tsc="full"):
        if not s.cfg.has_dot:
            raise ValueError("SUM uses the dot-product core (not configured)")
        s.emit(Op.SUM, rd=rd, ra=ra, rb=rb, typ=Typ.F32, tsc=tsc)
    def invsqr(s, rd, ra, tsc="full"):
        if not s.cfg.has_invsqr:
            raise ValueError("this eGPU configuration has no SFU")
        s.emit(Op.INVSQR, rd=rd, ra=ra, typ=Typ.F32, tsc=tsc)

    # --- control -------------------------------------------------------------
    def jmp(s, target): s.emit(Op.JMP, imm=target)
    def jsr(s, target): s.emit(Op.JSR, imm=target)
    def rts(s): s.emit(Op.RTS)
    def loop_(s, target): s.emit(Op.LOOP, imm=target)
    def init(s, count): s.emit(Op.INIT, imm=count)
    def stop(s): s.emit(Op.STOP)
    def nop(s, n=1):
        for _ in range(n):
            s.emit(Op.NOP)

    # --- predicates ----------------------------------------------------------
    def if_(s, cc: str, ra=0, rb=0, typ=Typ.I32, tsc="full"):
        if not s.cfg.has_predicates:
            raise ValueError("this eGPU configuration has no predicates")
        op = Op[f"IF_{cc.upper()}"]
        s.emit(op, ra=ra, rb=rb, typ=typ, tsc=tsc)
    def else_(s, tsc="full"): s.emit(Op.ELSE, tsc=tsc)
    def endif(s, tsc="full"): s.emit(Op.ENDIF, tsc=tsc)

    # --- structured helpers ------------------------------------------------
    def loop(self, count: int):
        """``with a.loop(n):`` — runs the body n times (INIT n-1 ... LOOP)."""
        asm = self

        class _Loop:
            def __enter__(ctx):
                if count < 1:
                    raise ValueError("loop count must be >= 1")
                asm.init(count - 1)
                ctx.top = asm.label()
                return ctx

            def __exit__(ctx, *exc):
                if exc[0] is None:
                    asm.loop_(ctx.top)

        return _Loop()

    # ------------------------------------------------------------- assembly
    def assemble(self, threads_active: int | None = None, *,
                 schedule_nops: bool = True) -> ProgramImage:
        threads_active = threads_active or self.cfg.max_threads
        items = list(self.items)
        if schedule_nops:
            items = schedule(items, self.cfg, threads_active)
        # pass 1: resolve label addresses
        addr, labels = 0, {}
        for it in items:
            if isinstance(it, Label):
                if it.name in labels:
                    raise ValueError(f"duplicate label {it.name!r}")
                labels[it.name] = addr
            else:
                addr += 1
        # pass 2: emit
        instrs: list[Instr] = []
        for it in items:
            if isinstance(it, Label):
                continue
            if isinstance(it.imm, str):
                it = it._replace(imm=labels[it.imm])
            instrs.append(it)
        if not instrs or instrs[-1].op != Op.STOP:
            instrs.append(Instr(op=int(Op.STOP)))
        arr = lambda f: np.array([getattr(i, f) for i in instrs], dtype=np.int32)
        words = np.array(
            [isa.encode_word(i, self.cfg.regs_per_thread) for i in instrs],
            dtype=np.uint64)
        listing = [repr(i) for i in instrs]
        return ProgramImage(cfg=self.cfg, op=arr("op"), typ=arr("typ"),
                            rd=arr("rd"), ra=arr("ra"), rb=arr("rb"),
                            imm=arr("imm"), tsc=arr("tsc"), words=words,
                            listing=listing, threads_active=threads_active)


# ---------------------------------------------------------------------------
# Hazard scheduling
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Write:
    start: int        # issue-start cycle of the producer
    per_wf: int       # producer issue cycles per wavefront
    wfs: int          # producer wavefront count
    lat: int          # result latency


def _per_wf(op: int, tsc: int, cfg: EGPUConfig) -> int:
    o = Op(op)
    width = isa.WIDTH_LANES[isa.tsc_width(tsc)]
    if o == Op.LOD:
        return -(-width // cfg.cost.sp_read_ports)
    if o == Op.STO:
        return -(-width // cfg.write_ports)
    return 1


def _ready_constraint(w: _Write, per_wf_c: int, wfs_c: int) -> int:
    """Earliest issue-start cycle for a consumer reading ``w``'s register.

    Producer wavefront ``k`` finishes issuing at ``start + per_wf*(k+1) - 1``
    and its result is readable ``lat`` cycles later.  The consumer's
    wavefront ``k`` reads at ``c_start + per_wf_c*k``.  The binding
    constraint is the max over the wavefronts both touch.
    """
    k_max = min(w.wfs, wfs_c) - 1
    d = w.per_wf - per_wf_c
    k = k_max if d > 0 else 0
    return w.start + w.per_wf * (k + 1) - 1 + w.lat - per_wf_c * k


def _reads(ins: Instr, cfg: EGPUConfig) -> list:
    o = Op(ins.op)
    rs: list = []
    if o in isa.READS_RA:
        rs.append(ins.ra)
    if o in isa.READS_RB:
        rs.append(ins.rb)
    if o in isa.READS_RD:
        rs.append(ins.rd)
    if o == Op.LOD:
        rs.append(Asm._VMEM)
    # every masked vector op consumes the predicate state
    if cfg.has_predicates and o not in isa.SCALAR_OPS:
        rs.append(Asm._VPRED)
    return rs


def _writes(ins: Instr, cfg: EGPUConfig) -> list:
    o = Op(ins.op)
    ws: list = []
    if o in isa.REG_WRITE_OPS:
        ws.append(ins.rd)
    if o == Op.STO:
        ws.append(Asm._VMEM)
    if o in isa.PRED_WRITE_OPS:
        ws.append(Asm._VPRED)
    return ws


def schedule(items: Sequence, cfg: EGPUConfig, threads_active: int) -> list:
    """Insert NOPs so that no read-after-write hazard remains.

    Linear pass with exact per-wavefront skew modelling; backward branches
    (LOOP/JMP to an earlier label) additionally drain any writes that are
    re-read at the loop head.
    """
    wfs_rt = max(1, -(-threads_active // cfg.num_sps))
    out: list = []
    ready: dict = {}          # reg -> _Write
    now = 0
    label_pos: dict[str, int] = {}

    def wf_count(tsc: int) -> int:
        return cost.depth_wavefronts(isa.tsc_depth(tsc), wfs_rt)

    for it in items:
        if isinstance(it, Label):
            label_pos[it.name] = len(out)
            out.append(it)
            continue
        ins: Instr = it
        o = Op(ins.op)

        # --- subroutine boundaries: drain every pending write ----------
        # (the linear pass cannot see call-graph edges; the paper's 8-deep
        # pipe makes the full drain at most 7 NOPs per JSR/RTS)
        # Forward JMPs drain too: the jump path reaches the target with
        # only one cycle elapsed, while the linear pass advances ``now``
        # through the whole skipped region — pending pre-JMP writes would
        # look settled at the join when at runtime they are not.
        if o in (Op.JSR, Op.RTS) or (
                o == Op.JMP and not (isinstance(ins.imm, str)
                                     and ins.imm in label_pos)):
            need = 0
            for w in ready.values():
                need = max(need,
                           w.start + w.per_wf * w.wfs - 1 + w.lat + 1)
            stall = max(0, need - now)
            for _ in range(stall):
                out.append(Instr(op=int(Op.NOP)))
                now += 1

        # --- backward-branch drain ------------------------------------
        if o in (Op.LOOP, Op.JMP, Op.JSR) and isinstance(ins.imm, str) \
                and ins.imm in label_pos:
            body = [x for x in out[label_pos[ins.imm]:] if isinstance(x, Instr)]
            need = 0
            for b in body:
                for r in _reads(b, cfg):
                    w = ready.get(r)
                    if w is not None:
                        need = max(need, _ready_constraint(
                            w, _per_wf(b.op, b.tsc, cfg), wf_count(b.tsc)))
            # +1: the branch itself takes a cycle before the head re-issues
            stall = max(0, need - (now + 1))
            for _ in range(stall):
                out.append(Instr(op=int(Op.NOP)))
                now += 1

        # --- RAW stall --------------------------------------------------
        if o not in (Op.NOP,):
            per_wf_c = _per_wf(ins.op, ins.tsc, cfg)
            wfs_c = wf_count(ins.tsc)
            need = 0
            for r in _reads(ins, cfg):
                w = ready.get(r)
                if w is not None:
                    need = max(need, _ready_constraint(w, per_wf_c, wfs_c))
            # WAW: preserve write order to the same register
            for r in _writes(ins, cfg):
                w = ready.get(r)
                if w is not None:
                    lat_c = cost.result_latency(ins.op, cfg)
                    need = max(need, w.start + w.lat - lat_c + 1)
            stall = max(0, need - now)
            for _ in range(stall):
                out.append(Instr(op=int(Op.NOP)))
                now += 1

        # --- issue ------------------------------------------------------
        start = now
        now += cost.issue_cycles(ins.op, ins.tsc, wfs_rt, cfg) \
            if o not in isa.SCALAR_OPS else 1
        for r in _writes(ins, cfg):
            ready[r] = _Write(start=start, per_wf=_per_wf(ins.op, ins.tsc, cfg),
                              wfs=wf_count(ins.tsc),
                              lat=cost.result_latency(ins.op, cfg))
        out.append(ins)
    return out
