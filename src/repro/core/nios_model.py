"""Nios II/e-class soft-RISC cost model — the paper's §7 baseline.

The paper benchmarks against a Nios II/e (1100 ALMs + 3 DSP, 347 MHz,
"most benchmarks retired an instruction every 1.7 clock cycles, except
the matrix-matrix multiplies and FFT, which required about 3 clocks"
because of 32x32 multiplier emulation).  We model exactly that: an
analytic dynamic-instruction count per algorithm x a measured CPI.
``tests/test_nios_model.py`` checks the model lands within ~35% of every
Nios cycle count printed in Tables 7/8.
"""
from __future__ import annotations

NIOS_FMAX_MHZ = 347.0
CPI_DEFAULT = 1.7
CPI_MUL_HEAVY = 3.0     # 32x32 multiplies emulated in ALMs

#: per-element inner-loop instruction counts (load/store/alu/branch),
#: from hand-compiling the kernels for a single-issue RISC.
_PER_ELEM = {
    "reduction": 8,      # ld, add, ptr++, cmp, branch + amortised spill
    "transpose": 12,     # ld, st, row/col addr arithmetic, loop
    "matmul": 15,        # 2 ld w/ addr gen, soft 32x32 mul-add seq, loop
    "bitonic": 15,       # 2 ld, cmp, cond swap (2 st), index xor/and, loop
    "fft": 34,           # 6 ld, 4 st, complex soft mul-add, twiddle addr
}


def cycles(bench: str, n: int) -> int:
    if bench == "reduction":
        work = n * _PER_ELEM["reduction"] + 64
        return int(work * CPI_DEFAULT * 2.0)   # read-use stalls on Nios II/e
    if bench == "transpose":
        work = n * n * _PER_ELEM["transpose"] + 128
        return int(work * CPI_DEFAULT)
    if bench == "matmul":
        work = n * n * n * _PER_ELEM["matmul"] + n * n * 4
        return int(work * CPI_MUL_HEAVY * 0.985)
    if bench == "bitonic":
        import math
        passes = sum(range(1, int(math.log2(n)) + 1))
        work = passes * n * _PER_ELEM["bitonic"] / 2 + 128
        return int(work * CPI_DEFAULT * 1.4)
    if bench == "fft":
        import math
        stages = int(math.log2(n))
        work = stages * (n // 2) * _PER_ELEM["fft"]
        return int(work * CPI_MUL_HEAVY * 1.1)
    raise KeyError(bench)


def time_us(bench: str, n: int) -> float:
    return cycles(bench, n) / NIOS_FMAX_MHZ


#: Paper-reported Nios cycles (Tables 7 and 8) for validation.  The
#: (reduction, 32) point is excluded from the tolerance test: the paper's
#: own scaling is anomalous there (459 -> 1803 cycles for 2x data, then
#: exactly 2x afterwards), which no linear instruction-count model fits.
PAPER_NIOS = {
    ("reduction", 32): 459, ("reduction", 64): 1803, ("reduction", 128): 3595,
    ("transpose", 32): 21809, ("transpose", 64): 86609, ("transpose", 128): 345233,
    ("matmul", 32): 1_450_000, ("matmul", 64): 11_600_000, ("matmul", 128): 92_500_000,
    ("bitonic", 32): 8457, ("bitonic", 64): 20687, ("bitonic", 128): 49741,
    ("bitonic", 256): 149271,
    ("fft", 32): 9165, ("fft", 64): 20848, ("fft", 128): 46667,
    ("fft", 256): 103636,
}
