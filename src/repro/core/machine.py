"""eGPU architectural state as a JAX pytree, plus host-side helpers."""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import isa
from .config import EGPUConfig


class MachineState(NamedTuple):
    """Every architectural structure of the eGPU, as arrays.

    The register file is ``uint32`` — FP32 values live in registers as raw
    bits (bitcast in/out of the FP units), exactly like the hardware, so
    integer/FP aliasing behaves faithfully.
    """

    regs: jnp.ndarray          # (T, R) uint32 — thread register files
    shared: jnp.ndarray        # (S,)  uint32 — shared memory
    pstack: jnp.ndarray        # (T, D) bool — per-thread predicate stacks
    pdepth: jnp.ndarray        # (T,)  int32 — predicate nesting depth
    lctr: jnp.ndarray          # (LD,) int32 — loop-counter stack
    lsp: jnp.ndarray           # ()    int32
    cstack: jnp.ndarray        # (CD,) int32 — subroutine return stack
    csp: jnp.ndarray           # ()    int32
    pc: jnp.ndarray            # ()    int32
    cycles: jnp.ndarray        # ()    int32 — the benchmark metric
    steps: jnp.ndarray         # ()    int32 — instructions executed
    halted: jnp.ndarray        # ()    bool
    threads_active: jnp.ndarray  # () int32 — runtime thread count
    tdx_dim: jnp.ndarray       # ()    int32 — TDX/TDY grid x-dimension
    stat_cycles: jnp.ndarray   # (NUM_OP_CLASSES,) int32 — Fig. 6 profile
    stat_instrs: jnp.ndarray   # (NUM_OP_CLASSES,) int32
    # hazard-checker bookkeeping (not architectural): one row per register
    # plus two virtual slots (shared-memory, predicate state); columns are
    # (issue_start, per_wf, wavefronts, latency) of the last writer.
    hazard: jnp.ndarray        # (R+2, 4) int32
    hazard_violations: jnp.ndarray  # () int32


def pack_shared_init(shared_init, shared_words: int) -> np.ndarray:
    """Coerce a shared-memory image to uint32 words (FP32 views FP bits)."""
    buf = np.asarray(shared_init)
    if buf.dtype.kind == "f":
        buf = buf.astype(np.float32).view(np.uint32)
    buf = buf.astype(np.uint32).ravel()
    if buf.size > shared_words:
        raise ValueError(
            f"shared_init ({buf.size} words) exceeds {shared_words}")
    return buf


def hazard_init(regs_per_thread: int) -> np.ndarray:
    """Initial hazard-checker rows: every slot "written long ago"."""
    hz = np.zeros((regs_per_thread + 2, 4), np.int32)
    hz[:, 0] = -(1 << 30)
    hz[:, 1] = 1
    hz[:, 2] = 1
    return hz


def init_state(cfg: EGPUConfig, *, threads: int | None = None,
               tdx_dim: int = 16,
               shared_init: np.ndarray | None = None) -> MachineState:
    threads = threads or cfg.max_threads
    if threads > cfg.max_threads or threads % cfg.num_sps:
        raise ValueError(
            f"runtime threads {threads} invalid for max {cfg.max_threads}")
    T, R, S = cfg.max_threads, cfg.regs_per_thread, cfg.shared_words
    D = max(1, cfg.predicate_levels)
    shared = jnp.zeros((S,), jnp.uint32)
    if shared_init is not None:
        buf = pack_shared_init(shared_init, S)
        shared = shared.at[: buf.size].set(jnp.asarray(buf))
    hz = hazard_init(R)
    return MachineState(
        regs=jnp.zeros((T, R), jnp.uint32),
        shared=shared,
        pstack=jnp.zeros((T, D), jnp.bool_),
        pdepth=jnp.zeros((T,), jnp.int32),
        lctr=jnp.zeros((cfg.max_loop_depth,), jnp.int32),
        lsp=jnp.int32(0),
        cstack=jnp.zeros((cfg.max_call_depth,), jnp.int32),
        csp=jnp.int32(0),
        pc=jnp.int32(0),
        cycles=jnp.int32(0),
        steps=jnp.int32(0),
        halted=jnp.bool_(False),
        threads_active=jnp.int32(threads),
        tdx_dim=jnp.int32(tdx_dim),
        stat_cycles=jnp.zeros((isa.NUM_OP_CLASSES,), jnp.int32),
        stat_instrs=jnp.zeros((isa.NUM_OP_CLASSES,), jnp.int32),
        hazard=jnp.asarray(hz),
        hazard_violations=jnp.int32(0),
    )


# --- host-side views -------------------------------------------------------

def shared_as_f32(state: MachineState) -> np.ndarray:
    return np.asarray(state.shared).view(np.float32)


def shared_as_u32(state: MachineState) -> np.ndarray:
    return np.asarray(state.shared)


def shared_as_i32(state: MachineState) -> np.ndarray:
    return np.asarray(state.shared).view(np.int32)


def regs_as_f32(state: MachineState) -> np.ndarray:
    return np.asarray(state.regs).view(np.float32)


def profile(state: MachineState) -> dict[str, tuple[int, int]]:
    """Instruction-mix profile (cycles, instructions) per class — Fig. 6."""
    out = {}
    for c in isa.OpClass:
        out[c.name] = (int(state.stat_cycles[c]), int(state.stat_instrs[c]))
    return out
