"""The eGPU basic-block compiler: specialize execution to the static program.

The interpreter (:mod:`repro.core.executor`) pays full per-instruction
dispatch cost — a program-row gather, an opcode-metadata gather, and a
switch/where-chain over the working set — on every ``while_loop`` trip.
But every :class:`ProgramImage` is completely static, and the eGPU ISA
has **no data-dependent branches**: JMP/JSR/LOOP targets and INIT loop
counts are all immediates, so the entire execution path (and with it the
cycle count, the instruction-mix profile and the RAW hazard checker) is
decodable ahead of time.  This module exploits that:

* the program is decomposed at control-flow boundaries into **basic
  blocks** (leaders: entry, branch/call targets, return addresses,
  fall-throughs past a sequencer op);
* each block is traced with opcodes/registers/immediates/TSC fields as
  *Python constants* — no program gather, no opcode-table gather, no
  switch, no hazard machinery — so the whole block fuses into one
  straight-line XLA computation (per-opcode value semantics come from
  :mod:`repro.core.semantics`, shared with the interpreter);
* a small ``lax.while_loop`` drives block to block through a
  ``lax.switch`` over the block entries, carrying only the architectural
  state;
* hazards, cycles-at-issue and the final hazard bookkeeping are computed
  **once, statically** by simulating the sequencer on the host
  (:func:`_simulate`); the baked results are bit-identical to the
  interpreter's because the simulated path *is* the executed path.

The dynamic state is split in two.  ``_Data`` (registers, shared memory,
predicate stacks, TDX grid) is per-job: under the fleet's compiled tier
it carries a leading batch axis and every same-program core advances in
lock-step through identical blocks.  ``_Seq`` (PC, cycles, stacks,
counters) is data-independent — identical for every core running the
program — so it stays unbatched even in a batched run, and block-to-block
control flow remains *real* control flow (one switch branch executes)
instead of vmap's execute-everything-select-one.

Results are bit-identical to :func:`repro.core.executor.run_program` —
registers, shared memory, cycles, steps, PC, stats, hazard rows and
violation count — which the equivalence suite (``tests/test_blockc.py``)
pins across the program suite and configuration space.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import isa, semantics
from . import machine as machine_mod
from .assembler import ProgramImage
from .config import EGPUConfig
from .executor import (_PF_IMM, _PF_OP, _PF_RA, _PF_RB, _PF_RD, _PF_TSC,
                       _PF_TYP, _TC_CLS, _TC_LAT, _TC_PER_WF0, _TC_READS_RA,
                       _TC_READS_RB, _TC_READS_RD, _TC_SCALAR, _TC_WRITES_PRED,
                       _TC_WRITES_RD, pad_image, tables_np)
from .isa import Op, Typ
from .machine import MachineState

_I32 = jnp.int32
_U32 = jnp.uint32

#: sequencer ops that end a basic block (IF/ELSE/ENDIF are *predicate*
#: ops — they mask threads but never move the PC, so they trace inline)
_SEQ_TERM = (int(Op.JMP), int(Op.JSR), int(Op.RTS), int(Op.LOOP),
             int(Op.STOP))

#: trace-size bound: longer straight-line runs are split with an
#: artificial fall-through (keeps per-block XLA compiles bounded)
_MAX_BLOCK = 192

#: host-side path-simulation bound (a program must halt within
#: ``min(cfg.max_steps, _SIM_CAP)`` to be block-compilable)
_SIM_CAP = 4_000_000


class BlockCompileError(Exception):
    """The program cannot be block-compiled (e.g. it does not halt within
    ``cfg.max_steps``, so interpreter equivalence cannot be guaranteed at
    block granularity).  Callers fall back to the interpreter."""


def _cdiv(a, b):
    return (a + b - 1) // b


def _gidx(i: int, n: int) -> int:
    """JAX dynamic-gather index semantics: negative wraps once, then
    clamps into range (mirrors ``arr[i]`` with a traced ``i``)."""
    if i < 0:
        i += n
    return min(max(i, 0), n - 1)


def _i32wrap(v: int) -> int:
    return ((v + (1 << 31)) & 0xFFFFFFFF) - (1 << 31)


# ---------------------------------------------------------------------------
# Static decode helpers
# ---------------------------------------------------------------------------

def _wfs_table(cfg: EGPUConfig, threads: int) -> list[int]:
    w_rt = _cdiv(threads, cfg.num_sps)
    return [1, w_rt, max(1, _cdiv(w_rt, 2)), max(1, _cdiv(w_rt, 4))]


def _tsc_static(cfg: EGPUConfig, tsc: int, threads: int):
    """(wfs, tsc_mask) for one instruction — everything Table 3 encodes,
    folded to Python/NumPy constants."""
    width_code = (tsc >> 2) & 3
    depth_code = tsc & 3
    wfs = _wfs_table(cfg, threads)[depth_code]
    lanes = isa.WIDTH_LANES[width_code]
    tid = np.arange(cfg.max_threads)
    tsc_mask = ((tid % cfg.num_sps < lanes) & (tid // cfg.num_sps < wfs)
                & (tid < threads))
    return wfs, tsc_mask


# ---------------------------------------------------------------------------
# CFG decomposition
# ---------------------------------------------------------------------------

def _decompose(packed: np.ndarray, n: int) -> list[tuple[int, int]]:
    """Split ``[0, n)`` into basic blocks ``(start, end)`` (end exclusive,
    terminator included).  Leaders: instruction 0, every in-range
    JMP/JSR/LOOP target, and every instruction after a sequencer op
    (fall-throughs and JSR return addresses)."""
    ops = packed[:n, _PF_OP]
    imms = packed[:n, _PF_IMM]
    leaders = {0}
    for i in range(n):
        o = int(ops[i])
        if o in (int(Op.JMP), int(Op.JSR), int(Op.LOOP)):
            t = int(imms[i])
            if 0 <= t < n:
                leaders.add(t)
        if o in _SEQ_TERM and i + 1 < n:
            leaders.add(i + 1)
    starts = sorted(leaders)
    blocks: list[tuple[int, int]] = []
    for s, e in zip(starts, starts[1:] + [n]):
        while e - s > _MAX_BLOCK:
            blocks.append((s, s + _MAX_BLOCK))
            s += _MAX_BLOCK
        blocks.append((s, e))
    return blocks


# ---------------------------------------------------------------------------
# Static path simulation: sequencer + cycles + hazard checker, on the host
# ---------------------------------------------------------------------------

class _SimResult(NamedTuple):
    steps: int
    cycles: int
    hazard: np.ndarray          # (R+2, 4) int32 — final checker rows
    violations: int


def _simulate(cfg: EGPUConfig, packed: np.ndarray, prog_len: int,
              threads: int, validate: bool) -> _SimResult:
    """Walk the (fully static) execution path once, mirroring the
    interpreter's sequencer, cycle accounting and hazard checker
    bit-for-bit.  Raises :class:`BlockCompileError` if the program does
    not halt before ``cfg.max_steps`` (the interpreter would then stop
    mid-block, which the block driver cannot reproduce)."""
    t = tables_np(cfg)
    R = cfg.regs_per_thread
    LD, CD = cfg.max_loop_depth, cfg.max_call_depth
    wfs_by_depth = _wfs_table(cfg, threads)
    hz = machine_mod.hazard_init(R).astype(np.int64)
    violations = 0
    lctr = [0] * LD
    cstack = [0] * CD
    lsp = csp = 0
    pc = cycles = steps = 0
    halted = False
    cap = min(cfg.max_steps, _SIM_CAP)
    L = packed.shape[0]

    while (not halted) and steps < cfg.max_steps and 0 <= pc < prog_len:
        if steps >= cap:
            raise BlockCompileError(
                f"program did not halt within {cap} steps")
        op, typ, rd, ra, rb, imm, tsc = (int(v) for v in packed[min(pc, L - 1)])
        width_code = (tsc >> 2) & 3
        depth_code = tsc & 3
        wfs = wfs_by_depth[depth_code]
        per_wf = int(t[op, _TC_PER_WF0 + width_code])
        scalar = bool(t[op, _TC_SCALAR])
        writes_rd = bool(t[op, _TC_WRITES_RD])
        issue = 1 if scalar else per_wf * wfs

        if validate:
            rows = [hz[_gidx(ra, R + 2)], hz[_gidx(rb, R + 2)],
                    hz[_gidx(rd, R + 2)], hz[R], hz[R + 1]]
            flags = [bool(t[op, _TC_READS_RA]), bool(t[op, _TC_READS_RB]),
                     bool(t[op, _TC_READS_RD]), op == Op.LOD,
                     cfg.has_predicates and not scalar]
            need = -(1 << 30)
            for (p_start, p_per_wf, p_wfs, p_lat), fl in zip(rows, flags):
                if not fl:
                    continue
                k = min(int(p_wfs), wfs) - 1 if p_per_wf > per_wf else 0
                cons = int(p_start) + int(p_per_wf) * (k + 1) - 1 \
                    + int(p_lat) - per_wf * k
                need = max(need, cons)
            if ((not scalar) or op == Op.LOD) and need > cycles:
                violations += 1
            new_row = (cycles, per_wf, wfs, int(t[op, _TC_LAT]))
            if writes_rd and 0 <= rd < R + 2:
                hz[rd] = new_row
            if op == Op.STO:
                hz[R] = new_row
            if t[op, _TC_WRITES_PRED]:
                hz[R + 1] = new_row

        if op == Op.JMP:
            pc = imm
        elif op == Op.JSR:
            if 0 <= csp < CD:
                cstack[csp] = pc + 1
            csp += 1
            pc = imm
        elif op == Op.RTS:
            pc = cstack[_gidx(csp - 1, CD)]
            csp -= 1
        elif op == Op.LOOP:
            ltop = lctr[_gidx(lsp - 1, LD)]
            if 0 <= lsp - 1 < LD:
                lctr[lsp - 1] = ltop - 1
            if ltop > 0:
                pc = imm
            else:
                lsp -= 1
                pc += 1
        elif op == Op.INIT:
            if 0 <= lsp < LD:
                lctr[lsp] = imm
            lsp += 1
            pc += 1
        else:
            if op == Op.STOP:
                halted = True
            pc += 1
        cycles = _i32wrap(cycles + issue)
        steps += 1

    if (not halted) and steps >= cfg.max_steps and 0 <= pc < prog_len:
        raise BlockCompileError(
            f"program did not halt within max_steps={cfg.max_steps}")
    return _SimResult(steps=steps, cycles=cycles,
                      hazard=hz.astype(np.int32), violations=violations)


# ---------------------------------------------------------------------------
# The dynamic state, split by batching behaviour
# ---------------------------------------------------------------------------

class _Data(NamedTuple):
    """Per-job state (batched under the fleet's compiled tier)."""

    regs: Any                  # (..., T, R) uint32
    shared: Any                # (..., S) uint32
    pstack: Any                # (..., T, D) bool
    tdx_dim: Any               # (...,) int32


class _Seq(NamedTuple):
    """Data-independent state — identical for every core running the
    program, so it stays unbatched even in a batched run."""

    pc: Any                    # () int32
    cycles: Any                # () int32
    steps: Any                 # () int32
    halted: Any                # () bool
    pdepth: Any                # (T,) int32
    lctr: Any                  # (LD,) int32
    lsp: Any                   # () int32
    cstack: Any                # (CD,) int32
    csp: Any                   # () int32
    stat_cycles: Any           # (NUM_OP_CLASSES,) int32
    stat_instrs: Any           # (NUM_OP_CLASSES,) int32


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

class CompiledProgram:
    """One program, block-compiled for one (config, thread-count) pair.

    ``run()`` executes a single core; ``run_batch()`` executes N cores in
    lock-step over batched data (same blocks, different data) — the
    fleet's compiled tier.  Fresh states only: the static path (and the
    baked hazard results) assume execution starts at PC 0 with empty
    stacks and zeroed registers, exactly like :func:`init_state`.
    """

    def __init__(self, image: ProgramImage, threads: int, *,
                 validate: bool = True):
        cfg = image.cfg
        if threads > cfg.max_threads or threads % cfg.num_sps:
            raise ValueError(
                f"runtime threads {threads} invalid for max "
                f"{cfg.max_threads}")
        self.cfg = cfg
        self.image = image
        self.threads = threads
        self.validate = validate
        self.packed, self.prog_len = pad_image(image)
        self.n = image.n
        self.sim = _simulate(cfg, self.packed, self.prog_len, threads,
                             validate)
        self.blocks = _decompose(self.packed, self.n)
        # NOT gated on cfg.has_predicates: the interpreter emulates a
        # one-level stack even for predicate-less configs (D clamps to 1)
        self.has_preds = any(
            int(o) in isa.PRED_WRITE_OPS for o in image.op)
        # pc -> block index; the padded STOP tail shares one dynamic block
        p2b = np.full((self.prog_len,), len(self.blocks), np.int32)
        for bi, (s, e) in enumerate(self.blocks):
            p2b[s:e] = bi
        self._pc2block = p2b
        self._tables = tables_np(cfg)
        self._run_jit = self._build_runner()

    # ------------------------------------------------------------- blocks
    def _block_fn(self, start: int, end: int):
        """Trace ``[start, end)`` as one straight-line computation."""
        cfg = self.cfg
        T, R, S = cfg.max_threads, cfg.regs_per_thread, cfg.shared_words
        D = max(1, cfg.predicate_levels)
        t = self._tables
        tid = np.arange(T, dtype=np.int32)
        tid0 = tid == 0
        rows = [tuple(int(v) for v in self.packed[i])
                for i in range(start, end)]
        term_op = rows[-1][_PF_OP] if rows[-1][_PF_OP] in _SEQ_TERM else None

        # per-block constants: cycles / instruction-mix increments
        block_cycles = 0
        stat_c = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_i = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        for (op, typ, rd, ra, rb, imm, tsc) in rows:
            wfs, _ = _tsc_static(cfg, tsc, self.threads)
            width_code = (tsc >> 2) & 3
            per_wf = int(t[op, _TC_PER_WF0 + width_code])
            issue = 1 if t[op, _TC_SCALAR] else per_wf * wfs
            block_cycles += issue
            stat_c[t[op, _TC_CLS]] += issue
            stat_i[t[op, _TC_CLS]] += 1

        def fn(data: _Data, seq: _Seq):
            regs, shared, pstack = data.regs, data.shared, data.pstack
            pdepth = seq.pdepth
            lctr, lsp = seq.lctr, seq.lsp
            cstack, csp = seq.cstack, seq.csp
            halted = seq.halted
            pc_next = jnp.int32(end)        # fall-through default
            pok = None                      # cached predicate mask

            for (op, typ, rd, ra, rb, imm, tsc) in rows:
                o = Op(op)
                if o in (Op.JMP, Op.STOP, Op.NOP):
                    continue                # handled below / no state change
                if o == Op.JSR or o == Op.RTS:
                    continue                # terminator, handled below
                if o == Op.LOOP:
                    continue                # terminator, handled below
                if o == Op.INIT:
                    lctr, lsp = semantics.loop_init(lctr, lsp, imm)
                    continue

                _, tsc_mask = _tsc_static(cfg, tsc, self.threads)
                if self.has_preds:
                    if pok is None:
                        pok = semantics.pred_ok(pstack, pdepth, D)
                    mask = tsc_mask & pok
                else:
                    mask = tsc_mask
                ra_r, rb_r, rd_r = (_gidx(ra, R), _gidx(rb, R),
                                    _gidx(rd, R))
                env = semantics.OpEnv(
                    cfg=cfg, rav=regs[..., ra_r], rbv=regs[..., rb_r],
                    rdv=regs[..., rd_r], signed=typ == Typ.I32, imm=imm,
                    mask=mask, tid=tid, shared=shared,
                    tdx_dim=data.tdx_dim)
                spec = semantics.build_spec(env)

                if o in isa.IF_OPS:
                    cond = spec[op][1]()
                    pstack, pdepth = semantics.pred_push(
                        pstack, pdepth, cond, tsc_mask, D)
                    pok = None
                elif o == Op.ELSE:
                    pstack = semantics.pred_else(pstack, pdepth, tsc_mask, D)
                    pok = None
                elif o == Op.ENDIF:
                    pdepth = semantics.pred_pop(pdepth, tsc_mask)
                    pok = None
                elif o == Op.STO:
                    addr = env.addr
                    sto_ok = mask & (addr >= 0) & (addr < S)
                    sidx = jnp.where(sto_ok, addr, S)
                    shared = semantics.store(shared, sidx, env.rdv)
                elif t[op, _TC_WRITES_RD]:
                    value = spec[op][0]().astype(_U32)
                    wmask = tid0 if o in (Op.DOT, Op.SUM) else mask
                    rd_w = min(max(rd, 0), R - 1)
                    col = jnp.where(wmask, value, regs[..., rd_w])
                    regs = regs.at[..., rd_w].set(col)

            # --- terminator --------------------------------------------
            imm = rows[-1][_PF_IMM]
            end_pc = end
            if term_op == Op.JMP:
                pc_next = jnp.int32(imm)
            elif term_op == Op.JSR:
                cstack, csp = semantics.call_push(
                    cstack, csp, jnp.int32(end_pc))
                pc_next = jnp.int32(imm)
            elif term_op == Op.RTS:
                pc_next = semantics.call_top(cstack, csp)
                csp = csp - 1
            elif term_op == Op.LOOP:
                lctr, taken, lsp_pop = semantics.loop_step(lctr, lsp)
                lsp = jnp.where(taken, lsp, lsp_pop)
                pc_next = jnp.where(taken, jnp.int32(imm),
                                    jnp.int32(end_pc))
            elif term_op == Op.STOP:
                halted = jnp.bool_(True)
                pc_next = jnp.int32(end_pc)

            seq2 = _Seq(
                pc=pc_next,
                cycles=seq.cycles + jnp.int32(_i32wrap(block_cycles)),
                steps=seq.steps + jnp.int32(len(rows)),
                halted=halted, pdepth=pdepth,
                lctr=lctr, lsp=jnp.asarray(lsp, _I32),
                cstack=cstack, csp=jnp.asarray(csp, _I32),
                stat_cycles=seq.stat_cycles + stat_c if self.validate
                else seq.stat_cycles,
                stat_instrs=seq.stat_instrs + stat_i if self.validate
                else seq.stat_instrs)
            return _Data(regs=regs, shared=shared, pstack=pstack,
                         tdx_dim=data.tdx_dim), seq2

        return fn

    def _pad_stop_fn(self):
        """One shared block for the padded STOP tail ``[n, prog_len)`` —
        the only block whose PC is dynamic."""
        stat_c = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_i = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_c[isa.OpClass.BRANCH] = 1
        stat_i[isa.OpClass.BRANCH] = 1

        def fn(data: _Data, seq: _Seq):
            return data, seq._replace(
                pc=seq.pc + 1, cycles=seq.cycles + 1, steps=seq.steps + 1,
                halted=jnp.bool_(True),
                stat_cycles=seq.stat_cycles + stat_c if self.validate
                else seq.stat_cycles,
                stat_instrs=seq.stat_instrs + stat_i if self.validate
                else seq.stat_instrs)

        return fn

    # ------------------------------------------------------------- driver
    def _build_runner(self):
        fns = [self._block_fn(s, e) for s, e in self.blocks]
        fns.append(self._pad_stop_fn())
        pc2block = jnp.asarray(self._pc2block)
        cfg = self.cfg
        T, R = cfg.max_threads, cfg.regs_per_thread
        D = max(1, cfg.predicate_levels)
        max_steps = cfg.max_steps
        prog_len = self.prog_len
        hazard = self.sim.hazard
        violations = self.sim.violations
        threads = self.threads

        def cond(carry):
            _, seq = carry
            return (~seq.halted) & (seq.steps < max_steps) & \
                (seq.pc >= 0) & (seq.pc < prog_len)

        def body(carry):
            data, seq = carry
            return lax.switch(pc2block[seq.pc], fns, data, seq)

        # One dispatch per run: the fresh registers/predicate stacks and
        # the fresh sequencer state are constants inside the jit, and the
        # final MachineState (including the statically baked hazard rows)
        # is assembled inside it too.  The shared-memory image is donated.
        @functools.partial(jax.jit, donate_argnums=(0,))
        def run(shared, tdx_dim):
            batch = shared.shape[:-1]          # () or (B,)
            z = jnp.int32(0)
            data = _Data(
                regs=jnp.zeros(batch + (T, R), jnp.uint32), shared=shared,
                pstack=jnp.zeros(batch + (T, D), jnp.bool_),
                tdx_dim=tdx_dim)
            seq = _Seq(
                pc=z, cycles=z, steps=z, halted=jnp.bool_(False),
                pdepth=jnp.zeros((T,), _I32),
                lctr=jnp.zeros((cfg.max_loop_depth,), _I32), lsp=z,
                cstack=jnp.zeros((cfg.max_call_depth,), _I32), csp=z,
                stat_cycles=jnp.zeros((isa.NUM_OP_CLASSES,), _I32),
                stat_instrs=jnp.zeros((isa.NUM_OP_CLASSES,), _I32))
            d, s = lax.while_loop(cond, body, (data, seq))

            def b(x):   # broadcast a seq leaf over the batch axis
                x = jnp.asarray(x)
                return jnp.broadcast_to(x, batch + x.shape)

            return MachineState(
                regs=d.regs, shared=d.shared, pstack=d.pstack,
                pdepth=b(s.pdepth), lctr=b(s.lctr), lsp=b(s.lsp),
                cstack=b(s.cstack), csp=b(s.csp), pc=b(s.pc),
                cycles=b(s.cycles), steps=b(s.steps), halted=b(s.halted),
                threads_active=b(jnp.int32(threads)),
                tdx_dim=d.tdx_dim,
                stat_cycles=b(s.stat_cycles), stat_instrs=b(s.stat_instrs),
                hazard=b(jnp.asarray(hazard)),
                hazard_violations=b(jnp.int32(violations)))

        return run

    # ------------------------------------------------------------- public
    def run(self, *, shared_init=None, tdx_dim: int = 16) -> MachineState:
        """Execute one core; bit-identical to ``run_program``."""
        S = self.cfg.shared_words
        shared = np.zeros((S,), np.uint32)
        if shared_init is not None:
            buf = machine_mod.pack_shared_init(shared_init, S)
            shared[:buf.size] = buf
        out = self._run_jit(jnp.asarray(shared), jnp.int32(tdx_dim))
        out.cycles.block_until_ready()
        return out

    def run_batch(self, shared_inits: list, tdx_dims) -> MachineState:
        """Execute N same-program cores in lock-step over batched data;
        returns the batched final state (slice jobs out along axis 0)."""
        S = self.cfg.shared_words
        n = len(shared_inits)
        shared = np.zeros((n, S), np.uint32)
        for i, s0 in enumerate(shared_inits):
            if s0 is None:
                continue
            buf = machine_mod.pack_shared_init(s0, S)
            shared[i, :buf.size] = buf
        out = self._run_jit(jnp.asarray(shared),
                            jnp.asarray(tdx_dims, _I32))
        out.cycles.block_until_ready()
        return out


# ---------------------------------------------------------------------------
# Compile cache + convenience drivers
# ---------------------------------------------------------------------------

_CACHE: dict = {}
_CACHE_MAX = 128


def program_key(image: ProgramImage) -> bytes:
    """Content identity of a program (the bit-packed instruction words
    encode every field) — used by the compile cache and the fleet's
    same-program batch grouping."""
    return image.words.tobytes()


def compile_program(image: ProgramImage, threads: int | None = None, *,
                    validate: bool = True) -> CompiledProgram:
    """Block-compile ``image`` for a static runtime thread count
    (default: the count it was assembled for).  Compiles are cached on
    (config, program bytes, threads, validate) — rejections too, so a
    non-halting program pays its (up to ``max_steps``-long) host-side
    path walk once, not on every fleet drain.

    Raises :class:`BlockCompileError` for programs whose static path does
    not halt within ``cfg.max_steps``.
    """
    threads = threads or image.threads_active
    key = (image.cfg, program_key(image), threads, validate)
    hit = _CACHE.get(key)
    if hit is None:
        if len(_CACHE) >= _CACHE_MAX:
            _CACHE.pop(next(iter(_CACHE)))
        try:
            hit = CompiledProgram(image, threads, validate=validate)
        except BlockCompileError as e:
            hit = e                      # negative-cache the rejection
        _CACHE[key] = hit
    if isinstance(hit, BlockCompileError):
        raise hit
    return hit


def run_compiled(image: ProgramImage, *, threads: int | None = None,
                 tdx_dim: int = 16, shared_init=None, validate: bool = True,
                 fallback: bool = True) -> MachineState:
    """Execute an assembled program through the block compiler.

    Drop-in for ``run_program(image, threads=..., tdx_dim=...,
    shared_init=...)`` — results are bit-identical.  ``fallback=True``
    silently routes programs the compiler rejects (non-halting static
    path) to the interpreter.
    """
    try:
        cp = compile_program(image, threads, validate=validate)
    except BlockCompileError:
        if not fallback:
            raise
        from .executor import run_program
        return run_program(image, validate=validate,
                           threads=threads or image.threads_active,
                           tdx_dim=tdx_dim, shared_init=shared_init)
    return cp.run(shared_init=shared_init, tdx_dim=tdx_dim)
