"""The eGPU block compiler: specialize execution to the static program.

The interpreter (:mod:`repro.core.executor`) pays full per-instruction
dispatch cost — a program-row gather, an opcode-metadata gather, and a
switch/where-chain over the working set — on every ``while_loop`` trip.
But every :class:`ProgramImage` is completely static, and the eGPU ISA
has **no data-dependent branches**: JMP/JSR/LOOP targets and INIT loop
counts are all immediates, so the entire execution path (and with it the
cycle count, the instruction-mix profile and the RAW hazard checker) is
decodable ahead of time.  This module exploits that:

* the program is decomposed at control-flow boundaries into **basic
  blocks** (leaders: entry, branch/call targets, return addresses,
  fall-throughs past a sequencer op);
* each block is traced with opcodes/registers/immediates/TSC fields as
  *Python constants* — no program gather, no opcode-table gather, no
  switch, no hazard machinery — so the whole block fuses into one
  straight-line XLA computation (per-opcode value semantics come from
  :mod:`repro.core.semantics`, shared with the interpreter);
* a small ``lax.while_loop`` drives block to block through a
  ``lax.switch`` over the block entries, carrying only the architectural
  state;
* hazards, cycles-at-issue and the final hazard bookkeeping are computed
  **once, statically** by simulating the sequencer on the host
  (:func:`_simulate`); the baked results are bit-identical to the
  interpreter's because the simulated path *is* the executed path.

The dynamic state is split in two.  ``_Data`` (registers, shared memory,
predicate stacks, TDX grid) is per-job: under the fleet's compiled tier
it carries a leading batch axis and every same-program core advances in
lock-step through identical blocks.  ``_Seq`` (PC, cycles, stacks,
counters) is data-independent — identical for every core running the
program — so it stays unbatched even in a batched run, and block-to-block
control flow remains *real* control flow (one switch branch executes)
instead of vmap's execute-everything-select-one.

On top of the basic-block tier sits the **superblock** tier: because
LOOP trip counts are INIT immediates, the *entire* execution path is one
static sequence of blocks, and the per-back-edge ``lax.switch`` dispatch
the block driver pays is avoidable.  The path simulator folds the
executed path online into a superblock *schedule* — straight-line pc
runs plus ``(body, count)`` repeat nodes at LOOP back-edges (fold is
equality-guarded, so a first iteration entered mid-body peels off
naturally and the schedule always flattens back to the exact executed
path).  The superblock runner traces that schedule with **no
``while_loop`` and no ``switch`` at all**: repeats small enough for the
trace budget unroll fully into the surrounding straight line; large
repeats become a ``lax.fori_loop`` whose body is the loop trace fused
once.  Every data-independent leaf (PC, cycles, steps, stacks, stats,
hazards) is baked from the simulation; only registers, shared memory and
the predicate state are traced.  Programs whose schedule exceeds the
trace budget fall back to the basic-block driver, and programs the
compiler rejects entirely fall back to the interpreter:
superblock → basic blocks → interpreter, bit-identical at every step.

Results are bit-identical to :func:`repro.core.executor.run_program` —
registers, shared memory, cycles, steps, PC, stats, hazard rows and
violation count — which the equivalence suites (``tests/test_blockc.py``,
``tests/test_superblock.py``) pin across the program suite and
configuration space.

**Tier selection is a static cost decision** (:class:`TierPolicy`): the
same way the paper fixes the pipeline structure from the statically
known fabric, ``mode="auto"`` picks between the basic-block driver and
the superblock runner from the already-computed path simulation —
dispatch counts, executed instructions, the repeat-node trip
distribution and the trace cost — instead of a binary eligibility
check.  The calibration behind the default thresholds lives in
``benchmarks/superblock.py`` (the ``auto_tier`` crossover sweep), and
every threshold is overridable per policy instance.

Callers that only read shared memory and the cycle count (the fleet
scheduler, throughput benchmarks) use the **light path**
(:meth:`CompiledProgram.run_light` / ``run_batch_light`` /
``run_light_dev``): only ``(shared, cycles, halted)`` leave the device,
nothing is donated (so device-resident inputs can be replayed across
drains), and the 18-leaf :class:`MachineState` assembly is skipped
entirely.
"""
from __future__ import annotations

import functools
import hashlib
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import cfg as cfg_mod
from . import isa, semantics
from . import machine as machine_mod
from .assembler import ProgramImage
from .config import EGPUConfig
from .executor import (_PF_IMM, _PF_OP, _PF_RA, _PF_RB, _PF_RD, _PF_TSC,
                       _PF_TYP, _TC_CLS, _TC_LAT, _TC_PER_WF0, _TC_READS_RA,
                       _TC_READS_RB, _TC_READS_RD, _TC_SCALAR, _TC_WRITES_PRED,
                       _TC_WRITES_RD, pad_image, tables_np)
from .isa import Op, Typ
from .machine import MachineState
from ..obs import trace as obs_trace

_I32 = jnp.int32
_U32 = jnp.uint32

#: block/trace structure is shared with the static analyzer — see
#: ``repro.core.cfg`` for the definitions
_SEQ_TERM = cfg_mod.SEQ_TERM
_MAX_BLOCK = cfg_mod.MAX_BLOCK
_MAX_TRACE = cfg_mod.MAX_TRACE

#: a repeat whose *executed* size is at most this unrolls fully into the
#: surrounding straight line (maximum fusion); larger repeats run as a
#: ``lax.fori_loop`` over the once-traced body.
_UNROLL_FULL = 256

#: host-side path-simulation bound (a program must halt within
#: ``min(cfg.max_steps, _SIM_CAP)`` to be block-compilable)
_SIM_CAP = 4_000_000


class BlockCompileError(Exception):
    """The program cannot be block-compiled (e.g. it does not halt within
    ``cfg.max_steps``, so interpreter equivalence cannot be guaranteed at
    block granularity).  Callers fall back to the interpreter."""


def _cdiv(a, b):
    return (a + b - 1) // b


def _gidx(i: int, n: int) -> int:
    """JAX dynamic-gather index semantics: negative wraps once, then
    clamps into range (mirrors ``arr[i]`` with a traced ``i``)."""
    if i < 0:
        i += n
    return min(max(i, 0), n - 1)


def _i32wrap(v: int) -> int:
    return ((v + (1 << 31)) & 0xFFFFFFFF) - (1 << 31)


# ---------------------------------------------------------------------------
# Static decode helpers
# ---------------------------------------------------------------------------

def _wfs_table(cfg: EGPUConfig, threads: int) -> list[int]:
    w_rt = _cdiv(threads, cfg.num_sps)
    return [1, w_rt, max(1, _cdiv(w_rt, 2)), max(1, _cdiv(w_rt, 4))]


def _tsc_static(cfg: EGPUConfig, tsc: int, threads: int):
    """(wfs, tsc_mask) for one instruction — everything Table 3 encodes,
    folded to Python/NumPy constants."""
    width_code = (tsc >> 2) & 3
    depth_code = tsc & 3
    wfs = _wfs_table(cfg, threads)[depth_code]
    lanes = isa.WIDTH_LANES[width_code]
    tid = np.arange(cfg.max_threads)
    tsc_mask = ((tid % cfg.num_sps < lanes) & (tid // cfg.num_sps < wfs)
                & (tid < threads))
    return wfs, tsc_mask


# ---------------------------------------------------------------------------
# CFG decomposition
# ---------------------------------------------------------------------------

#: shared with the static analyzer — extracted to ``repro.core.cfg``
_decompose = cfg_mod.decompose


# ---------------------------------------------------------------------------
# Superblock schedules: the compressed static path
# ---------------------------------------------------------------------------
#
# A *schedule* is a tuple of items; an item is an ``int`` pc (execute
# that instruction) or ``("rep", body, count)`` where ``body`` is itself
# a schedule executed ``count`` times.  Flattening a schedule always
# reproduces the exact executed path — folding is equality-guarded.

def _sched_insts(items) -> int:
    """Instruction slots a schedule *traces* (each repeat body once)."""
    n = 0
    for it in items:
        n += 1 if isinstance(it, (int, np.integer)) else _sched_insts(it[1])
    return n


def _sched_execd(items) -> int:
    """Instructions a schedule *executes* (repeat bodies times count)."""
    n = 0
    for it in items:
        if isinstance(it, (int, np.integer)):
            n += 1
        else:
            n += it[2] * _sched_execd(it[1])
    return n


def _trace_cost(items) -> int:
    """Instructions the superblock runner will actually trace, given the
    full-unroll policy (small repeats inline ``count`` times, large ones
    trace the body once under ``lax.fori_loop``)."""
    c = 0
    for it in items:
        if isinstance(it, (int, np.integer)):
            c += 1
        else:
            ex = it[2] * _sched_execd(it[1])
            c += ex if ex <= _UNROLL_FULL else _trace_cost(it[1])
    return c


class _PlanStats(NamedTuple):
    """What the superblock runner would actually do with a schedule,
    mirroring its unroll policy exactly (see ``_apply_schedule``)."""

    trace_cost: int             # instructions traced (== _trace_cost)
    fori_reps: int              # repeat nodes run as ``lax.fori_loop``
    unrolled_reps: int          # repeat nodes inlined into the trace
    fori_trips: tuple           # trip counts of the fori repeats
    fori_execd: int             # instructions executed inside fori reps


def _plan_stats(items) -> _PlanStats:
    trace = fori = unrolled = fori_execd = 0
    trips: list[int] = []
    for it in items:
        if isinstance(it, (int, np.integer)):
            trace += 1
            continue
        _, body, count = it
        ex = count * _sched_execd(body)
        if ex <= _UNROLL_FULL:
            # the whole subtree inlines: nested repeats unroll with it
            trace += ex
            unrolled += 1 + _count_reps(body)
        else:
            sub = _plan_stats(body)
            trace += sub.trace_cost
            fori += 1 + sub.fori_reps
            unrolled += sub.unrolled_reps
            trips.append(count)
            trips.extend(sub.fori_trips)
            fori_execd += ex
    return _PlanStats(trace_cost=trace, fori_reps=fori,
                      unrolled_reps=unrolled, fori_trips=tuple(trips),
                      fori_execd=fori_execd)


def _count_reps(items) -> int:
    n = 0
    for it in items:
        if not isinstance(it, (int, np.integer)):
            n += 1 + _count_reps(it[1])
    return n


def _sched_rep_trips(items) -> int:
    """Summed trip counts over every repeat node (each node once, like
    ``_PlanStats.fori_trips``) — event-counter bookkeeping."""
    n = 0
    for it in items:
        if not isinstance(it, (int, np.integer)):
            n += it[2] + _sched_rep_trips(it[1])
    return n


def _sched_rep_execd(items) -> int:
    """Instructions executed inside any repeat node (top-level bodies
    times count, nesting included) — event-counter bookkeeping."""
    n = 0
    for it in items:
        if not isinstance(it, (int, np.integer)):
            n += it[2] * _sched_execd(it[1])
    return n


#: default :class:`TierPolicy` threshold table.  Calibrated on the CPU
#: backend by the ``auto_tier`` crossover sweep in
#: ``benchmarks/superblock.py`` (loop_saxpy back-edge counts 8 -> 2048,
#: interleaved best-of timing through the light path, which is what the
#: fleet scheduler and the throughput benchmarks actually run): the
#: basic-block driver's cost grows ~linearly with its ``lax.switch``
#: dispatch count while the superblock runner stays nearly flat, and
#: the superblock's fixed per-call cost — mostly the 18-leaf
#: ``MachineState`` assembly on the full path — shrinks enough on the
#: light path that the measured crossover sits between 16 and 32
#: back-edges.  Batched lock-step runs tilt further: the block driver's
#: per-dispatch carried-state copies scale with the batch width, and at
#: batch >= 4 the superblock tier measured faster (or equal) on every
#: swept program, so wide batches always take an eligible superblock.
_TIER_DEFAULTS: dict[str, int | None] = {
    # hard eligibility bound on the traced-instruction budget
    # (None -> the module-wide ``_MAX_TRACE``)
    "max_trace_cost": None,
    # batches at least this wide always take an eligible superblock
    "batch_superblock_min": 4,
    # single-core: a plan must save at least this many block-driver
    # switch dispatches to amortize the superblock's fixed overhead
    "min_backedge_dispatches": 24,
    # single-core: a plan tracing at least this many instructions wins
    # on cross-block fusion even with few dispatches (bitonic/FFT-like
    # straight-line-heavy programs); below it, short fully-unrolled
    # traces stay on the (cheaper-to-launch) block driver
    "min_trace_fusion": 256,
    # single-core: a plan executing at least this many instructions
    # inside fori repeats amortizes the fixed overhead through the fused
    # loop body regardless of the dispatch count
    "min_fori_execd": 8192,
}


class TierPolicy:
    """The static cost model behind ``mode="auto"`` tier selection.

    Decides basic-block driver vs superblock runner from the host-side
    path simulation alone (:class:`_SimResult`) — no measurement, no
    dynamic feedback — the way the paper fixes processor structure from
    the statically-known resource mix.  The decision procedure, first
    match wins:

    1. no folded schedule, or its trace cost over ``max_trace_cost``
       -> **blocks** (ineligible);
    2. ``batch >= batch_superblock_min`` -> **superblock** (the block
       driver's per-dispatch carried-state copies scale with the batch
       width; measured at batch 32 the superblock tier is faster on
       every swept program);
    3. ``dispatches >= min_backedge_dispatches`` -> **superblock** (the
       dispatch savings amortize the fixed overhead);
    4. ``trace_cost >= min_trace_fusion`` -> **superblock** (cross-block
       fusion of a long trace — whether straight-line or unrolled);
    5. instructions executed inside ``fori``-run repeats
       ``>= min_fori_execd`` -> **superblock**;
    6. otherwise -> **blocks** (small paths — few dispatches, short
       trace: the superblock's fixed per-call cost eats the dispatch
       win).

    Thresholds are overridable per instance (``TierPolicy(
    min_backedge_dispatches=64)``); instances are immutable, hashable
    and usable as compile-cache key components.
    """

    def __init__(self, **overrides: int | None):
        unknown = set(overrides) - set(_TIER_DEFAULTS)
        if unknown:
            raise ValueError(
                f"unknown TierPolicy thresholds {sorted(unknown)}; "
                f"known: {sorted(_TIER_DEFAULTS)}")
        table = dict(_TIER_DEFAULTS)
        table.update(overrides)
        self._table = table
        self._key = tuple(sorted(table.items()))

    @property
    def table(self) -> dict[str, int | None]:
        """A copy of the threshold table (the instance stays immutable)."""
        return dict(self._table)

    def __eq__(self, other) -> bool:
        return isinstance(other, TierPolicy) and self._key == other._key

    def __hash__(self) -> int:
        return hash(self._key)

    def __repr__(self) -> str:
        diff = {k: v for k, v in self._table.items()
                if v != _TIER_DEFAULTS[k]}
        return f"TierPolicy({', '.join(f'{k}={v}' for k, v in diff.items())})"

    # ------------------------------------------------------------ model
    def batch_class(self, batch: int) -> int:
        """Collapse a batch-size hint to the classes the decision can
        distinguish (keeps compile-cache keys from fragmenting across
        every batch shape)."""
        wide = self._table["batch_superblock_min"]
        return wide if batch >= wide else 1

    def features(self, sim: _SimResult,
                 cfg_facts: dict | None = None) -> dict:
        """The decision's inputs, extracted from one path simulation.

        ``cfg_facts`` merges static control-flow-graph facts
        (:func:`repro.core.cfg.summary`) into the feature dict — the
        decision rules ignore keys they don't know, so the extra
        features ride along for logging and offline cost-model
        fitting."""
        cap = self._table["max_trace_cost"]
        cap = _MAX_TRACE if cap is None else cap
        base = {"dispatches": sim.dispatches, "execd": sim.steps}
        if cfg_facts:
            base.update(cfg_facts)
        if sim.schedule is None:
            return {**base, "eligible": False, "trace_cost": None,
                    "fori_reps": 0, "unrolled_reps": 0,
                    "fori_trips": (), "fori_execd": 0}
        ps = _plan_stats(sim.schedule)
        return {**base, "eligible": ps.trace_cost <= cap,
                "trace_cost": ps.trace_cost, "fori_reps": ps.fori_reps,
                "unrolled_reps": ps.unrolled_reps,
                "fori_trips": ps.fori_trips, "fori_execd": ps.fori_execd}

    def choose(self, sim: _SimResult, batch: int = 1, *,
               features: dict | None = None) -> str:
        """``"superblock"`` or ``"blocks"`` for this path at this batch
        width — the cheaper tier under the calibrated cost model.
        ``features`` accepts a precomputed :meth:`features` result so a
        caller that already extracted them doesn't pay the schedule
        walk twice."""
        f = self.features(sim) if features is None else features
        tier, rule = self._decide(f, batch)
        tr = obs_trace.current_tracer()
        if tr is not None:
            feats = {k: list(v) if isinstance(v, tuple) else v
                     for k, v in f.items()}
            tr.event("tier_decision", tier=tier, rule=rule,
                     batch=int(batch), features=feats,
                     thresholds=dict(self._table))
        return tier

    def _decide(self, f: dict, batch: int) -> tuple[str, str]:
        """(tier, first-matching rule) — the loggable decision core."""
        if not f["eligible"]:
            return "blocks", "ineligible (no schedule or over trace cap)"
        t = self._table
        if batch >= t["batch_superblock_min"]:
            return "superblock", (f"batch {batch} >= "
                                  f"batch_superblock_min "
                                  f"{t['batch_superblock_min']}")
        if f["dispatches"] >= t["min_backedge_dispatches"]:
            return "superblock", (f"dispatches {f['dispatches']} >= "
                                  f"min_backedge_dispatches "
                                  f"{t['min_backedge_dispatches']}")
        if f["trace_cost"] >= t["min_trace_fusion"]:
            return "superblock", (f"trace_cost {f['trace_cost']} >= "
                                  f"min_trace_fusion "
                                  f"{t['min_trace_fusion']}")
        if f["fori_execd"] >= t["min_fori_execd"]:
            return "superblock", (f"fori_execd {f['fori_execd']} >= "
                                  f"min_fori_execd {t['min_fori_execd']}")
        return "blocks", "no superblock rule fired"


#: the policy ``mode="auto"`` uses unless a caller overrides it
DEFAULT_TIER_POLICY = TierPolicy()


#: Per-backend threshold tables consulted by
#: :meth:`TierPolicy.for_backend`.  ``"cpu"`` is the measured default
#: (the ``auto_tier`` sweep above).  The ``"gpu"``/``"tpu"`` seeds are
#: *priors*, not measurements: on accelerators the block driver's
#: ``lax.switch`` dispatch is relatively more expensive (each dispatch
#: is a device-side branch over all traced blocks) while the
#: superblock's fixed host-side cost is amortized by the launch, so the
#: crossover moves earlier.  ``benchmarks/calibrate.py`` replaces a
#: seed with a fitted table by running the same sweep on the actual
#: backend and calling :func:`register_backend_table`.
_TIER_TABLES: dict[str, dict[str, int | None]] = {
    "cpu": dict(_TIER_DEFAULTS),
    "gpu": {**_TIER_DEFAULTS, "min_backedge_dispatches": 12,
            "min_trace_fusion": 128, "min_fori_execd": 4096},
    "tpu": {**_TIER_DEFAULTS, "min_backedge_dispatches": 12,
            "min_trace_fusion": 128, "min_fori_execd": 4096},
}


def register_backend_table(kind: str, **thresholds: int | None) -> None:
    """Install a (typically calibration-fitted) threshold table for one
    backend kind (``"cpu"``/``"gpu"``/``"tpu"``).  Unnamed thresholds
    keep the module defaults.  Subsequent
    :meth:`TierPolicy.for_backend`/:func:`default_policy_for_device`
    calls see the new table; already-constructed policies are unchanged
    (instances are immutable)."""
    unknown = set(thresholds) - set(_TIER_DEFAULTS)
    if unknown:
        raise ValueError(
            f"unknown TierPolicy thresholds {sorted(unknown)}; "
            f"known: {sorted(_TIER_DEFAULTS)}")
    _TIER_TABLES[kind] = {**_TIER_DEFAULTS, **thresholds}


def tier_policy_for_backend(kind: str) -> TierPolicy:
    """The :class:`TierPolicy` for a backend kind, from the registered
    (seeded or calibrated) table; unknown kinds fall back to the CPU
    defaults."""
    table = _TIER_TABLES.get(kind)
    if table is None:
        return DEFAULT_TIER_POLICY
    overrides = {k: v for k, v in table.items() if v != _TIER_DEFAULTS[k]}
    return TierPolicy(**overrides) if overrides else DEFAULT_TIER_POLICY


def default_policy_for_device(device) -> TierPolicy:
    """Policy for a concrete jax device (``None`` -> the default
    policy, so unpinned schedulers never touch device state)."""
    if device is None:
        return DEFAULT_TIER_POLICY
    return tier_policy_for_backend(device.platform)


class _PathRecorder:
    """Online fold of the executed path into a superblock schedule.

    Every executed pc is appended to the open schedule; at each LOOP
    back-edge the just-completed iteration is compared against the
    previous one (or an already-open repeat node) and folded when equal.
    A first iteration entered mid-body simply fails the comparison and
    stays inline — a free peel.  All mutations preserve the invariant
    that the schedule flattens to the exact executed path, so bookkeeping
    confusion (unbalanced INIT/LOOP, JMP out of a loop) can only cost
    compression, never correctness.  Recording bails out (``schedule()``
    returns None) when the retained size exceeds the trace budget or a
    LOOP fires with no open loop instance.
    """

    def __init__(self, cap: int):
        self._cap = cap
        self._items: list = []
        self._insts = 0             # instruction slots currently retained
        self._dead = False
        self._loops: list[dict] = []   # parallels the simulator loop stack

    def _bail(self) -> None:
        self._dead = True
        self._items = []
        self._loops = []

    def step(self, pc: int) -> None:
        if self._dead:
            return
        self._items.append(pc)
        self._insts += 1
        if self._insts > 2 * self._cap:
            self._bail()

    def on_init(self) -> None:
        if self._dead:
            return
        self._loops.append({"iter_start": len(self._items), "cand": None,
                            "cand_start": 0, "rep_idx": None})

    def on_loop(self, taken: bool) -> None:
        """Called after the LOOP pc itself was recorded via ``step``."""
        if self._dead:
            return
        if not self._loops:
            self._bail()                 # unbalanced LOOP: give up folding
            return
        inst = self._loops[-1]
        cur = self._items
        seg = tuple(cur[inst["iter_start"]:])
        ri = inst["rep_idx"]
        if ri is not None and cur[ri][1] == seg:
            cur[ri] = ("rep", seg, cur[ri][2] + 1)
            del cur[inst["iter_start"]:]
            self._insts -= _sched_insts(seg)
        elif inst["cand"] == seg:
            del cur[inst["cand_start"]:]
            cur.append(("rep", seg, 2))
            self._insts -= _sched_insts(seg)
            inst["rep_idx"] = len(cur) - 1
            inst["cand"] = None
            inst["iter_start"] = len(cur)
        else:
            inst["cand"] = seg
            inst["cand_start"] = inst["iter_start"]
            inst["rep_idx"] = None
            inst["iter_start"] = len(cur)
        if not taken:
            self._loops.pop()

    def schedule(self) -> tuple | None:
        return None if self._dead else tuple(self._items)


# ---------------------------------------------------------------------------
# Static path simulation: sequencer + cycles + hazard checker, on the host
# ---------------------------------------------------------------------------

class _SimResult(NamedTuple):
    steps: int
    cycles: int
    hazard: np.ndarray          # (R+2, 4) int32 — final checker rows
    violations: int
    pc: int                     # final PC
    halted: bool
    lctr: np.ndarray            # (LD,) int32 — final loop-counter stack
    lsp: int
    cstack: np.ndarray          # (CD,) int32 — final call stack
    csp: int
    stat_cycles: np.ndarray     # (NUM_OP_CLASSES,) int32
    stat_instrs: np.ndarray
    dispatches: int             # block-driver switch dispatches on this path
    schedule: tuple | None      # folded superblock schedule (None: too big)
    # event counters (python ints — unbounded, never wrapped):
    backedges: int = 0          # taken LOOP back-edges on the path
    lane_offered: int = 0       # vector retires x runtime thread count
    lane_active: int = 0        # of which the TSC mask left on


def _simulate(cfg: EGPUConfig, packed: np.ndarray, prog_len: int,
              threads: int, validate: bool, *,
              block_starts: frozenset = frozenset(),
              n_real: int | None = None) -> _SimResult:
    """Walk the (fully static) execution path once, mirroring the
    interpreter's sequencer, cycle accounting and hazard checker
    bit-for-bit, while folding the path into a superblock schedule and
    counting the block-driver dispatches it would cost.  Raises
    :class:`BlockCompileError` if the program does not halt before
    ``cfg.max_steps`` (the interpreter would then stop mid-block, which
    neither compiled driver can reproduce)."""
    t = tables_np(cfg)
    R = cfg.regs_per_thread
    LD, CD = cfg.max_loop_depth, cfg.max_call_depth
    wfs_by_depth = _wfs_table(cfg, threads)
    hz = machine_mod.hazard_init(R).astype(np.int64)
    violations = 0
    lctr = [0] * LD
    cstack = [0] * CD
    lsp = csp = 0
    pc = cycles = steps = 0
    halted = False
    cap = min(cfg.max_steps, _SIM_CAP)
    L = packed.shape[0]
    n_real = prog_len if n_real is None else n_real
    stat_c = [0] * isa.NUM_OP_CLASSES
    stat_i = [0] * isa.NUM_OP_CLASSES
    dispatches = 0
    backedges = 0
    lane_offered = lane_active = 0
    act_lut: dict[int, int] = {}    # tsc code -> active lanes (16 codes)
    rec = _PathRecorder(_MAX_TRACE)

    while (not halted) and steps < cfg.max_steps and 0 <= pc < prog_len:
        if steps >= cap:
            raise BlockCompileError(
                f"program did not halt within {cap} steps")
        op, typ, rd, ra, rb, imm, tsc = (int(v) for v in packed[min(pc, L - 1)])
        width_code = (tsc >> 2) & 3
        depth_code = tsc & 3
        wfs = wfs_by_depth[depth_code]
        per_wf = int(t[op, _TC_PER_WF0 + width_code])
        scalar = bool(t[op, _TC_SCALAR])
        writes_rd = bool(t[op, _TC_WRITES_RD])
        issue = 1 if scalar else per_wf * wfs
        rec.step(pc)
        if pc >= n_real or pc in block_starts:
            dispatches += 1
        stat_c[int(t[op, _TC_CLS])] += issue
        stat_i[int(t[op, _TC_CLS])] += 1
        if not scalar:
            act = act_lut.get(tsc)
            if act is None:
                act = act_lut[tsc] = int(
                    _tsc_static(cfg, tsc, threads)[1].sum())
            lane_offered += threads
            lane_active += act

        if validate:
            rows = [hz[_gidx(ra, R + 2)], hz[_gidx(rb, R + 2)],
                    hz[_gidx(rd, R + 2)], hz[R], hz[R + 1]]
            flags = [bool(t[op, _TC_READS_RA]), bool(t[op, _TC_READS_RB]),
                     bool(t[op, _TC_READS_RD]), op == Op.LOD,
                     cfg.has_predicates and not scalar]
            need = -(1 << 30)
            for (p_start, p_per_wf, p_wfs, p_lat), fl in zip(rows, flags):
                if not fl:
                    continue
                k = min(int(p_wfs), wfs) - 1 if p_per_wf > per_wf else 0
                cons = int(p_start) + int(p_per_wf) * (k + 1) - 1 \
                    + int(p_lat) - per_wf * k
                need = max(need, cons)
            if ((not scalar) or op == Op.LOD) and need > cycles:
                violations += 1
            new_row = (cycles, per_wf, wfs, int(t[op, _TC_LAT]))
            if writes_rd and 0 <= rd < R + 2:
                hz[rd] = new_row
            if op == Op.STO:
                hz[R] = new_row
            if t[op, _TC_WRITES_PRED]:
                hz[R + 1] = new_row

        if op == Op.JMP:
            pc = imm
        elif op == Op.JSR:
            if 0 <= csp < CD:
                cstack[csp] = pc + 1
            csp += 1
            pc = imm
        elif op == Op.RTS:
            pc = cstack[_gidx(csp - 1, CD)]
            csp -= 1
        elif op == Op.LOOP:
            ltop = lctr[_gidx(lsp - 1, LD)]
            if 0 <= lsp - 1 < LD:
                lctr[lsp - 1] = ltop - 1
            if ltop > 0:
                pc = imm
                backedges += 1
            else:
                lsp -= 1
                pc += 1
            rec.on_loop(ltop > 0)
        elif op == Op.INIT:
            if 0 <= lsp < LD:
                lctr[lsp] = imm
            lsp += 1
            pc += 1
            rec.on_init()
        else:
            if op == Op.STOP:
                halted = True
            pc += 1
        cycles = _i32wrap(cycles + issue)
        steps += 1

    if (not halted) and steps >= cfg.max_steps and 0 <= pc < prog_len:
        raise BlockCompileError(
            f"program did not halt within max_steps={cfg.max_steps}")
    return _SimResult(
        steps=steps, cycles=cycles, hazard=hz.astype(np.int32),
        violations=violations, pc=_i32wrap(pc), halted=halted,
        lctr=np.asarray([_i32wrap(v) for v in lctr], np.int32),
        lsp=_i32wrap(lsp),
        cstack=np.asarray([_i32wrap(v) for v in cstack], np.int32),
        csp=_i32wrap(csp),
        stat_cycles=np.asarray([_i32wrap(v) for v in stat_c], np.int32),
        stat_instrs=np.asarray([_i32wrap(v) for v in stat_i], np.int32),
        dispatches=dispatches, schedule=rec.schedule(),
        backedges=backedges, lane_offered=lane_offered,
        lane_active=lane_active)


# ---------------------------------------------------------------------------
# The dynamic state, split by batching behaviour
# ---------------------------------------------------------------------------

class _Data(NamedTuple):
    """Per-job state (batched under the fleet's compiled tier)."""

    regs: Any                  # (..., T, R) uint32
    shared: Any                # (..., S) uint32
    pstack: Any                # (..., T, D) bool
    tdx_dim: Any               # (...,) int32


class _Seq(NamedTuple):
    """Data-independent state — identical for every core running the
    program, so it stays unbatched even in a batched run."""

    pc: Any                    # () int32
    cycles: Any                # () int32
    steps: Any                 # () int32
    halted: Any                # () bool
    pdepth: Any                # (T,) int32
    lctr: Any                  # (LD,) int32
    lsp: Any                   # () int32
    cstack: Any                # (CD,) int32
    csp: Any                   # () int32
    stat_cycles: Any           # (NUM_OP_CLASSES,) int32
    stat_instrs: Any           # (NUM_OP_CLASSES,) int32


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

class CompiledProgram:
    """One program, compiled for one (config, thread-count) pair.

    ``run()`` executes a single core; ``run_batch()`` executes N cores in
    lock-step over batched data (same blocks, different data) — the
    fleet's compiled tier.  Fresh states only: the static path (and the
    baked hazard results) assume execution starts at PC 0 with empty
    stacks and zeroed registers, exactly like :func:`init_state`.

    ``mode`` selects the tier: ``"auto"`` (default) asks the
    :class:`TierPolicy` cost model to pick the cheaper tier for this
    path at this batch width (``batch_hint``); ``"superblock"`` requires
    the superblock runner (raising :class:`BlockCompileError` when the
    folded path is over the trace budget); ``"blocks"`` forces the
    basic-block driver.  The tier actually chosen is exposed as
    ``self.mode`` (the policy's inputs as ``self.tier_features``), and
    ``self.switch_dispatches`` counts the block-driver ``lax.switch``
    dispatches the program pays on this tier (0 on the superblock tier —
    that is the point).
    """

    def __init__(self, image: ProgramImage, threads: int, *,
                 validate: bool = True, mode: str = "auto",
                 policy: TierPolicy | None = None, batch_hint: int = 1):
        cfg = image.cfg
        if mode not in ("auto", "superblock", "blocks"):
            raise ValueError(f"unknown compile mode {mode!r}")
        if threads < 1 or threads > cfg.max_threads \
                or threads % cfg.num_sps:
            raise ValueError(
                f"runtime threads {threads} invalid for max "
                f"{cfg.max_threads}")
        self.cfg = cfg
        self.image = image
        self.threads = threads
        self.validate = validate
        self.packed, self.prog_len = pad_image(image)
        self.n = image.n
        self.blocks = _decompose(self.packed, self.n)
        self.sim = _simulate(
            cfg, self.packed, self.prog_len, threads, validate,
            block_starts=frozenset(s for s, _ in self.blocks),
            n_real=self.n)
        # NOT gated on cfg.has_predicates: the interpreter emulates a
        # one-level stack even for predicate-less configs (D clamps to 1)
        self.has_preds = any(
            int(o) in isa.PRED_WRITE_OPS for o in image.op)
        # pc -> block index; the padded STOP tail shares one dynamic block
        p2b = np.full((self.prog_len,), len(self.blocks), np.int32)
        for bi, (s, e) in enumerate(self.blocks):
            p2b[s:e] = bi
        self._pc2block = p2b
        self._tables = tables_np(cfg)
        self._tid = np.arange(cfg.max_threads, dtype=np.int32)
        self._tid0 = self._tid == 0
        self.schedule = self.sim.schedule
        self.policy = DEFAULT_TIER_POLICY if policy is None else policy
        self.batch_hint = batch_hint
        self.tier_features = self.policy.features(
            self.sim, cfg_facts=cfg_mod.summary(self.packed, self.n))
        eligible = self.tier_features["eligible"]
        if mode == "superblock" and not eligible:
            cap = self.policy.table["max_trace_cost"]
            cap = _MAX_TRACE if cap is None else cap
            cost = self.tier_features["trace_cost"]
            raise BlockCompileError(
                "program is not superblock-eligible ("
                + ("the path did not fold to a schedule"
                   if cost is None else
                   f"trace cost {cost} exceeds the {cap}-instruction "
                   f"budget") + ")")
        if mode == "auto":
            self.mode = self.policy.choose(
                self.sim, batch=batch_hint, features=self.tier_features)
        else:
            self.mode = mode
        if self.mode == "superblock":
            self.switch_dispatches = 0
            self._run_jit = self._build_super_runner()
        else:
            self.switch_dispatches = self.sim.dispatches
            self._run_jit = self._build_runner()
        self._light_jit = None           # built lazily on first use
        #: AOT-compiled light executables keyed by input shapes — split
        #: so the fleet can attribute XLA compile time separately from
        #: dispatch time (``FleetStats.compile_s`` vs ``wall_s``)
        self._light_execs: dict = {}
        self._counters = None            # EventCounters, built lazily

    # ---------------------------------------------------- event counters
    def event_counters(self):
        """This program's per-core :class:`~repro.obs.EventCounters`,
        baked from the path simulation (exact, free at runtime).  The
        per-class retire/issue counts are bit-identical to the
        interpreter's ``stat_instrs`` / ``stat_cycles``; the plan-shape
        counters (fori vs unrolled repeats) describe the tier this
        compile actually runs."""
        if self._counters is None:
            from ..obs.counters import EventCounters
            sim = self.sim
            f = self.tier_features
            if self.mode == "superblock" and self.schedule is not None:
                rep_trips = _sched_rep_trips(self.schedule)
                rep_execd = _sched_rep_execd(self.schedule)
                fori_trips = sum(f["fori_trips"])
                plan = dict(
                    fori_reps=f["fori_reps"],
                    unrolled_reps=f["unrolled_reps"],
                    fori_trips=fori_trips,
                    unrolled_trips=rep_trips - fori_trips,
                    fori_instrs=f["fori_execd"],
                    unrolled_instrs=rep_execd - f["fori_execd"])
            else:
                plan = dict(fori_reps=0, unrolled_reps=0, fori_trips=0,
                            unrolled_trips=0, fori_instrs=0,
                            unrolled_instrs=0)
            nopc = int(isa.OpClass.NOPC)
            self._counters = EventCounters(
                instrs=int(sim.steps), cycles=int(sim.cycles),
                instrs_by_class=tuple(int(v) for v in sim.stat_instrs),
                cycles_by_class=tuple(int(v) for v in sim.stat_cycles),
                loop_backedges=int(sim.backedges),
                block_dispatches=int(self.switch_dispatches),
                hazard_nop_instrs=int(sim.stat_instrs[nopc]),
                hazard_nop_cycles=int(sim.stat_cycles[nopc]),
                hazard_violations=int(sim.violations),
                lane_steps_offered=int(sim.lane_offered),
                lane_steps_active=int(sim.lane_active), **plan)
        return self._counters

    # ----------------------------------------------------- shared data op
    def _apply_row(self, row, regs, shared, pstack, pdepth, pok, tdx_dim):
        """One instruction's *data* semantics — registers, shared memory,
        predicate state — with every decoded field a Python constant.
        Sequencer ops (JMP/JSR/RTS/LOOP/INIT/STOP/NOP) are data no-ops:
        their effects are either handled by the block terminator (basic
        blocks) or baked statically (superblocks).  ``pok`` is the cached
        predicate mask, invalidated by predicate writers; shared between
        both compiled tiers so their semantics cannot drift."""
        cfg = self.cfg
        R, S = cfg.regs_per_thread, cfg.shared_words
        D = max(1, cfg.predicate_levels)
        t = self._tables
        (op, typ, rd, ra, rb, imm, tsc) = row
        o = Op(op)
        if o in (Op.JMP, Op.JSR, Op.RTS, Op.LOOP, Op.INIT, Op.STOP,
                 Op.NOP):
            return regs, shared, pstack, pdepth, pok

        _, tsc_mask = _tsc_static(cfg, tsc, self.threads)
        if self.has_preds:
            if pok is None:
                pok = semantics.pred_ok(pstack, pdepth, D)
            mask = tsc_mask & pok
        else:
            mask = tsc_mask
        ra_r, rb_r, rd_r = _gidx(ra, R), _gidx(rb, R), _gidx(rd, R)
        env = semantics.OpEnv(
            cfg=cfg, rav=regs[..., ra_r], rbv=regs[..., rb_r],
            rdv=regs[..., rd_r], signed=typ == Typ.I32, imm=imm,
            mask=mask, tid=self._tid, shared=shared, tdx_dim=tdx_dim)
        spec = semantics.build_spec(env)

        if o in isa.IF_OPS:
            cond = spec[op][1]()
            pstack, pdepth = semantics.pred_push(
                pstack, pdepth, cond, tsc_mask, D)
            pok = None
        elif o == Op.ELSE:
            pstack = semantics.pred_else(pstack, pdepth, tsc_mask, D)
            pok = None
        elif o == Op.ENDIF:
            pdepth = semantics.pred_pop(pdepth, tsc_mask)
            pok = None
        elif o == Op.STO:
            addr = env.addr
            sto_ok = mask & (addr >= 0) & (addr < S)
            sidx = jnp.where(sto_ok, addr, S)
            shared = semantics.store(shared, sidx, env.rdv)
        elif t[op, _TC_WRITES_RD]:
            value = spec[op][0]().astype(_U32)
            wmask = self._tid0 if o in (Op.DOT, Op.SUM) else mask
            rd_w = min(max(rd, 0), R - 1)
            col = jnp.where(wmask, value, regs[..., rd_w])
            regs = regs.at[..., rd_w].set(col)
        return regs, shared, pstack, pdepth, pok

    # ------------------------------------------------------------- blocks
    def _block_fn(self, start: int, end: int):
        """Trace ``[start, end)`` as one straight-line computation."""
        cfg = self.cfg
        t = self._tables
        rows = [tuple(int(v) for v in self.packed[i])
                for i in range(start, end)]
        term_op = rows[-1][_PF_OP] if rows[-1][_PF_OP] in _SEQ_TERM else None

        # per-block constants: cycles / instruction-mix increments
        block_cycles = 0
        stat_c = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_i = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        for (op, typ, rd, ra, rb, imm, tsc) in rows:
            wfs, _ = _tsc_static(cfg, tsc, self.threads)
            width_code = (tsc >> 2) & 3
            per_wf = int(t[op, _TC_PER_WF0 + width_code])
            issue = 1 if t[op, _TC_SCALAR] else per_wf * wfs
            block_cycles += issue
            stat_c[t[op, _TC_CLS]] += issue
            stat_i[t[op, _TC_CLS]] += 1

        def fn(data: _Data, seq: _Seq):
            regs, shared, pstack = data.regs, data.shared, data.pstack
            pdepth = seq.pdepth
            lctr, lsp = seq.lctr, seq.lsp
            cstack, csp = seq.cstack, seq.csp
            halted = seq.halted
            pc_next = jnp.int32(end)        # fall-through default
            pok = None                      # cached predicate mask

            for row in rows:
                if row[_PF_OP] == Op.INIT:
                    lctr, lsp = semantics.loop_init(lctr, lsp,
                                                    row[_PF_IMM])
                    continue
                regs, shared, pstack, pdepth, pok = self._apply_row(
                    row, regs, shared, pstack, pdepth, pok, data.tdx_dim)

            # --- terminator --------------------------------------------
            imm = rows[-1][_PF_IMM]
            end_pc = end
            if term_op == Op.JMP:
                pc_next = jnp.int32(imm)
            elif term_op == Op.JSR:
                cstack, csp = semantics.call_push(
                    cstack, csp, jnp.int32(end_pc))
                pc_next = jnp.int32(imm)
            elif term_op == Op.RTS:
                pc_next = semantics.call_top(cstack, csp)
                csp = csp - 1
            elif term_op == Op.LOOP:
                lctr, taken, lsp_pop = semantics.loop_step(lctr, lsp)
                lsp = jnp.where(taken, lsp, lsp_pop)
                pc_next = jnp.where(taken, jnp.int32(imm),
                                    jnp.int32(end_pc))
            elif term_op == Op.STOP:
                halted = jnp.bool_(True)
                pc_next = jnp.int32(end_pc)

            seq2 = _Seq(
                pc=pc_next,
                cycles=seq.cycles + jnp.int32(_i32wrap(block_cycles)),
                steps=seq.steps + jnp.int32(len(rows)),
                halted=halted, pdepth=pdepth,
                lctr=lctr, lsp=jnp.asarray(lsp, _I32),
                cstack=cstack, csp=jnp.asarray(csp, _I32),
                stat_cycles=seq.stat_cycles + stat_c if self.validate
                else seq.stat_cycles,
                stat_instrs=seq.stat_instrs + stat_i if self.validate
                else seq.stat_instrs)
            return _Data(regs=regs, shared=shared, pstack=pstack,
                         tdx_dim=data.tdx_dim), seq2

        return fn

    def _pad_stop_fn(self):
        """One shared block for the padded STOP tail ``[n, prog_len)`` —
        the only block whose PC is dynamic."""
        stat_c = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_i = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_c[isa.OpClass.BRANCH] = 1
        stat_i[isa.OpClass.BRANCH] = 1

        def fn(data: _Data, seq: _Seq):
            return data, seq._replace(
                pc=seq.pc + 1, cycles=seq.cycles + 1, steps=seq.steps + 1,
                halted=jnp.bool_(True),
                stat_cycles=seq.stat_cycles + stat_c if self.validate
                else seq.stat_cycles,
                stat_instrs=seq.stat_instrs + stat_i if self.validate
                else seq.stat_instrs)

        return fn

    # --------------------------------------------------------- superblock
    def _apply_schedule(self, items, state, tdx_dim):
        """Trace a schedule over the dynamic state — the superblock
        runner's core, shared by the full and light runners.

        Straight-line schedule items trace inline; a repeat node either
        unrolls fully (small executed size — maximal fusion across the
        back-edge) or becomes a ``lax.fori_loop`` whose body is the loop
        trace fused once (the unroll policy ``_plan_stats`` mirrors).
        """
        regs, shared, pstack, pdepth = state
        pok = None
        for it in items:
            if isinstance(it, (int, np.integer)):
                row = tuple(int(v) for v in self.packed[it])
                regs, shared, pstack, pdepth, pok = self._apply_row(
                    row, regs, shared, pstack, pdepth, pok, tdx_dim)
                continue
            _, body, count = it
            st = (regs, shared, pstack, pdepth)
            if count * _sched_execd(body) <= _UNROLL_FULL:
                for _ in range(count):
                    st = self._apply_schedule(body, st, tdx_dim)
            else:
                st = lax.fori_loop(
                    0, count,
                    lambda _, s, _b=body: self._apply_schedule(
                        _b, s, tdx_dim), st)
            regs, shared, pstack, pdepth = st
            pok = None                 # pstack/pdepth may have moved
        return regs, shared, pstack, pdepth

    def _super_final(self, shared, tdx_dim):
        """Traced: fresh state -> final dynamic leaves, per the folded
        static path."""
        cfg = self.cfg
        T, R = cfg.max_threads, cfg.regs_per_thread
        D = max(1, cfg.predicate_levels)
        batch = shared.shape[:-1]              # () or (B,)
        return self._apply_schedule(self.schedule, (
            jnp.zeros(batch + (T, R), jnp.uint32), shared,
            jnp.zeros(batch + (T, D), jnp.bool_),
            jnp.zeros((T,), _I32)), tdx_dim)

    def _build_super_runner(self):
        """The superblock driver: the folded static path, traced as one
        computation with no ``while_loop`` and no ``switch``.

        Every data-independent leaf of the final :class:`MachineState`
        (PC, cycles, steps, loop/call stacks, stats, hazards) is baked
        from the host-side simulation; only registers, shared memory and
        the predicate state flow through the trace.  ``pdepth`` is
        data-independent too but rides along dynamically so unbalanced
        IF/ENDIF inside a folded loop body stays exact across
        iterations.
        """
        sim = self.sim
        threads = self.threads
        zeros = np.zeros((isa.NUM_OP_CLASSES,), np.int32)
        stat_c = sim.stat_cycles if self.validate else zeros
        stat_i = sim.stat_instrs if self.validate else zeros

        @functools.partial(jax.jit, donate_argnums=(0,))
        def run(shared, tdx_dim):
            batch = shared.shape[:-1]          # () or (B,)
            regs, shared_f, pstack, pdepth = self._super_final(
                shared, tdx_dim)

            def b(x):   # broadcast a baked leaf over the batch axis
                x = jnp.asarray(x)
                return jnp.broadcast_to(x, batch + x.shape)

            return MachineState(
                regs=regs, shared=shared_f, pstack=pstack,
                pdepth=b(pdepth), lctr=b(jnp.asarray(sim.lctr)),
                lsp=b(jnp.int32(sim.lsp)),
                cstack=b(jnp.asarray(sim.cstack)),
                csp=b(jnp.int32(sim.csp)), pc=b(jnp.int32(sim.pc)),
                cycles=b(jnp.int32(sim.cycles)),
                steps=b(jnp.int32(sim.steps)),
                halted=b(jnp.bool_(sim.halted)),
                threads_active=b(jnp.int32(threads)), tdx_dim=tdx_dim,
                stat_cycles=b(jnp.asarray(stat_c)),
                stat_instrs=b(jnp.asarray(stat_i)),
                hazard=b(jnp.asarray(sim.hazard)),
                hazard_violations=b(jnp.int32(sim.violations)))

        return run

    # ------------------------------------------------------------- driver
    def _blocks_final(self, shared, tdx_dim):
        """Traced: fresh state -> final ``(_Data, _Seq)`` through the
        ``while_loop`` + ``switch`` block driver — shared by the full
        and light runners."""
        fns = [self._block_fn(s, e) for s, e in self.blocks]
        fns.append(self._pad_stop_fn())
        pc2block = jnp.asarray(self._pc2block)
        cfg = self.cfg
        T, R = cfg.max_threads, cfg.regs_per_thread
        D = max(1, cfg.predicate_levels)
        max_steps = cfg.max_steps
        prog_len = self.prog_len

        def cond(carry):
            _, seq = carry
            return (~seq.halted) & (seq.steps < max_steps) & \
                (seq.pc >= 0) & (seq.pc < prog_len)

        def body(carry):
            data, seq = carry
            return lax.switch(pc2block[seq.pc], fns, data, seq)

        batch = shared.shape[:-1]              # () or (B,)
        z = jnp.int32(0)
        data = _Data(
            regs=jnp.zeros(batch + (T, R), jnp.uint32), shared=shared,
            pstack=jnp.zeros(batch + (T, D), jnp.bool_),
            tdx_dim=tdx_dim)
        seq = _Seq(
            pc=z, cycles=z, steps=z, halted=jnp.bool_(False),
            pdepth=jnp.zeros((T,), _I32),
            lctr=jnp.zeros((cfg.max_loop_depth,), _I32), lsp=z,
            cstack=jnp.zeros((cfg.max_call_depth,), _I32), csp=z,
            stat_cycles=jnp.zeros((isa.NUM_OP_CLASSES,), _I32),
            stat_instrs=jnp.zeros((isa.NUM_OP_CLASSES,), _I32))
        return lax.while_loop(cond, body, (data, seq))

    def _build_runner(self):
        hazard = self.sim.hazard
        violations = self.sim.violations
        threads = self.threads

        # One dispatch per run: the fresh registers/predicate stacks and
        # the fresh sequencer state are constants inside the jit, and the
        # final MachineState (including the statically baked hazard rows)
        # is assembled inside it too.  The shared-memory image is donated.
        @functools.partial(jax.jit, donate_argnums=(0,))
        def run(shared, tdx_dim):
            batch = shared.shape[:-1]          # () or (B,)
            d, s = self._blocks_final(shared, tdx_dim)

            def b(x):   # broadcast a seq leaf over the batch axis
                x = jnp.asarray(x)
                return jnp.broadcast_to(x, batch + x.shape)

            return MachineState(
                regs=d.regs, shared=d.shared, pstack=d.pstack,
                pdepth=b(s.pdepth), lctr=b(s.lctr), lsp=b(s.lsp),
                cstack=b(s.cstack), csp=b(s.csp), pc=b(s.pc),
                cycles=b(s.cycles), steps=b(s.steps), halted=b(s.halted),
                threads_active=b(jnp.int32(threads)),
                tdx_dim=d.tdx_dim,
                stat_cycles=b(s.stat_cycles), stat_instrs=b(s.stat_instrs),
                hazard=b(jnp.asarray(hazard)),
                hazard_violations=b(jnp.int32(violations)))

        return run

    def light_fn(self):
        """The *unjitted* light-path function ``(shared, tdx_dim) ->
        (shared, cycles, halted)`` — for callers that wrap their own
        transform around it (the sharded fleet ``shard_map``s it over
        the 1-D job mesh; every row is an independent core, so sharding
        the leading batch axis is bit-identical to the single-device
        call)."""
        sim = self.sim

        if self.mode == "superblock":
            def run(shared, tdx_dim):
                batch = shared.shape[:-1]
                _, shared_f, _, _ = self._super_final(shared, tdx_dim)
                return (shared_f,
                        jnp.broadcast_to(jnp.int32(sim.cycles), batch),
                        jnp.broadcast_to(jnp.bool_(sim.halted), batch))
            return run

        def run(shared, tdx_dim):
            batch = shared.shape[:-1]
            d, s = self._blocks_final(shared, tdx_dim)
            return (d.shared,
                    jnp.broadcast_to(s.cycles, batch),
                    jnp.broadcast_to(s.halted, batch))
        return run

    def _build_light_runner(self):
        """The light path: only ``(shared, cycles, halted)`` leave the
        device.  No input donation — the fleet's residency cache replays
        the same device-resident shared image across drains, which a
        donated (consumed) buffer would forbid.  On the superblock tier
        cycles/halted are baked constants; on the blocks tier they fall
        out of the driver loop."""
        return jax.jit(self.light_fn())

    # ------------------------------------------------------------- public
    def run(self, *, shared_init=None, tdx_dim: int = 16) -> MachineState:
        """Execute one core; bit-identical to ``run_program``."""
        S = self.cfg.shared_words
        shared = np.zeros((S,), np.uint32)
        if shared_init is not None:
            buf = machine_mod.pack_shared_init(shared_init, S)
            shared[:buf.size] = buf
        with obs_trace.span("run_compiled", tier=self.mode):
            out = self._run_jit(jnp.asarray(shared), jnp.int32(tdx_dim))
            out.cycles.block_until_ready()
        return out

    def run_batch(self, shared_inits: list, tdx_dims) -> MachineState:
        """Execute N same-program cores in lock-step over batched data;
        returns the batched final state (slice jobs out along axis 0)."""
        S = self.cfg.shared_words
        n = len(shared_inits)
        shared = np.zeros((n, S), np.uint32)
        for i, s0 in enumerate(shared_inits):
            if s0 is None:
                continue
            buf = machine_mod.pack_shared_init(s0, S)
            shared[i, :buf.size] = buf
        with obs_trace.span("run_compiled", tier=self.mode,
                            batch=len(shared_inits)):
            out = self._run_jit(jnp.asarray(shared),
                                jnp.asarray(tdx_dims, _I32))
            out.cycles.block_until_ready()
        return out

    # -------------------------------------------------------- light path
    def light_compile(self, shared, tdx_dim, device=None) -> float:
        """Ensure the light-path executable for these input shapes is
        built and XLA-compiled ahead of time; returns the host seconds
        that took (0.0 when already compiled).  The fleet calls this
        before its timed dispatch so ``FleetStats.compile_s`` carries
        the one-time compile cost instead of ``wall_s``.

        AOT executables are pinned to the devices their inputs were
        lowered on, so ``device`` is part of the cache key: a pinned
        fleet scheduler gets its own entry per device, and ``None``
        (today's unpinned path) keeps the default placement."""
        shared = jnp.asarray(shared, _U32)
        tdx_dim = jnp.asarray(tdx_dim, _I32)
        key = (np.shape(shared), np.shape(tdx_dim), device)
        if key in self._light_execs:
            return 0.0
        if device is not None:
            shared = jax.device_put(shared, device)
            tdx_dim = jax.device_put(tdx_dim, device)
        t0 = time.perf_counter()
        with obs_trace.span("compile", kind="xla_light", tier=self.mode,
                            batch=key[0][:-1]):
            if self._light_jit is None:
                self._light_jit = self._build_light_runner()
            self._light_execs[key] = \
                self._light_jit.lower(shared, tdx_dim).compile()
        return time.perf_counter() - t0

    def run_light_dev(self, shared, tdx_dim, device=None):
        """Raw light entry: device (or host) arrays in — ``(..., S)``
        uint32 shared image, ``(...,)``/scalar int32 TDX — device arrays
        ``(shared, cycles, halted)`` out.  No host sync, no donation:
        the same input buffer can be replayed across calls, which is
        what keeps the fleet's residency cache sound.  Dispatches the
        shape-keyed AOT executable (see :meth:`light_compile`); when
        ``device`` is given inputs are placed there first (a no-op for
        already-resident buffers) and the device-keyed executable runs
        — cross-device replay of a pinned executable is a jax error."""
        shared = jnp.asarray(shared, _U32)
        tdx_dim = jnp.asarray(tdx_dim, _I32)
        if device is not None:
            shared = jax.device_put(shared, device)
            tdx_dim = jax.device_put(tdx_dim, device)
        key = (np.shape(shared), np.shape(tdx_dim), device)
        exe = self._light_execs.get(key)
        if exe is None:
            self.light_compile(shared, tdx_dim, device)
            exe = self._light_execs[key]
        return exe(shared, tdx_dim)

    def run_light(self, *, shared_init=None, tdx_dim: int = 16):
        """Execute one core, returning only ``(shared, cycles, halted)``
        — for callers that never read registers, stacks or stats.  The
        leaves are bit-identical to the same-named :meth:`run` leaves;
        the other 15 ``MachineState`` leaves are never assembled or
        transferred."""
        S = self.cfg.shared_words
        shared = np.zeros((S,), np.uint32)
        if shared_init is not None:
            buf = machine_mod.pack_shared_init(shared_init, S)
            shared[:buf.size] = buf
        sh, cyc, halted = self.run_light_dev(jnp.asarray(shared),
                                             jnp.int32(tdx_dim))
        sh.block_until_ready()
        return sh, int(cyc), bool(halted)

    def run_batch_light(self, shared_inits: list, tdx_dims):
        """Batched light path: N same-program cores in lock-step,
        returning ``(shared (N, S), cycles (N,), halted (N,))`` only."""
        S = self.cfg.shared_words
        n = len(shared_inits)
        shared = np.zeros((n, S), np.uint32)
        for i, s0 in enumerate(shared_inits):
            if s0 is None:
                continue
            buf = machine_mod.pack_shared_init(s0, S)
            shared[i, :buf.size] = buf
        out = self.run_light_dev(jnp.asarray(shared),
                                 jnp.asarray(tdx_dims, _I32))
        out[0].block_until_ready()
        return out


# ---------------------------------------------------------------------------
# Compile cache + convenience drivers
# ---------------------------------------------------------------------------

_CACHE: dict = {}
_CACHE_MAX = 128


def program_key(image: ProgramImage) -> bytes:
    """Content identity of a program (the bit-packed instruction words
    encode every field) — used by the compile cache and the fleet's
    same-program batch grouping."""
    return image.words.tobytes()


def normalize_threads(image: ProgramImage, threads: int | None) -> int:
    """``None`` means "the count the image was assembled for"; anything
    else must be an explicit valid count.  In particular ``threads=0``
    is rejected rather than silently mapped to the image default (the
    old ``threads or image.threads_active`` idiom did exactly that)."""
    if threads is None:
        return image.threads_active
    threads = int(threads)
    if threads < 1:
        raise ValueError(
            f"invalid runtime thread count {threads}; pass threads=None "
            f"for the image default ({image.threads_active})")
    return threads


def compile_program(image: ProgramImage, threads: int | None = None, *,
                    validate: bool = True, mode: str = "auto",
                    policy: TierPolicy | None = None,
                    batch_hint: int = 1,
                    optimize: bool = False) -> CompiledProgram:
    """Compile ``image`` for a static runtime thread count (default: the
    count it was assembled for).  Compiles are cached on (config,
    program bytes, threads, validate, mode, policy, batch class) with
    LRU eviction — hits move to the back of the queue, so a hot program
    is never evicted to keep a cold (or negative-cached) one.
    Rejections are cached too, so a non-halting program pays its (up to
    ``max_steps``-long) host-side path walk once, not on every fleet
    drain.

    ``mode``: ``"auto"`` asks the :class:`TierPolicy` cost model
    (``policy``, default :data:`DEFAULT_TIER_POLICY`) to pick the
    cheaper tier for this path at ``batch_hint`` lock-step cores;
    ``"superblock"`` and ``"blocks"`` force a tier (the former raising
    :class:`BlockCompileError` when ineligible).  ``batch_hint`` is
    collapsed to the policy's batch classes before keying the cache, so
    fleet drains at different batch sizes share compiles.

    Raises :class:`BlockCompileError` for programs whose static path does
    not halt within ``cfg.max_steps``.

    ``optimize=True`` first runs the verified pre-compile optimizer
    (:func:`repro.analysis.optimizer.optimize_image`, itself cached):
    constant folding + dead-code elimination with hazard NOPs
    re-derived by the scheduler, bit-identical architectural end state
    guaranteed.  The optimized image then keys the compile cache as
    usual (distinct program bytes, distinct entry).
    """
    threads = normalize_threads(image, threads)
    if optimize:
        from ..analysis.optimizer import optimize_image_cached
        image = optimize_image_cached(image, threads).image
    pol = DEFAULT_TIER_POLICY if policy is None else policy
    hint = pol.batch_class(batch_hint) if mode == "auto" else 1
    key = (image.cfg, program_key(image), threads, validate, mode, pol,
           hint)
    hit = _CACHE.pop(key, None)          # pop + reinsert = move-to-end
    with obs_trace.span("compile", cache_hit=hit is not None,
                        mode=mode, threads=threads) as sp:
        if hit is None:
            while len(_CACHE) >= _CACHE_MAX:
                _CACHE.pop(next(iter(_CACHE)))   # oldest entry first (LRU)
            try:
                hit = CompiledProgram(image, threads, validate=validate,
                                      mode=mode, policy=pol,
                                      batch_hint=hint)
            except BlockCompileError as e:
                hit = e                  # negative-cache the rejection
        if sp.active:
            sp.set(program=hashlib.blake2b(
                       key[1], digest_size=4).hexdigest(),
                   tier=getattr(hit, "mode", "rejected"))
    _CACHE[key] = hit
    if isinstance(hit, BlockCompileError):
        raise hit
    return hit


def run_compiled(image: ProgramImage, *, threads: int | None = None,
                 tdx_dim: int = 16, shared_init=None, validate: bool = True,
                 fallback: bool = True, mode: str = "auto",
                 policy: TierPolicy | None = None) -> MachineState:
    """Execute an assembled program through the block compiler.

    Drop-in for ``run_program(image, threads=..., tdx_dim=...,
    shared_init=...)`` — results are bit-identical.  ``fallback=True``
    silently routes programs the compiler rejects (non-halting static
    path, or over-budget traces under ``mode="superblock"``) to the
    interpreter, completing the superblock → basic-block → interpreter
    chain.
    """
    threads = normalize_threads(image, threads)
    try:
        cp = compile_program(image, threads, validate=validate, mode=mode,
                             policy=policy)
    except BlockCompileError:
        if not fallback:
            raise
        from .executor import run_program
        return run_program(image, validate=validate, threads=threads,
                           tdx_dim=tdx_dim, shared_init=shared_init)
    return cp.run(shared_init=shared_init, tdx_dim=tdx_dim)
