"""Cycle-accurate cost model for the eGPU.

The model follows the paper's microarchitecture:

* the sequencer issues one *wavefront* (16 lanes) per cycle for vector
  (operation) instructions, so an op costs ``active_wavefronts`` cycles;
* shared-memory instructions are port-limited (paper §3.1 / §5.1):
  the DP shared memory has 4 read ports and 1 write port per cycle, the
  QP memory doubles the write ports.  A full-width (16-lane) store
  therefore takes 16 cycles per wavefront in DP mode — which is exactly
  why the paper's dynamic thread-space subsetting ("subset write can be
  16x faster than using the generic write") pays off;
* sequencer-only instructions (branches, loop control, NOP) cost 1 cycle;
* there is no hazard hardware: results have a pipeline latency and the
  assembler inserts NOPs to cover read-after-write hazards
  (:func:`repro.core.assembler.schedule`).

The same integer math is used by the Python-side scheduler and the JAX
executor (the executor re-implements it with jnp scalars — see
``executor._issue_cycles``); ``tests/test_cost.py`` asserts they agree.
"""
from __future__ import annotations

from .config import EGPUConfig
from . import isa
from .isa import Op


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def depth_wavefronts(depth_code: int, runtime_wavefronts: int) -> int:
    """Number of wavefronts issued for a TSC depth code (Table 3)."""
    if depth_code == isa.DEPTH_WF0:
        return 1
    if depth_code == isa.DEPTH_ALL:
        return runtime_wavefronts
    if depth_code == isa.DEPTH_HALF:
        return max(1, _cdiv(runtime_wavefronts, 2))
    return max(1, _cdiv(runtime_wavefronts, 4))


def issue_cycles(op: int, tsc: int, runtime_wavefronts: int,
                 cfg: EGPUConfig) -> int:
    """Cycles the instruction occupies the issue stage."""
    op = Op(op)
    if op in isa.SCALAR_OPS:
        return 1
    width_lanes = isa.WIDTH_LANES[isa.tsc_width(tsc)]
    wfs = depth_wavefronts(isa.tsc_depth(tsc), runtime_wavefronts)
    if op == Op.LOD:
        return wfs * _cdiv(width_lanes, cfg.cost.sp_read_ports)
    if op == Op.STO:
        return wfs * _cdiv(width_lanes, cfg.write_ports)
    # All other vector ops (ALU/FP/predicate/thread/extension reads):
    # one cycle per active wavefront, independent of width.
    return wfs


def result_latency(op: int, cfg: EGPUConfig) -> int:
    """Cycles after the *first* issue cycle until the result is readable.

    Used by the NOP scheduler: a consumer must not start issuing before
    ``producer_start + result_latency``.
    """
    op = Op(op)
    c = cfg.cost
    if op in (Op.DOT, Op.SUM):
        return c.dot_latency
    if op == Op.INVSQR:
        return c.invsqr_latency
    if op == Op.LOD:
        return c.mem_latency
    if op in isa.SCALAR_OPS or op in (Op.STO, Op.ELSE, Op.ENDIF):
        return 0
    return c.pipe_latency


def bus_transfer_cycles(n_words: int) -> int:
    """Loading/unloading over the 32-bit data bus (paper §7: +4.7% avg)."""
    return n_words
