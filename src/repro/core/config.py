"""Static scalability: the eGPU configuration space (paper §3, §5).

Every knob here is a configuration-time parameter of the soft processor;
the area/Fmax consequences are modelled in :mod:`repro.core.area_model`
and validated against Tables 4-6 of the paper.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostParams:
    """Pipeline/latency parameters for the cycle cost model.

    The eGPU has an 8-stage pipeline and *no* hazard-tracking hardware
    (paper §3): dependent instructions closer than the producer's latency
    must be separated by NOPs, which the assembler inserts.
    """

    pipe_latency: int = 8        # ALU/FP result latency (8-stage pipe)
    mem_latency: int = 8         # shared-memory load-to-use latency
    dot_latency: int = 24        # DOT/SUM writeback latency ("waiting for
                                 # the dot product to write back", §7)
    invsqr_latency: int = 16     # SFU latency
    sp_read_ports: int = 4       # shared memory read ports (DP and QP)
    # write ports depend on memory_mode: 1 (DP) or 2 (QP)


@dataclasses.dataclass(frozen=True)
class EGPUConfig:
    """One statically-configured eGPU instance."""

    # --- thread space -----------------------------------------------------
    num_sps: int = 16            # wavefront width (fixed at 16 in the paper)
    max_threads: int = 512       # configured thread space
    regs_per_thread: int = 16    # 16 / 32 / 64 in the paper's tables

    # --- memories -----------------------------------------------------------
    shared_kb: int = 8           # shared memory size in KB (32-bit words)
    memory_mode: str = "dp"      # "dp" (1GHz M20K) or "qp" (600MHz, 2 wr ports)

    # --- integer ALU ----------------------------------------------------------
    alu_bits: int = 32           # 16 or 32
    alu_features: str = "full"   # "min" | "small" | "full"  (Table 6)
    shift_bits: int = 32         # 1, 16, or 32 (shift precision)

    # --- predicates -------------------------------------------------------
    predicate_levels: int = 0    # 0 disables predicates entirely

    # --- extension units ------------------------------------------------------
    has_dot: bool = False        # dot-product core
    has_invsqr: bool = False     # reciprocal-sqrt SFU

    # --- sequencer limits ---------------------------------------------------
    max_loop_depth: int = 8
    max_call_depth: int = 8
    max_steps: int = 2_000_000   # executor safety bound (instructions)

    cost: CostParams = dataclasses.field(default_factory=CostParams)

    # -----------------------------------------------------------------------
    def __post_init__(self):
        if self.num_sps != 16:
            raise ValueError("the eGPU wavefront width is 16 SPs")
        if self.max_threads % self.num_sps:
            raise ValueError("max_threads must be a multiple of num_sps")
        if self.memory_mode not in ("dp", "qp"):
            raise ValueError(f"bad memory_mode {self.memory_mode!r}")
        if self.alu_bits not in (16, 32):
            raise ValueError("alu_bits must be 16 or 32")
        if self.shift_bits not in (1, 16, 32):
            raise ValueError("shift_bits must be 1, 16 or 32")
        if self.regs_per_thread not in (8, 16, 32, 64, 128):
            raise ValueError("unsupported regs_per_thread")

    # --- derived quantities -------------------------------------------------
    @property
    def max_wavefronts(self) -> int:
        return self.max_threads // self.num_sps

    @property
    def shared_words(self) -> int:
        return self.shared_kb * 1024 // 4

    @property
    def write_ports(self) -> int:
        return 2 if self.memory_mode == "qp" else 1

    @property
    def fmax_mhz(self) -> float:
        """Paper §6: DP instances close at 771 MHz (DSP-limited); QP at
        600 MHz (QP M20K-limited)."""
        return 600.0 if self.memory_mode == "qp" else 771.0

    @property
    def has_predicates(self) -> bool:
        return self.predicate_levels > 0

    def cycles_to_us(self, cycles) -> float:
        return float(cycles) / self.fmax_mhz

    def replace(self, **kw) -> "EGPUConfig":
        return dataclasses.replace(self, **kw)


# --- The paper's published configurations (Tables 4 and 5) -----------------

def table4_configs() -> dict[str, EGPUConfig]:
    """DP-memory instances of Table 4 (in row order)."""
    return {
        "small_dp_a": EGPUConfig(alu_bits=16, shift_bits=1, max_threads=512,
                                 regs_per_thread=16, shared_kb=8,
                                 predicate_levels=0, alu_features="min"),
        "small_dp_b": EGPUConfig(alu_bits=16, shift_bits=16, max_threads=512,
                                 regs_per_thread=16, shared_kb=32,
                                 predicate_levels=5, alu_features="full"),
        "medium_dp_a": EGPUConfig(alu_bits=16, shift_bits=16, max_threads=512,
                                  regs_per_thread=32, shared_kb=32,
                                  predicate_levels=5, alu_features="full"),
        "medium_dp_b": EGPUConfig(alu_bits=32, shift_bits=16, max_threads=512,
                                  regs_per_thread=32, shared_kb=32,
                                  predicate_levels=5, alu_features="full"),
        "large_dp_a": EGPUConfig(alu_bits=32, shift_bits=16, max_threads=512,
                                 regs_per_thread=64, shared_kb=32,
                                 predicate_levels=8, alu_features="full",
                                 has_dot=True),
        "large_dp_b": EGPUConfig(alu_bits=32, shift_bits=32, max_threads=512,
                                 regs_per_thread=64, shared_kb=64,
                                 predicate_levels=16, alu_features="full",
                                 has_dot=True),
    }


def table5_configs() -> dict[str, EGPUConfig]:
    """QP-memory instances of Table 5 (in row order)."""
    return {
        "small_qp": EGPUConfig(memory_mode="qp", alu_bits=32, shift_bits=1,
                               max_threads=512, regs_per_thread=64,
                               shared_kb=32, predicate_levels=0,
                               alu_features="min"),
        "medium_qp": EGPUConfig(memory_mode="qp", alu_bits=32, shift_bits=32,
                                max_threads=1024, regs_per_thread=32,
                                shared_kb=64, predicate_levels=0,
                                alu_features="full", has_dot=True),
        "large_qp_a": EGPUConfig(memory_mode="qp", alu_bits=32, shift_bits=32,
                                 max_threads=1024, regs_per_thread=32,
                                 shared_kb=64, predicate_levels=16,
                                 alu_features="full", has_dot=True),
        "large_qp_b": EGPUConfig(memory_mode="qp", alu_bits=32, shift_bits=32,
                                 max_threads=1024, regs_per_thread=32,
                                 shared_kb=128, predicate_levels=10,
                                 alu_features="full", has_dot=True),
    }


#: The configuration used for the paper's vector/matrix benchmarks (§7):
#: "32 registers per thread, with a 32 bit ALU, and a 128KB shared memory".
def benchmark_config(memory_mode: str = "dp", *, has_dot: bool = False,
                     predicate_levels: int = 0,
                     max_threads: int = 512) -> EGPUConfig:
    return EGPUConfig(
        max_threads=max_threads,
        regs_per_thread=32,
        shared_kb=128,
        memory_mode=memory_mode,
        alu_bits=32,
        shift_bits=32,
        predicate_levels=predicate_levels,
        has_dot=has_dot,
        has_invsqr=True,
        alu_features="full",
    )
