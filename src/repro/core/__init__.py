"""eGPU core: the paper's contribution as a composable JAX module."""
from .config import (EGPUConfig, CostParams, table4_configs, table5_configs,
                     benchmark_config)
from .isa import (Op, Typ, Instr, OpClass, encode_word, decode_word, iw_bits,
                  TSC_FULL, TSC_WF0, TSC_CPU, TSC_MCU, PERSONALITIES)
from .assembler import Asm, ProgramImage, schedule
from .machine import (MachineState, init_state, shared_as_f32, shared_as_u32,
                      shared_as_i32, profile)
from .executor import make_step, pad_image, run_program
from .blockc import (DEFAULT_TIER_POLICY, BlockCompileError, CompiledProgram,
                     TierPolicy, compile_program, run_compiled)
from .area_model import resources, Resources
from . import cost, area_model, semantics

__all__ = [
    "EGPUConfig", "CostParams", "table4_configs", "table5_configs",
    "benchmark_config", "Op", "Typ", "Instr", "OpClass", "encode_word",
    "decode_word", "iw_bits", "TSC_FULL", "TSC_WF0", "TSC_CPU", "TSC_MCU",
    "PERSONALITIES", "Asm", "ProgramImage", "schedule", "MachineState",
    "init_state", "shared_as_f32", "shared_as_u32", "shared_as_i32",
    "profile", "run_program", "make_step", "pad_image", "resources",
    "Resources", "cost", "area_model", "semantics", "BlockCompileError",
    "CompiledProgram", "compile_program", "run_compiled", "TierPolicy",
    "DEFAULT_TIER_POLICY",
]
