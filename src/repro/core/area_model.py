"""Resource (ALM / FF / DSP / M20K) and Fmax model — paper §5 & §6.

The M20K and DSP counts follow the paper's exact formulas (§5.5) and
reproduce Tables 4/5 to the block.  The ALM/FF counts use the paper's
per-component figures (Table 6 ALUs, ~150 ALM SP mux/control, ~250 ALM
sequencer, ~5 ALM/thread predicates) with coefficients fitted to Tables
4/5; `benchmarks/table_area.py` prints the model-vs-paper error per row
(within ~±12% ALMs, ±5% FFs, exact DSP/M20K).
"""
from __future__ import annotations

import dataclasses
import math

from .config import EGPUConfig
from .isa import iw_bits


#: Table 6 — integer ALU (ALMs, FFs) by (precision, feature set).
#: "small" = arith + logic + shifts; "full" adds popcount/max/min etc.
ALU_TABLE = {
    (16, "min"): (90, 136),
    (16, "small"): (134, 207),
    (16, "full"): (199, 269),
    (32, "min"): (208, 406),
    (32, "small"): (300, 550),   # interpolated; paper lists min/full for 32
    (32, "full"): (394, 704),
}

SP_MUX_ALM = 150          # §5.5 "SP overhead (mux and control) ~150 ALMs"
CONTROL_ALM = 250         # §5.4 fetch/decode/control 200-250 ALMs
DOT_CORE_ALM = 200        # dot-product core soft logic (adder tree control)
M20K_GLUE_ALM = 1.5       # column-interface/addressing glue per M20K
PRED_ALM_PER_THREAD = 2.2   # base stack+control, amortised (fit to Tab. 4)
PRED_ALM_PER_LEVEL = 0.15   # "incremental cost of one level ... trivial"
SP_PIPE_FF = 550          # SP pipeline wrapper FFs (fit)
SP_LANE_FF = 6            # per-resident-thread FFs (fit)

DSP_FP_PER_SP = 1         # FP32 mult-add datapath (§5.2)
DSP_INTMUL_PER_2SP = 1    # integer multiplier shared per SP pair (Fig. 5)
DSP_DOT_CORE = 8          # dot-product tree

DEFAULT_PROGRAM_WORDS = 1024   # §5.4 example program space


@dataclasses.dataclass(frozen=True)
class Resources:
    alms: int
    ffs: int
    dsps: int
    m20ks: int
    fmax_mhz: float        # design Fmax (embedded-feature limited)
    soft_fmax_mhz: float   # slowest path outside DSP/M20K (reported in Tab.4)

    @property
    def normalized_cost(self) -> int:
        """Paper §7: cost = ALMs + 100 x DSPs."""
        return self.alms + 100 * self.dsps


def m20k_registers(cfg: EGPUConfig) -> int:
    """§5.5: DP reg M20Ks = threads x regs / 256; QP halves this unless the
    register space is below the QP minimum (threads x regs / 16 <= 2047)."""
    dp = math.ceil(cfg.max_threads * cfg.regs_per_thread / 256)
    if cfg.memory_mode == "qp":
        if cfg.max_threads * cfg.regs_per_thread / 16 > 2047:
            return dp // 2
        return dp
    return dp


def m20k_shared(cfg: EGPUConfig) -> int:
    """§5.5: DP shared-memory M20Ks = 2 x size(KB); QP halves this."""
    dp = 2 * cfg.shared_kb
    return dp // 2 if cfg.memory_mode == "qp" else dp


def m20k_instructions(cfg: EGPUConfig,
                      program_words: int = DEFAULT_PROGRAM_WORDS) -> int:
    """§5.4: one M20K per 512 (<=40-bit) IWs; wider IWs add one x8-format
    M20K per 2k instructions."""
    base = math.ceil(program_words / 512)
    extra = math.ceil(program_words / 2048) if iw_bits(cfg.regs_per_thread) > 40 else 0
    return base + extra


def resources(cfg: EGPUConfig,
              program_words: int = DEFAULT_PROGRAM_WORDS) -> Resources:
    n_sp = cfg.num_sps
    threads_per_sp = cfg.max_threads // n_sp

    alu_alm, alu_ff = ALU_TABLE[(cfg.alu_bits, cfg.alu_features)]
    if cfg.memory_mode == "qp" and cfg.alu_bits == 32 \
            and cfg.alu_features == "full":
        # §5.2: the QP eGPU (600 MHz target) uses the 4-stage 32-bit ALU,
        # "about the size of the 16-bit full function ALU"
        alu_alm, alu_ff = ALU_TABLE[(16, "full")]
        alu_ff = int(alu_ff * 1.6)   # wider datapath keeps more pipe FFs
    pred_alm = 0.0
    pred_ff = 0
    if cfg.has_predicates:
        per_thread = PRED_ALM_PER_THREAD + PRED_ALM_PER_LEVEL * cfg.predicate_levels
        pred_alm = per_thread * threads_per_sp
        pred_ff = cfg.max_threads * cfg.predicate_levels

    m20ks = (m20k_registers(cfg) + m20k_shared(cfg)
             + m20k_instructions(cfg, program_words))

    alms = (CONTROL_ALM
            + n_sp * (SP_MUX_ALM + alu_alm + pred_alm)
            + (DOT_CORE_ALM if cfg.has_dot else 0)
            + M20K_GLUE_ALM * m20ks)

    ffs = n_sp * (alu_ff + SP_PIPE_FF + SP_LANE_FF * threads_per_sp) + pred_ff

    dsps = n_sp * DSP_FP_PER_SP + (n_sp // 2) * DSP_INTMUL_PER_2SP
    if cfg.has_dot:
        dsps += DSP_DOT_CORE

    # Fmax: always embedded-feature limited (§6); the soft-logic path is an
    # empirical fit to the "Freq" column of Tables 4/5.
    soft = 1050.0 - 0.015 * alms
    if cfg.memory_mode == "qp":
        soft -= 60.0   # 4-stage (not 5) integer ALU pipeline (§5.2)
    return Resources(alms=round(alms), ffs=round(ffs), dsps=dsps,
                     m20ks=m20ks, fmax_mhz=cfg.fmax_mhz,
                     soft_fmax_mhz=round(soft))


#: Paper-reported rows for validation: (config-name -> (ALM, FF, DSP, M20K,
#: soft-Fmax, design-Fmax)).  Tables 4 and 5.
PAPER_TABLE4 = {
    "small_dp_a": (4243, 13635, 24, 50, 1018, 771),
    "small_dp_b": (7518, 18992, 24, 98, 898, 771),
    "medium_dp_a": (7579, 19155, 24, 131, 883, 771),
    "medium_dp_b": (9754, 25425, 24, 131, 902, 771),
    "large_dp_a": (10127, 26040, 32, 195, 860, 771),
    "large_dp_b": (10697, 26618, 32, 259, 841, 771),
}
PAPER_TABLE5 = {
    "small_qp": (5468, 14487, 24, 98, 840, 600),
    "medium_qp": (7057, 16722, 32, 131, 763, 600),
    "large_qp_a": (11314, 25050, 32, 131, 763, 600),
    "large_qp_b": (10174, 23094, 32, 195, 714, 600),
}

#: §7: Nios II/e comparison core and the DSP-cost normalisation.
NIOS_ALMS = 1100
NIOS_DSPS = 3
NIOS_FMAX_MHZ = 347.0
DSP_ALM_EQUIV = 100
