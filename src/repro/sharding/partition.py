"""Logical-axis -> mesh PartitionSpec resolution (GSPMD rules).

Models annotate every parameter/cache leaf with *logical* axis names
(see models/common.py).  This module resolves them against a concrete
mesh with per-architecture divisibility fallbacks:

* attention shards **heads** when both H and KV divide the model axis,
  otherwise **head_dim** (phi3's 40H / minitron's 24H / small-KV GQA all
  hit this; head_dim is 64/128 and always divides);
* MoE shards **experts** when E divides (qwen3: 128/16), otherwise the
  per-expert ffn dim (granite: 40 experts -> shard expert_d_ff=512);
* **vocab** falls back to replicated when it does not divide (granite
  49155, seamless 256206, internvl2 92553 are not multiples of 16);
* **fsdp** (ZeRO) shards the d_model dim of weights over the data axis
  when enabled — required for llama3-405b optimizer state;
* **batch** spans ("pod", "data") on the multi-pod mesh;
* KV caches shard **sequence** (SP), which divides for every shape.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Resolved logical->physical map for one (cfg, mesh) pair."""
    mapping: tuple           # tuple of (logical, physical) pairs

    def physical(self, logical):
        return dict(self.mapping).get(logical)


def _axis(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def make_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
               seq_shard: bool = True, cache_axis: str = "seq") -> ShardingRules:
    m = _axis(mesh, "model")
    d = _axis(mesh, "data")
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)

    # Attention sharding ladder (see EXPERIMENTS.md #Perf iteration 2):
    #  1. q+kv heads shard when both divide the model axis;
    #  2. q heads only when kv does not divide (kv params replicated) —
    #     sharding head_dim instead all-reduces the full (B,H,S,T) logits
    #     tensor (~2 TB/step for llama3 train_4k: measured, rejected);
    #  3. attention replicated when q heads do not divide either
    #     (phi3 40H, minitron/granite 24H) — the FFN carries the TP axis.
    q_ok = cfg.n_heads % m == 0
    kv_ok = cfg.kv_heads % m == 0
    attn_q = "model" if q_ok else None
    attn_kv = "model" if (q_ok and kv_ok) else None
    attn_hd = None

    experts_ok = cfg.num_experts and cfg.num_experts % m == 0
    expert_ff_ok = cfg.expert_d_ff and cfg.expert_d_ff % m == 0

    mapping = {
        "batch": batch_axes,
        "fsdp": "data" if (fsdp and cfg.d_model % d == 0) else None,
        "heads": attn_q,
        "kv_heads": attn_kv,
        "hd": attn_hd,
        "ff": "model",   # every assigned arch's ffn/inner dims divide by 16
        "heads2": None,  # xlstm inner->inner projections: input dim already
                         # carries the "ff" model sharding

        "vocab": "model" if cfg.vocab % m == 0 else None,
        "experts": "model" if experts_ok else None,
        "expert_ff": None if experts_ok else ("model" if expert_ff_ok else None),
        "seq": "model" if (seq_shard and cache_axis == "seq") else None,
        "cache_heads": "model" if (cache_axis == "heads"
                                   and cfg.kv_heads % m == 0) else None,
        "layers": None,
        None: None,
    }
    return ShardingRules(mapping=tuple(mapping.items()))


def to_pspec(spec_tuple, rules: ShardingRules) -> P:
    """One logical tuple -> PartitionSpec."""
    phys = []
    for logical in spec_tuple:
        p = rules.physical(logical)
        phys.append(p)
    return P(*phys)


def tree_pspecs(spec_tree, rules: ShardingRules):
    return jax.tree.map(lambda s: to_pspec(s, rules), spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def tree_shardings(spec_tree, rules: ShardingRules, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_pspecs(spec_tree, rules),
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(rules: ShardingRules, ndim: int) -> P:
    """Data batches: leading dim over the batch axes, rest replicated."""
    return P(rules.physical("batch"), *([None] * (ndim - 1)))


def check_divisibility(shape, pspec: P, mesh: Mesh) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, ax in zip(shape, tuple(pspec) + (None,) * (len(shape) - len(pspec))):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= sizes[a]
        if dim % total:
            return False
    return True
