"""The paper's benchmark programs, hand-written in eGPU assembly (§7).

Each builder returns a :class:`Bench` with the assembled image, the
shared-memory initial contents, and a NumPy oracle.  The five benchmarks
match the paper's: vector reduction, matrix transpose, matrix-matrix
multiply, bitonic sort, FFT — plus dot-product and dynamic-scaling
variants.
"""
from .common import Bench, run_bench
from .reduction import build_reduction
from .transpose import build_transpose
from .matmul import build_matmul
from .bitonic import build_bitonic
from .fft import build_fft

__all__ = ["Bench", "run_bench", "build_reduction", "build_transpose",
           "build_matmul", "build_bitonic", "build_fft"]
