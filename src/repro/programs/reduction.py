"""Vector reduction (paper §7, Table 7).

The showcase for *dynamic scalability*: the tree reduction narrows every
step, and the TSC field narrows the issued thread space with it — the
final steps run as "multithreaded CPU" / "MCU" personalities, exactly as
described in the paper ("All final vector reductions end up in the first
SP, and we can use the multi-threaded CPU or MCU eGPU dynamic scaling
personalities to write these values to the shared memory").

Variants:
  * plain        — TSC-subset tree (the paper's eGPU-DP/QP columns)
  * use_dot      — the SUM extension unit (the paper's eGPU-Dot column)
  * no_dynamic   — ablation: full-width issue with predicate masking
                   (what a conventional SIMT core without the paper's
                   dynamic thread-space control would do)
"""
from __future__ import annotations

import numpy as np

from ..core import isa
from ..core.assembler import Asm
from ..core.config import EGPUConfig
from ..core import machine as machine_mod
from .common import Bench


def _strides(n: int):
    s = n // 2
    while s >= 1:
        yield s
        s //= 2


def _tsc_for_stride(s: int, n: int):
    """Pick the cheapest TSC coding whose active set covers threads < s.

    Wavefront-level strides use depth codes; sub-wavefront strides use
    width codes (over-wide writes only touch lanes >= s, which later steps
    never read — see the 'garbage tail' argument in tests).
    """
    wfs = n // 16
    if s >= 16:
        need = s // 16
        if need == wfs:
            return isa.TSC_FULL
        if 2 * need == wfs:
            return (isa.WIDTH_ALL, isa.DEPTH_HALF)
        if 4 * need == wfs:
            return (isa.WIDTH_ALL, isa.DEPTH_QUARTER)
        return (isa.WIDTH_ALL, isa.DEPTH_WF0) if need == 1 else isa.TSC_FULL
    if s > 4:
        return (isa.WIDTH_ALL, isa.DEPTH_WF0)      # 16 lanes, garbage tail
    if s > 1:
        return (isa.WIDTH_QUARTER, isa.DEPTH_WF0)  # 4 lanes
    return (isa.WIDTH_ONE, isa.DEPTH_WF0)          # MCU


def build_reduction(cfg: EGPUConfig, n: int, *, use_dot: bool = False,
                    no_dynamic: bool = False,
                    multi_load: bool = False) -> Bench:
    """``multi_load`` (§Perf, beyond-paper): for large n each thread folds
    ``fold`` elements with LOD-offset immediates before the TSC tree, so
    the tree depth stops growing with n (fixes the 1.45x blow-up at
    n=128 vs the paper's flat scaling)."""
    if n % 16 or (not multi_load and n > cfg.max_threads):
        raise ValueError(f"n={n} must be a multiple of 16 <= {cfg.max_threads}")
    a = Asm(cfg)
    R_TID, R_ACC, R_T, R_S, R_OUT = 1, 2, 3, 4, 5

    n_elems = n
    fold = 4 if (multi_load and n >= 64) else 1
    threads = max(16, n // fold)
    a.tdx(R_TID)                       # tid (tdx_dim == threads)
    a.lod(R_ACC, R_TID, 0)             # acc = x[tid]
    for j in range(1, fold):
        a.lod(R_T, R_TID, j * threads)
        a.fadd(R_ACC, R_ACC, R_T)
    if fold > 1:
        a.sto(R_ACC, R_TID, 0)         # partials into x[0:threads]
        n = threads                    # tree runs over the partials

    if use_dot:
        a.sum_(R_OUT, R_ACC)           # thread0.R_OUT = sum over thread space
        a.lodi(R_TID, 0, tsc="mcu")
        a.sto(R_OUT, R_TID, 0, tsc="mcu")   # x[0] = result (1-cycle write)
    elif no_dynamic:
        # conventional SIMT: full-width issue, predicate-masked writeback
        if not cfg.has_predicates:
            raise ValueError("no_dynamic ablation needs predicates")
        for s in _strides(n):
            a.lodi(R_S, s)
            a.if_("lt", R_TID, R_S, typ=isa.Typ.U32)   # only t < s writes
            a.lod(R_T, R_TID, s)       # x[t + s]
            a.fadd(R_ACC, R_ACC, R_T)
            a.sto(R_ACC, R_TID, 0)     # full-width store, masked writeback
            a.endif()
    else:
        for s in _strides(n):
            tsc = _tsc_for_stride(s, n)
            a.lod(R_T, R_TID, s, tsc=tsc)
            a.fadd(R_ACC, R_ACC, R_T, tsc=tsc)
            a.sto(R_ACC, R_TID, 0, tsc=tsc)
    a.stop()

    img = a.assemble(threads_active=max(16, n))
    rng = np.random.default_rng(n_elems)
    data = rng.standard_normal(n_elems).astype(np.float32)

    def oracle(_):
        return np.array([data.sum()], dtype=np.float32)

    def view(st):
        return machine_mod.shared_as_f32(st)[:1]

    name = f"reduction{'_dot' if use_dot else ''}" \
           f"{'_nodyn' if no_dynamic else ''}" \
           f"{'_mload' if fold > 1 else ''}_{n_elems}_{cfg.memory_mode}"
    return Bench(name=name, image=img, shared_init=data, oracle=oracle,
                 result_view=view, tdx_dim=n, atol=1e-3 * n_elems,
                 data_words=n_elems + 1)
