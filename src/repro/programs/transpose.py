"""Matrix transpose (paper §7, Table 7).

Cycle mechanics per the paper: an n x n transpose needs ~n^2 write
cycles (1 write port, DP) plus n^2/4 read cycles, and the QP variant
"writes two transposed elements per clock" (~40% fewer cycles).
Addresses step incrementally between 512-element chunks: because the
chunk stride (512) is a multiple of n, each thread's column is fixed and
its destination advances by 512/n per chunk — two ADDs per chunk.
"""
from __future__ import annotations

import numpy as np

from ..core.assembler import Asm
from ..core.config import EGPUConfig
from ..core import machine as machine_mod
from .common import Bench, log2i


def build_transpose(cfg: EGPUConfig, n: int) -> Bench:
    t = cfg.max_threads
    if n * n % t:
        raise ValueError("matrix must tile by the thread space")
    chunks = max(1, n * n // t)
    ln = log2i(n)
    dst_base = n * n
    if 2 * n * n > cfg.shared_words:
        raise ValueError("matrix pair does not fit shared memory")

    a = Asm(cfg)
    (R_E, R_ROW, R_COL, R_DST, R_SHIFT, R_MASK, R_V, R_DSTEP, R_SSTEP) = \
        range(1, 10)

    a.tdx(R_E)                     # element index = tid  (tdx_dim = threads)
    a.lodi(R_SHIFT, ln)
    a.lodi(R_MASK, n - 1)
    a.shr(R_ROW, R_E, R_SHIFT)     # row = e >> log2 n
    a.and_(R_COL, R_E, R_MASK)     # col = e & (n-1)
    a.shl(R_DST, R_COL, R_SHIFT)   # dst = col * n
    a.add(R_DST, R_DST, R_ROW)     # dst += row
    a.lodi(R_T := 10, dst_base)
    a.add(R_DST, R_DST, R_T)       # dst += dst_base
    a.lodi(R_SSTEP, t)             # src chunk stride
    a.lodi(R_DSTEP, t >> ln)       # dst chunk stride = 512 / n

    if chunks > 1:
        with a.loop(chunks):
            a.lod(R_V, R_E, 0)
            a.sto(R_V, R_DST, 0)
            a.add(R_E, R_E, R_SSTEP)
            a.add(R_DST, R_DST, R_DSTEP)
    else:
        a.lod(R_V, R_E, 0)
        a.sto(R_V, R_DST, 0)
    a.stop()

    img = a.assemble(threads_active=t)
    rng = np.random.default_rng(n)
    data = rng.standard_normal(n * n).astype(np.float32)

    def oracle(_):
        return data.reshape(n, n).T.ravel()

    def view(st):
        return machine_mod.shared_as_f32(st)[dst_base: dst_base + n * n]

    return Bench(name=f"transpose_{n}_{cfg.memory_mode}", image=img,
                 shared_init=data, oracle=oracle, result_view=view,
                 tdx_dim=t, data_words=2 * n * n)
