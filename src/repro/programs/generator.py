"""Seeded random CFG program generator for analyzer soundness fuzzing.

Emits structured eGPU programs whose shape is drawn from the full ISA
grammar the static verifier must cover: nested counted loops
(INIT/LOOP), predicate regions (IF/ELSE/ENDIF, optionally with an ELSE
arm), subroutines (JSR/RTS, acyclic call chains), forward JMPs,
LOD/STO address arithmetic, and the narrow thread-space personalities
("wf0"/"cpu"/"mcu"/...).  All value ops are *integer* ops so a numpy
reference run is bit-exact against the JAX interpreter.

With ``hostility > 0`` a program may also contain deliberately broken
constructs — constant out-of-bounds stores, stray ELSE/ENDIF, stack
overflows past the configured limits, out-of-image branch targets —
which the verifier is expected to reject.  The soundness property under
test: whenever :func:`repro.analysis.analyze` reports no ERROR, the
concrete run must halt cleanly with no stack faults, every access the
analyzer *proved* in bounds must stay in bounds, and a static step
count must match the executed count exactly.
"""
from __future__ import annotations

import random

from ..core import isa
from ..core.assembler import Asm, ProgramImage
from ..core.config import EGPUConfig
from ..core.isa import Typ

#: thread-space personalities the generator samples for value ops
_PERSONALITIES = ("full", "full", "full", "wf0", "cpu", "mcu", "quarter")


class _Gen:
    def __init__(self, cfg: EGPUConfig, rng: random.Random,
                 n_target: int, hostility: float):
        self.cfg = cfg
        self.rng = rng
        self.a = Asm(cfg)
        self.budget = n_target
        self.hostility = hostility
        self.regs = list(range(1, min(cfg.regs_per_thread, 12)))
        self.S = cfg.shared_words
        self.loop_depth = 0
        self.pred_depth = 0
        self.max_loop = min(cfg.max_loop_depth, 3)
        self.max_pred = min(cfg.predicate_levels, 3) \
            if cfg.has_predicates else 0
        self.subs: list[str] = []

    # ------------------------------------------------------------ helpers
    def r(self) -> int:
        return self.rng.choice(self.regs)

    def tsc(self) -> str:
        return self.rng.choice(_PERSONALITIES)

    def bad(self, p: float) -> bool:
        return self.hostility > 0 and self.rng.random() < p * self.hostility

    # ------------------------------------------------------------- pieces
    def value_op(self) -> None:
        a, rng = self.a, self.rng
        k = rng.randrange(9)
        rd, ra, rb = self.r(), self.r(), self.r()
        tsc = self.tsc()
        typ = rng.choice((Typ.U32, Typ.I32))
        if k == 0:
            a.lodi(rd, rng.randrange(-64, 256), tsc=tsc)
        elif k == 1:
            a.tdx(rd, tsc=tsc)
        elif k == 2:
            a.add(rd, ra, rb, typ=typ, tsc=tsc)
        elif k == 3:
            a.sub(rd, ra, rb, typ=typ, tsc=tsc)
        elif k == 4:
            a.xor(rd, ra, rb, tsc=tsc)
        elif k == 5:
            a.and_(rd, ra, rb, tsc=tsc)
        elif k == 6:
            a.shr(rd, ra, rb, typ=typ, tsc=tsc)
        elif k == 7:
            a.min_(rd, ra, rb, typ=typ, tsc=tsc)
        else:
            a.cnot(rd, ra, tsc=tsc)
        self.budget -= 1

    def memory_op(self) -> None:
        a, rng = self.a, self.rng
        addr, rv = self.r(), self.r()
        if rng.random() < 0.7:
            # provably in-bounds: small constant base + tdx lane id
            base = rng.randrange(0, max(1, self.S - 64))
            a.lodi(addr, min(base, 32767))
            if rng.random() < 0.5:
                a.tdx(rv)
                a.add(addr, addr, rv, typ=Typ.U32)
            off = rng.randrange(0, 16)
        elif self.bad(0.6):
            # constant, provably out of bounds (expected: ERROR)
            a.lodi(addr, min(self.S + rng.randrange(1, 64), 32767))
            off = rng.randrange(0, 8)
        else:
            # derived address the intervals may or may not bound
            a.xor(addr, self.r(), self.r())
            off = rng.randrange(0, 8)
        self.budget -= 2
        if rng.random() < 0.5:
            a.lod(rv, addr, off, tsc=self.tsc())
        else:
            a.sto(rv, addr, off, tsc=self.tsc())
        self.budget -= 1

    def loop(self, depth_left: int) -> None:
        a = self.a
        if self.loop_depth >= self.max_loop or self.budget < 4:
            self.value_op()
            return
        trips = self.rng.randrange(0, 4)     # INIT c -> body runs c+1 times
        a.init(trips)
        head = a.label()
        self.loop_depth += 1
        self.budget -= 2
        self.body(depth_left - 1, self.budget // 2 + 1)
        self.loop_depth -= 1
        a.loop_(head)

    def predicate(self, depth_left: int) -> None:
        a, rng = self.a, self.rng
        if self.pred_depth >= self.max_pred or self.budget < 4:
            self.value_op()
            return
        cc = rng.choice(("eq", "lt", "gt", "nz"))
        if cc == "nz":
            a.if_(cc, self.r())
        else:
            a.if_(cc, self.r(), self.r(), typ=Typ.I32)
        self.pred_depth += 1
        self.budget -= 2
        self.body(depth_left - 1, self.budget // 2 + 1)
        if rng.random() < 0.6:
            a.else_()
            self.budget -= 1
            self.body(depth_left - 1, self.budget // 2 + 1)
        self.pred_depth -= 1
        a.endif()

    def jump_over(self) -> None:
        """Forward JMP across a (now unreachable) chunk."""
        a = self.a
        tgt = f"_fwd{a._auto}"
        a._auto += 1
        a.jmp(tgt)
        self.budget -= 1
        for _ in range(self.rng.randrange(1, 3)):
            self.value_op()
        a.label(tgt)

    def broken(self) -> None:
        """One deliberately malformed construct (verifier food)."""
        a, rng = self.a, self.rng
        k = rng.randrange(4)
        if k == 0:
            a.endif()                      # stray ENDIF (underflow)
        elif k == 1:
            a.else_()                      # stray ELSE
        elif k == 2:
            a.emit(isa.Op.JMP, imm=4096)   # out-of-image target
        else:
            for _ in range(self.cfg.max_loop_depth + 1):
                a.init(0)                  # overflow the loop stack
                self.budget -= 1
            lbl = a.label()
            self.value_op()
            for _ in range(self.cfg.max_loop_depth + 1):
                a.loop_(lbl)
                self.budget -= 1
        self.budget -= 1

    def call(self) -> None:
        if not self.subs:
            self.value_op()
            return
        self.a.jsr(self.rng.choice(self.subs))
        self.budget -= 1

    # --------------------------------------------------------------- body
    def body(self, depth_left: int, budget_cap: int) -> None:
        spent = 0
        n = self.rng.randrange(2, 6)
        for _ in range(n):
            if self.budget <= 1 or spent >= budget_cap:
                break
            before = self.budget
            roll = self.rng.random()
            if self.bad(0.05):
                self.broken()
            elif roll < 0.15 and depth_left > 0:
                self.loop(depth_left)
            elif roll < 0.30 and depth_left > 0 and self.max_pred:
                self.predicate(depth_left)
            elif roll < 0.38:
                self.memory_op()
            elif roll < 0.43:
                self.jump_over()
            elif roll < 0.48:
                self.call()
            else:
                self.value_op()
            spent += before - self.budget

    # -------------------------------------------------------------- build
    def build(self, threads: int) -> ProgramImage:
        a, rng = self.a, self.rng
        n_subs = rng.randrange(0, 3)
        self.subs = [f"_sub{i}" for i in range(n_subs)]
        while self.budget > 2:
            self.body(3, self.budget)
        a.stop()
        for i, name in enumerate(self.subs):
            a.label(name)
            # a sub may tail-call a strictly later sub: chains stay
            # acyclic and at most n_subs deep
            self.subs = [f"_sub{j}" for j in range(i + 1, n_subs)]
            self.budget = rng.randrange(2, 6)
            self.body(1, self.budget)
            a.rts()
        return a.assemble(threads_active=threads)


def generate_program(cfg: EGPUConfig, seed: int, *, n_target: int = 40,
                     hostility: float = 0.0,
                     threads: int | None = None) -> ProgramImage:
    """One seeded random program.  ``n_target`` bounds the pre-schedule
    instruction count; ``hostility`` in [0, 1] scales the probability of
    deliberately broken constructs (0 disables them); ``threads``
    defaults to a random multiple of the wavefront width."""
    rng = random.Random(seed)
    if threads is None:
        w = cfg.max_threads // cfg.num_sps
        threads = cfg.num_sps * rng.randrange(1, w + 1)
    return _Gen(cfg, rng, n_target, hostility).build(threads)
