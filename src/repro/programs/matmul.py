"""Matrix-matrix multiply (paper §7, Table 7).

Two implementations, as in the paper:

* plain  — one thread per output element, k-loop in registers; C
  overwrites A row-blocks as they die (this is how the paper fits
  128x128 in a 128KB shared memory);
* use_dot — the dot-product extension computes a whole <a-row, b-col>
  inner product per issue; results collect in SP0 and are written back
  with 1-cycle MCU-personality stores (dynamic scalability), software-
  pipelined 8 DOTs deep to hide the unit's writeback latency.
"""
from __future__ import annotations

import numpy as np

from ..core.assembler import Asm
from ..core.config import EGPUConfig
from ..core import machine as machine_mod
from .common import Bench, log2i


def build_matmul(cfg: EGPUConfig, n: int, *, use_dot: bool = False) -> Bench:
    t = cfg.max_threads
    ln = log2i(n)
    if 2 * n * n > cfg.shared_words:
        raise ValueError("A+B do not fit shared memory")

    a = Asm(cfg)
    if not use_dot:
        rpp = t // n
        passes = n // rpp
        (R_J, R_IL, R_IG, R_PB, R_A, R_B, R_AV, R_BV, R_P, R_ACC, R_ONE,
         R_N, R_SH, R_C, R_RPP) = range(1, 16)
        a.tdx(R_J)
        a.tdy(R_IL)
        a.lodi(R_PB, 0)
        a.lodi(R_ONE, 1)
        a.lodi(R_N, n)
        a.lodi(R_SH, ln)
        a.lodi(R_RPP, rpp)
        with a.loop(passes):
            a.add(R_IG, R_IL, R_PB)
            a.shl(R_A, R_IG, R_SH)
            a.add(R_C, R_A, R_J)
            a.or_(R_B, R_J, R_J)        # b addr = j (register copy)
            a.lodi(R_ACC, 0)
            with a.loop(n):
                a.lod(R_AV, R_A, 0)
                a.lod(R_BV, R_B, n * n)
                a.fmul(R_P, R_AV, R_BV)
                a.fadd(R_ACC, R_ACC, R_P)
                a.add(R_A, R_A, R_ONE)
                a.add(R_B, R_B, R_N)
            a.sto(R_ACC, R_C, 0)
            a.add(R_PB, R_PB, R_RPP)
        threads = t
        tdx_dim = n
    else:
        # threads span the k dimension; DOT folds a whole inner product.
        (R_K, R_A, R_B, R_BV, R_AROW, R_N, R_SH, R_C) = range(1, 9)
        DOT_REGS = list(range(16, 24))      # 8-deep software pipeline
        groups = n // len(DOT_REGS)
        a.tdx(R_K)                          # k  (tdx_dim = n)
        a.lodi(R_N, n)
        a.lodi(R_SH, ln)
        a.add(R_A, R_K, 0)                  # a addr = 0*n + k
        a.lodi(R_C, 0, tsc="mcu")           # C writeback cursor (SP0)
        with a.loop(n):                     # rows i
            a.lod(R_AROW, R_A, 0)           # a[i, :] across threads
            a.shl(R_B, R_K, R_SH)           # b addr = k*n (+j below)
            with a.loop(groups):            # 8-column groups
                for g, rdot in enumerate(DOT_REGS):
                    a.lod(R_BV, R_B, n * n + g)   # b[k, j+g]
                    a.dot(rdot, R_AROW, R_BV)
                for g, rdot in enumerate(DOT_REGS):
                    a.sto(rdot, R_C, g, tsc="mcu")   # 1-cycle subset writes
                a.lodi(R_BV, len(DOT_REGS))
                a.add(R_B, R_B, R_BV)
                a.add(R_C, R_C, R_BV, tsc="mcu")
            a.add(R_A, R_A, R_N)
        threads = n
        tdx_dim = n
    a.stop()

    img = a.assemble(threads_active=threads)
    rng = np.random.default_rng(n)
    A = (rng.standard_normal((n, n)) / np.sqrt(n)).astype(np.float32)
    B = (rng.standard_normal((n, n)) / np.sqrt(n)).astype(np.float32)
    data = np.concatenate([A.ravel(), B.ravel()])

    def oracle(_):
        return (A @ B).ravel()

    def view(st):
        return machine_mod.shared_as_f32(st)[: n * n]   # C overwrote A

    name = f"matmul{'_dot' if use_dot else ''}_{n}_{cfg.memory_mode}"
    return Bench(name=name, image=img, shared_init=data, oracle=oracle,
                 result_view=view, tdx_dim=tdx_dim, atol=5e-3, rtol=5e-3,
                 data_words=3 * n * n)
