"""Radix-2 DIT FFT (paper §7, Table 8).

One thread per butterfly (n/2 threads).  The input is permuted into a
scratch region using the BVS (bit-reverse) instruction — the reason that
instruction exists in the ISA — then log2(n) in-place butterfly stages
run in scratch.  Twiddle factors are precomputed into shared memory
(there is no trig unit; the paper's kernels do the same).

Layout (32-bit words): re [0,n), im [n,2n), twiddle-re [2n, 2n+n/2),
twiddle-im [2n+n/2, 3n), scratch-re [3n, 4n), scratch-im [4n, 5n).
"""
from __future__ import annotations

import numpy as np

from ..core.assembler import Asm
from ..core.config import EGPUConfig
from ..core import machine as machine_mod
from .common import Bench, log2i


def build_fft(cfg: EGPUConfig, n: int) -> Bench:
    ln = log2i(n)
    threads = max(16, n // 2)
    if threads > cfg.max_threads or 5 * n > cfg.shared_words:
        raise ValueError("FFT size out of range")
    TW_RE, TW_IM = 2 * n, 2 * n + n // 2
    S_RE, S_IM = 3 * n, 4 * n

    a = Asm(cfg)
    (R_TID, R_E, R_REV, R_SH, R_V, R_OFF,
     R_I, R_TW, R_POS, R_GRP, R_DM,
     R_AR, R_AI, R_BR, R_BI, R_WR, R_WI,
     R_M1, R_M2, R_TR, R_TI, R_O) = range(1, 23)

    a.tdx(R_TID)
    # ---- bit-reversal reorder into scratch (2 elements per thread) -------
    a.lodi(R_SH, 32 - ln)
    for off in (0, n // 2):
        a.lodi(R_OFF, off)
        a.add(R_E, R_TID, R_OFF)        # element index
        a.bvs(R_REV, R_E)
        a.shr(R_REV, R_REV, R_SH)       # rev = bitrev(e) >> (32-log2 n)
        a.lod(R_V, R_REV, 0)            # re[rev]
        a.sto(R_V, R_E, S_RE)
        a.lod(R_V, R_REV, n)            # im[rev]
        a.sto(R_V, R_E, S_IM)

    # ---- log2(n) butterfly stages ----------------------------------------
    for s in range(ln):
        d = 1 << s
        a.lodi(R_DM, d - 1)
        a.and_(R_POS, R_TID, R_DM)      # pos = t & (d-1)
        a.lodi(R_SH, s)
        a.shr(R_GRP, R_TID, R_SH)       # grp = t >> s
        a.lodi(R_SH, s + 1)
        a.shl(R_I, R_GRP, R_SH)
        a.add(R_I, R_I, R_POS)          # i = grp*2d + pos   (j = i + d)
        a.lodi(R_SH, ln - 1 - s)
        a.shl(R_TW, R_POS, R_SH)        # twiddle index = pos * n/(2d)
        a.lod(R_AR, R_I, S_RE)
        a.lod(R_AI, R_I, S_IM)
        a.lod(R_BR, R_I, S_RE + d)
        a.lod(R_BI, R_I, S_IM + d)
        a.lod(R_WR, R_TW, TW_RE)
        a.lod(R_WI, R_TW, TW_IM)
        a.fmul(R_M1, R_BR, R_WR)
        a.fmul(R_M2, R_BI, R_WI)
        a.fsub(R_TR, R_M1, R_M2)        # tr = br*wr - bi*wi
        a.fmul(R_M1, R_BR, R_WI)
        a.fmul(R_M2, R_BI, R_WR)
        a.fadd(R_TI, R_M1, R_M2)        # ti = br*wi + bi*wr
        a.fadd(R_O, R_AR, R_TR)
        a.sto(R_O, R_I, S_RE)           # re[i] = ar + tr
        a.fadd(R_O, R_AI, R_TI)
        a.sto(R_O, R_I, S_IM)
        a.fsub(R_O, R_AR, R_TR)
        a.sto(R_O, R_I, S_RE + d)       # re[j] = ar - tr
        a.fsub(R_O, R_AI, R_TI)
        a.sto(R_O, R_I, S_IM + d)
    a.stop()

    img = a.assemble(threads_active=threads)
    rng = np.random.default_rng(n)
    re = rng.standard_normal(n).astype(np.float32)
    im = rng.standard_normal(n).astype(np.float32)
    m = np.arange(n // 2)
    tw_re = np.cos(2 * np.pi * m / n).astype(np.float32)
    tw_im = (-np.sin(2 * np.pi * m / n)).astype(np.float32)
    data = np.concatenate([re, im, tw_re, tw_im,
                           np.zeros(2 * n, np.float32)])

    def oracle(_):
        sp = np.fft.fft(re.astype(np.float64) + 1j * im.astype(np.float64))
        return np.concatenate([sp.real, sp.imag]).astype(np.float32)

    def view(st):
        buf = machine_mod.shared_as_f32(st)
        return np.concatenate([buf[S_RE:S_RE + n], buf[S_IM:S_IM + n]])

    return Bench(name=f"fft_{n}_{cfg.memory_mode}", image=img,
                 shared_init=data, oracle=oracle, result_view=view,
                 tdx_dim=threads, atol=2e-3 * np.sqrt(n), rtol=1e-3,
                 data_words=4 * n)
