"""Bitonic sort (paper §7, Table 8) — requires predicates.

Per-pass logic (one thread per element, Batcher's network unrolled, which
matches the paper's ~250-instruction program for 256 elements): each
thread keeps MIN or MAX of its pair depending on whether it is the lower
partner XNOR the block direction — selected with the predicate stack
(IF/ELSE/ENDIF), the feature whose ~50% area cost the paper highlights.
"""
from __future__ import annotations

import numpy as np

from ..core import isa
from ..core.assembler import Asm
from ..core.config import EGPUConfig
from ..core import machine as machine_mod
from .common import Bench, log2i


def build_bitonic(cfg: EGPUConfig, n: int) -> Bench:
    if not cfg.has_predicates:
        raise ValueError("bitonic sort requires predicates (paper §7)")
    if n % 16 or n > cfg.max_threads:
        raise ValueError("n must be a multiple of 16 within the thread space")
    log2i(n)  # power-of-two check

    a = Asm(cfg)
    (R_TID, R_J, R_K, R_P, R_V, R_PV, R_TJ, R_TK, R_OUT) = range(1, 10)
    a.tdx(R_TID)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            a.lodi(R_J, j)
            a.lodi(R_K, k)
            a.xor(R_P, R_TID, R_J)          # partner index
            a.lod(R_V, R_TID, 0)
            a.lod(R_PV, R_P, 0)
            a.and_(R_TJ, R_TID, R_J)
            a.and_(R_TK, R_TID, R_K)
            a.cnot(R_TJ, R_TJ)              # 1 iff lower partner
            a.cnot(R_TK, R_TK)              # 1 iff ascending block
            a.if_("eq", R_TJ, R_TK)         # lower==asc -> keep MIN
            a.min_(R_OUT, R_V, R_PV, typ=isa.Typ.I32)
            a.else_()
            a.max_(R_OUT, R_V, R_PV, typ=isa.Typ.I32)
            a.endif()
            a.sto(R_OUT, R_TID, 0)
            j //= 2
        k *= 2
    a.stop()

    img = a.assemble(threads_active=n)
    rng = np.random.default_rng(n)
    data = rng.integers(-(2**30), 2**30, size=n, dtype=np.int32)

    def oracle(_):
        return np.sort(data)

    def view(st):
        return machine_mod.shared_as_i32(st)[:n]

    return Bench(name=f"bitonic_{n}_{cfg.memory_mode}", image=img,
                 shared_init=data.view(np.uint32), oracle=oracle,
                 result_view=view, tdx_dim=n, data_words=2 * n)
