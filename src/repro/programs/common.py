"""Shared benchmark plumbing."""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from ..core import machine as machine_mod
from ..core.assembler import ProgramImage
from ..core.executor import run_program


@dataclasses.dataclass
class Bench:
    name: str
    image: ProgramImage
    shared_init: np.ndarray           # initial shared memory (uint32 view ok)
    oracle: Callable[[np.ndarray], np.ndarray]   # f(shared_init_f32/i32) -> expected
    result_view: Callable[[machine_mod.MachineState], np.ndarray]
    tdx_dim: int = 16
    atol: float = 1e-4
    rtol: float = 1e-4
    data_words: int = 0               # words moved over the bus (load+unload)


@dataclasses.dataclass
class BenchResult:
    name: str
    cycles: int
    time_us: float
    correct: bool
    hazard_violations: int
    steps: int
    profile: dict
    bus_cycles: int
    max_abs_err: float = 0.0


def run_bench(b: Bench) -> BenchResult:
    st = run_program(b.image, shared_init=b.shared_init,
                     tdx_dim=b.tdx_dim)
    got = np.asarray(b.result_view(st))
    exp = np.asarray(b.oracle(b.shared_init))
    if got.dtype.kind == "f":
        correct = bool(np.allclose(got, exp, atol=b.atol, rtol=b.rtol))
        err = float(np.max(np.abs(got - exp))) if got.size else 0.0
    else:
        correct = bool(np.array_equal(got, exp))
        err = float(np.max(np.abs(got.astype(np.int64) - exp.astype(np.int64)))) if got.size else 0.0
    cfg = b.image.cfg
    cycles = int(st.cycles)
    return BenchResult(
        name=b.name, cycles=cycles, time_us=cfg.cycles_to_us(cycles),
        correct=correct, hazard_violations=int(st.hazard_violations),
        steps=int(st.steps), profile=machine_mod.profile(st),
        bus_cycles=b.data_words, max_abs_err=err)


def log2i(n: int) -> int:
    l = n.bit_length() - 1
    if 1 << l != n:
        raise ValueError(f"{n} is not a power of two")
    return l
