"""Per-core event counters — the soft GPU's hardware-counter analogue.

A hard GPU samples event counters at runtime; this soft GPU's executed
path is fully static, so every counter is **baked host-side** from the
block compiler's path simulation (``repro.core.blockc._simulate``) and
its superblock plan — exact, not sampled, and free at runtime.  The
per-opcode-class retire/issue counts are bit-identical to the
interpreter's ``stat_instrs`` / ``stat_cycles`` machine-state leaves
(the equivalence suites pin this), so a counter reader never needs to
know which tier actually ran the job.

Counter definitions (see the README table):

=======================  ==================================================
``instrs``               instructions retired on the executed path
``cycles``               issue cycles (the paper's per-kernel cycle count)
``instrs_by_class``      retires per :class:`~repro.core.isa.OpClass`
``cycles_by_class``      issue cycles per opcode class
``loop_backedges``       taken LOOP back-edges
``block_dispatches``     block-driver ``lax.switch`` dispatches actually
                         paid on the tier that ran (0 on superblock)
``fori_reps``            repeat nodes run as ``lax.fori_loop``
``unrolled_reps``        repeat nodes inlined into the trace
``fori_trips``           summed trip counts of the fori repeats
``unrolled_trips``       summed trip counts of the inlined repeats
``fori_instrs``          instructions executed inside fori repeats
``unrolled_instrs``      instructions executed inside inlined repeats
``hazard_nop_instrs``    scheduler NOP padding retired (hazard stalls)
``hazard_nop_cycles``    issue cycles lost to that padding
``hazard_violations``    hazard-checker violations on the path
``lane_steps_offered``   vector retires x runtime thread count
``lane_steps_active``    of which lanes the TSC mask left on
=======================  ==================================================

``lane_steps_offered - lane_steps_active`` is the predicated-off
lane-step count — the thread-space-subsetting utilization story the
paper tells, as a counter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from ..core.isa import NUM_OP_CLASSES, OpClass

__all__ = ["EventCounters", "aggregate"]


@dataclasses.dataclass(frozen=True)
class EventCounters:
    """One core's (or one aggregate's) event-counter block."""

    instrs: int
    cycles: int
    instrs_by_class: tuple          # (NUM_OP_CLASSES,) of int
    cycles_by_class: tuple
    loop_backedges: int
    block_dispatches: int
    fori_reps: int
    unrolled_reps: int
    fori_trips: int
    unrolled_trips: int
    fori_instrs: int
    unrolled_instrs: int
    hazard_nop_instrs: int
    hazard_nop_cycles: int
    hazard_violations: int
    lane_steps_offered: int
    lane_steps_active: int

    @property
    def lane_steps_masked(self) -> int:
        """Lane-steps predicated off by TSC masks."""
        return self.lane_steps_offered - self.lane_steps_active

    @property
    def lane_utilization(self) -> float:
        """Active fraction of offered vector lane-steps (1.0 when the
        path retired no vector instructions)."""
        if not self.lane_steps_offered:
            return 1.0
        return self.lane_steps_active / self.lane_steps_offered

    def profile(self) -> dict[str, tuple[int, int]]:
        """``{class name: (cycles, instrs)}`` — the per-class mix in the
        same shape :meth:`repro.fleet.scheduler.JobResult.profile`
        reports."""
        return {c.name: (int(self.cycles_by_class[c]),
                         int(self.instrs_by_class[c]))
                for c in OpClass}

    def flat(self) -> dict[str, int]:
        """A flat ``{name: int}`` view (classes as ``instrs.<CLS>`` /
        ``cycles.<CLS>``) — the shape trace events and the tracer's
        running totals use, mergeable by plain addition."""
        d = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, tuple):
                stem = f.name.split("_by_class")[0]
                for c in OpClass:
                    d[f"{stem}.{c.name}"] = int(v[c])
            else:
                d[f.name] = int(v)
        return d


def aggregate(counters: Iterable[EventCounters | None]) -> EventCounters | None:
    """Sum counter blocks field-wise (``None`` entries — jobs without
    counters — are skipped; all-``None`` aggregates to ``None``)."""
    cs = [c for c in counters if c is not None]
    if not cs:
        return None
    kw = {}
    for f in dataclasses.fields(EventCounters):
        vals = [getattr(c, f.name) for c in cs]
        if isinstance(vals[0], tuple):
            kw[f.name] = tuple(int(sum(col)) for col in zip(*vals))
        else:
            kw[f.name] = int(sum(vals))
    return EventCounters(**kw)
