"""Contextvar-scoped tracing with a Chrome/Perfetto trace-event exporter.

The soft-GPU stack's observability layer: nested wall-clock **spans**
(``drain -> partition -> compile -> residency -> dispatch ->
device_sync -> collect``), point-in-time **instant events** (tier
decisions, per-drain counter rollups) and **async pairs** (per-job
submit -> deliver latency), all recorded against one monotonic clock
and exported as Chrome trace-event JSON — load the file at
``ui.perfetto.dev`` or ``chrome://tracing``.

Zero overhead when disabled is the design contract: every
instrumentation site goes through :func:`span` / :func:`event` /
:func:`current_tracer`, which cost one contextvar read and a ``None``
check when no tracer is installed (``span`` returns a shared no-op
singleton; no timestamps are taken, nothing allocates per event).
Results are bit-identical with tracing on or off — the tracer observes
the host-side orchestration, never the computation.

    tracer = Tracer()
    with tracer:                        # installs into the contextvar
        fleet.drain()
    tracer.save("trace.json")

Instrumented code does not import the tracer instance; it calls the
module-level helpers::

    with span("dispatch", cores=n):
        ...
    event("tier_decision", tier=tier, rule=rule)
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Any

from . import recorder as _recorder

__all__ = [
    "Tracer", "span", "event", "current_tracer", "NULL_SPAN",
]

_TRACER: contextvars.ContextVar["Tracer | None"] = \
    contextvars.ContextVar("repro_obs_tracer", default=None)


def current_tracer() -> "Tracer | None":
    """The tracer installed in the current context, or ``None``."""
    return _TRACER.get()


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""

    __slots__ = ()
    #: instrumentation sites can skip building expensive span arguments
    #: (digests, feature dicts) when the span is inert
    active = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records ``[enter, exit)`` as a complete event in
    the tracer and/or the flight recorder (whichever are installed)."""

    __slots__ = ("_tr", "_rec", "_name", "_args", "_t0")
    active = True

    def __init__(self, tr: "Tracer | None", name: str, args: dict,
                 rec=None):
        self._tr = tr
        self._rec = rec
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self._tr
        if tr is not None:
            tr._events.append({
                "name": self._name, "cat": "span", "ph": "X",
                "ts": (self._t0 - tr._t0) / 1e3,
                "dur": (t1 - self._t0) / 1e3,
                "pid": tr._pid, "tid": tr._tid(),
                "args": self._args,
            })
        if self._rec is not None:
            self._rec.record_span(self._name, self._t0, t1,
                                  args=self._args)
        return False

    def set(self, **args):
        """Attach/overwrite span arguments (shown in the trace viewer)."""
        self._args.update(args)
        return self


class Tracer:
    """An event sink plus the context-manager that installs it.

    All timestamps are microseconds relative to the tracer's creation,
    from ``time.perf_counter_ns`` (monotonic).  ``with tracer:`` scopes
    activation; activation nests and is per-context (contextvar), so a
    tracer can be installed around any slice of work without touching
    global state.
    """

    def __init__(self, label: str = "repro"):
        self.label = label
        self._t0 = time.perf_counter_ns()
        self._pid = os.getpid()
        self._events: list[dict] = []
        self._tids: dict[int, int] = {}
        self.counters: dict[str, int] = {}
        # per-thread token stacks: contextvar reset tokens are only
        # valid in the context that set them, and one tracer may be
        # entered concurrently from many dispatcher threads
        self._tokens = threading.local()

    # ------------------------------------------------------ activation
    def __enter__(self):
        stack = getattr(self._tokens, "stack", None)
        if stack is None:
            stack = self._tokens.stack = []
        stack.append(_TRACER.set(self))
        return self

    def __exit__(self, *exc):
        _TRACER.reset(self._tokens.stack.pop())
        return False

    # --------------------------------------------------------- recording
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def event(self, name: str, cat: str = "event", **args) -> None:
        """Record an instant event (a point on the timeline).  ``cat``
        groups events for filtering in the Perfetto UI and in
        :mod:`repro.obs.report` (e.g. ``"serve"`` for retry/timeout/
        degrade events, ``"fault"`` for injections)."""
        self._events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self.now_us(), "pid": self._pid, "tid": self._tid(),
            "args": args,
        })

    def async_begin(self, name: str, id: int, **args) -> None:
        """Open one side of an async pair (e.g. job submit)."""
        self._events.append({
            "name": name, "cat": "async", "ph": "b", "id": int(id),
            "ts": self.now_us(), "pid": self._pid, "tid": self._tid(),
            "args": args,
        })

    def async_end(self, name: str, id: int, **args) -> None:
        """Close an async pair (e.g. job result delivered)."""
        self._events.append({
            "name": name, "cat": "async", "ph": "e", "id": int(id),
            "ts": self.now_us(), "pid": self._pid, "tid": self._tid(),
            "args": args,
        })

    def add_counters(self, counters: dict[str, int]) -> None:
        """Accumulate event-counter totals across the trace's lifetime."""
        for k, v in counters.items():
            self.counters[k] = self.counters.get(k, 0) + int(v)

    @property
    def events(self) -> list[dict]:
        return self._events

    # ----------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """The trace as a Chrome trace-event JSON object."""
        evs = [{
            "name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
            "args": {"name": f"repro.obs:{self.label}"},
        }]
        evs.extend(self._events)
        if self.counters:
            evs.append({
                "name": "counters_total", "cat": "event", "ph": "i",
                "s": "g", "ts": self.now_us(), "pid": self._pid,
                "tid": 0, "args": {"counters": dict(self.counters)},
            })
        return {"traceEvents": evs, "displayTimeUnit": "ms",
                "otherData": {"tool": "repro.obs", "label": self.label}}

    def save(self, path: str) -> None:
        """Write Chrome/Perfetto-loadable trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, default=_jsonable)


def _jsonable(x: Any):
    """Fallback serializer: numpy scalars/arrays -> Python numbers/lists."""
    if hasattr(x, "item") and getattr(x, "ndim", None) in (0, None):
        return x.item()
    if hasattr(x, "tolist"):
        return x.tolist()
    return str(x)


def span(name: str, **args):
    """A span against the current tracer and/or flight recorder; a
    shared no-op when neither is installed.

    The disabled path is two contextvar reads and ``None`` checks —
    callers building expensive span arguments should gate on
    ``sp.active`` (or :func:`current_tracer`) instead of precomputing.
    """
    tr = _TRACER.get()
    rec = _recorder.current_recorder()
    if tr is None and rec is None:
        return NULL_SPAN
    return _Span(tr, name, args, rec=rec)


def event(name: str, cat: str = "event", **args) -> None:
    """An instant event against the current tracer and/or flight
    recorder; no-op when neither is installed."""
    tr = _TRACER.get()
    if tr is not None:
        tr.event(name, cat=cat, **args)
    rec = _recorder.current_recorder()
    if rec is not None:
        rec.record(name, cat=cat, **args)
