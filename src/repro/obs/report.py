"""Offline trace summarizer: ``python -m repro.obs.report trace.json``.

Reads a Chrome trace-event JSON written by
:meth:`repro.obs.trace.Tracer.save` and prints

* the **span tree** — nested spans aggregated by path, with total and
  *self* times (time not covered by child spans) and call counts;
* **coverage** — the fraction of each top-level span's wall time its
  children account for (the CI acceptance bar is >= 95% for ``drain``);
* **counter totals** — the event-counter rollup across the trace;
* the **tier-decision table** — every ``TierPolicy`` choice with the
  feature values and the first rule that fired;
* **job latency** — submit -> deliver percentiles from the async pairs.

``--metrics snapshot.json`` switches to the telemetry view: counters,
gauges, histogram percentiles (lifetime and rolling-window), and the
SLO status a :class:`~repro.obs.metrics.MetricsSnapshot` embeds in its
``meta`` (a snapshot file is auto-detected by its ``kind`` field, so
the flag is optional).

Every section is also available as a plain function for programmatic
use (the obs benchmark gates on :func:`coverage`).
"""
from __future__ import annotations

import argparse
import json
from typing import Any

from .metrics import MetricsSnapshot, _fmt


def load(path: str) -> list[dict]:
    """The trace's event list (accepts both the ``{"traceEvents": []}``
    object form and a bare JSON array)."""
    with open(path) as f:
        data = json.load(f)
    return data["traceEvents"] if isinstance(data, dict) else data


# ---------------------------------------------------------------------------
# Span forest reconstruction
# ---------------------------------------------------------------------------

def build_tree(events: list[dict]) -> list[dict]:
    """Rebuild the span forest from flat ``"X"`` events by timestamp
    containment per (pid, tid) track.  Returns root nodes; each node is
    ``{name, ts, dur, args, children}`` with ``dur`` in microseconds."""
    roots: list[dict] = []
    tracks: dict[tuple, list[dict]] = {}
    spans = [e for e in events if e.get("ph") == "X"]
    # children were appended after their parents opened but close first:
    # sorting by (start asc, duration desc) puts every parent before its
    # children, so a simple open-span stack rebuilds the nesting
    spans.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
    for e in spans:
        node = {"name": e["name"], "ts": e["ts"],
                "dur": e.get("dur", 0.0), "args": e.get("args", {}),
                "children": []}
        stack = tracks.setdefault((e.get("pid"), e.get("tid")), [])
        while stack and e["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - 1e-9:
            stack.pop()
        (stack[-1]["children"] if stack else roots).append(node)
        stack.append(node)
    return roots


def _fold(nodes: list[dict], table: dict, path: tuple) -> None:
    for n in nodes:
        key = path + (n["name"],)
        row = table.setdefault(key, {"count": 0, "total": 0.0,
                                     "child": 0.0})
        row["count"] += 1
        row["total"] += n["dur"]
        row["child"] += sum(c["dur"] for c in n["children"])
        _fold(n["children"], table, key)


def span_table(roots: list[dict]) -> list[dict]:
    """Aggregate the forest by name-path: one row per unique nesting
    path with call count, total time, and self time (all in us)."""
    table: dict[tuple, dict] = {}
    _fold(roots, table, ())
    return [{"path": k, "count": v["count"], "total_us": v["total"],
             "self_us": v["total"] - v["child"]}
            for k, v in table.items()]


def coverage(roots: list[dict], name: str = "drain") -> list[float]:
    """Per-instance child coverage of every span called ``name``: the
    fraction of its wall time accounted for by its direct children."""
    out: list[float] = []

    def walk(nodes):
        for n in nodes:
            if n["name"] == name and n["dur"] > 0:
                out.append(sum(c["dur"] for c in n["children"]) / n["dur"])
            walk(n["children"])

    walk(roots)
    return out


# ---------------------------------------------------------------------------
# Non-span sections
# ---------------------------------------------------------------------------

def counter_totals(events: list[dict]) -> dict[str, int]:
    """The trace's final counter rollup (the exporter's
    ``counters_total`` instant), falling back to summing per-drain
    ``drain_counters`` events for partial traces."""
    for e in reversed(events):
        if e.get("name") == "counters_total":
            return dict(e["args"]["counters"])
    totals: dict[str, int] = {}
    for e in events:
        if e.get("name") == "drain_counters":
            for k, v in e.get("args", {}).items():
                if isinstance(v, (int, float)):
                    totals[k] = totals.get(k, 0) + int(v)
    return totals


def tier_decisions(events: list[dict]) -> list[dict]:
    """Every ``TierPolicy`` decision event's args, in trace order."""
    return [dict(e.get("args", {})) for e in events
            if e.get("name") == "tier_decision"]


def job_latencies(events: list[dict],
                  name: str | None = None) -> dict[tuple, float]:
    """``{(name, id): begin -> end latency in us}`` from async pairs.

    The scheduler emits ``job`` pairs (handed to a drain -> delivered);
    the serving layer emits ``request`` pairs (submitted -> future
    resolved, queue wait and retries included) — same id space, distinct
    names, so pairs are keyed by both.  Pass ``name`` to filter."""
    begins: dict[tuple, float] = {}
    lat: dict[tuple, float] = {}
    for e in events:
        if e.get("cat") != "async":
            continue
        if name is not None and e.get("name") != name:
            continue
        key = (e.get("name"), e["id"])
        if e["ph"] == "b":
            begins[key] = e["ts"]
        elif e["ph"] == "e" and key in begins:
            lat[key] = e["ts"] - begins[key]
    return lat


def serve_events(events: list[dict]) -> dict[str, int]:
    """Counts of serving/fault instants (``cat`` in ``serve``/``fault``),
    keyed ``"<cat>:<name>"`` — the at-a-glance robustness story of a
    chaos run (retries, timeouts, degradations, injections...)."""
    out: dict[str, int] = {}
    for e in events:
        if e.get("ph") == "i" and e.get("cat") in ("serve", "fault"):
            k = f"{e['cat']}:{e['name']}"
            out[k] = out.get(k, 0) + 1
    return out


def admission_events(events: list[dict]) -> dict:
    """Static-verifier admission activity: how many submits the
    whole-program analyzer rejected before compile, with the diagnostic
    codes that fired (``admission_lint_reject`` instants from either
    the scheduler or the serving layer)."""
    rejects = 0
    errors = 0
    codes: dict[str, int] = {}
    for e in events:
        if e.get("ph") == "i" and e.get("name") == "admission_lint_reject":
            a = e.get("args", {})
            rejects += 1
            errors += int(a.get("errors", 0))
            for c in str(a.get("codes", "")).split(","):
                if c:
                    codes[c] = codes.get(c, 0) + 1
    return {"rejects": rejects, "errors": errors, "codes": codes}


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[i]


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.3f}ms"
    return f"{us:.1f}us"


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

def render(events: list[dict]) -> str:
    roots = build_tree(events)
    lines: list[str] = []

    lines.append("== span tree (count, total, self) ==")
    rows = sorted(span_table(roots), key=lambda r: r["path"])
    if not rows:
        lines.append("  (no spans)")
    for r in rows:
        indent = "  " * len(r["path"])
        lines.append(f"{indent}{r['path'][-1]:<14} x{r['count']:<5} "
                     f"total {_fmt_us(r['total_us']):>10}  "
                     f"self {_fmt_us(r['self_us']):>10}")

    covs = coverage(roots, "drain")
    if covs:
        lines.append("")
        lines.append(f"== drain coverage == {len(covs)} drain(s), child "
                     f"spans cover min {min(covs):.1%} / "
                     f"mean {sum(covs) / len(covs):.1%} of drain wall time")

    totals = counter_totals(events)
    if totals:
        lines.append("")
        lines.append("== counter totals ==")
        for k in sorted(totals):
            lines.append(f"  {k:<24} {totals[k]:>14,}")
        offered = totals.get("lane_steps_offered", 0)
        if offered:
            util = totals.get("lane_steps_active", 0) / offered
            lines.append(f"  {'lane_utilization':<24} {util:>14.1%}")

    decisions = tier_decisions(events)
    if decisions:
        lines.append("")
        lines.append("== tier decisions ==")
        lines.append(f"  {'tier':<11} {'batch':>5} {'disp':>6} "
                     f"{'trace':>6} {'fori':>8}  rule")
        for d in decisions:
            f: dict[str, Any] = d.get("features", {})
            lines.append(
                f"  {d.get('tier', '?'):<11} {d.get('batch', 0):>5} "
                f"{f.get('dispatches', 0):>6} "
                f"{str(f.get('trace_cost')):>6} "
                f"{f.get('fori_execd', 0):>8}  {d.get('rule', '?')}")

    all_lat = job_latencies(events)
    names = sorted({k[0] for k in all_lat})
    for nm in names:
        lat = sorted(v for k, v in all_lat.items() if k[0] == nm)
        label = {"job": "dispatch->deliver",
                 "request": "submit->resolve"}.get(nm, nm)
        lines.append("")
        lines.append(
            f"== {nm} latency == {len(lat)} jobs, {label} "
            f"p50 {_fmt_us(_pct(lat, 0.50))} / "
            f"p90 {_fmt_us(_pct(lat, 0.90))} / "
            f"p99 {_fmt_us(_pct(lat, 0.99))} / max {_fmt_us(lat[-1])}")

    srv = serve_events(events)
    if srv:
        lines.append("")
        lines.append("== serving / fault events ==")
        for k in sorted(srv):
            lines.append(f"  {k:<32} {srv[k]:>8,}")

    adm = admission_events(events)
    if adm["rejects"]:
        lines.append("")
        lines.append("== static-verifier admission ==")
        lines.append(f"  {'programs rejected':<32} {adm['rejects']:>8,}")
        lines.append(f"  {'ERROR diagnostics':<32} {adm['errors']:>8,}")
        for c in sorted(adm["codes"]):
            lines.append(f"  {'code: ' + c:<32} {adm['codes'][c]:>8,}")

    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Metrics-snapshot rendering
# ---------------------------------------------------------------------------

def _fmt_s(seconds: float | None) -> str:
    if seconds is None:
        return "-"
    return _fmt_us(seconds * 1e6)


def render_metrics(snap: MetricsSnapshot) -> str:
    """Human-readable view of a :class:`MetricsSnapshot`: counters and
    gauges (per label set), histogram percentiles (lifetime and, when a
    rolling window was configured, windowed), and the embedded SLO
    status from ``meta``."""
    lines: list[str] = []

    scalars = [m for m in snap.metrics if m["type"] in ("counter", "gauge")]
    if scalars:
        lines.append("== counters / gauges ==")
        for m in sorted(scalars, key=lambda m: m["name"]):
            for s in m["samples"]:
                lab = ",".join(f"{k}={v}"
                               for k, v in sorted(s["labels"].items()))
                tag = f"{m['name']}{{{lab}}}" if lab else m["name"]
                lines.append(f"  {tag:<56} {_fmt(s['value']):>12}")

    hists = [m for m in snap.metrics if m["type"] == "histogram"]
    for m in sorted(hists, key=lambda m: m["name"]):
        lines.append("")
        lines.append(f"== {m['name']} ==")
        for s in m["samples"]:
            lab = ",".join(f"{k}={v}"
                           for k, v in sorted(s["labels"].items()))
            flt = dict(s["labels"])
            n = s["count"]
            mean = s["sum"] / n if n else 0.0
            row = (f"  {{{lab}}}" if lab else "  (all)")
            row = (f"{row:<36} n={n:<8} mean {_fmt_s(mean):>9} "
                   f"p50 {_fmt_s(snap.percentile(m['name'], .5, **flt)):>9}"
                   f" p99 "
                   f"{_fmt_s(snap.percentile(m['name'], .99, **flt)):>9}")
            if "window" in s:
                wn = s["window"]["count"]
                wp99 = snap.percentile(m["name"], .99, window=True, **flt)
                row += (f"  | window({_fmt(s['window']['span_s'])}s) "
                        f"n={wn} p99 {_fmt_s(wp99)}")
            lines.append(row)

    slo = snap.meta.get("slo")
    if slo:
        lines.append("")
        lines.append("== SLO status ==")
        for k in sorted(slo):
            v = slo[k]
            if (k.endswith("_s") and isinstance(v, float)
                    and k not in ("window_s", "slo_latency_s")):
                v = _fmt_s(v)            # latency keys read best scaled
            lines.append(f"  {k:<24} {v}")
    for k, v in sorted(snap.meta.items()):
        if k != "slo":
            lines.append(f"  meta.{k}: {v}")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs Chrome/Perfetto trace or "
                    "metrics snapshot.")
    ap.add_argument("trace", help="trace JSON written with --trace / "
                                  "Tracer.save(), or a metrics snapshot "
                                  "written with MetricsSnapshot.save()")
    ap.add_argument("--metrics", action="store_true",
                    help="force the metrics-snapshot view (auto-detected "
                         "from the file's kind field otherwise)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    is_snap = args.metrics or (
        isinstance(doc, dict) and doc.get("kind") == "repro.obs.metrics")
    if is_snap:
        print(render_metrics(MetricsSnapshot.from_json(doc)))
    else:
        print(render(doc["traceEvents"] if isinstance(doc, dict) else doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
