"""Always-on serving metrics: a thread-safe registry of counters,
gauges, and fixed-bucket histograms with label sets.

Unlike the :mod:`repro.obs.trace` tracer — which is zero-overhead
precisely because it is *off* in production — the registry is designed
to stay installed for the life of a service.  Every primitive is a
dict update or a couple of list increments under one registry lock, so
the cost per operation is bounded and small (the ``benchmarks/obs.py``
gate holds the whole telemetry stack to <=3% of serve throughput), and
nothing here ever touches job *results*: bit-identity with telemetry
on/off is asserted in CI.

Three layers:

``MetricsRegistry``
    The mutable store.  ``counter`` / ``gauge`` / ``histogram`` create
    (or fetch) a named family with a fixed tuple of label names; the
    shorthand ``inc`` / ``set_gauge`` / ``observe`` auto-create
    families from the label keys at the call site.  Histograms carry a
    rolling window (time-sliced delta ring) alongside the lifetime
    buckets so p50/p99 can be read "over the last N seconds".

``MetricsSnapshot``
    An immutable copy of the registry at one instant.  Knows how to
    compute bucket-interpolated percentiles and SLO error-budget burn,
    round-trips through JSON, and renders the Prometheus text
    exposition format.

Ambient helpers
    ``installed()`` puts a registry in a contextvar;
    module-level ``inc`` / ``observe`` / ``set_gauge`` no-op in one
    contextvar read when nothing is installed.  This is how leaf code
    (``fleet/engine.py``, ``fleet/faults.py``) reports without
    threading a registry through every signature.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import threading
import time
from collections import deque

__all__ = [
    "MetricsRegistry",
    "MetricsSnapshot",
    "DEFAULT_TIME_BUCKETS",
    "SIZE_BUCKETS",
    "current_registry",
    "inc",
    "observe",
    "set_gauge",
]

# Log-spaced seconds ladder: 0.5 ms .. 10 s covers everything from a
# single compiled dispatch to a chaos-hang drain; the +Inf bucket is
# implicit.
DEFAULT_TIME_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

# Powers of two for cohort / batch sizes.
SIZE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)

_WINDOW_SLICES = 6


def _labelkey(labelnames, labels):
    try:
        return tuple(str(labels[k]) for k in labelnames)
    except KeyError as e:
        raise ValueError(
            f"missing label {e.args[0]!r}; expected {labelnames}") from e


class _ScalarChild:
    """One (labelvalues -> value) cell of a counter or gauge family."""

    __slots__ = ("_family", "value")

    def __init__(self, family):
        self._family = family
        self.value = 0.0

    def inc(self, value=1.0):
        if value < 0 and self._family.kind == "counter":
            raise ValueError("counters are monotonic; inc() needs >= 0")
        with self._family._lock:
            self.value += value

    def set(self, value):
        with self._family._lock:
            self.value = float(value)


class _HistChild:
    """One cell of a histogram family: lifetime per-bucket counts plus
    a rolling window kept as a ring of time-sliced deltas."""

    __slots__ = ("_family", "counts", "sum", "count",
                 "_slice", "_scounts", "_ssum", "_scount", "_ring")

    def __init__(self, family):
        self._family = family
        n = len(family.buckets) + 1          # last slot = +Inf
        self.counts = [0] * n
        self.sum = 0.0
        self.count = 0
        self._slice = None                   # current slice id
        self._scounts = [0] * n              # deltas within the slice
        self._ssum = 0.0
        self._scount = 0
        self._ring = deque()                 # (slice_id, counts, sum, n)

    def _bucket_index(self, value):
        buckets = self._family.buckets
        lo, hi = 0, len(buckets)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def _roll(self, sid):
        """Close the current slice into the ring; evict stale slices."""
        if self._slice is not None and self._scount:
            self._ring.append(
                (self._slice, self._scounts, self._ssum, self._scount))
            self._scounts = [0] * (len(self._family.buckets) + 1)
            self._ssum = 0.0
            self._scount = 0
        self._slice = sid
        horizon = sid - _WINDOW_SLICES
        while self._ring and self._ring[0][0] <= horizon:
            self._ring.popleft()

    def observe(self, value):
        fam = self._family
        with fam._lock:
            i = self._bucket_index(value)
            self.counts[i] += 1
            self.sum += value
            self.count += 1
            if fam.window_s:
                sid = int(fam._clock() // fam._slice_s)
                if sid != self._slice:
                    self._roll(sid)
                self._scounts[i] += 1
                self._ssum += value
                self._scount += 1

    def _window_state(self):
        """(counts, sum, count) over the rolling window.  Caller holds
        the registry lock."""
        fam = self._family
        if not fam.window_s:
            return None
        sid = int(fam._clock() // fam._slice_s)
        if sid != self._slice:
            self._roll(sid)
        counts = list(self._scounts)
        total, n = self._ssum, self._scount
        for _, c, s, k in self._ring:
            for j, v in enumerate(c):
                counts[j] += v
            total += s
            n += k
        return counts, total, n


class _Family:
    """A named metric with a fixed label-name tuple and one child per
    observed label-value combination."""

    __slots__ = ("name", "kind", "help", "labelnames", "buckets",
                 "window_s", "_slice_s", "_clock", "_lock", "_children")

    def __init__(self, name, kind, help_text, labelnames, lock, clock,
                 buckets=None, window_s=None):
        self.name = name
        self.kind = kind
        self.help = help_text
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets) if buckets else None
        self.window_s = window_s
        self._slice_s = (window_s / _WINDOW_SLICES) if window_s else None
        self._clock = clock
        self._lock = lock
        self._children = {}

    def labels(self, **labels):
        key = _labelkey(self.labelnames, labels)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    child = (_HistChild(self)
                             if self.kind == "histogram"
                             else _ScalarChild(self))
                    self._children[key] = child
        return child

    # convenience when the family is label-free or the caller has the
    # labels inline
    def inc(self, value=1.0, **labels):
        self.labels(**labels).inc(value)

    def set(self, value, **labels):
        self.labels(**labels).set(value)

    def observe(self, value, **labels):
        self.labels(**labels).observe(value)

    def value(self, **labels):
        key = _labelkey(self.labelnames, labels)
        child = self._children.get(key)
        return child.value if child is not None else 0.0

    def total(self, **label_filter):
        """Sum child values whose labels match every given filter."""
        idx = [(self.labelnames.index(k), str(v))
               for k, v in label_filter.items()]
        out = 0.0
        with self._lock:
            for key, child in self._children.items():
                if all(key[i] == v for i, v in idx):
                    out += child.value
        return out


class MetricsRegistry:
    """Thread-safe store of metric families.

    One lock guards every mutation; all primitives are O(1) dict/list
    work so the lock is held for sub-microsecond stretches.  A single
    registry is intended to outlive scheduler replacements (the
    :class:`~repro.fleet.service.FleetService` watchdog hands the same
    registry to each replacement scheduler), which is what makes
    service-lifetime counts drift-free.
    """

    def __init__(self, clock=time.monotonic):
        self._lock = threading.RLock()
        self._clock = clock
        self._families = {}

    # ------------------------------------------------------- creation
    def _family(self, name, kind, help_text, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}, not {kind}")
                if tuple(labelnames) != fam.labelnames:
                    raise ValueError(
                        f"metric {name!r} labelnames {fam.labelnames} "
                        f"!= {tuple(labelnames)}")
                return fam
            fam = _Family(name, kind, help_text, labelnames,
                          self._lock, self._clock, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name, help_text="", labelnames=()):
        return self._family(name, "counter", help_text, labelnames)

    def gauge(self, name, help_text="", labelnames=()):
        return self._family(name, "gauge", help_text, labelnames)

    def histogram(self, name, help_text="", labelnames=(),
                  buckets=DEFAULT_TIME_BUCKETS, window_s=None):
        return self._family(name, "histogram", help_text, labelnames,
                            buckets=buckets, window_s=window_s)

    # ------------------------------------------- call-site shorthands
    def inc(self, name, value=1.0, **labels):
        fam = self._families.get(name)
        if fam is None:
            fam = self.counter(name, labelnames=tuple(sorted(labels)))
        fam.labels(**labels).inc(value)

    def set_gauge(self, name, value, **labels):
        fam = self._families.get(name)
        if fam is None:
            fam = self.gauge(name, labelnames=tuple(sorted(labels)))
        fam.labels(**labels).set(value)

    def observe(self, name, value, **labels):
        fam = self._families.get(name)
        if fam is None:
            fam = self.histogram(name, labelnames=tuple(sorted(labels)))
        fam.labels(**labels).observe(value)

    # ---------------------------------------------------------- reads
    def value(self, name, **labels):
        fam = self._families.get(name)
        return fam.value(**labels) if fam is not None else 0.0

    def total(self, name, **label_filter):
        fam = self._families.get(name)
        return fam.total(**label_filter) if fam is not None else 0.0

    def snapshot(self):
        """An immutable :class:`MetricsSnapshot` of everything."""
        out = []
        with self._lock:
            for fam in self._families.values():
                samples = []
                for key, child in sorted(fam._children.items()):
                    labels = dict(zip(fam.labelnames, key))
                    if fam.kind == "histogram":
                        win = child._window_state()
                        sample = {
                            "labels": labels,
                            "counts": list(child.counts),
                            "sum": child.sum,
                            "count": child.count,
                        }
                        if win is not None:
                            wc, ws, wn = win
                            sample["window"] = {
                                "counts": wc, "sum": ws, "count": wn,
                                "span_s": fam.window_s,
                            }
                    else:
                        sample = {"labels": labels, "value": child.value}
                    samples.append(sample)
                out.append({
                    "name": fam.name,
                    "type": fam.kind,
                    "help": fam.help,
                    "labelnames": list(fam.labelnames),
                    "buckets": (list(fam.buckets)
                                if fam.buckets else None),
                    "samples": samples,
                })
        return MetricsSnapshot(ts=time.time(), metrics=out)

    def to_prometheus(self):
        return self.snapshot().to_prometheus()

    # -------------------------------------------------------- ambient
    @contextlib.contextmanager
    def installed(self):
        """Make this registry the ambient one for the calling context.

        The reset token lives in a closure local, so overlapping
        installs from different threads (a watchdog-abandoned drain
        thread racing its replacement) cannot interleave.
        """
        tok = _REGISTRY.set(self)
        try:
            yield self
        finally:
            _REGISTRY.reset(tok)


# --------------------------------------------------------------------
# snapshot


def _fmt(v):
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.10g}"


def _prom_labels(labels):
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + body + "}"


@dataclasses.dataclass
class MetricsSnapshot:
    """A frozen copy of a registry: the unit of export, reporting, and
    SLO math.  ``meta`` carries side-band context (e.g. the service's
    computed SLO status at close)."""

    ts: float
    metrics: list
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------- lookups
    def _metric(self, name):
        for m in self.metrics:
            if m["name"] == name:
                return m
        return None

    def value(self, name, **labels):
        m = self._metric(name)
        if m is None:
            return 0.0
        want = {k: str(v) for k, v in labels.items()}
        for s in m["samples"]:
            if s["labels"] == want:
                return s.get("value", s.get("count", 0.0))
        return 0.0

    def total(self, name, **label_filter):
        m = self._metric(name)
        if m is None:
            return 0.0
        want = {k: str(v) for k, v in label_filter.items()}
        out = 0.0
        for s in m["samples"]:
            if all(s["labels"].get(k) == v for k, v in want.items()):
                out += s.get("value", s.get("count", 0.0))
        return out

    def _merged_hist(self, name, window=False, **label_filter):
        """Merge matching histogram children into one (buckets,
        counts, sum, count) tuple — percentiles across label values."""
        m = self._metric(name)
        if m is None or m["type"] != "histogram":
            return None
        buckets = m["buckets"]
        counts = [0] * (len(buckets) + 1)
        total, n = 0.0, 0
        want = {k: str(v) for k, v in label_filter.items()}
        for s in m["samples"]:
            if not all(s["labels"].get(k) == v for k, v in want.items()):
                continue
            src = s.get("window") if window else s
            if src is None:
                src = s
            for j, c in enumerate(src["counts"]):
                counts[j] += c
            total += src["sum"]
            n += src["count"]
        return buckets, counts, total, n

    def percentile(self, name, q, window=False, **label_filter):
        """Bucket-interpolated q-quantile (q in [0, 1]); ``None`` when
        the (windowed) histogram is empty."""
        merged = self._merged_hist(name, window=window, **label_filter)
        if merged is None:
            return None
        buckets, counts, _, n = merged
        if n == 0:
            return None
        rank = q * n
        cum = 0
        for i, c in enumerate(counts):
            prev = cum
            cum += c
            if cum >= rank and c:
                lo = buckets[i - 1] if i > 0 else 0.0
                hi = buckets[i] if i < len(buckets) else buckets[-1]
                if i >= len(buckets):
                    return hi            # +Inf bucket: clamp
                return lo + (hi - lo) * (rank - prev) / c
        return buckets[-1]

    def count_le(self, name, threshold, window=False, **label_filter):
        """Observations <= threshold, rounded up to the nearest bucket
        edge (conservative for SLO "good" counts)."""
        merged = self._merged_hist(name, window=window, **label_filter)
        if merged is None:
            return 0
        buckets, counts, _, _ = merged
        good = 0
        for i, edge in enumerate(buckets):
            if edge > threshold:
                break
            good += counts[i]
        return good

    def hist_count(self, name, window=False, **label_filter):
        merged = self._merged_hist(name, window=window, **label_filter)
        return merged[3] if merged else 0

    def slo_burn(self, name, threshold_s, target, window=True,
                 good_filter=None, **label_filter):
        """Error-budget burn rate: fraction of bad requests divided by
        the budget (1 - target).  1.0 = burning exactly at budget.

        ``good_filter`` narrows which label values count as *good*
        (e.g. ``{"outcome": "ok"}``) while the denominator spans every
        child matching ``label_filter`` — so failed requests are bad no
        matter how fast they failed.
        """
        total = self.hist_count(name, window=window, **label_filter)
        if total == 0:
            return 0.0
        gf = dict(label_filter)
        gf.update(good_filter or {})
        good = self.count_le(name, threshold_s, window=window, **gf)
        bad_frac = max(0.0, 1.0 - good / total)
        budget = max(1e-9, 1.0 - target)
        return bad_frac / budget

    # -------------------------------------------------------- export
    def to_prometheus(self):
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        for m in self.metrics:
            name, kind = m["name"], m["type"]
            if m["help"]:
                lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} {kind}")
            for s in m["samples"]:
                labels = s["labels"]
                if kind == "histogram":
                    cum = 0
                    for i, edge in enumerate(m["buckets"]):
                        cum += s["counts"][i]
                        lab = dict(labels, le=_fmt(edge))
                        lines.append(
                            f"{name}_bucket{_prom_labels(lab)} {cum}")
                    cum += s["counts"][-1]
                    lab = dict(labels, le="+Inf")
                    lines.append(
                        f"{name}_bucket{_prom_labels(lab)} {cum}")
                    lines.append(
                        f"{name}_sum{_prom_labels(labels)} "
                        f"{_fmt(s['sum'])}")
                    lines.append(
                        f"{name}_count{_prom_labels(labels)} "
                        f"{s['count']}")
                else:
                    lines.append(
                        f"{name}{_prom_labels(labels)} "
                        f"{_fmt(s['value'])}")
        return "\n".join(lines) + "\n"

    def to_json(self):
        return {"kind": "repro.obs.metrics", "version": 1,
                "ts": self.ts, "meta": self.meta,
                "metrics": self.metrics}

    @classmethod
    def from_json(cls, doc):
        if doc.get("kind") != "repro.obs.metrics":
            raise ValueError("not a repro.obs.metrics snapshot")
        return cls(ts=doc["ts"], metrics=doc["metrics"],
                   meta=doc.get("meta", {}))

    def save(self, path):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def load(cls, path):
        with open(path) as f:
            return cls.from_json(json.load(f))


# --------------------------------------------------------------------
# ambient registry

_REGISTRY: contextvars.ContextVar[MetricsRegistry | None] = \
    contextvars.ContextVar("repro_obs_metrics", default=None)


def current_registry():
    """The ambient registry, or ``None``."""
    return _REGISTRY.get()


def inc(name, value=1.0, **labels):
    reg = _REGISTRY.get()
    if reg is not None:
        reg.inc(name, value, **labels)


def observe(name, value, **labels):
    reg = _REGISTRY.get()
    if reg is not None:
        reg.observe(name, value, **labels)


def set_gauge(name, value, **labels):
    reg = _REGISTRY.get()
    if reg is not None:
        reg.set_gauge(name, value, **labels)
