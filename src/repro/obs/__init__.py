"""Observability for the soft-GPU stack: tracing, event counters,
tier-decision logging, and a Chrome/Perfetto trace exporter.

Zero overhead when disabled; results are bit-identical with tracing on
or off.  See :mod:`repro.obs.trace` for the span API,
:mod:`repro.obs.counters` for the counter definitions, and
``python -m repro.obs.report trace.json`` for the offline summarizer.
"""
from .trace import NULL_SPAN, Tracer, current_tracer, event, span
from .counters import EventCounters, aggregate

__all__ = [
    "Tracer", "span", "event", "current_tracer", "NULL_SPAN",
    "EventCounters", "aggregate",
]
