"""Observability for the soft-GPU stack: tracing, event counters,
always-on serving metrics, a flight recorder, and Chrome/Perfetto
exporters.

Two regimes, one discipline (results bit-identical either way):

* **Deep tracing** (:mod:`repro.obs.trace`) records everything and is
  therefore zero-overhead-when-*off* — install a :class:`Tracer`
  around the slice of work you are debugging.
* **Always-on telemetry** (:mod:`repro.obs.metrics`,
  :mod:`repro.obs.recorder`) is bounded-overhead-when-*on*: a
  thread-safe :class:`MetricsRegistry` (counters / gauges / windowed
  histograms, Prometheus text exporter) and a :class:`FlightRecorder`
  ring buffer that dumps a Perfetto "blackbox" on failure.  The
  serving stack keeps both installed for its whole life.

``python -m repro.obs.report trace.json`` summarizes traces and
blackbox dumps; ``python -m repro.obs.report --metrics snap.json``
renders a metrics snapshot.
"""
from .trace import NULL_SPAN, Tracer, current_tracer, event, span
from .counters import EventCounters, aggregate
from .metrics import (
    DEFAULT_TIME_BUCKETS,
    MetricsRegistry,
    MetricsSnapshot,
    current_registry,
)
from .recorder import FlightRecorder, current_recorder

__all__ = [
    "Tracer", "span", "event", "current_tracer", "NULL_SPAN",
    "EventCounters", "aggregate",
    "MetricsRegistry", "MetricsSnapshot", "DEFAULT_TIME_BUCKETS",
    "current_registry",
    "FlightRecorder", "current_recorder",
]
