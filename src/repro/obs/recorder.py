"""Flight recorder: a bounded, always-on ring buffer of recent
span/event records, dumped as a Perfetto-compatible "blackbox" JSON
when something goes wrong.

The :mod:`repro.obs.trace` tracer records *everything* and therefore
must be off in production.  The recorder inverts the trade: it records
only a fixed-size tail (a ``deque(maxlen=...)``, O(1) memory, one
append per record) and is meant to stay installed for the life of a
:class:`~repro.fleet.service.FleetService`.  When a watchdog reset,
retry exhaustion, or injected fault fires, ``dump()`` freezes the ring
into a Chrome/Perfetto trace-event file — so every production failure
ships with its last ~N events of context instead of a bare counter
increment.

Layering note: :mod:`repro.obs.trace` imports this module so its
module-level ``span()`` / ``event()`` helpers can feed the recorder;
this module must therefore not import ``trace``.
"""

from __future__ import annotations

import collections
import contextlib
import contextvars
import json
import os
import tempfile
import threading
import time

__all__ = [
    "FlightRecorder",
    "current_recorder",
    "record",
    "trigger",
]

_PID = os.getpid()


def _jsonable(obj):
    """Best-effort JSON fallback for arbitrary span args (mirrors the
    tracer's serializer; kept local to avoid an import cycle)."""
    if hasattr(obj, "item") and getattr(obj, "ndim", None) == 0:
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return repr(obj)


class FlightRecorder:
    """Bounded ring of recent events with rate-limited blackbox dumps.

    Parameters
    ----------
    capacity:
        Ring size in records; the memory bound.
    blackbox_dir:
        Where dumps land.  Created on first dump; defaults to a fresh
        ``repro-blackbox-*`` temp directory.
    label:
        Embedded in dump filenames and metadata (e.g. a service name).
    min_dump_interval_s:
        Per-reason rate limit so a fault storm produces one dump per
        reason per interval instead of thousands.
    """

    def __init__(self, capacity=4096, blackbox_dir=None,
                 label="service", min_dump_interval_s=1.0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.label = label
        self.min_dump_interval_s = min_dump_interval_s
        self._blackbox_dir = blackbox_dir
        self._t0_ns = time.perf_counter_ns()
        self._buf = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._tids = {}
        self._last_dump = {}
        self._seq = 0
        self.recorded = 0
        self.dumps = []

    # ------------------------------------------------------ recording
    def _now_us(self):
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _tid(self):
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids) + 1)
        return tid

    def record(self, name, cat="event", **args):
        """Append an instant event.  O(1); safe from any thread."""
        rec = {
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": _PID, "tid": self._tid(),
            "args": args,
        }
        with self._lock:
            self.recorded += 1
            self._buf.append(rec)

    def record_span(self, name, t0_ns, t1_ns, cat="span", args=None):
        """Append a completed span (called by the trace module when a
        ``span()`` context exits with a recorder installed)."""
        rec = {
            "name": name, "cat": cat, "ph": "X",
            "ts": (t0_ns - self._t0_ns) / 1e3,
            "dur": max(0.0, (t1_ns - t0_ns) / 1e3),
            "pid": _PID, "tid": self._tid(),
            "args": args or {},
        }
        with self._lock:
            self.recorded += 1
            self._buf.append(rec)

    # --------------------------------------------------------- reads
    def __len__(self):
        return len(self._buf)

    def tail(self, n=None):
        """The most recent ``n`` records (all, when ``n`` is None)."""
        with self._lock:
            recs = list(self._buf)
        return recs if n is None else recs[-n:]

    def recent_for(self, ticket, n=32):
        """Records relevant to one ticket: entries that mention its id
        plus id-less cohort context (dispatches, resets, faults)."""
        out = []
        for r in self.tail():
            args = r.get("args") or {}
            rid = args.get("id", args.get("ticket"))
            if rid is None or str(rid) == str(ticket):
                out.append(r)
        return out[-n:]

    # --------------------------------------------------------- dumps
    @property
    def blackbox_dir(self):
        if self._blackbox_dir is None:
            self._blackbox_dir = tempfile.mkdtemp(
                prefix="repro-blackbox-")
        return self._blackbox_dir

    def to_chrome(self, reason=None, **info):
        """The ring as a Chrome/Perfetto trace-event document."""
        with self._lock:
            events = [dict(r) for r in self._buf]
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "tool": "repro.obs.recorder",
                "label": self.label,
                "reason": reason,
                "capacity": self.capacity,
                "recorded": self.recorded,
                **info,
            },
        }

    def dump(self, reason, force=False, **info):
        """Freeze the ring to a blackbox JSON file; returns the path,
        or ``None`` when rate-limited."""
        now = time.monotonic()
        with self._lock:
            last = self._last_dump.get(reason)
            if (not force and last is not None
                    and now - last < self.min_dump_interval_s):
                return None
            self._last_dump[reason] = now
            self._seq += 1
            seq = self._seq
        self.record("blackbox_dump", cat="recorder",
                    reason=reason, **info)
        doc = self.to_chrome(reason=reason, **info)
        path = os.path.join(
            self.blackbox_dir,
            f"blackbox-{self.label}-{seq:03d}-{reason}.json")
        with open(path, "w") as f:
            json.dump(doc, f, default=_jsonable)
        self.dumps.append(path)
        return path

    # -------------------------------------------------------- ambient
    @contextlib.contextmanager
    def installed(self):
        """Make this recorder ambient for the calling context.  The
        reset token is a closure local — overlapping installs across
        threads (watchdog-abandoned drains) cannot interleave."""
        tok = _RECORDER.set(self)
        try:
            yield self
        finally:
            _RECORDER.reset(tok)


# --------------------------------------------------------------------
# ambient recorder

_RECORDER: contextvars.ContextVar[FlightRecorder | None] = \
    contextvars.ContextVar("repro_obs_recorder", default=None)


def current_recorder():
    """The ambient recorder, or ``None``."""
    return _RECORDER.get()


def record(name, cat="event", **args):
    """Record into the ambient recorder; one contextvar read and a
    no-op when none is installed."""
    rec = _RECORDER.get()
    if rec is not None:
        rec.record(name, cat=cat, **args)


def trigger(reason, **info):
    """Dump the ambient recorder's blackbox (rate-limited); returns
    the path or ``None``."""
    rec = _RECORDER.get()
    if rec is not None:
        return rec.dump(reason, **info)
    return None
