"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import, and everything else must see the real device count.
"""
from __future__ import annotations

import math

import jax

#: TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def _require_devices(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Fail a mesh request that oversubscribes the visible devices with
    an actionable message (``jax.make_mesh`` would raise an opaque
    reshape error deep inside sharding internals)."""
    need = math.prod(shape)
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but "
            f"only {have} are visible; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"(set BEFORE jax is imported) or shrink the mesh")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _require_devices(shape, axes)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    _require_devices((data, model), ("data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    return int(mesh.devices.size)
