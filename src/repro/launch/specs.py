"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``build_cell`` returns everything the dry-run needs: the step function,
abstract arguments, and matching in_shardings — with no device
allocation anywhere (eval_shape end to end).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..models import api, vlm
from ..models.common import ModelConfig
from ..sharding import partition
from ..training import optimizer as opt_mod, steps

ENC_LEN = 4096       # encoder frames for enc-dec decode cells


@dataclasses.dataclass
class Cell:
    arch: str
    shape: configs.ShapeSpec
    cfg: ModelConfig
    step_fn: Callable
    args: tuple                  # abstract args (ShapeDtypeStruct trees)
    in_shardings: tuple
    out_shardings: Any = None    # set when pin_out=True (see #Perf)
    donate_argnums: tuple = ()
    model_params_bytes: int = 0
    notes: str = ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def param_count(shapes_tree) -> int:
    import math
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes_tree))


def _batch_axes_or_none(rules, mesh, b):
    ax = rules.physical("batch")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        total *= sizes[a]
    return ax if b % total == 0 else None


def train_batch_struct(cfg: ModelConfig, b: int, s: int):
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch = {"patches": _sds((b, cfg.num_patches, vlm.D_VIT), jnp.bfloat16),
                 "tokens": _sds((b, s - cfg.num_patches), jnp.int32)}
    return batch


def _batch_shardings(batch, rules, mesh, b):
    ax = _batch_axes_or_none(rules, mesh, b)
    return jax.tree.map(
        lambda l: NamedSharding(mesh, P(ax, *([None] * (l.ndim - 1)))), batch)


def build_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               seq_shard: bool = True, remat: bool | None = None,
               cfg=None, shape=None, enc_len: int | None = None,
               cache_axis: str = "seq", pin_out: bool = False,
               microbatches: int = 1) -> Cell:
    cfg = cfg if cfg is not None else configs.get(arch)
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    shape = shape if shape is not None else configs.SHAPES[shape_name]
    rules = partition.make_rules(cfg, mesh, fsdp=fsdp, seq_shard=seq_shard,
                                 cache_axis=cache_axis)

    pspec_tree = partition.tree_shardings(api.param_specs(cfg), rules, mesh)
    params_struct = _abstract(lambda: api.init_params(
        jax.random.PRNGKey(0), cfg))
    n_params = param_count(params_struct)

    if shape.kind == "train":
        ocfg = opt_mod.OptConfig(state_dtype=cfg.param_dtype)
        opt_struct = _abstract(lambda: opt_mod.init(params_struct_like(
            params_struct), ocfg))
        opt_shard = {
            "m": pspec_tree, "v": pspec_tree,
            "count": NamedSharding(mesh, P()),
        }
        batch = train_batch_struct(cfg, shape.global_batch, shape.seq_len)
        batch_shard = _batch_shardings(batch, rules, mesh, shape.global_batch)
        settings = steps.TrainSettings(microbatches=microbatches)
        step = steps.make_train_step(cfg, ocfg, settings)
        out_sh = (pspec_tree, opt_shard, None, None) if pin_out else None
        return Cell(arch=arch, shape=shape, cfg=cfg, step_fn=step,
                    args=(params_struct, opt_struct, batch, None),
                    in_shardings=(pspec_tree, opt_shard, batch_shard, None),
                    out_shardings=out_sh,
                    donate_argnums=(0, 1),
                    model_params_bytes=n_params)

    if shape.kind == "prefill":
        batch = train_batch_struct(cfg, shape.global_batch, shape.seq_len)
        batch_shard = _batch_shardings(batch, rules, mesh, shape.global_batch)
        step = steps.make_prefill_step(cfg, max_len=shape.seq_len)
        return Cell(arch=arch, shape=shape, cfg=cfg, step_fn=step,
                    args=(params_struct, batch),
                    in_shardings=(pspec_tree, batch_shard),
                    model_params_bytes=n_params)

    # decode
    b = shape.global_batch
    bax = _batch_axes_or_none(rules, mesh, b)
    if bax is None:  # tiny batches (long_500k B=1): replicate the batch dim
        rules = dataclasses.replace(rules, mapping=tuple(
            (k, None if k == "batch" else v) for k, v in rules.mapping))
    cache_struct = _abstract(lambda: api.init_cache(
        cfg, b, max_len=shape.seq_len, enc_len=enc_len or ENC_LEN))
    cache_shard = partition.tree_shardings(api.cache_specs(cfg), rules, mesh)
    vec = NamedSharding(mesh, P(bax))
    token = _sds((b,), jnp.int32)
    lengths = _sds((b,), jnp.int32)
    active = _sds((b,), jnp.int32)
    step = steps.make_serve_decode_step(cfg)
    out_sh = (None, cache_shard, vec) if pin_out else None
    return Cell(arch=arch, shape=shape, cfg=cfg, step_fn=step,
                args=(params_struct, cache_struct, token, lengths, active),
                in_shardings=(pspec_tree, cache_shard, vec, vec, vec),
                out_shardings=out_sh,
                donate_argnums=(1,),
                model_params_bytes=n_params)


def params_struct_like(struct):
    """eval_shape trees are already ShapeDtypeStructs — optimizer init only
    reads .shape, so pass through."""
    return struct
