"""End-to-end training driver.

Runs on whatever devices exist (1 CPU for the examples; the production
mesh on a real pod — the same code path, just a different mesh).
Features exercised here: synthetic data pipeline, AdamW, checkpointing
with auto-restore, NaN sentinel with retry-from-checkpoint, async saves,
optional gradient compression and microbatch accumulation.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import api
from ..training import checkpoint, compression, data, optimizer as opt_mod
from ..training.steps import TrainSettings, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-nan-at", type=int, default=-1,
                    help="fault-injection test hook")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    ocfg = opt_mod.OptConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                             total_steps=args.steps,
                             state_dtype=cfg.param_dtype)
    settings = TrainSettings(microbatches=args.microbatches,
                             compress_grads=args.compress_grads)

    key = jax.random.PRNGKey(args.seed)
    params = api.init_params(key, cfg)
    opt_state = opt_mod.init(params, ocfg)
    residual = compression.init_residual(params) if args.compress_grads else None
    start_step = 0

    if args.resume and args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start_step, _ = checkpoint.restore(
            args.ckpt_dir, (params, opt_state))
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, ocfg, settings),
                      donate_argnums=(0, 1))
    ds = data.SyntheticLM(cfg, args.batch, args.seq, seed=args.seed)

    losses = []
    pending_save = None
    t0 = time.time()
    step = start_step
    injected = False
    while step < args.steps:
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch(step).items()}
        if step == args.inject_nan_at and not injected:   # fault injection
            injected = True         # once: the restore path must not re-hit
            bad = jax.tree.map(
                lambda p: (p * jnp.nan).astype(p.dtype) if p.ndim else p,
                params)
            params = bad
        params, opt_state, residual, metrics = step_fn(
            params, opt_state, batch, residual)
        loss = float(metrics["loss"])
        finite = bool(metrics["finite"] > 0)
        if not finite:
            print(f"step {step}: NON-FINITE loss/grad — restoring")
            if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
                (params, opt_state), step, _ = checkpoint.restore(
                    args.ckpt_dir, (params, opt_state))
                continue
            else:
                params = api.init_params(key, cfg)  # cold restart
                opt_state = opt_mod.init(params, ocfg)
                continue
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = checkpoint.save_async(
                args.ckpt_dir, step + 1, (params, opt_state))
        step += 1
    if pending_save is not None:
        pending_save.join()
    if args.ckpt_dir:
        checkpoint.save(args.ckpt_dir, step, (params, opt_state))
    print(f"final loss {np.mean(losses[-10:]):.4f} "
          f"(first-10 mean {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
