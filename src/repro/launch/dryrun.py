"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
cell on 512 placeholder host devices, and extract the roofline terms.

The ``os.environ`` assignment below MUST stay ahead of any other import —
jax locks the device count at first init, and only the dry-run may see
512 devices (smoke tests and benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Per cell, emits JSON with:
  * compiled.memory_analysis()  — bytes/device proof-of-fit,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), which cost_analysis does not report.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback

import jax

from .. import configs
from . import mesh as mesh_mod
from . import specs as specs_mod

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b("
    + "|".join(_COLLECTIVES) + r")\b")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (optimized) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = None
        for c in _COLLECTIVES:
            if f" {c}(" in line or f"{c}-start(" in line or f"{c}-done(" in line:
                m = c
                break
        if m is None:
            continue
        if f"{m}-done(" in line:
            continue  # avoid double counting start/done pairs
        lhs = line.split("=", 1)[0] + "= " + line.split("=", 1)[1]
        shapes = _TUPLE_RE.findall(line.split(f" {m}")[0])
        total = sum(_nbytes(d, dims) for d, dims in shapes)
        out[m] += total
        count[m] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fsdp: bool = True, seq_shard: bool = True,
             remat: bool | None = None, extra_tag: str = "",
             pin_out: bool = False, cache_axis: str = "seq",
             microbatches: int = 1) -> dict:
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = mesh_mod.mesh_chips(mesh)
    t0 = time.time()
    cell = specs_mod.build_cell(arch, shape_name, mesh, fsdp=fsdp,
                                seq_shard=seq_shard, remat=remat,
                                pin_out=pin_out, cache_axis=cache_axis,
                                microbatches=microbatches)
    with mesh:
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums, **kw)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "kind": cell.shape.kind,
        "params": cell.model_params_bytes,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "tag": extra_tag,
    }
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {"flops": ca.get("flops"),
                       "bytes_accessed": ca.get("bytes accessed"),
                       "transcendentals": ca.get("transcendentals")}
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}
    try:
        rec["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--remat", choices=["on", "off"], default=None)
    ap.add_argument("--pin-out", action="store_true")
    ap.add_argument("--cache-axis", choices=["seq", "heads"], default="seq")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    if args.all:
        cells = [(a, s.name) for a, s, ok, _ in configs.cells() if ok]
    else:
        cells = [(args.arch, args.shape)]

    remat = None if args.remat is None else args.remat == "on"
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.tag:
                tag += f"__{args.tag}"
            fname = os.path.join(args.out, tag + ".json")
            if os.path.exists(fname):
                print(f"SKIP {tag} (cached)")
                continue
            try:
                rec = run_cell(arch, shape, multi_pod=mp,
                               fsdp=not args.no_fsdp,
                               seq_shard=not args.no_seq_shard,
                               remat=remat, extra_tag=args.tag,
                               pin_out=args.pin_out,
                               cache_axis=args.cache_axis,
                               microbatches=args.microbatches)
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                c = rec.get("cost", {})
                m = rec.get("memory", {})
                print(f"OK   {tag}: flops={c.get('flops'):.3e} "
                      f"coll={rec['collectives']['total_bytes']:.3e}B "
                      f"temp={m.get('temp_bytes')} "
                      f"({rec['lower_s']}s/{rec['compile_s']}s)")
            except Exception as e:
                n_fail += 1
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
