"""Serving driver: prefill + batched decode with dynamic-wavefront
request masking (the paper's TSC at request granularity).

Requests arrive with ragged prompt lengths; finished requests free their
slot mask immediately (no dead time) and new requests can be swapped in —
the continuous-batching analogue of eGPU's per-instruction thread-space
subsetting.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import api
from ..training.steps import make_serve_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    rng = np.random.default_rng(args.seed)
    b = args.requests
    params = api.init_params(jax.random.PRNGKey(args.seed), cfg)

    # ragged prompts, one batch
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (b, args.prompt_len)))
    batch = {"tokens": prompt}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, args.prompt_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, 1024)), jnp.float32)

    t0 = time.time()
    logits, cache, lengths = api.prefill(cfg, params, batch, args.max_len)
    print(f"prefill: {b} x {args.prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(make_serve_decode_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # ragged stop times: request i finishes after 4 + i tokens (demo of the
    # dynamic-wavefront mask — finished slots stop burning cache updates)
    stop_after = jnp.asarray(
        np.minimum(4 + np.arange(b), args.max_new), jnp.int32)
    out_tokens = [np.asarray(tok)]
    active = jnp.ones((b,), jnp.int32)
    t0 = time.time()
    for step in range(args.max_new):
        logits, cache, lengths = decode(params, cache, tok, lengths, active)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
        active = (jnp.asarray(step + 1, jnp.int32) < stop_after).astype(jnp.int32)
    dt = time.time() - t0
    toks = np.stack(out_tokens, 1)
    done = int(jnp.sum(stop_after))
    print(f"decode: {args.max_new} steps x {b} reqs in {dt:.2f}s "
          f"({done} useful tokens, {1e3*dt/args.max_new:.1f} ms/step)")
    print("sample continuation:", toks[0, :8].tolist())
    return toks


if __name__ == "__main__":
    main()
