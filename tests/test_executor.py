"""Instruction-semantics tests: the JAX executor vs numpy int64 oracles.

One shared small config keeps jit cache warm across the suite.
"""
import numpy as np
from _hyp import given, settings, st

from repro.core import Asm, EGPUConfig, Typ, run_program
from repro.core import machine as machine_mod

CFG = EGPUConfig(max_threads=32, regs_per_thread=16, shared_kb=2,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

U32 = lambda x: np.uint32(x & 0xFFFFFFFF)


def run_binop(op_emit, a_vals, b_vals, typ=Typ.I32):
    """Load per-thread a/b via shared memory, run op, read result col."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)        # ra values at shared[0:32]
    a.lod(3, 1, 32)       # rb values at shared[32:64]
    op_emit(a, 4, 2, 3, typ)
    a.sto(4, 1, 64)
    a.stop()
    img = a.assemble(threads_active=32)
    buf = np.zeros(128, np.uint32)
    buf[:32] = a_vals.astype(np.uint32)
    buf[32:64] = b_vals.astype(np.uint32)
    st_ = run_program(img, shared_init=buf, tdx_dim=32)
    assert int(st_.hazard_violations) == 0
    return machine_mod.shared_as_u32(st_)[64:96]


ints = st.integers(0, 0xFFFFFFFF)


@given(st.lists(ints, min_size=32, max_size=32),
       st.lists(ints, min_size=32, max_size=32))
@settings(max_examples=6, deadline=None)
def test_add_sub_match_two_complement(av, bv):
    a = np.array(av, np.uint32)
    b = np.array(bv, np.uint32)
    got = run_binop(lambda s, rd, ra, rb, t: s.add(rd, ra, rb, t), a, b)
    assert np.array_equal(got, (a + b).astype(np.uint32))
    got = run_binop(lambda s, rd, ra, rb, t: s.sub(rd, ra, rb, t), a, b)
    assert np.array_equal(got, (a - b).astype(np.uint32))


@given(st.lists(ints, min_size=32, max_size=32),
       st.lists(ints, min_size=32, max_size=32))
@settings(max_examples=5, deadline=None)
def test_mul16_and_mul24(av, bv):
    a = np.array(av, np.uint32)
    b = np.array(bv, np.uint32)
    a16 = (a & 0xFFFF).astype(np.int64)
    b16 = (b & 0xFFFF).astype(np.int64)
    got = run_binop(lambda s, rd, ra, rb, t: s.mul16lo(rd, ra, rb, t), a, b,
                    Typ.U32)
    assert np.array_equal(got, ((a16 * b16) & 0xFFFFFFFF).astype(np.uint32))
    got = run_binop(lambda s, rd, ra, rb, t: s.mul16hi(rd, ra, rb, t), a, b,
                    Typ.U32)
    assert np.array_equal(got, ((a16 * b16) >> 16).astype(np.uint32))
    # signed 24-bit high product
    def s24(x):
        x = x.astype(np.int64) & 0xFFFFFF
        return np.where(x >= 1 << 23, x - (1 << 24), x)
    p = s24(a) * s24(b)
    got = run_binop(lambda s, rd, ra, rb, t: s.mul24hi(rd, ra, rb, t), a, b,
                    Typ.I32)
    assert np.array_equal(got, ((p >> 24) & 0xFFFFFFFF).astype(np.uint32))


@given(st.lists(ints, min_size=32, max_size=32),
       st.lists(st.integers(0, 31), min_size=32, max_size=32))
@settings(max_examples=5, deadline=None)
def test_shifts(av, sh):
    a = np.array(av, np.uint32)
    s_ = np.array(sh, np.uint32)
    got = run_binop(lambda x, rd, ra, rb, t: x.shl(rd, ra, rb, t), a, s_,
                    Typ.U32)
    assert np.array_equal(got, (a.astype(np.int64) << s_).astype(np.uint32))
    got = run_binop(lambda x, rd, ra, rb, t: x.shr(rd, ra, rb, t), a, s_,
                    Typ.U32)
    assert np.array_equal(got, (a >> s_).astype(np.uint32))
    got = run_binop(lambda x, rd, ra, rb, t: x.shr(rd, ra, rb, t), a, s_,
                    Typ.I32)
    assert np.array_equal(got, (a.view(np.int32) >> s_).astype(np.int32).view(np.uint32))


@given(st.lists(ints, min_size=32, max_size=32))
@settings(max_examples=4, deadline=None)
def test_unary_ops(av):
    a = np.array(av, np.uint32)
    b = np.zeros(32, np.uint32)
    got = run_binop(lambda s, rd, ra, rb, t: s.pop(rd, ra), a, b)
    assert np.array_equal(got, np.array([bin(x).count("1") for x in a],
                                        np.uint32))
    got = run_binop(lambda s, rd, ra, rb, t: s.bvs(rd, ra), a, b)
    exp = np.array([int(f"{x:032b}"[::-1], 2) for x in a], np.uint32)
    assert np.array_equal(got, exp)
    got = run_binop(lambda s, rd, ra, rb, t: s.cnot(rd, ra), a, b)
    assert np.array_equal(got, (a == 0).astype(np.uint32))


def test_fp_ops_bitcast_through_registers():
    rng = np.random.default_rng(0)
    af = rng.standard_normal(32).astype(np.float32)
    bf = rng.standard_normal(32).astype(np.float32)
    a, b = af.view(np.uint32), bf.view(np.uint32)
    got = run_binop(lambda s, rd, ra, rb, t: s.fadd(rd, ra, rb), a, b)
    assert np.array_equal(got.view(np.float32), af + bf)
    got = run_binop(lambda s, rd, ra, rb, t: s.fmul(rd, ra, rb), a, b)
    assert np.array_equal(got.view(np.float32), af * bf)
    got = run_binop(lambda s, rd, ra, rb, t: s.fmax(rd, ra, rb), a, b)
    assert np.array_equal(got.view(np.float32), np.maximum(af, bf))


def test_max_min_signed_unsigned():
    a = np.array([0xFFFFFFFF, 5, 0x80000000, 7] * 8, np.uint32)
    b = np.array([1, 0xFFFFFFFE, 3, 7] * 8, np.uint32)
    got = run_binop(lambda s, rd, ra, rb, t: s.max_(rd, ra, rb, t), a, b,
                    Typ.I32)
    assert np.array_equal(got.view(np.int32),
                          np.maximum(a.view(np.int32), b.view(np.int32)))
    got = run_binop(lambda s, rd, ra, rb, t: s.max_(rd, ra, rb, t), a, b,
                    Typ.U32)
    assert np.array_equal(got, np.maximum(a, b))


def test_nested_predicates_and_else():
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 8)
    a.lodi(3, 4)
    a.if_("lt", 1, 2, typ=Typ.U32)        # t < 8
    a.if_("lt", 1, 3, typ=Typ.U32)        # t < 4
    a.lodi(4, 1)
    a.else_()
    a.lodi(4, 2)
    a.endif()
    a.else_()
    a.lodi(4, 3)
    a.endif()
    a.sto(4, 1, 0)
    a.stop()
    st_ = run_program(a.assemble(threads_active=32), tdx_dim=32)
    got = machine_mod.shared_as_u32(st_)[:32]
    exp = np.where(np.arange(32) < 4, 1, np.where(np.arange(32) < 8, 2, 3))
    assert np.array_equal(got, exp)
    assert int(st_.hazard_violations) == 0


def test_jsr_rts_and_nested_loops():
    a = Asm(CFG)
    a.lodi(1, 0)
    a.lodi(5, 1)
    with a.loop(3):
        with a.loop(4):
            a.jsr("incr")
    a.sto(1, 0, 10, tsc="mcu")
    a.stop()
    a.label("incr")
    a.add(1, 1, 5)
    a.rts()
    st_ = run_program(a.assemble(threads_active=32), tdx_dim=32)
    assert machine_mod.shared_as_u32(st_)[10] == 12
    assert int(st_.hazard_violations) == 0


def test_tsc_masks_issue_cycles():
    """Full-width store = 16 cycles/wavefront; MCU store = 1 (Table 3)."""
    def prog(tsc):
        a = Asm(CFG)
        a.tdx(1)
        a.sto(1, 1, 0, tsc=tsc)
        a.stop()
        return run_program(a.assemble(threads_active=32), tdx_dim=32)
    full = prog("full")          # 2 wavefronts x 16 = 32 cycles for STO
    mcu = prog("mcu")            # 1 cycle
    # subtract the common TDX + STOP cycles
    assert int(full.cycles) - int(mcu.cycles) == 31


def test_dot_and_sum_units():
    rng = np.random.default_rng(1)
    vals = rng.standard_normal(32).astype(np.float32)
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.sum_(3, 2)
    a.dot(4, 2, 2)
    a.sto(3, 0, 40, tsc="mcu")
    a.sto(4, 0, 41, tsc="mcu")
    a.stop()
    st_ = run_program(a.assemble(threads_active=32), shared_init=vals,
                      tdx_dim=32)
    out = machine_mod.shared_as_f32(st_)
    assert np.isclose(out[40], vals.sum(), rtol=1e-5)
    assert np.isclose(out[41], (vals * vals).sum(), rtol=1e-5)
    assert int(st_.hazard_violations) == 0


def test_hazard_checker_flags_unscheduled_raw():
    a = Asm(CFG)
    a.lodi(1, 7, tsc="mcu")
    a.add(2, 1, 1, tsc="mcu")    # reads r1 one cycle after LODI: hazard
    a.stop()
    img = a.assemble(threads_active=32, schedule_nops=False)
    st_ = run_program(img, tdx_dim=32)
    assert int(st_.hazard_violations) > 0
    img2 = a.assemble(threads_active=32, schedule_nops=True)
    st2 = run_program(img2, tdx_dim=32)
    assert int(st2.hazard_violations) == 0
    assert int(st2.cycles) > int(st_.cycles)   # the NOPs cost cycles
