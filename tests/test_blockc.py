"""Block-compiler tests: compiled execution vs the interpreter.

The contract under test: :func:`repro.core.blockc.run_compiled` (and the
fleet's compiled lock-step tier) produces final machine states
**bit-identical** to :func:`repro.core.executor.run_program` — registers,
shared memory, cycles, steps, PC, predicate/loop/call stacks,
instruction-mix stats, and the statically-baked hazard rows/violations —
across the whole program suite and the configuration space (16-bit ALU,
no-predicate, dp/qp memory).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (Asm, BlockCompileError, EGPUConfig, Op, Typ,
                        compile_program, run_compiled, run_program)
from repro.core import blockc
from repro.core import machine as machine_mod
from repro.fleet import Fleet, FleetScheduler
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose)

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

#: the satellite configuration axes: 16-bit ALU, no predicates, dp/qp
CONFIGS = {
    "dp": CFG,
    "qp": CFG.replace(memory_mode="qp"),
    "alu16": CFG.replace(alu_bits=16, shift_bits=16),
    "nopred": CFG.replace(predicate_levels=0),
}


def _assert_states_equal(ref, got, label):
    for leaf in ref._fields:
        r = np.asarray(getattr(ref, leaf))
        g = np.asarray(getattr(got, leaf))
        assert np.array_equal(r, g), f"{label}: {leaf} differs"


def _suite(cfg):
    """Every program in repro.programs that this config can assemble."""
    builders = [
        lambda: build_reduction(cfg, 32),
        lambda: build_reduction(cfg, 32, use_dot=True),
        lambda: build_reduction(cfg, 32, no_dynamic=True),
        lambda: build_transpose(cfg, 16),
        lambda: build_matmul(cfg, 8),
        lambda: build_bitonic(cfg, 16),
        lambda: build_fft(cfg, 16),
    ]
    out = []
    for b in builders:
        try:
            out.append(b())
        except ValueError:
            pass            # feature not present in this config
    return out


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_equivalence_sweep(name):
    """Acceptance: compiled == interpreted, bit for bit, every leaf,
    every suite program, every config axis — on both compiled tiers
    (``auto`` now prefers the superblock runner, so the basic-block
    driver is pinned explicitly with ``mode="blocks"``)."""
    cfg = CONFIGS[name]
    benches = _suite(cfg)
    assert benches, name
    for b in benches:
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        for mode in ("auto", "blocks"):
            got = run_compiled(b.image, shared_init=b.shared_init,
                               tdx_dim=b.tdx_dim, fallback=False, mode=mode)
            _assert_states_equal(ref, got, f"{name}/{b.name}/{mode}")


def test_equivalence_validate_false():
    """The fast path (no hazard checker, no stat counters) matches
    run_program(validate=False) exactly too."""
    b = build_reduction(CFG, 32)
    ref = run_program(b.image, validate=False, shared_init=b.shared_init,
                      tdx_dim=b.tdx_dim)
    got = run_compiled(b.image, validate=False, shared_init=b.shared_init,
                       tdx_dim=b.tdx_dim, fallback=False)
    _assert_states_equal(ref, got, "validate=False")


def test_control_flow_corners():
    """JSR/RTS nesting, nested predicates with ELSE, and a LOOP chain —
    the block boundaries the compiler must cut at."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 8)
    a.lodi(5, 1)
    a.lodi(6, 0)
    a.if_("lt", 1, 2, typ=Typ.U32)
    with a.loop(3):
        a.jsr("incr")
    a.else_()
    a.lodi(6, 99)
    a.endif()
    a.sto(6, 1, 0)
    a.stop()
    a.label("incr")
    a.add(6, 6, 5)
    a.rts()
    img = a.assemble(threads_active=32)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False)
    _assert_states_equal(ref, got, "control-flow")
    # ... and the program actually diverged per-thread
    out = machine_mod.shared_as_u32(got)[:32]
    exp = np.where(np.arange(32) < 8, 3, 99)
    assert np.array_equal(out, exp)


def test_hazard_violations_baked_statically():
    """An unscheduled RAW program: the statically-computed violation
    count and hazard rows equal the interpreter's dynamic checker."""
    a = Asm(CFG)
    a.lodi(1, 7, tsc="mcu")
    a.add(2, 1, 1, tsc="mcu")      # reads r1 one cycle after LODI: hazard
    a.stop()
    img = a.assemble(threads_active=32, schedule_nops=False)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False)
    assert int(ref.hazard_violations) > 0
    _assert_states_equal(ref, got, "hazard")


def test_non_halting_program_falls_back():
    """A program that never halts within max_steps is rejected by the
    compiler and routed to the interpreter by run_compiled."""
    cfg = CFG.replace(max_steps=64)
    a = Asm(cfg)
    a.label("spin")
    a.add(1, 1, 1)
    a.jmp("spin")
    img = a.assemble(threads_active=32)
    with pytest.raises(BlockCompileError):
        compile_program(img)
    # the rejection is negative-cached: the second attempt must raise
    # without re-walking the static path (no way to observe the walk
    # directly, but the cached object identity is pinned)
    with pytest.raises(BlockCompileError):
        compile_program(img)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32)      # fallback=True default
    _assert_states_equal(ref, got, "fallback")
    assert int(got.steps) == 64


def test_predicate_ops_in_predicate_less_config():
    """The interpreter emulates a one-level predicate stack even when
    cfg.predicate_levels == 0 (D clamps to 1); the compiler must too.
    The assembler's if_ helper refuses such programs, so emit raw."""
    cfg = EGPUConfig(max_threads=32, regs_per_thread=16, shared_kb=2,
                     predicate_levels=0)
    a = Asm(cfg)
    a.tdx(1)
    a.lodi(2, 8)
    a.emit(Op.IF_LT, ra=1, rb=2, typ=Typ.U32)
    a.lodi(3, 1)
    a.emit(Op.ELSE)
    a.lodi(3, 2)
    a.emit(Op.ENDIF)
    a.sto(3, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False)
    _assert_states_equal(ref, got, "nopred-if")


def test_jmp_into_stop_padding():
    """A JMP past the last instruction lands in the padded STOP tail;
    the compiler's shared pad block must mirror the interpreter."""
    a = Asm(CFG)
    a.lodi(1, 5)
    a.jmp(40)                      # into the [n, padded_len) STOP rows
    a.stop()
    img = a.assemble(threads_active=32, schedule_nops=False)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False)
    _assert_states_equal(ref, got, "pad-jmp")
    assert bool(got.halted)


def _tiny_prog(value: int):
    a = Asm(CFG)
    a.lodi(1, value)
    a.sto(1, 0, 0)
    a.stop()
    return a.assemble(threads_active=32)


def test_compile_cache_is_lru_not_fifo():
    """A cache hit moves the entry to the back of the eviction queue, so
    a hot program survives while cold entries are evicted first."""
    imgs = [_tiny_prog(v) for v in (101, 102, 103)]
    old_max, old_cache = blockc._CACHE_MAX, dict(blockc._CACHE)
    blockc._CACHE.clear()
    blockc._CACHE_MAX = 2
    try:
        cp_a = compile_program(imgs[0])
        cp_b = compile_program(imgs[1])
        assert compile_program(imgs[0]) is cp_a    # hit: A moves to back
        cp_c = compile_program(imgs[2])            # evicts B (LRU), not A
        assert compile_program(imgs[0]) is cp_a    # A survived the evict
        assert compile_program(imgs[2]) is cp_c
        assert compile_program(imgs[1]) is not cp_b    # B was recompiled
    finally:
        blockc._CACHE_MAX = old_max
        blockc._CACHE.clear()
        blockc._CACHE.update(old_cache)


def test_explicit_zero_threads_rejected():
    """``threads=0`` must raise, not silently fall back to the image
    default (the old ``threads or image.threads_active`` behaviour)."""
    img = _tiny_prog(7)
    with pytest.raises(ValueError, match="thread count"):
        compile_program(img, 0)
    with pytest.raises(ValueError, match="thread count"):
        run_compiled(img, threads=0)
    with pytest.raises(ValueError, match="thread count"):
        run_compiled(img, threads=-16)
    sched = FleetScheduler(CFG, batch_size=2)
    with pytest.raises(ValueError, match="thread count"):
        sched.submit(img, threads=0)
    # None still means "the image default"
    assert compile_program(img, None).threads == img.threads_active


# ---------------------------------------------------------------------------
# Fleet: the compiled lock-step tier
# ---------------------------------------------------------------------------

def test_fleet_groups_same_program_jobs():
    """Same-program jobs (different data) run the compiled tier; the
    per-job results are bit-identical to run_program."""
    b = build_reduction(CFG, 32)
    rng = np.random.default_rng(7)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(9)]
    fleet = Fleet(CFG, batch_size=4)
    hs = [fleet.submit(b.image, d, tdx_dim=b.tdx_dim) for d in datas]
    results = fleet.drain()
    assert fleet.stats.compiled_jobs == 9
    assert fleet.stats.jobs == 9
    # 9 jobs at batch 4 -> chunks 4+4+1 (pow2 buckets), all compiled
    assert fleet.stats.compiled_batches == 3
    for d, h in zip(datas, hs):
        ref = run_program(b.image, shared_init=d, tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())
        assert int(ref.cycles) == results[h].cycles
        assert int(ref.steps) == results[h].steps
        assert results[h].profile() == machine_mod.profile(ref)
        assert results[h].hazard_violations == 0


def test_fleet_mixed_batch_falls_back_to_interpreter():
    """Below compile_min, or with per-job thread counts differing, jobs
    stay on the interpreter tier — and results still match."""
    b1 = build_reduction(CFG, 32)
    b2 = build_transpose(CFG, 16)
    sched = FleetScheduler(CFG, batch_size=4, compile_min=2)
    h1 = sched.submit(b1.image, b1.shared_init, tdx_dim=b1.tdx_dim)
    h2 = sched.submit(b2.image, b2.shared_init, tdx_dim=b2.tdx_dim)
    results = sched.drain()
    assert sched.stats.compiled_jobs == 0      # singletons: interpreter
    for b, h in ((b1, h1), (b2, h2)):
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32()), b.name


def test_fleet_mixed_tiers_in_one_drain():
    """A drain mixing a compiled group with interpreter leftovers."""
    b1 = build_reduction(CFG, 32)
    b2 = build_transpose(CFG, 16)
    b3 = build_fft(CFG, 16)
    fleet = Fleet(CFG, batch_size=8, compile_min=3)
    handles = []
    jobs = [b1, b1, b1, b1, b2, b3]            # 4x same program + 2 mixed
    for b in jobs:
        handles.append(fleet.submit(b.image, b.shared_init,
                                    tdx_dim=b.tdx_dim))
    results = fleet.drain()
    assert fleet.stats.compiled_jobs == 4
    assert fleet.stats.jobs == 6
    for b, h in zip(jobs, handles):
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32()), b.name
        assert int(ref.cycles) == results[h].cycles


def test_compiled_batch_tdx_dims_vary():
    """TDX grid is per-job data even on the compiled tier."""
    a = Asm(CFG)
    a.tdx(1)
    a.tdy(2)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    fleet = Fleet(CFG, batch_size=4)
    hs = [fleet.submit(img, tdx_dim=d) for d in (4, 8, 16, 32)]
    results = fleet.drain()
    assert fleet.stats.compiled_jobs == 4
    for d, h in zip((4, 8, 16, 32), hs):
        ref = run_program(img, tdx_dim=d)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32()), d


# ---------------------------------------------------------------------------
# Property test: random straight-line programs
# ---------------------------------------------------------------------------

_ALU = [Op.ADD, Op.SUB, Op.NEG, Op.ABS, Op.MUL16LO, Op.MUL16HI,
        Op.MUL24LO, Op.MUL24HI, Op.AND, Op.OR, Op.XOR, Op.NOT, Op.CNOT,
        Op.BVS, Op.SHL, Op.SHR, Op.POP, Op.MAX, Op.MIN, Op.FADD, Op.FSUB,
        Op.FNEG, Op.FABS, Op.FMUL, Op.FMAX, Op.FMIN, Op.LOD, Op.STO,
        Op.LODI, Op.TDX, Op.TDY]

instr_st = st.tuples(st.sampled_from(_ALU), st.sampled_from([Typ.U32,
                                                             Typ.I32]),
                     st.integers(0, 31), st.integers(0, 31),
                     st.integers(0, 31), st.integers(-64, 64))


@given(st.lists(instr_st, min_size=1, max_size=40),
       st.lists(st.integers(0, 0xFFFFFFFF), min_size=32, max_size=32))
@settings(max_examples=10, deadline=None)
def test_random_straight_line_programs_match(instrs, seed_words):
    """Hypothesis: arbitrary straight-line op soup (random registers,
    random immediates, aliasing reads/writes, out-of-range addresses)
    is bit-identical between the two tiers."""
    a = Asm(CFG)
    for (op, typ, rd, ra, rb, imm) in instrs:
        a.emit(op, typ=typ, rd=rd, ra=ra, rb=rb,
               imm=imm if op in (Op.LOD, Op.STO, Op.LODI) else 0)
    a.stop()
    img = a.assemble(threads_active=32)
    buf = np.array(seed_words, np.uint32)
    ref = run_program(img, shared_init=buf, tdx_dim=16)
    got = run_compiled(img, shared_init=buf, tdx_dim=16, fallback=False)
    _assert_states_equal(ref, got, "random")
