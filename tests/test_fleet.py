"""Fleet-engine tests: batched multi-core execution vs sequential runs.

The contract under test: a fleet of N homogeneous cores running
heterogeneous jobs in ONE vmapped dispatch produces results bit-identical
to N sequential ``run_program`` calls — shared memory, cycle counts,
step counts, instruction-mix profile, and zero hazard violations.
"""
import numpy as np
import pytest

from repro.core import Asm, EGPUConfig, Typ, run_program
from repro.core import machine as machine_mod
from repro.fleet import Fleet, FleetScheduler, fleet_run, run_jobs, \
    unstack_state
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose)

CFG = EGPUConfig(max_threads=64, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)


def _suite():
    """The full paper suite + dynamic-scalability variants (per-job
    thread counts differ: 16..64)."""
    return [
        build_reduction(CFG, 32),
        build_reduction(CFG, 32, use_dot=True),
        build_reduction(CFG, 32, no_dynamic=True),
        build_reduction(CFG, 64),
        build_transpose(CFG, 16),
        build_matmul(CFG, 16),
        build_bitonic(CFG, 32),
        build_fft(CFG, 32),
    ]


def test_32_core_fleet_bit_identical_to_sequential():
    """Acceptance: >= 32 heterogeneous jobs, one vmapped dispatch per
    batch, bit-identical shared memory / cycles / steps, zero hazards.

    ``use_compiler=False`` pins the interpreter tier's packing contract;
    the block-compiled tier has its own suite in ``test_blockc.py``.
    """
    benches = _suite()
    jobs = [benches[i % len(benches)] for i in range(32)]
    fleet = Fleet(CFG, batch_size=32, use_compiler=False)
    handles = [fleet.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim,
                            tag=b.name) for b in jobs]
    results = fleet.drain()
    assert fleet.stats.batches == 1          # one dispatch for all 32
    assert fleet.stats.jobs == 32
    assert fleet.stats.compiled_jobs == 0
    for b, h in zip(jobs, handles):
        st = run_program(b.image, shared_init=b.shared_init,
                         tdx_dim=b.tdx_dim)
        r = results[h]
        assert np.array_equal(machine_mod.shared_as_u32(st),
                              r.shared_u32()), b.name
        assert int(st.cycles) == r.cycles, b.name
        assert int(st.steps) == r.steps, b.name
        assert r.hazard_violations == 0, b.name
        assert r.profile() == machine_mod.profile(st), b.name


def test_fleet_oracles_still_hold():
    """The fleet results also satisfy each benchmark's NumPy oracle
    (checked through each bench's own result view, fed from the fleet's
    shared memory)."""
    benches = _suite()
    results = run_jobs(CFG, [dict(image=b.image, shared_init=b.shared_init,
                                  tdx_dim=b.tdx_dim) for b in benches])

    class _View:
        def __init__(self, shared_u32):
            self.shared = shared_u32

    for b, r in zip(benches, results):
        exp = np.asarray(b.oracle(b.shared_init))
        got = np.asarray(b.result_view(_View(r.shared_u32())))
        if exp.dtype.kind == "f":
            assert np.allclose(got, exp, atol=b.atol, rtol=b.rtol), b.name
        else:
            assert np.array_equal(got, exp), b.name


def test_mixed_thread_counts_and_personalities():
    """One batch mixing runtime thread counts (static scalability) and
    per-instruction TSC personalities (dynamic scalability)."""
    def prog(tsc, value):
        a = Asm(CFG)
        a.tdx(1)
        a.lodi(2, value, tsc=tsc)
        a.sto(2, 1, 0, tsc=tsc)
        a.stop()
        return a

    cases = [("full", 11, 64), ("full", 12, 32), ("wf0", 13, 64),
             ("cpu", 14, 32), ("mcu", 15, 16), ("quarter", 16, 48)]
    fleet = Fleet(CFG, batch_size=8)
    handles = []
    images = []
    for tsc, value, threads in cases:
        img = prog(tsc, value).assemble(threads_active=threads)
        images.append(img)
        handles.append(fleet.submit(img, threads=threads, tdx_dim=threads,
                                    tag=tsc))
    results = fleet.drain()
    for (tsc, value, threads), img, h in zip(cases, images, handles):
        st = run_program(img, tdx_dim=threads)
        r = results[h]
        assert np.array_equal(machine_mod.shared_as_u32(st),
                              r.shared_u32()), tsc
        assert int(st.cycles) == r.cycles, tsc
        assert r.hazard_violations == 0


def test_scheduler_packs_partial_batches():
    """5 jobs at batch 4 -> two dispatches, filler slots excluded
    (interpreter tier; the compiled tier pads with same-program slots
    and is covered in ``test_blockc.py``)."""
    b = build_reduction(CFG, 32)
    sched = FleetScheduler(CFG, batch_size=4, use_compiler=False)
    hs = [sched.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
          for _ in range(5)]
    assert sched.pending == 5
    results = sched.drain()
    assert sched.pending == 0
    assert sched.stats.batches == 2
    assert sched.stats.pad_slots == 3
    assert sorted(results) == sorted(hs)
    ref = run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    for h in hs:
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())
    assert sched.stats.jobs == 5
    assert sched.stats.jobs_per_sec > 0


def test_fleet_run_low_level_unstack():
    """engine.fleet_run returns the batched state; unstack slices cores."""
    b1 = build_reduction(CFG, 32)
    b2 = build_transpose(CFG, 16)
    final = fleet_run([b1.image, b2.image],
                      init_kw=[dict(shared_init=b1.shared_init,
                                    tdx_dim=b1.tdx_dim),
                               dict(shared_init=b2.shared_init,
                                    tdx_dim=b2.tdx_dim)])
    for i, b in enumerate((b1, b2)):
        st = run_program(b.image, shared_init=b.shared_init,
                         tdx_dim=b.tdx_dim)
        core = unstack_state(final, i)
        assert np.array_equal(np.asarray(core.shared),
                              machine_mod.shared_as_u32(st))
        assert int(core.cycles) == int(st.cycles)


def test_drain_requeues_jobs_when_compiled_batch_raises(monkeypatch):
    """Crash safety: a batch failure mid-drain must not lose queued jobs.
    The failing batch and everything after it go back on the queue (in
    submission order) and a later drain retries them successfully."""
    from repro.core.blockc import CompiledProgram

    b = build_reduction(CFG, 32)
    rng = np.random.default_rng(5)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(6)]
    fleet = Fleet(CFG, batch_size=2)
    hs = [fleet.submit(b.image, d, tdx_dim=b.tdx_dim) for d in datas]

    calls = {"n": 0}
    real_run_light = CompiledProgram.run_light_dev

    def failing_run_light(self, shared, tdx_dims, device=None):
        calls["n"] += 1
        if calls["n"] == 2:                 # second batch of the drain
            raise RuntimeError("injected batch failure")
        return real_run_light(self, shared, tdx_dims, device)

    monkeypatch.setattr(CompiledProgram, "run_light_dev", failing_run_light)
    with pytest.raises(RuntimeError, match="injected"):
        fleet.drain()
    # first batch (2 jobs) completed — its results are stashed for the
    # next drain; the other 4 are back on the queue.  Nothing lost.
    assert fleet.pending == 4
    monkeypatch.setattr(CompiledProgram, "run_light_dev", real_run_light)
    results = fleet.drain()
    assert sorted(results) == sorted(hs)      # salvaged + retried
    for d, h in zip(datas, hs):
        ref = run_program(b.image, shared_init=d, tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())


def test_drain_requeues_jobs_when_interpreter_batch_raises(monkeypatch):
    """Same contract on the interpreter tier (singletons below
    compile_min), including a failure on the very first batch."""
    import repro.fleet.scheduler as sched_mod

    b1 = build_reduction(CFG, 32)
    b2 = build_transpose(CFG, 16)
    fleet = Fleet(CFG, batch_size=4)
    h1 = fleet.submit(b1.image, b1.shared_init, tdx_dim=b1.tdx_dim)
    h2 = fleet.submit(b2.image, b2.shared_init, tdx_dim=b2.tdx_dim)

    def boom(*a, **k):
        raise RuntimeError("interpreter tier down")

    monkeypatch.setattr(sched_mod, "fleet_run", boom)
    with pytest.raises(RuntimeError, match="tier down"):
        fleet.drain()
    assert fleet.pending == 2
    monkeypatch.undo()
    results = fleet.drain()
    assert sorted(results) == sorted([h1, h2])
    for b, h in ((b1, h1), (b2, h2)):
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32()), b.name


def test_compiled_tier_pow2_bucketing_and_padding():
    """The compiled tier pads chunks to the next power of two with
    same-program filler slots: padded slots must never leak into the
    results dict, and pad_slots/compiled_batches must stay consistent
    across chunk splits (11 jobs at batch 4 -> chunks 4+4+3, the last
    bucketed to 4 with 1 pad slot)."""
    b = build_reduction(CFG, 32)
    rng = np.random.default_rng(9)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(11)]
    sched = FleetScheduler(CFG, batch_size=4)
    hs = [sched.submit(b.image, d, tdx_dim=b.tdx_dim) for d in datas]
    results = sched.drain()
    assert sched.stats.compiled_jobs == 11
    assert sched.stats.compiled_batches == 3
    assert sched.stats.pad_slots == 1
    assert sched.stats.jobs == 11
    # exactly the submitted handles — no filler handle (-1), no dupes
    assert sorted(results) == sorted(hs)
    assert -1 not in results
    for d, h in zip(datas, hs):
        ref = run_program(b.image, shared_init=d, tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())

    # a 3-job drain buckets to 4 (pow2), again without leaking the pad
    sched2 = FleetScheduler(CFG, batch_size=8)
    hs2 = [sched2.submit(b.image, d, tdx_dim=b.tdx_dim)
           for d in datas[:3]]
    results2 = sched2.drain()
    assert sched2.stats.compiled_batches == 1
    assert sched2.stats.pad_slots == 1          # 3 -> pow2 bucket 4
    assert sorted(results2) == sorted(hs2)


def test_fleet_rejects_mismatched_config():
    other = EGPUConfig(max_threads=32, regs_per_thread=16, shared_kb=2)
    a = Asm(other)
    a.stop()
    img = a.assemble()
    fleet = Fleet(CFG)
    with pytest.raises(ValueError):
        fleet.submit(img)


def test_submit_validates_shared_init_fail_fast():
    """Regression: a malformed ``shared_init`` (over-length or an
    unpackable dtype) raises ``ValueError`` at submit time and leaves
    the queue untouched — it must never reach a drain, where the shape
    or cast error would take the whole batch down with it."""
    a = Asm(CFG)
    a.stop()
    img = a.assemble()
    fleet = Fleet(CFG, batch_size=4)
    with pytest.raises(ValueError, match="exceeds"):
        fleet.submit(img, np.zeros(CFG.shared_words + 1, np.float32))
    with pytest.raises(ValueError, match="dtype"):
        fleet.submit(img, np.zeros(8, np.complex64))
    with pytest.raises(ValueError, match="thread count"):
        fleet.submit(img, threads=CFG.num_sps + 1)
    assert fleet.pending == 0
    h = fleet.submit(img, np.zeros(8, np.float32))  # valid job still fine
    assert fleet.pending == 1
    assert h in fleet.drain()


def _loop_prog(iters=64):
    """Same-program loop job for the compiled/superblock fleet tiers."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    with a.loop(iters):
        a.fadd(2, 2, 2)
    a.sto(2, 1, 0)
    a.stop()
    return a.assemble(threads_active=32)


def test_residency_cache_hits_on_repeat_drains():
    """Repeat drains of the same program over the same inputs replay
    the device-resident batch (nonzero hits), changed inputs miss, and
    results stay bit-identical to the interpreter throughout."""
    img = _loop_prog()
    rng = np.random.default_rng(21)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(4)]
    fleet = Fleet(CFG, batch_size=4)

    def drain_and_check(batch_datas):
        hs = [fleet.submit(img, d, tdx_dim=32) for d in batch_datas]
        results = fleet.drain()
        for d, h in zip(batch_datas, hs):
            ref = run_program(img, shared_init=d, tdx_dim=32)
            assert np.array_equal(machine_mod.shared_as_u32(ref),
                                  results[h].shared_u32())
            assert int(ref.cycles) == results[h].cycles
            assert int(ref.steps) == results[h].steps
            assert results[h].profile() == machine_mod.profile(ref)

    drain_and_check(datas)
    assert fleet.stats.residency_hits == 0
    assert fleet.stats.residency_misses == 1
    drain_and_check(datas)                    # identical content: replay
    drain_and_check(datas)
    assert fleet.stats.residency_hits == 2
    assert fleet.stats.residency_misses == 1
    drain_and_check([d + 1 for d in datas])   # new content: transfer
    assert fleet.stats.residency_hits == 2
    assert fleet.stats.residency_misses == 2


def test_residency_cache_invalidated_with_compile_cache():
    """A recompiled program (compile-cache eviction) must not replay
    stale device buffers: the residency entry is keyed to the exact
    CompiledProgram object it was built against."""
    from repro.core import blockc

    img = _loop_prog()
    rng = np.random.default_rng(22)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(4)]
    fleet = Fleet(CFG, batch_size=4)
    for _ in range(2):
        hs = [fleet.submit(img, d, tdx_dim=32) for d in datas]
        results = fleet.drain()
    assert fleet.stats.residency_hits == 1
    blockc._CACHE.clear()                     # force a recompile
    hs = [fleet.submit(img, d, tdx_dim=32) for d in datas]
    results = fleet.drain()
    assert fleet.stats.residency_hits == 1    # no stale replay
    assert fleet.stats.residency_misses == 2
    ref = run_program(img, shared_init=datas[0], tdx_dim=32)
    assert np.array_equal(machine_mod.shared_as_u32(ref),
                          results[hs[0]].shared_u32())


def test_residency_cache_lru_bound():
    """The cache never exceeds its bound; evicted batches just
    re-transfer (a miss, never an error)."""
    img = _loop_prog()
    rng = np.random.default_rng(23)
    fleet = Fleet(CFG, batch_size=2, residency_max=2)
    batches = [[rng.standard_normal(32).astype(np.float32)
                for _ in range(2)] for _ in range(4)]
    for batch_datas in batches:               # 4 distinct batch contents
        for d in batch_datas:
            fleet.submit(img, d, tdx_dim=32)
        fleet.drain()
    assert len(fleet._sched._residency) <= 2
    assert fleet.stats.residency_misses == 4
    # the two youngest entries are still resident
    for batch_datas in batches[-2:]:
        for d in batch_datas:
            fleet.submit(img, d, tdx_dim=32)
        fleet.drain()
    assert fleet.stats.residency_hits == 2


def test_stats_consistent_after_failed_then_salvaged_drain(monkeypatch):
    """Regression: across a failed drain and the delivering drain, every
    job is counted into jobs/wall_s/tier counters exactly once, and the
    delivered-but-precomputed results are reported via salvaged_jobs so
    per-drain consumers don't double-dip the timing."""
    from repro.core.blockc import CompiledProgram

    img = _loop_prog()
    rng = np.random.default_rng(31)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(6)]
    fleet = Fleet(CFG, batch_size=2)
    hs = [fleet.submit(img, d, tdx_dim=32) for d in datas]

    calls = {"n": 0}
    real = CompiledProgram.run_light_dev

    def failing2(self, shared, tdx, device=None):
        calls["n"] += 1
        if calls["n"] in (2, 4):
            raise RuntimeError("injected")
        return real(self, shared, tdx, device)

    monkeypatch.setattr(CompiledProgram, "run_light_dev", failing2)
    with pytest.raises(RuntimeError):
        fleet.drain()
    s = fleet.stats
    # only the successfully executed batch is accounted
    assert s.jobs == s.compiled_jobs == s.superblock_jobs == 2
    assert s.batches == s.compiled_batches == 1
    assert s.salvaged_jobs == 0               # computed, not yet delivered
    wall_after_fail = s.wall_s
    assert wall_after_fail > 0
    # the unfinished jobs are re-queued in submission order, once each
    assert [j.handle for j in fleet._sched._queue] == hs[2:]

    # second consecutive failing drain: the first stash must survive,
    # the batch that just ran (call 3) joins it, and nothing from either
    # failed drain is double-counted
    with pytest.raises(RuntimeError):
        fleet.drain()
    assert s.jobs == s.compiled_jobs == s.superblock_jobs == 4
    assert s.batches == s.compiled_batches == 2
    assert s.salvaged_jobs == 0               # still undelivered
    assert [j.handle for j in fleet._sched._queue] == hs[4:]

    monkeypatch.setattr(CompiledProgram, "run_light_dev", real)
    results = fleet.drain()
    assert sorted(results) == sorted(hs)
    # each of the 6 jobs counted exactly once across all three drains;
    # the 4 salvaged results added no second helping of jobs/wall time
    assert s.jobs == s.compiled_jobs == s.superblock_jobs == 6
    assert s.batches == s.compiled_batches == 3
    assert s.salvaged_jobs == 4
    assert s.jobs_per_sec == pytest.approx(s.jobs / s.wall_s)
    for d, h in zip(datas, hs):
        ref = run_program(img, shared_init=d, tdx_dim=32)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())


def test_alu16_masks_lodi_tdx_tdy():
    """16-bit ALU configs clip LODI/TDX/TDY through the integer-ALU width
    mask (the once-dead ``alu_bits < 32`` path in the executor)."""
    cfg16 = EGPUConfig(max_threads=32, regs_per_thread=16, shared_kb=2,
                       alu_bits=16, shift_bits=16)
    a = Asm(cfg16)
    a.lodi(1, -1)          # sign-extends to 0xFFFFFFFF on a 32-bit ALU
    a.tdx(2)
    a.sto(1, 2, 0)
    a.stop()
    st = run_program(a.assemble(threads_active=32), tdx_dim=32)
    got = machine_mod.shared_as_u32(st)[:32]
    assert (got == 0xFFFF).all()       # clipped to 16 bits, not 0xFFFFFFFF

    # ... and arithmetic on the masked value stays mod-2^16
    a = Asm(cfg16)
    a.lodi(1, -1)
    a.lodi(2, 1)
    a.add(3, 1, 2, typ=Typ.U32)
    a.tdx(4)
    a.sto(3, 4, 0)
    a.stop()
    st = run_program(a.assemble(threads_active=32), tdx_dim=32)
    assert machine_mod.shared_as_u32(st)[0] == 0     # 0xFFFF + 1 == 0
