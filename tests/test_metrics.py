"""Telemetry tests: the metrics registry (thread safety, histogram
percentile math, rolling windows, snapshot/Prometheus round-trips), the
flight recorder (ring boundedness, blackbox dumps), stats-as-views over
the registry, bit-identity with telemetry on/off across all three
tiers, and the chaos path that turns a watchdog reset into a loadable
blackbox."""
import json
import threading

import numpy as np
import pytest

from repro.core import Asm, EGPUConfig, run_program
from repro.core import machine as machine_mod
from repro.core.blockc import TierPolicy
from repro.fleet import (FaultPlan, FleetScheduler, FleetService, JobError)
from repro.obs import trace as obs_trace
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, MetricsRegistry,
                               MetricsSnapshot)
from repro.obs.recorder import FlightRecorder

CFG = EGPUConfig(max_threads=64, regs_per_thread=32, shared_kb=4,
                 predicate_levels=4, has_dot=True, has_invsqr=True)


def _loop_prog(iters=16):
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    with a.loop(iters):
        a.fadd(2, 2, 2)
    a.sto(2, 1, 0)
    a.stop()
    return a.assemble(threads_active=32)


def _datas(n, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(32).astype(np.float32) for _ in range(n)]


def _refs(img, datas):
    return [machine_mod.shared_as_u32(
        run_program(img, shared_init=d, tdx_dim=32)) for d in datas]


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_basics_and_errors():
    reg = MetricsRegistry()
    reg.inc("a_total", 2)
    reg.inc("a_total")
    assert reg.value("a_total") == 3
    with pytest.raises(ValueError):
        reg.inc("a_total", -1)                   # counters are monotonic
    reg.set_gauge("g", 7)
    reg.set_gauge("g", 3)
    assert reg.value("g") == 3
    with pytest.raises(ValueError):
        reg.gauge("a_total")                     # kind conflict
    reg.counter("b_total", labelnames=("x",))
    with pytest.raises(ValueError):
        reg.counter("b_total", labelnames=("y",))   # labelname conflict
    with pytest.raises(ValueError):
        reg.inc("b_total")                       # missing label value


def test_label_totals_and_filters():
    reg = MetricsRegistry()
    reg.inc("jobs_total", 3, tier="interp", program="p0")
    reg.inc("jobs_total", 4, tier="blocks", program="p0")
    reg.inc("jobs_total", 5, tier="blocks", program="p1")
    assert reg.total("jobs_total") == 12
    assert reg.total("jobs_total", tier="blocks") == 9
    assert reg.total("jobs_total", tier="blocks", program="p1") == 5
    assert reg.total("jobs_total", tier="nope") == 0
    assert reg.total("missing_total") == 0


def test_registry_thread_safety_exact_counts():
    reg = MetricsRegistry()
    reg.counter("c_total", labelnames=("w",))
    reg.histogram("h_seconds")
    n_threads, n_iter = 8, 500

    def work(w):
        for i in range(n_iter):
            reg.inc("c_total", w=w)              # per-thread child
            reg.inc("c_total", w="all")          # contended child
            reg.observe("h_seconds", 0.001 * (i % 7 + 1))

    ths = [threading.Thread(target=work, args=(str(k),))
           for k in range(n_threads)]
    for t in ths:
        t.start()
    for t in ths:
        t.join()
    assert reg.total("c_total", w="all") == n_threads * n_iter
    assert reg.total("c_total") == 2 * n_threads * n_iter
    snap = reg.snapshot()
    assert snap.hist_count("h_seconds") == n_threads * n_iter


def _bucket_span(v):
    lo = 0.0
    for edge in DEFAULT_TIME_BUCKETS:
        if v <= edge:
            return edge - lo
        lo = edge
    return DEFAULT_TIME_BUCKETS[-1]


def test_histogram_percentiles_vs_exact():
    reg = MetricsRegistry()
    rng = np.random.default_rng(11)
    vals = rng.uniform(0.001, 0.5, 500)
    for v in vals:
        reg.observe("lat_seconds", float(v))
    snap = reg.snapshot()
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        est = snap.percentile("lat_seconds", q)
        # bucket interpolation: within the containing bucket's width
        # (one neighbour of slack for the rank convention)
        assert abs(est - exact) <= 2 * _bucket_span(exact), (q, est, exact)
    # +Inf observations clamp to the last finite edge
    reg2 = MetricsRegistry()
    reg2.observe("big_seconds", 100.0)
    assert reg2.snapshot().percentile("big_seconds", 0.99) == \
        DEFAULT_TIME_BUCKETS[-1]
    assert reg.snapshot().percentile("absent", 0.5) is None


def test_count_le_is_conservative():
    reg = MetricsRegistry()
    for _ in range(10):
        reg.observe("lat_seconds", 0.03)         # bucket (0.025, 0.05]
    snap = reg.snapshot()
    assert snap.count_le("lat_seconds", 0.05) == 10   # edge included
    assert snap.count_le("lat_seconds", 0.04) == 0    # never overcounts


def test_rolling_window_with_fake_clock():
    clk = {"t": 0.0}
    reg = MetricsRegistry(clock=lambda: clk["t"])
    reg.histogram("lat_seconds", window_s=6.0)
    for _ in range(10):
        reg.observe("lat_seconds", 0.01)
    clk["t"] = 3.0
    for _ in range(5):
        reg.observe("lat_seconds", 0.01)
    snap = reg.snapshot()
    assert snap.hist_count("lat_seconds") == 15
    assert snap.hist_count("lat_seconds", window=True) == 15
    clk["t"] = 8.0                   # first burst aged out of the window
    snap = reg.snapshot()
    assert snap.hist_count("lat_seconds", window=True) == 5
    clk["t"] = 60.0                  # everything aged out
    snap = reg.snapshot()
    assert snap.hist_count("lat_seconds", window=True) == 0
    assert snap.percentile("lat_seconds", 0.99, window=True) is None
    assert snap.hist_count("lat_seconds") == 15       # lifetime keeps all


def test_slo_burn_math():
    reg = MetricsRegistry()
    reg.histogram("req_seconds", labelnames=("outcome",), window_s=60.0)
    for _ in range(90):
        reg.observe("req_seconds", 0.01, outcome="ok")     # good
    for _ in range(6):
        reg.observe("req_seconds", 2.0, outcome="ok")      # slow = bad
    for _ in range(4):
        reg.observe("req_seconds", 0.001, outcome="error")  # fast but bad
    snap = reg.snapshot()
    burn = snap.slo_burn("req_seconds", threshold_s=0.1, target=0.99,
                         good_filter={"outcome": "ok"})
    # 10 bad of 100 over a 1% budget -> 10x burn
    assert burn == pytest.approx(10.0 / 0.01 / 100.0)
    assert snap.slo_burn("absent", 0.1, 0.99) == 0.0


def test_snapshot_json_round_trip_and_prometheus(tmp_path):
    reg = MetricsRegistry()
    reg.inc("jobs_total", 5, tier="blocks")
    reg.set_gauge("depth", 2)
    for v in (0.001, 0.02, 0.3):
        reg.observe("lat_seconds", v, outcome="ok")
    snap = reg.snapshot()
    snap.meta["slo"] = {"burn": 0.5}
    path = snap.save(tmp_path / "snap.json")
    back = MetricsSnapshot.load(path)
    assert back.total("jobs_total") == 5
    assert back.value("depth") == 2
    assert back.meta["slo"]["burn"] == 0.5
    assert back.percentile("lat_seconds", 0.5) == \
        snap.percentile("lat_seconds", 0.5)
    text = back.to_prometheus()
    assert text == reg.to_prometheus()
    assert '# TYPE jobs_total counter' in text
    assert 'jobs_total{tier="blocks"} 5' in text
    assert '# TYPE lat_seconds histogram' in text
    # cumulative buckets end at the +Inf total
    assert 'lat_seconds_bucket{le="+Inf",outcome="ok"} 3' in text
    assert 'lat_seconds_count{outcome="ok"} 3' in text
    with pytest.raises(ValueError):
        MetricsSnapshot.from_json({"kind": "nope"})


def test_ambient_helpers_no_op_without_registry():
    from repro.obs import metrics as m
    m.inc("never_total")                         # must not raise
    m.observe("never_seconds", 1.0)
    m.set_gauge("never", 1.0)
    assert m.current_registry() is None
    reg = MetricsRegistry()
    with reg.installed():
        assert m.current_registry() is reg
        m.inc("seen_total", 2)
    assert m.current_registry() is None
    assert reg.value("seen_total") == 2


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------

def test_recorder_ring_is_bounded():
    rec = FlightRecorder(capacity=16)
    for i in range(100):
        rec.record("e", i=i)
    assert len(rec) == 16
    assert rec.recorded == 100
    tail = rec.tail(4)
    assert [r["args"]["i"] for r in tail] == [96, 97, 98, 99]


def test_recorder_recent_for_filters_by_ticket():
    rec = FlightRecorder(capacity=64)
    rec.record("dispatch", jobs=4)               # id-less cohort context
    rec.record("job_retry", id=7)
    rec.record("job_retry", id=9)
    got = rec.recent_for(7)
    names = [(r["name"], r["args"].get("id")) for r in got]
    assert ("dispatch", None) in names
    assert ("job_retry", 7) in names
    assert ("job_retry", 9) not in names


def test_recorder_dump_rate_limit_and_loadable_json(tmp_path):
    rec = FlightRecorder(capacity=32, blackbox_dir=str(tmp_path),
                         label="t")
    rec.record("before", k=1)
    p1 = rec.dump("unit_test", extra="x")
    assert p1 is not None
    assert rec.dump("unit_test") is None         # rate-limited
    assert rec.dump("unit_test", force=True) is not None
    assert rec.dump("other_reason") is not None  # per-reason limits
    with open(p1) as f:
        doc = json.load(f)
    names = [e["name"] for e in doc["traceEvents"]]
    assert "before" in names
    od = doc["otherData"]
    assert od["tool"] == "repro.obs.recorder"
    assert od["reason"] == "unit_test" and od["extra"] == "x"
    assert len(rec.dumps) == 3


def test_span_and_event_feed_recorder_without_tracer():
    rec = FlightRecorder(capacity=32)
    with rec.installed():
        with obs_trace.span("work", k=1):
            pass
        obs_trace.event("ping", n=2)
    recs = rec.tail()
    spans = [r for r in recs if r["ph"] == "X"]
    assert [s["name"] for s in spans] == ["work"]
    assert spans[0]["dur"] >= 0.0
    assert any(r["name"] == "ping" and r["ph"] == "i" for r in recs)


# ---------------------------------------------------------------------------
# Stats as registry views
# ---------------------------------------------------------------------------

def test_fleet_stats_match_registry_and_prometheus():
    img = _loop_prog()
    datas = _datas(4)
    sched = FleetScheduler(CFG, batch_size=4, compile_min=1)
    hs = [sched.submit(img, d, tdx_dim=32) for d in datas]
    r1 = sched.drain()
    for d in datas:
        sched.submit(img, d, tdx_dim=32)
    sched.drain()
    st = sched.stats
    reg = st.registry
    assert st.jobs == 8 == int(reg.total("fleet_jobs_total"))
    assert st.batches == int(reg.total("fleet_batches_total"))
    assert st.compiled_jobs == int(
        reg.total("fleet_jobs_total", tier="blocks")
        + reg.total("fleet_jobs_total", tier="superblock"))
    assert st.residency_hits == int(
        reg.total("fleet_residency_lookups_total", result="hit"))
    assert st.residency_hits >= 1                # second drain replays
    text = reg.to_prometheus()
    assert "fleet_jobs_total{" in text
    assert "fleet_dispatch_seconds_bucket" in text
    for h, ref in zip(hs, _refs(img, datas)):
        assert np.array_equal(r1[h].shared_u32(), ref)


def test_service_stats_are_views_not_copies():
    img = _loop_prog()
    with FleetService(CFG, batch_size=4, max_delay_s=0.001) as svc:
        futs = [svc.submit(img, d, tdx_dim=32) for d in _datas(4)]
        for f in futs:
            f.result(timeout=300)
        st = svc.stats
        assert st.submitted == st.completed == 4
        assert st.registry is svc.metrics
        # the scheduler writes into the same registry: no drift between
        # service-lifetime and per-drain counts
        assert svc._sched.stats.registry is svc.metrics
        assert svc.metrics.total("serve_completed_total") == 4
    snap = svc.stats.final_snapshot
    assert snap is not None
    assert snap.total("serve_completed_total") == 4
    assert snap.meta["slo"]["request_p99_s"] is not None
    assert svc.slo_status()["window_s"] == svc.slo_window_s


# ---------------------------------------------------------------------------
# Bit-identity: telemetry on/off, all three tiers
# ---------------------------------------------------------------------------

_FORCE_BLOCKS = TierPolicy(batch_superblock_min=10**9,
                           min_backedge_dispatches=10**9,
                           min_trace_fusion=10**9,
                           min_fori_execd=10**9)


@pytest.mark.parametrize("tier,kw", [
    ("interp", {"use_compiler": False}),
    ("blocks", {"tier_policy": _FORCE_BLOCKS}),
    ("superblock", {}),
])
def test_bit_identical_with_telemetry_on_and_off(tier, kw):
    img = _loop_prog()
    datas = _datas(4)
    refs = _refs(img, datas)
    outs = {}
    for tm in (True, False):
        with FleetService(CFG, batch_size=4, max_delay_s=0.001,
                          telemetry=tm, slo_latency_s=0.1, **kw) as svc:
            futs = [svc.submit(img, d, tdx_dim=32) for d in datas]
            outs[tm] = [f.result(timeout=600) for f in futs]
        assert svc.stats.completed == 4
    assert all(r.tier == tier for r in outs[True]), \
        [r.tier for r in outs[True]]
    for on, off, ref in zip(outs[True], outs[False], refs):
        u_on = on.shared_u32()
        assert np.array_equal(u_on, off.shared_u32())
        assert np.array_equal(u_on, ref)
        assert on.cycles == off.cycles


def test_telemetry_off_strips_histograms_and_recorder():
    img = _loop_prog()
    with FleetService(CFG, batch_size=4, max_delay_s=0.001,
                      telemetry=False) as svc:
        futs = [svc.submit(img, d, tdx_dim=32) for d in _datas(4)]
        for f in futs:
            f.result(timeout=300)
    assert svc.recorder is None
    assert svc.stats.completed == 4              # counters stay: they
    snap = svc.stats.final_snapshot              # ARE the stats store
    assert snap.hist_count("serve_request_latency_seconds") == 0
    assert snap.value("serve_queue_depth") == 0


# ---------------------------------------------------------------------------
# Failure context: recent_events and the chaos blackbox
# ---------------------------------------------------------------------------

def test_job_error_carries_recent_events(tmp_path):
    img = _loop_prog()
    plan = FaultPlan(seed=4, dispatch=1.0)
    svc = FleetService(CFG, batch_size=2, max_delay_s=0.001, faults=plan,
                       max_retries=0, backoff_s=0.001,
                       blackbox_dir=str(tmp_path))
    try:
        fut = svc.submit(img, _datas(1)[0], tdx_dim=32)
        with pytest.raises(JobError) as ei:
            fut.result(timeout=600)
    finally:
        svc.close()
    err = ei.value
    assert err.kind == "error"
    assert err.recent_events, "flight-recorder tail must ride the error"
    names = {r["name"] for r in err.recent_events}
    assert "dispatch" in names or "fault_dispatch" in names
    # retry exhaustion dumped a blackbox
    assert svc.stats.blackbox_path is not None


def test_chaos_watchdog_reset_produces_loadable_blackbox(tmp_path):
    img = _loop_prog()
    datas = _datas(4)
    # warm the compiled path: the short watchdog must race only the
    # injected hang, never a cold multi-second XLA compile
    warm = FleetScheduler(CFG, batch_size=4, compile_min=1,
                          fixed_bucket=True)
    warm.submit(img, datas[0], tdx_dim=32)
    warm.drain()
    plan = FaultPlan(seed=5,
                     device_sync={"p": 1.0, "count": 1, "hang_s": 1.5})
    svc = FleetService(CFG, batch_size=4, max_delay_s=0.001, faults=plan,
                       dispatch_timeout_s=0.3, max_retries=2,
                       blackbox_dir=str(tmp_path), slo_latency_s=0.1)
    try:
        futs = [svc.submit(img, d, tdx_dim=32) for d in datas]
        res = [f.result(timeout=600) for f in futs]
    finally:
        svc.close()
    for r, ref in zip(res, _refs(img, datas)):
        assert np.array_equal(r.shared_u32(), ref)
    st = svc.stats
    assert st.scheduler_resets == 1
    assert st.timeouts == 4
    # the reset dumped a blackbox into our dir, and it loads as a
    # Chrome/Perfetto trace with the hang context inside
    assert st.blackbox_path is not None
    with open(st.blackbox_path) as f:
        doc = json.load(f)
    assert doc["otherData"]["tool"] == "repro.obs.recorder"
    names = [e["name"] for e in doc["traceEvents"]]
    assert doc["otherData"]["reason"] == "dispatch_timeout"
    assert "dispatch_timeout" in names
    assert "fault_injected" in names             # the hang's injection
    # ... and the injection itself triggered its own earlier dump
    reasons = [d for d in svc.recorder.dumps
               if "fault_device_sync" in d]
    assert reasons
    # the replacement scheduler adopted the same registry (no drift)
    assert svc._sched.stats.registry is svc.metrics
    # Prometheus counters agree exactly with the stats views
    snap = st.final_snapshot
    assert snap.total("serve_failed_total") == st.failed
    assert snap.total("serve_scheduler_resets_total") == 1
    assert snap.total("serve_watchdog_jobs_total") == 4
    text = snap.to_prometheus()
    assert ('serve_scheduler_resets_total'
            '{device="default",reason="dispatch_timeout"} 1') in text
    assert snap.meta["slo"]["burn"] >= 0.0


# ---------------------------------------------------------------------------
# report --metrics rendering
# ---------------------------------------------------------------------------

def test_report_renders_metrics_snapshot(tmp_path):
    from repro.obs import report as report_mod

    reg = MetricsRegistry()
    reg.inc("serve_submitted_total", 5, priority=1)
    reg.set_gauge("serve_queue_depth", 3)
    reg.histogram("serve_request_latency_seconds",
                  labelnames=("outcome",), window_s=60.0)
    for v in (0.001, 0.02, 0.3):
        reg.observe("serve_request_latency_seconds", v, outcome="ok")
    snap = reg.snapshot()
    snap.meta["slo"] = {"window_s": 60.0, "burn": 0.25,
                        "request_p99_s": 0.29}
    text = report_mod.render_metrics(snap)
    assert "serve_submitted_total{priority=1}" in text
    assert "serve_queue_depth" in text
    assert "serve_request_latency_seconds" in text
    assert "SLO status" in text and "burn" in text
    # and the CLI path accepts a snapshot file (auto-detected)
    path = snap.save(tmp_path / "snap.json")
    assert report_mod.main([str(path)]) == 0
    assert report_mod.main(["--metrics", str(path)]) == 0
