"""Tier-policy tests: the static cost model behind ``mode="auto"``.

The contract under test: tier selection is a *static* decision computed
from the path simulation alone — programs on the cheap side of the
calibrated crossover stay on the basic-block driver, programs past it
(or wide lock-step batches, or long fused traces) take the superblock
runner; explicit ``mode=`` overrides always win; and the light path
(``run_light`` / ``run_batch_light``) returns bit-identical
shared/cycles/halted leaves on every tier.
"""
import numpy as np
import pytest

from repro.core import (Asm, BlockCompileError, CompiledProgram,
                        DEFAULT_TIER_POLICY, EGPUConfig, TierPolicy,
                        compile_program, run_program)
from repro.core import blockc

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)


def _saxpy(iters, cfg=CFG):
    """One LOOP back-edge per iteration — the crossover stress test."""
    a = Asm(cfg)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lod(3, 1, 32)
    with a.loop(iters):
        a.fmul(3, 3, 4)
        a.fadd(3, 3, 2)
    a.sto(3, 1, 32)
    a.stop()
    rng = np.random.default_rng(iters)
    return (a.assemble(threads_active=32),
            rng.standard_normal(64).astype(np.float32))


def _straightline(n_instr, cfg=CFG):
    """A long straight-line program (no loops at all)."""
    a = Asm(cfg)
    a.tdx(1)
    a.lodi(2, 1)
    for _ in range(n_instr):
        a.add(2, 2, 1)
    a.sto(2, 1, 0)
    a.stop()
    return a.assemble(threads_active=32, schedule_nops=False)


# ---------------------------------------------------------------- policy
def test_crossover_boundary_selects_the_faster_tier():
    """Programs straddling the calibrated crossover: below the
    dispatch threshold the fixed superblock overhead loses and auto
    stays on blocks; above it the dispatch savings win and auto takes
    the superblock (the `auto_tier` sweep in benchmarks/superblock.py
    measures that these *are* the faster sides)."""
    below, _ = _saxpy(8)        # 10 dispatches, short unrolled trace
    above, _ = _saxpy(512)      # 514 dispatches
    assert compile_program(below).mode == "blocks"
    assert compile_program(above).mode == "superblock"
    thr = DEFAULT_TIER_POLICY.table["min_backedge_dispatches"]
    f_below = compile_program(below).tier_features
    f_above = compile_program(above).tier_features
    assert f_below["dispatches"] < thr <= f_above["dispatches"]


def test_wide_batches_always_take_the_superblock():
    """The block driver's per-dispatch carried-state copies scale with
    the batch width, so an eligible program on a wide lock-step batch
    goes superblock even below the single-core crossover."""
    img, _ = _saxpy(8)
    wide = DEFAULT_TIER_POLICY.table["batch_superblock_min"]
    assert compile_program(img, batch_hint=1).mode == "blocks"
    assert compile_program(img, batch_hint=wide).mode == "superblock"
    # batch classes collapse: every wide hint shares one cache entry
    assert compile_program(img, batch_hint=wide) \
        is compile_program(img, batch_hint=4 * wide)


def test_long_fused_trace_takes_the_superblock():
    """A straight-line program past ``min_trace_fusion`` wins on
    cross-block fusion despite having (almost) no dispatches."""
    thr = DEFAULT_TIER_POLICY.table["min_trace_fusion"]
    long_img = _straightline(thr + 16)
    short_img = _straightline(32)
    assert compile_program(long_img).mode == "superblock"
    assert compile_program(short_img).mode == "blocks"


def test_mode_overrides_always_force_their_tier():
    """Explicit ``mode=`` beats the cost model on both sides of the
    crossover, and results stay bit-identical to the interpreter."""
    for iters in (8, 512):
        img, data = _saxpy(iters)
        cb = compile_program(img, mode="blocks")
        cs = compile_program(img, mode="superblock")
        assert cb.mode == "blocks" and cb.switch_dispatches > 0
        assert cs.mode == "superblock" and cs.switch_dispatches == 0
        ref = run_program(img, shared_init=data, tdx_dim=32)
        for cp in (cb, cs):
            got = cp.run(shared_init=data, tdx_dim=32)
            for leaf in ref._fields:
                assert np.array_equal(np.asarray(getattr(ref, leaf)),
                                      np.asarray(getattr(got, leaf))), \
                    (iters, cp.mode, leaf)


def test_policy_threshold_table_overrides():
    """Every threshold is overridable; instances are value-equal and
    hashable (they key the compile cache)."""
    eager = TierPolicy(min_backedge_dispatches=2)
    never = TierPolicy(min_backedge_dispatches=10**9,
                       min_trace_fusion=10**9, min_fori_execd=10**9)
    img, _ = _saxpy(16)
    assert compile_program(img).mode == "blocks"
    assert compile_program(img, policy=eager).mode == "superblock"
    assert compile_program(img, policy=never).mode == "blocks"
    assert TierPolicy(min_backedge_dispatches=2) == eager
    assert hash(TierPolicy(min_backedge_dispatches=2)) == hash(eager)
    assert eager != never and eager != DEFAULT_TIER_POLICY
    assert TierPolicy() == DEFAULT_TIER_POLICY
    with pytest.raises(ValueError):
        TierPolicy(min_backedge_dispatch=1)        # typo'd key
    # the table property is a copy: mutating it cannot corrupt the policy
    t = eager.table
    t["min_backedge_dispatches"] = 999
    assert eager.table["min_backedge_dispatches"] == 2


def test_features_expose_the_simulation_inputs():
    img, _ = _saxpy(400)
    cp = compile_program(img, mode="superblock")
    f = DEFAULT_TIER_POLICY.features(cp.sim)
    assert f["eligible"]
    assert f["dispatches"] == cp.sim.dispatches > 400
    assert f["execd"] == cp.sim.steps
    assert f["fori_reps"] == 1              # one big fori-run repeat
    assert f["fori_trips"] == (400,)
    assert f["fori_execd"] > 0
    assert f["trace_cost"] == blockc._trace_cost(cp.schedule)
    # tiny loop: everything unrolls, nothing runs as fori
    small, _ = _saxpy(8)
    fs = DEFAULT_TIER_POLICY.features(compile_program(small).sim)
    assert fs["fori_reps"] == 0 and fs["unrolled_reps"] == 1
    assert fs["fori_trips"] == ()


def test_ineligible_schedule_stays_on_blocks_for_auto():
    """Over-budget paths: auto -> blocks, forced superblock raises —
    under any policy."""
    img, _ = _saxpy(200)
    old = blockc._MAX_TRACE
    blockc._MAX_TRACE = 4
    try:
        cp = CompiledProgram(img, 32,
                             policy=TierPolicy(min_backedge_dispatches=1))
        assert cp.mode == "blocks"
        assert not cp.tier_features["eligible"]
        with pytest.raises(BlockCompileError):
            CompiledProgram(img, 32, mode="superblock")
    finally:
        blockc._MAX_TRACE = old


# ------------------------------------------------------------ light path
@pytest.mark.parametrize("mode", ["blocks", "superblock"])
def test_run_light_bit_identical_to_run(mode):
    """run_light()/(batch) == run()/(batch) on shared/cycles/halted,
    bit for bit, on both compiled tiers."""
    img, data = _saxpy(64)
    cp = compile_program(img, mode=mode)
    ref = cp.run(shared_init=data, tdx_dim=32)
    sh, cyc, halted = cp.run_light(shared_init=data, tdx_dim=32)
    assert np.array_equal(np.asarray(ref.shared), np.asarray(sh))
    assert int(ref.cycles) == cyc
    assert bool(ref.halted) == halted

    datas = [data, data * 2, data + 3, None]
    refb = cp.run_batch(datas, [32, 32, 16, 8])
    shb, cycb, hb = cp.run_batch_light(datas, [32, 32, 16, 8])
    assert np.array_equal(np.asarray(refb.shared), np.asarray(shb))
    assert np.array_equal(np.asarray(refb.cycles), np.asarray(cycb))
    assert np.array_equal(np.asarray(refb.halted), np.asarray(hb))


def test_run_light_dev_does_not_consume_its_input():
    """The light path never donates: the same device buffer can be
    replayed across calls (what the fleet residency cache relies on)."""
    import jax.numpy as jnp

    img, data = _saxpy(32)
    cp = compile_program(img, mode="superblock")
    S = CFG.shared_words
    shared = np.zeros((2, S), np.uint32)
    shared[0, :64] = data.view(np.uint32)
    shared[1, :64] = (data * 2).view(np.uint32)
    dev = jnp.asarray(shared)
    tdx = jnp.asarray([32, 32], jnp.int32)
    first = np.asarray(cp.run_light_dev(dev, tdx)[0])
    second = np.asarray(cp.run_light_dev(dev, tdx)[0])   # replay
    assert np.array_equal(first, second)
