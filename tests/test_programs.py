"""Benchmark-program tests: correctness vs numpy + cycle fidelity vs the
paper's Tables 7/8 + the dynamic-scalability ablation."""
import pytest

from repro.core import benchmark_config
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose, run_bench)

# (name, n, column) -> paper cycles; column in {dp, qp, dot}
PAPER = {
    ("reduction", 32, "dp"): 168, ("reduction", 32, "qp"): 160,
    ("reduction", 64, "dp"): 202, ("reduction", 128, "dp"): 216,
    ("transpose", 32, "dp"): 1720, ("transpose", 32, "qp"): 1208,
    ("transpose", 64, "dp"): 5529,
    ("bitonic", 32, "dp"): 1742, ("bitonic", 64, "dp"): 3728,
    ("fft", 32, "dp"): 876, ("fft", 64, "dp"): 1695,
    ("fft", 64, "qp"): 1312,
}
TOL = 0.5   # +/-50% band: the paper's assembly is unpublished; trends and
            # ratios are validated tightly below, absolutes loosely here.


def _run(builder, n, mode="dp", **kw):
    cfg = benchmark_config(mode, has_dot=kw.pop("has_dot", False),
                           predicate_levels=kw.pop("pred", 0))
    r = run_bench(builder(cfg, n, **kw))
    assert r.correct, f"{r.name} produced wrong results"
    assert r.hazard_violations == 0, f"{r.name} has RAW hazards"
    return r


@pytest.mark.parametrize("n", [32, 64])
def test_reduction_correct_and_in_band(n):
    r = _run(build_reduction, n)
    p = PAPER[("reduction", n, "dp")]
    assert abs(r.cycles - p) / p < TOL


def test_reduction_qp_saves_write_cycles():
    dp = _run(build_reduction, 32, "dp")
    qp = _run(build_reduction, 32, "qp")
    assert qp.cycles < dp.cycles            # doubled write ports


def test_reduction_dot_unit_matches_paper_ratio():
    dp = _run(build_reduction, 64, "dp")
    dot = _run(build_reduction, 64, "dp", has_dot=True, use_dot=True)
    # paper: 94/202 = 0.47x; ours should be at least that good
    assert dot.cycles / dp.cycles < 0.5


def test_dynamic_scaling_beats_predicated_masking():
    """The paper's core claim: TSC thread-space subsetting vs running all
    threads with predicate write-masking."""
    dyn = _run(build_reduction, 64, "dp")
    nodyn = _run(build_reduction, 64, "dp", pred=4, no_dynamic=True)
    assert nodyn.cycles / dyn.cycles > 2.0   # we measure ~3.4x


@pytest.mark.parametrize("n", [32, 64])
def test_transpose_cycles_model(n):
    r = _run(build_transpose, n)
    p = PAPER[("transpose", n, "dp")]
    assert abs(r.cycles - p) / p < 0.25
    # paper: QP writes two elements per clock -> ~40% fewer cycles
    rq = _run(build_transpose, n, "qp")
    assert 0.55 < rq.cycles / r.cycles < 0.8


def test_matmul_correct_and_dot_speedup():
    plain = _run(build_matmul, 32)
    dot = _run(build_matmul, 32, has_dot=True, use_dot=True)
    assert dot.cycles < plain.cycles
    # our tiled assembly beats the paper's 111546; sanity: within 5x below
    assert plain.cycles < 111546


@pytest.mark.parametrize("n", [32, 64])
def test_bitonic_sort(n):
    r = _run(build_bitonic, n, pred=2)
    p = PAPER[("bitonic", n, "dp")]
    assert abs(r.cycles - p) / p < 0.35


@pytest.mark.parametrize("n", [32, 64])
def test_fft(n):
    r = _run(build_fft, n)
    p = PAPER[("fft", n, "dp")]
    assert abs(r.cycles - p) / p < 0.35


def test_fft_qp_ratio_matches_paper():
    dp = _run(build_fft, 64)
    qp = _run(build_fft, 64, "qp")
    # paper table 8: 1312/1695 = 0.77 in cycles
    assert 0.6 < qp.cycles / dp.cycles < 0.9


def test_profile_memory_dominates_fft():
    """Fig. 6: memory ops dominate; FP ~10% of cycles."""
    cfg = benchmark_config("dp")
    r = run_bench(build_fft(cfg, 64))
    total = sum(c for c, _ in r.profile.values())
    mem = r.profile["MEM_RD"][0] + r.profile["MEM_WR"][0]
    fp = r.profile["FP"][0]
    assert mem / total > 0.4
    assert fp / total < 0.25
