"""Assembler error paths and encode/decode fuzz.

The assembler is the admission boundary's first line: anything it lets
through must be encodable, decodable, and within the ISA's field
ranges.  These tests pin the rejection behaviour (undefined TSC width,
register/immediate overflow, unresolved labels, predicate ops on
predicate-free configs) and fuzz the word codec round-trip
deterministically (no hypothesis needed).
"""
import random

import pytest

from repro.core import Asm, EGPUConfig, Op, Typ, isa
from repro.core.isa import Instr, decode_word, encode_word

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)


# --------------------------------------------------------------------------
# rejection paths
# --------------------------------------------------------------------------

def test_emit_rejects_undefined_tsc_width():
    a = Asm(CFG)
    with pytest.raises(ValueError):
        a.emit(Op.ADD, rd=1, ra=2, rb=3, tsc=0b1100)


def test_encode_rejects_register_overflow():
    for field in ("rd", "ra", "rb"):
        ins = Instr(op=int(Op.ADD), **{field: CFG.regs_per_thread})
        with pytest.raises(ValueError):
            encode_word(ins, CFG.regs_per_thread)


def test_lodi_rejects_imm_overflow():
    a = Asm(CFG)
    with pytest.raises(ValueError):
        a.lodi(1, 65536)
    with pytest.raises(ValueError):
        a.lodi(1, -32769)


def test_lodi_accepts_boundary_imms():
    a = Asm(CFG)
    a.lodi(1, -32768)
    a.lodi(1, 32767)
    a.lodi(1, 65535)        # unsigned view of the 16-bit field
    img = a.assemble(threads_active=32)
    assert img.n >= 3


def test_if_rejected_without_predicate_hw():
    a = Asm(CFG.replace(predicate_levels=0))
    with pytest.raises(ValueError):
        a.if_("nz", 1)


def test_unresolved_label_rejected_at_assemble():
    a = Asm(CFG)
    a.jmp("nowhere")
    with pytest.raises(KeyError):
        a.assemble(threads_active=32)


def test_duplicate_label_rejected():
    a = Asm(CFG)
    a.label("x")
    a.lodi(1, 1)
    a.label("x")
    a.jmp("x")
    with pytest.raises(ValueError):
        a.assemble(threads_active=32)


# --------------------------------------------------------------------------
# codec fuzz (deterministic)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("regs", [16, 32, 64])
def test_word_roundtrip_fuzz(regs):
    rng = random.Random(0xE69F0 + regs)
    for _ in range(2000):
        ins = Instr(
            op=rng.randrange(isa.NUM_OPCODES),
            typ=rng.randrange(3),
            rd=rng.randrange(regs),
            ra=rng.randrange(regs),
            rb=rng.randrange(regs),
            imm=rng.randrange(-32768, 32768),
            tsc=rng.randrange(16),
        )
        word = encode_word(ins, regs)
        assert word < (1 << (isa.iw_bits(regs) + 1))
        assert decode_word(word, regs) == ins


def test_assembled_image_decodes_to_emitted_fields():
    """The packed words and the decoded field arrays of a ProgramImage
    agree instruction-by-instruction."""
    a = Asm(CFG)
    a.lodi(1, -5)
    a.tdx(2)
    a.add(3, 1, 2, typ=Typ.I32)
    a.sto(3, 2, 7)
    img = a.assemble(threads_active=32)
    for pc in range(img.n):
        ins = decode_word(int(img.words[pc]), CFG.regs_per_thread)
        assert ins.op == int(img.op[pc])
        assert ins.typ == int(img.typ[pc])
        assert ins.rd == int(img.rd[pc])
        assert ins.ra == int(img.ra[pc])
        assert ins.rb == int(img.rb[pc])
        assert ins.imm == int(img.imm[pc])
        assert ins.tsc == int(img.tsc[pc])
