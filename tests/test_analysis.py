"""Static-analyzer tests: diagnostics, facts, and admission wiring.

Covers the dataflow passes over the real program suite (which must lint
ERROR/WARN-clean), targeted bad-construct programs that each trip one
specific ERROR, the block-local IF/ELSE coverage machinery, and the
submit-time admission path (``check_job`` / ``FleetService.submit``
rejecting ERROR programs with a structured error before compile).
"""
import numpy as np
import pytest

from repro.analysis import (AnalysisReport, ProgramVerificationError,
                            analyze, analyze_cached)
from repro.analysis.concrete import concrete_run
from repro.analysis.lint import suite
from repro.core import Asm, EGPUConfig, Op
from repro.core.executor import run_program
from repro.fleet.scheduler import check_job
from repro.programs.generator import generate_program

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

SUITE = suite(CFG)


# --------------------------------------------------------------------------
# suite-level: the shipped programs are clean and the facts are exact
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bench", SUITE, ids=[b.name for b in SUITE])
def test_suite_lints_clean(bench):
    rep = analyze(bench.image, bench.image.threads_active,
                  tdx_dim=bench.tdx_dim)
    assert rep.errors() == [], rep.render()
    assert rep.warnings() == [], rep.render()


@pytest.mark.parametrize("bench", SUITE, ids=[b.name for b in SUITE])
def test_static_steps_match_interpreter(bench):
    """The trip-count pass predicts the executed instruction count
    exactly for every suite program (they are all JMP/JSR-free)."""
    rep = analyze(bench.image, bench.image.threads_active,
                  tdx_dim=bench.tdx_dim)
    ss = rep.facts["static_steps"]
    assert ss is not None
    st = run_program(bench.image, threads=bench.image.threads_active,
                     tdx_dim=bench.tdx_dim, shared_init=bench.shared_init)
    assert ss == int(st.steps)


def test_facts_shape():
    bench = SUITE[0]
    rep = analyze(bench.image, bench.image.threads_active,
                  tdx_dim=bench.tdx_dim)
    f = rep.facts
    for key in ("threads", "tdx_dim", "n_blocks", "reached_blocks",
                "static_steps", "loop_trips", "access_verdicts",
                "max_pred_depth", "max_loop_depth", "max_call_depth",
                "fold_candidates", "pred_at", "analysis_clipped"):
        assert key in f, key
    assert f["threads"] == bench.image.threads_active
    assert f["reached_blocks"] >= 1
    assert not f["analysis_clipped"]
    # every reachable pc got a predicate-depth annotation
    assert f["pred_at"].get(0) == 0


def test_analyze_cached_hits():
    bench = SUITE[0]
    r1 = analyze_cached(bench.image, bench.image.threads_active,
                        tdx_dim=bench.tdx_dim)
    r2 = analyze_cached(bench.image, bench.image.threads_active,
                        tdx_dim=bench.tdx_dim)
    assert r1 is r2


# --------------------------------------------------------------------------
# targeted bad constructs -> one specific ERROR each
# --------------------------------------------------------------------------

def _codes(rep: AnalysisReport) -> set:
    return {d.code for d in rep.errors()}


def test_stray_endif_is_error():
    a = Asm(CFG)
    a.lodi(1, 7)
    a.endif()
    img = a.assemble(threads_active=32)
    assert "pred-underflow" in _codes(analyze(img, 32))


def test_stray_else_is_error():
    a = Asm(CFG)
    a.else_()
    img = a.assemble(threads_active=32)
    assert "pred-underflow" in _codes(analyze(img, 32))


def test_pred_overflow_is_error():
    a = Asm(CFG)
    a.lodi(1, 1)
    for _ in range(CFG.predicate_levels + 1):
        a.if_("nz", 1)
    for _ in range(CFG.predicate_levels + 1):
        a.endif()
    img = a.assemble(threads_active=32)
    assert "pred-overflow" in _codes(analyze(img, 32))


def test_loop_overflow_is_error():
    a = Asm(CFG)
    for _ in range(CFG.max_loop_depth + 1):
        a.init(0)
    img = a.assemble(threads_active=32)
    assert "loop-overflow" in _codes(analyze(img, 32))


def test_loop_underflow_is_error():
    a = Asm(CFG)
    top = a.label()
    a.lodi(1, 1)
    a.loop_(top)
    img = a.assemble(threads_active=32)
    assert "loop-underflow" in _codes(analyze(img, 32))


def test_rts_underflow_is_error():
    a = Asm(CFG)
    a.rts()
    img = a.assemble(threads_active=32)
    assert "call-underflow" in _codes(analyze(img, 32))


def test_bad_branch_target_is_error():
    a = Asm(CFG)
    a.emit(Op.JMP, imm=4096)
    img = a.assemble(threads_active=32)
    assert "bad-branch-target" in _codes(analyze(img, 32))


def test_const_oob_store_is_error():
    a = Asm(CFG)
    a.lodi(1, CFG.shared_words + 5)
    a.lodi(2, 1)
    a.sto(2, 1)
    img = a.assemble(threads_active=32)
    rep = analyze(img, 32)
    assert "oob-access" in _codes(rep)
    assert rep.facts["access_verdicts"]


def test_undefined_tsc_width_is_error():
    from repro.core.isa import decode_word, encode_word
    a = Asm(CFG)
    a.lodi(1, 7)
    img = a.assemble(threads_active=32)
    # emit() refuses width '11', so forge the encoded word directly
    ins = decode_word(int(img.words[0]), CFG.regs_per_thread)
    img.words[0] = np.uint64(
        encode_word(ins._replace(tsc=0b1100), CFG.regs_per_thread))
    img.tsc[0] = 0b1100
    assert "undefined-tsc-width" in _codes(analyze(img, 32))


def test_undefined_read_is_warn_not_error():
    a = Asm(CFG)
    a.add(1, 2, 3)           # r2/r3 never written
    img = a.assemble(threads_active=32)
    rep = analyze(img, 32)
    assert "undefined-read" in {d.code for d in rep.warnings()}
    assert rep.errors() == []


def test_fixpoint_path_fault_not_erased_at_join():
    """Regression: a stack fault seen during the fixpoint poisons the
    abstract stack to None; the join with the clean entry state used to
    erase the evidence before the reporting replay ran.  (Found by the
    random-program fuzzer, generator seed 1002.)"""
    img = generate_program(CFG, 1002, hostility=1.0)
    res = concrete_run(img, img.threads_active)
    assert "loop-overflow" in res.stack_faults
    assert "loop-overflow" in _codes(analyze(img, img.threads_active))


# --------------------------------------------------------------------------
# IF/ELSE both-arms coverage machinery
# --------------------------------------------------------------------------

def test_both_arms_write_covers_read():
    a = Asm(CFG)
    a.tdx(1)
    a.if_("nz", 1)
    a.lodi(2, 10)
    a.else_()
    a.lodi(2, 20)
    a.endif()
    a.add(3, 2, 2)           # r2 defined on every thread: no warning
    img = a.assemble(threads_active=32)
    rep = analyze(img, 32)
    assert rep.warnings() == [], rep.render()


def test_one_arm_write_warns():
    a = Asm(CFG)
    a.tdx(1)
    a.if_("nz", 1)
    a.lodi(2, 10)
    a.endif()
    a.add(3, 2, 2)           # r2 defined only where the IF was taken
    img = a.assemble(threads_active=32)
    rep = analyze(img, 32)
    assert "partial-def-read" in {d.code for d in rep.warnings()}


def test_read_inside_writing_arm_is_clean():
    a = Asm(CFG)
    a.tdx(1)
    a.if_("nz", 1)
    a.lodi(2, 10)
    a.add(3, 2, 2)           # read in the same arm as the write
    a.endif()
    img = a.assemble(threads_active=32)
    rep = analyze(img, 32)
    assert rep.warnings() == [], rep.render()


# --------------------------------------------------------------------------
# submit-time admission
# --------------------------------------------------------------------------

def _bad_image():
    a = Asm(CFG)
    a.lodi(1, CFG.shared_words + 5)
    a.lodi(2, 1)
    a.sto(2, 1)
    return a.assemble(threads_active=32)


def test_check_job_rejects_error_program():
    img = _bad_image()
    with pytest.raises(ProgramVerificationError) as ei:
        check_job(CFG, img, None, 32)
    assert any(d.code == "oob-access" for d in ei.value.diagnostics)
    assert isinstance(ei.value, ValueError)


def test_check_job_rejects_bad_branch_target():
    a = Asm(CFG)
    a.emit(Op.JMP, imm=4096)
    img = a.assemble(threads_active=32)
    with pytest.raises(ProgramVerificationError) as ei:
        check_job(CFG, img, None, 32)
    assert any(d.code == "bad-branch-target" for d in ei.value.diagnostics)


def test_check_job_lint_opt_out():
    check_job(CFG, _bad_image(), None, 32, lint=False)   # no raise


def test_check_job_accepts_suite():
    for bench in SUITE:
        check_job(CFG, bench.image, bench.shared_init,
                  bench.image.threads_active, tdx_dim=bench.tdx_dim)


def test_service_submit_rejects_with_job_error():
    from repro.fleet.service import FleetService, JobError
    svc = FleetService(CFG)
    try:
        with pytest.raises(JobError) as ei:
            svc.submit(_bad_image(), threads=32)
        assert ei.value.kind == "rejected"
        assert svc.stats.lint_rejected == 1
    finally:
        svc.close()
