"""ISA-level tests: instruction count, word packing, TSC coding."""
import pytest
from _hyp import given, settings, st

from repro.core import isa
from repro.core.isa import Instr, Op


def test_opcode_count_is_61():
    assert isa.NUM_OPCODES == 61
    conds = [op for op in Op if op.name.startswith("IF_")]
    assert len(conds) == 18          # "including 18 conditional cases"


def test_iw_widths_match_paper():
    # §5.4: 40/43/46-bit IWs for 16/32/64 registers per thread
    assert isa.iw_bits(16) == 40
    assert isa.iw_bits(32) == 43
    assert isa.iw_bits(64) == 46


@pytest.mark.parametrize("regs", [16, 32, 64])
@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_word_roundtrip(regs, data):
    ins = Instr(
        op=data.draw(st.integers(0, isa.NUM_OPCODES - 1)),
        typ=data.draw(st.integers(0, 2)),
        rd=data.draw(st.integers(0, regs - 1)),
        ra=data.draw(st.integers(0, regs - 1)),
        rb=data.draw(st.integers(0, regs - 1)),
        imm=data.draw(st.integers(-32768, 32767)),
        tsc=data.draw(st.integers(0, 15)),
    )
    word = isa.encode_word(ins, regs)
    assert word < (1 << (isa.iw_bits(regs) + 1))
    back = isa.decode_word(word, regs)
    assert back == ins


def test_tsc_personalities():
    assert isa.tsc_width(isa.TSC_FULL) == isa.WIDTH_ALL
    assert isa.tsc_depth(isa.TSC_FULL) == isa.DEPTH_ALL
    assert isa.tsc_width(isa.TSC_MCU) == isa.WIDTH_ONE
    assert isa.tsc_depth(isa.TSC_MCU) == isa.DEPTH_WF0
    with pytest.raises(ValueError):
        isa.tsc_encode(3, 0)        # undefined width coding (Table 3)


def test_pred_write_ops_pin_enum_layout():
    """Regression for the predicate-hazard writer gate: it must be
    derived from PRED_WRITE_OPS, whose membership is exactly the ops
    that modify predicate state — the 18 IF.cc cases plus ELSE/ENDIF.
    Pins the enum layout so growing Op past ENDIF cannot silently tag a
    new sequencer op as a predicate writer (the old ``op >= IF_EQ``
    comparison would have)."""
    expected = {op for op in isa.Op if op.name.startswith("IF_")} \
        | {isa.Op.ELSE, isa.Op.ENDIF}
    assert isa.PRED_WRITE_OPS == frozenset(expected)
    assert len(isa.PRED_WRITE_OPS) == 20
    # today the members happen to be the contiguous tail of the enum;
    # the set (not that coincidence) is what the executor/assembler use
    assert sorted(isa.PRED_WRITE_OPS) == list(range(int(isa.Op.IF_EQ),
                                                    int(isa.Op.ENDIF) + 1))
    assert isa.Op.STOP not in isa.PRED_WRITE_OPS
    assert isa.Op.NOP not in isa.PRED_WRITE_OPS
    assert isa.IF_OPS < isa.PRED_WRITE_OPS
