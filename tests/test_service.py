"""Serving-layer tests: FleetService futures/deadlines/retries/
backpressure, the fault-injection harness, per-unit tier degradation,
bisection, salvage checksums, and a small chaos soak."""
import time

import numpy as np
import pytest

from repro.core import Asm, EGPUConfig, run_program
from repro.core import machine as machine_mod
from repro.fleet import (AdmissionError, FaultPlan, FleetScheduler,
                         FleetService, InjectedFault, JobError, serve_jobs)

CFG = EGPUConfig(max_threads=64, regs_per_thread=32, shared_kb=4,
                 predicate_levels=4, has_dot=True, has_invsqr=True)


def _loop_prog(iters=16):
    """Same-program loop job: lands on the compiled/superblock tiers."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    with a.loop(iters):
        a.fadd(2, 2, 2)
    a.sto(2, 1, 0)
    a.stop()
    return a.assemble(threads_active=32)


def _datas(n, seed=5):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(32).astype(np.float32) for _ in range(n)]


def _refs(img, datas):
    return [machine_mod.shared_as_u32(
        run_program(img, shared_init=d, tdx_dim=32)) for d in datas]


# ---------------------------------------------------------------------------
# FleetService basics
# ---------------------------------------------------------------------------

def test_service_round_trip_bit_identical():
    img = _loop_prog()
    datas = _datas(12)
    with FleetService(CFG, batch_size=4, max_delay_s=0.001) as svc:
        futs = [svc.submit(img, d, tdx_dim=32) for d in datas]
        res = [f.result(timeout=300) for f in futs]
    for d, r, ref in zip(datas, res, _refs(img, datas)):
        assert np.array_equal(r.shared_u32(), ref)
    st = svc.stats
    assert st.submitted == st.completed == 12
    assert st.failed == st.retries == st.rejected == 0
    assert st.dispatched_jobs == 12


def test_service_submit_validates_inputs():
    img = _loop_prog()
    with FleetService(CFG, batch_size=4) as svc:
        with pytest.raises(ValueError):
            svc.submit(img, np.zeros(4, np.complex64))      # bad dtype
        with pytest.raises(ValueError):
            svc.submit(img, np.zeros(CFG.shared_words + 1,
                                     np.float32))           # over-length
        with pytest.raises(ValueError):
            svc.submit(img, threads=CFG.num_sps + 1)        # ragged
    assert svc.stats.submitted == 0


def test_deadline_miss_fails_fast():
    img = _loop_prog()
    with FleetService(CFG, batch_size=4, max_delay_s=0.5) as svc:
        fut = svc.submit(img, _datas(1)[0], deadline_s=1e-4)
        with pytest.raises(JobError) as ei:
            fut.result(timeout=60)
    assert ei.value.kind == "deadline"
    assert svc.stats.deadline_misses == 1
    assert svc.stats.failed == 1


def test_backpressure_reject_mode():
    img = _loop_prog()
    svc = FleetService(CFG, batch_size=4, max_delay_s=5.0, max_pending=2,
                       admission="reject")
    try:
        f1 = svc.submit(img, _datas(1)[0])
        f2 = svc.submit(img, _datas(1)[0])
        with pytest.raises(AdmissionError):
            svc.submit(img, _datas(1)[0])
        assert svc.stats.rejected == 1
    finally:
        svc.close()
    assert f1.result(timeout=60) is not None
    assert f2.result(timeout=60) is not None


def test_backpressure_block_mode_unblocks_on_drain():
    img = _loop_prog()
    svc = FleetService(CFG, batch_size=2, max_delay_s=0.001, max_pending=2,
                       admission="block")
    try:
        futs = [svc.submit(img, d) for d in _datas(2)]
        # the third submit may block until the dispatcher frees capacity;
        # it must return (not raise) and its job must complete
        f3 = svc.submit(img, _datas(1, seed=9)[0])
        assert f3.result(timeout=300) is not None
        for f in futs:
            assert f.result(timeout=300) is not None
    finally:
        svc.close()
    assert svc.stats.rejected == 0


def test_close_without_wait_fails_queued_jobs():
    img = _loop_prog()
    svc = FleetService(CFG, batch_size=4, max_delay_s=10.0)
    fut = svc.submit(img, _datas(1)[0])
    svc.close(wait=False)
    try:
        fut.result(timeout=60)
    except JobError as e:
        assert e.kind == "shutdown"
    # a dispatch may have squeaked in before close; either way it resolved
    assert fut.done()
    with pytest.raises(RuntimeError):
        svc.submit(img, _datas(1)[0])


def test_priority_lanes_dispatch_high_priority_first():
    img = _loop_prog()
    order: list[int] = []
    # batch_size starts larger than the job count so the dispatcher
    # cannot form a cohort while we enqueue; shrinking it afterwards
    # releases cohorts of 2, best priority first
    svc = FleetService(CFG, batch_size=64, max_delay_s=30.0)
    try:
        futs = []
        for i, d in enumerate(_datas(6)):
            prio = 0 if i == 5 else 1    # last submit, highest priority
            f = svc.submit(img, d, priority=prio)
            f.add_done_callback(lambda _, i=i: order.append(i))
            futs.append(f)
        svc.batch_size = 2
        with svc._work:
            svc._work.notify_all()
        for f in futs:
            f.result(timeout=300)
    finally:
        svc.close()
    # the priority-0 job (index 5) rode the first cohort of 2
    assert 5 in order[:2], order


# ---------------------------------------------------------------------------
# Fault plan
# ---------------------------------------------------------------------------

def test_fault_plan_rejects_unknown_site():
    with pytest.raises(ValueError):
        FaultPlan(seed=1, not_a_site=1.0)


def test_fault_plan_where_filter_and_count():
    plan = FaultPlan(seed=3, dispatch={"p": 1.0, "count": 2,
                                       "where": {"tier": "blocks"}})
    with plan:
        from repro.fleet import faults
        assert faults.fire("dispatch", tier="superblock") is None
        assert faults.fire("dispatch", tier="blocks") is not None
        assert faults.fire("dispatch", tier="blocks") is not None
        assert faults.fire("dispatch", tier="blocks") is None   # count cap
    assert plan.injected["dispatch"] == 2
    assert plan.encounters["dispatch"] == 3     # where-misses don't count


def test_fault_plan_deterministic_across_runs():
    def run(seed):
        plan = FaultPlan(seed=seed, dispatch=0.3, compile=0.5)
        with plan:
            from repro.fleet import faults
            pattern = []
            for i in range(50):
                pattern.append(faults.fire("dispatch", k=i) is not None)
                pattern.append(faults.fire("compile", k=i) is not None)
        return pattern, dict(plan.injected)

    p1, i1 = run(17)
    p2, i2 = run(17)
    p3, _ = run(18)
    assert p1 == p2 and i1 == i2
    assert p1 != p3                      # seed actually matters


# ---------------------------------------------------------------------------
# Per-unit tier degradation (satellite: compile faults fall down the chain)
# ---------------------------------------------------------------------------

def _drain_with_plan(plan, datas, img, **sched_kw):
    sched = FleetScheduler(CFG, batch_size=4, trace=True, **sched_kw)
    hs = [sched.submit(img, d, tdx_dim=32) for d in datas]
    with plan:
        results = sched.drain()
    return sched, [results[h] for h in hs]


def test_compile_fault_at_superblock_degrades_to_blocks():
    img = _loop_prog()
    datas = _datas(4)
    plan = FaultPlan(seed=1, compile={"p": 1.0, "count": 1,
                                      "where": {"tier": "superblock"}})
    sched, res = _drain_with_plan(plan, datas, img)
    assert plan.injected["compile"] == 1
    assert all(r.tier == "blocks" for r in res)     # next tier down
    for r, ref in zip(res, _refs(img, datas)):
        assert np.array_equal(r.shared_u32(), ref)  # bit-identical
    assert sched.stats.degraded_units == 1
    evs = [e for e in sched.tracer.events if e["name"] == "tier_degrade"]
    assert evs and evs[0]["args"]["from_tier"] == "superblock"
    assert evs[0]["args"]["to_tier"] == "blocks"
    assert evs[0]["args"]["error"] == "InjectedFault"


def test_compile_fault_at_both_tiers_degrades_to_interpreter():
    img = _loop_prog()
    datas = _datas(4)
    plan = FaultPlan(seed=1, compile={"p": 1.0, "count": 2})
    sched, res = _drain_with_plan(plan, datas, img)
    assert plan.injected["compile"] == 2
    assert all(r.tier == "interp" for r in res)
    for r, ref in zip(res, _refs(img, datas)):
        assert np.array_equal(r.shared_u32(), ref)
    assert sched.stats.degraded_units == 2
    tiers = [(e["args"]["from_tier"], e["args"]["to_tier"])
             for e in sched.tracer.events if e["name"] == "tier_degrade"]
    assert tiers == [("superblock", "blocks"), ("blocks", "interp")]


def test_dispatch_fault_bisects_and_degrades_per_job():
    """drain_isolated contains a poison dispatch: bisection isolates it,
    the single survivor degrades down the tiers, and the cohort's other
    jobs still deliver bit-identical results."""
    img = _loop_prog()
    datas = _datas(4)
    sched = FleetScheduler(CFG, batch_size=4, trace=True)
    hs = [sched.submit(img, d, tdx_dim=32) for d in datas]
    plan = FaultPlan(seed=2, dispatch={"p": 1.0, "count": 1})
    with plan:
        results, failures = sched.drain_isolated()
    assert not failures
    assert sorted(results) == sorted(hs)
    for h, d, ref in zip(hs, datas, _refs(img, datas)):
        assert np.array_equal(results[h].shared_u32(), ref)
    assert sched.stats.bisections >= 1
    names = {e["name"] for e in sched.tracer.events}
    assert "batch_bisect" in names and "fault_injected" in names


def test_job_fails_structured_when_every_tier_fails():
    """An unlimited dispatch fault defeats every tier and every retry:
    the future resolves with JobError, the service stays alive."""
    img = _loop_prog()
    plan = FaultPlan(seed=4, dispatch=1.0)       # every dispatch, forever
    svc = FleetService(CFG, batch_size=2, max_delay_s=0.001, faults=plan,
                       max_retries=1, backoff_s=0.001)
    try:
        futs = [svc.submit(img, d) for d in _datas(2)]
        errs = []
        for f in futs:
            with pytest.raises(JobError) as ei:
                f.result(timeout=600)
            errs.append(ei.value)
    finally:
        svc.close()
    for e in errs:
        assert e.kind == "error"
        assert e.attempts == 2                   # initial + 1 retry
        assert isinstance(e.cause, InjectedFault)
    assert svc.stats.failed == 2
    assert svc.stats.retries == 2


def test_device_sync_hang_trips_watchdog_and_recovers():
    img = _loop_prog()
    datas = _datas(4)
    # warm the compiled path first: the short watchdog below must race
    # only the injected hang, never a cold multi-second XLA compile
    sched = FleetScheduler(CFG, batch_size=4, compile_min=1,
                           fixed_bucket=True)
    sched.submit(img, datas[0], tdx_dim=32)
    sched.drain()
    plan = FaultPlan(seed=5,
                     device_sync={"p": 1.0, "count": 1, "hang_s": 1.5})
    svc = FleetService(CFG, batch_size=4, max_delay_s=0.001, faults=plan,
                       dispatch_timeout_s=0.3, max_retries=2)
    try:
        futs = [svc.submit(img, d, tdx_dim=32) for d in datas]
        res = [f.result(timeout=600) for f in futs]
    finally:
        svc.close()
    assert svc.stats.timeouts == 4               # the whole hung cohort
    assert svc.stats.scheduler_resets == 1
    for r, ref in zip(res, _refs(img, datas)):
        assert np.array_equal(r.shared_u32(), ref)


def test_residency_evict_fault_is_harmless():
    img = _loop_prog()
    datas = _datas(4)
    sched = FleetScheduler(CFG, batch_size=4)
    plan = FaultPlan(seed=6, residency_evict=1.0)
    with plan:
        hs = [sched.submit(img, d, tdx_dim=32) for d in datas]
        r1 = sched.drain()
        for d in datas:
            sched.submit(img, d, tdx_dim=32)
        sched.drain()
    assert plan.injected["residency_evict"] >= 1
    assert sched.stats.residency_hits == 0       # every lookup evicted
    for h, ref in zip(hs, _refs(img, datas)):
        assert np.array_equal(r1[h].shared_u32(), ref)


def test_salvage_corruption_detected_and_reexecuted(monkeypatch):
    """A salvaged result corrupted while stashed fails its delivery
    checksum: it is dropped, its job re-executed, and the caller still
    gets the right answer — corruption costs a re-run, never a wrong
    result."""
    from repro.core.blockc import CompiledProgram

    img = _loop_prog()
    datas = _datas(6)
    sched = FleetScheduler(CFG, batch_size=2, trace=True)
    hs = [sched.submit(img, d, tdx_dim=32) for d in datas]

    calls = {"n": 0}
    real = CompiledProgram.run_light_dev

    def failing(self, shared, tdx, device=None):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected batch failure")
        return real(self, shared, tdx, device)

    monkeypatch.setattr(CompiledProgram, "run_light_dev", failing)
    plan = FaultPlan(seed=7, salvage_corrupt=1.0)
    with plan:
        with pytest.raises(RuntimeError):
            sched.drain()                # stashes 2 results, corrupts 1
    monkeypatch.setattr(CompiledProgram, "run_light_dev", real)
    results = sched.drain()
    assert sorted(results) == sorted(hs)
    assert sched.stats.salvage_dropped == 1
    assert sched.stats.salvaged_jobs == 1        # the intact stash only
    for h, ref in zip(hs, _refs(img, datas)):
        assert np.array_equal(results[h].shared_u32(), ref)
    names = [e["name"] for e in sched.tracer.events]
    assert "salvage_corrupt" in names


# ---------------------------------------------------------------------------
# Chaos soak + serve_jobs convenience
# ---------------------------------------------------------------------------

def test_chaos_soak_every_future_resolves_bit_identical():
    img = _loop_prog()
    datas = _datas(48)
    refs = _refs(img, datas)
    plan = FaultPlan(seed=23,
                     compile={"p": 1.0, "count": 2},
                     dispatch={"p": 1.0, "count": 2, "after": 1},
                     residency_evict=0.2)
    svc = FleetService(CFG, batch_size=8, max_delay_s=0.001, faults=plan,
                       max_retries=3, backoff_s=0.001)
    try:
        futs = [svc.submit(img, d, tdx_dim=32) for d in datas]
        outcomes = []
        for f in futs:
            try:
                outcomes.append(f.result(timeout=600))
            except JobError as e:
                outcomes.append(e)
    finally:
        svc.close()
    assert len(outcomes) == len(datas)           # every future resolved
    assert plan.total_injected() >= 3
    for o, ref in zip(outcomes, refs):
        if not isinstance(o, Exception):
            assert np.array_equal(o.shared_u32(), ref)
    assert not any(isinstance(o, Exception) for o in outcomes), \
        "contained faults should salvage every job here"


def test_serve_jobs_orders_outcomes_by_submission():
    img = _loop_prog()
    datas = _datas(6)
    out = serve_jobs(CFG, [{"image": img, "shared_init": d, "tdx_dim": 32}
                           for d in datas],
                     batch_size=4, max_delay_s=0.001)
    assert len(out) == 6
    for o, ref in zip(out, _refs(img, datas)):
        assert not isinstance(o, Exception)
        assert np.array_equal(o.shared_u32(), ref)


def test_traced_service_emits_request_pairs_and_serve_events():
    from repro.obs import report as report_mod

    img = _loop_prog()
    datas = _datas(4)
    plan = FaultPlan(seed=9, compile={"p": 1.0, "count": 1})
    svc = FleetService(CFG, batch_size=4, max_delay_s=0.001, trace=True,
                       faults=plan)
    try:
        futs = [svc.submit(img, d) for d in datas]
        for f in futs:
            f.result(timeout=300)
    finally:
        svc.close()
    events = svc.tracer.events
    req = report_mod.job_latencies(events, name="request")
    assert len(req) == 4 and all(v >= 0 for v in req.values())
    srv = report_mod.serve_events(events)
    assert srv.get("fault:fault_injected", 0) >= 1
    assert srv.get("serve:tier_degrade", 0) >= 1
    text = report_mod.render(events)
    assert "request latency" in text
    assert "serving / fault events" in text
