"""Multi-device fleet tests: sharded scheduling vs the 1-device engine.

The contract under test (see docs/architecture.md): sharding the job
stream across devices is a *placement* decision, never a *results*
decision — the sharded scheduler is bit-identical to the single-device
scheduler on every tier, on one device or many; a dead device costs
capacity, never availability, and never a job.

The single-device degenerate cases run everywhere.  The genuinely
multi-device cases need >1 visible device — the ``multi-device`` CI job
provides 4 via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
— and skip elsewhere.
"""
import jax
import numpy as np
import pytest

from repro.core import EGPUConfig, run_program
from repro.core import machine as machine_mod
from repro.core.blockc import (DEFAULT_TIER_POLICY, TierPolicy,
                               default_policy_for_device,
                               tier_policy_for_backend)
from repro.fleet import (FaultPlan, FleetScheduler, FleetService,
                         ShardedFleetScheduler, balance_units,
                         device_label, fleet_devices)
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose)

CFG = EGPUConfig(max_threads=64, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

NDEV = len(jax.devices())
multi = pytest.mark.skipif(
    NDEV < 2, reason="needs >1 device "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

_FORCE_BLOCKS = TierPolicy(batch_superblock_min=10**9,
                           min_backedge_dispatches=10**9,
                           min_trace_fusion=10**9,
                           min_fori_execd=10**9)

TIERS = [
    ("interp", {"use_compiler": False}),
    ("blocks", {"tier_policy": _FORCE_BLOCKS}),
    ("superblock", {}),
]


def _suite():
    return [
        build_reduction(CFG, 32),
        build_reduction(CFG, 32, use_dot=True),
        build_reduction(CFG, 64),
        build_transpose(CFG, 16),
        build_matmul(CFG, 16),
        build_bitonic(CFG, 32),
        build_fft(CFG, 32),
    ]


def _run(sched, jobs):
    hs = [sched.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim,
                       tag=b.name) for b in jobs]
    rs = sched.drain()
    return [rs[h] for h in hs]


def _assert_identical(a, b, names):
    for ra, rb, name in zip(a, b, names):
        assert np.array_equal(ra.shared_u32(), rb.shared_u32()), name
        assert ra.cycles == rb.cycles, name
        assert ra.steps == rb.steps, name


# ---------------------------------------------------------------------------
# degenerate single-device path: must be bit-identical everywhere
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tier,kw", TIERS, ids=[t for t, _ in TIERS])
def test_one_device_mesh_bit_identical_per_tier(tier, kw):
    """ShardedFleetScheduler on a 1-device mesh == FleetScheduler, for
    the full suite, on every execution tier."""
    suite = _suite()
    jobs = [suite[i % len(suite)] for i in range(14)]
    base = _run(FleetScheduler(CFG, batch_size=4, **kw), jobs)
    shard = _run(ShardedFleetScheduler(CFG, batch_size=4, devices=1,
                                       **kw), jobs)
    _assert_identical(base, shard, [b.name for b in jobs])


def test_one_device_matches_sequential_reference():
    """...and both match N independent ``run_program`` calls."""
    suite = _suite()
    shard = _run(ShardedFleetScheduler(CFG, batch_size=4, devices=1),
                 suite)
    for b, r in zip(suite, shard):
        st = run_program(b.image, shared_init=b.shared_init,
                         tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(st),
                              r.shared_u32()), b.name
        assert int(st.cycles) == r.cycles, b.name


def test_megabatch_path_one_device():
    """Same-program runs >= one slab ride the shard_map megabatch even
    on a 1-device mesh; results and stats labels stay correct."""
    b = build_reduction(CFG, 32)
    n = 4 * 3 + 2                       # 3 slabs (batch 4) + remainder
    base = _run(FleetScheduler(CFG, batch_size=4), [b] * n)
    sh = ShardedFleetScheduler(CFG, batch_size=4, devices=1)
    shard = _run(sh, [b] * n)
    _assert_identical(base, shard, [b.name] * n)
    per = sh.stats.per_device()
    assert per.get("mesh", {}).get("jobs", 0) == 12
    assert sum(d["jobs"] for d in per.values()) == n


def test_fleet_facade_devices_knob():
    from repro.fleet import Fleet

    suite = _suite()
    plain = Fleet(CFG, batch_size=4)
    sharded = Fleet(CFG, batch_size=4, devices=1)
    assert isinstance(sharded._sched, ShardedFleetScheduler)
    _assert_identical(_run(plain._sched, suite),
                      _run(sharded._sched, suite),
                      [b.name for b in suite])


# ---------------------------------------------------------------------------
# topology helpers (pure host logic)
# ---------------------------------------------------------------------------

def test_device_resolution_and_labels():
    devs = fleet_devices("all")
    assert len(devs) == NDEV
    assert fleet_devices(None) == devs
    assert fleet_devices(devs[0]) == (devs[0],)
    assert device_label(None) == "default"
    lbl = device_label(devs[0])
    assert devs[0].platform in lbl and str(devs[0].id) in lbl


def test_balance_units_lpt():
    units = [("a", 10.0), ("b", 8.0), ("c", 2.0), ("d", 2.0),
             ("e", 1.0), ("f", 1.0)]
    lanes = balance_units(units, 2, cost=lambda u: u[1])
    loads = sorted(sum(u[1] for u in lane) for lane in lanes)
    assert loads == [12.0, 12.0]            # LPT: perfectly balanced
    # submission order is preserved within each lane
    order = {u: i for i, u in enumerate(units)}
    for lane in lanes:
        idx = [order[u] for u in lane]
        assert idx == sorted(idx)
    # more lanes than units: empties allowed, nothing lost
    lanes = balance_units(units[:2], 4, cost=lambda u: u[1])
    assert sorted(len(x) for x in lanes) == [0, 0, 1, 1]


def test_per_backend_policy_tables():
    assert default_policy_for_device(None) is DEFAULT_TIER_POLICY
    assert tier_policy_for_backend("nosuch") is DEFAULT_TIER_POLICY
    # accelerator priors move the crossover earlier, never later
    gpu = tier_policy_for_backend("gpu")
    assert gpu.table["min_backedge_dispatches"] \
        <= DEFAULT_TIER_POLICY.table["min_backedge_dispatches"]
    # a pinned scheduler derives its policy from its device's platform
    dev = jax.devices()[0]
    assert default_policy_for_device(dev) == \
        tier_policy_for_backend(dev.platform)


def test_register_backend_table_roundtrip():
    from repro.core import blockc

    saved = dict(blockc._TIER_TABLES)
    try:
        blockc.register_backend_table("cpu", min_backedge_dispatches=7)
        assert tier_policy_for_backend(
            "cpu").table["min_backedge_dispatches"] == 7
        with pytest.raises(ValueError, match="unknown TierPolicy"):
            blockc.register_backend_table("cpu", min_backedge=1)
    finally:
        blockc._TIER_TABLES.clear()
        blockc._TIER_TABLES.update(saved)


# ---------------------------------------------------------------------------
# genuinely multi-device: sharding, balancing, failover
# ---------------------------------------------------------------------------

@multi
@pytest.mark.parametrize("tier,kw", TIERS, ids=[t for t, _ in TIERS])
def test_all_devices_bit_identical_per_tier(tier, kw):
    suite = _suite()
    jobs = [suite[i % len(suite)] for i in range(4 * NDEV + 3)]
    base = _run(FleetScheduler(CFG, batch_size=4, **kw), jobs)
    sh = ShardedFleetScheduler(CFG, batch_size=4, devices="all", **kw)
    shard = _run(sh, jobs)
    _assert_identical(base, shard, [b.name for b in jobs])
    per = sh.stats.per_device()
    assert sum(d["jobs"] for d in per.values()) == len(jobs)
    assert len([k for k in per if k != "mesh"]) >= 2, \
        f"work must spread across devices: {per}"


@multi
def test_megabatch_shards_across_devices():
    """A same-program run >= one slab (n_devices * batch) dispatches as
    ONE shard_map megabatch over the whole mesh."""
    b = build_reduction(CFG, 32)
    sh = ShardedFleetScheduler(CFG, batch_size=4, devices="all")
    n = sh._slab * 2 + 3
    base = _run(FleetScheduler(CFG, batch_size=4), [b] * n)
    shard = _run(sh, [b] * n)
    _assert_identical(base, shard, [b.name] * n)
    per = sh.stats.per_device()
    assert per.get("mesh", {}).get("jobs", 0) == sh._slab * 2
    assert per.get("mesh", {}).get("batches", 0) == 2


@multi
def test_sharded_repeat_drains_hit_residency():
    """Per-device residency caches survive across sharded drains."""
    b = build_matmul(CFG, 8)
    sh = ShardedFleetScheduler(CFG, batch_size=4, devices="all")
    n = sh._slab
    _run(sh, [b] * n)
    _run(sh, [b] * n)
    assert sh._mega_residency.hits > 0


@multi
def test_device_kill_chaos_every_future_resolves():
    """The ISSUE's acceptance chaos run: kill one whole device mid-load;
    every future resolves, failed == 0 (a device death consumes no
    retry attempts), and only the dead device leaves the healthy set."""
    b = build_reduction(CFG, 32)
    victim = device_label(jax.devices()[1])
    plan = FaultPlan(seed=5, device_fail={"p": 1.0, "count": 1,
                                          "where": {"device": victim}})
    svc = FleetService(CFG, batch_size=4, max_delay_s=0.001,
                       devices="all", faults=plan)
    assert victim in svc._dev_labels
    truth = run_program(b.image, shared_init=b.shared_init,
                        tdx_dim=b.tdx_dim)
    futs = [svc.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
            for _ in range(10 * NDEV)]
    res = [f.result(timeout=300) for f in futs]
    svc.close()
    assert plan.injected["device_fail"] == 1
    assert svc.stats.failed == 0
    assert len(res) == 10 * NDEV
    for r in res:
        assert np.array_equal(machine_mod.shared_as_u32(truth),
                              r.shared_u32())
    healthy = svc.healthy_devices
    assert victim not in healthy
    assert len(healthy) == NDEV - 1
    assert svc.metrics.total("serve_device_unhealthy",
                             device=victim) == 1


@multi
def test_last_healthy_device_never_killed():
    """A device_fail plan that matches every device can only retire
    N-1 of them: the last healthy dispatcher refuses to die and keeps
    serving (availability floor)."""
    b = build_reduction(CFG, 32)
    plan = FaultPlan(seed=9, device_fail=1.0)   # match everything
    svc = FleetService(CFG, batch_size=4, max_delay_s=0.001,
                       devices="all", faults=plan)
    futs = [svc.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
            for _ in range(6 * NDEV)]
    res = [f.result(timeout=300) for f in futs]
    svc.close()
    assert len(res) == 6 * NDEV
    assert svc.stats.failed == 0
    assert len(svc.healthy_devices) == 1


@multi
def test_service_multi_device_bit_identical_and_spread():
    """Per-device dispatchers draining the shared queue: results match
    the fault-free single-dispatcher service and more than one device
    does work."""
    suite = _suite()
    jobs = [suite[i % len(suite)] for i in range(8 * NDEV)]

    def serve(devices):
        svc = FleetService(CFG, batch_size=4, max_delay_s=0.001,
                           devices=devices)
        futs = [svc.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
                for b in jobs]
        res = [f.result(timeout=300) for f in futs]
        svc.close()
        return res, svc

    many, svc = serve("all")
    one, _ = serve(None)
    _assert_identical(many, one, [b.name for b in jobs])
    snap = svc.metrics.snapshot()
    used = {s["labels"]["device"]
            for s in snap._metric("serve_dispatches_total")["samples"]
            if s["value"]}
    assert len(used) >= 2, f"dispatches must spread: {used}"
