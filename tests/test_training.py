"""Training-infrastructure tests: convergence, checkpoint/restart, fault
injection, gradient compression (hypothesis), straggler mitigation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

import repro.configs as C
from repro.models import api
from repro.training import checkpoint, compression, data, optimizer as opt_mod
from repro.training.steps import TrainSettings, make_train_step


def _setup(arch="yi_9b", **okw):
    cfg = C.get_smoke(arch)
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=5, total_steps=100, **okw)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init(params, ocfg)
    return cfg, ocfg, params, opt


def test_loss_descends_on_synthetic_bigrams():
    cfg, ocfg, params, opt = _setup()
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    ds = data.SyntheticLM(cfg, batch=8, seq=32)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch(i).items()}
        params, opt, _, m = step(params, opt, batch, None)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_nan_sentinel_skips_update():
    cfg, ocfg, params, opt = _setup()
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=())
    ds = data.SyntheticLM(cfg, batch=4, seq=16)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch(0).items()}
    bad_params = jax.tree.map(
        lambda p: (p * jnp.nan).astype(p.dtype), params)
    new_params, new_opt, _, m = step(bad_params, opt, batch, None)
    assert float(m["finite"]) == 0.0
    # params passed through unchanged (not updated with NaN gradients)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(bad_params)):
        assert a.shape == b.shape
    # the whole update is skipped, count included (retry-same-step policy)
    assert int(new_opt["count"]) == int(opt["count"])


def test_checkpoint_roundtrip(tmp_path):
    cfg, ocfg, params, opt = _setup()
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, 7, (params, opt))
    assert checkpoint.latest_step(path) == 7
    (p2, o2), step, _ = checkpoint.restore(path, (params, opt))
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_atomicity(tmp_path):
    cfg, ocfg, params, opt = _setup()
    path = str(tmp_path / "ckpt")
    t = checkpoint.save_async(path, 3, params)
    t.join()
    assert checkpoint.latest_step(path) == 3
    # a later save supersedes atomically
    checkpoint.save(path, 5, params)
    assert checkpoint.latest_step(path) == 5
    assert not any(f.startswith("ckpt.tmp") for f in os.listdir(tmp_path))


def test_train_driver_recovers_from_injected_fault(tmp_path):
    """End-to-end fault tolerance: NaN injection mid-run -> auto restore."""
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "yi-9b", "--smoke", "--steps", "16", "--batch", "4",
        "--seq", "16", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "5", "--inject-nan-at", "8", "--log-every", "100",
    ])
    assert len(losses) >= 14            # run completed despite the fault
    assert np.isfinite(losses).all()


# --- gradient compression ---------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.01, 10),
                    jnp.float32)
    q, s = compression.quantize(x)
    err = np.abs(np.asarray(compression.dequantize(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-7   # half-ulp of the int8 grid


def test_error_feedback_accumulates_to_unbiased():
    """EF property: the running sum of compressed grads tracks the running
    sum of true grads (quantisation error does not accumulate)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal(64) * 0.1, jnp.float32)}
    residual = {"w": jnp.zeros(64, jnp.float32)}
    total = jnp.zeros(64, jnp.float32)
    for _ in range(50):
        g_c, residual = compression.apply_error_feedback(g_true, residual)
        total = total + g_c["w"]
    np.testing.assert_allclose(np.asarray(total / 50),
                               np.asarray(g_true["w"]), atol=2e-3)


def test_compressed_training_still_converges():
    cfg, ocfg, params, opt = _setup()
    settings_ = TrainSettings(compress_grads=True)
    step = jax.jit(make_train_step(cfg, ocfg, settings_), donate_argnums=(0, 1))
    residual = compression.init_residual(params)
    ds = data.SyntheticLM(cfg, batch=8, seq=32)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch(i).items()}
        params, opt, residual, m = step(params, opt, batch, residual)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


# --- straggler mitigation ---------------------------------------------------

def test_microbatch_drop_stale_rescales_correctly():
    cfg, ocfg, params, opt = _setup()
    settings_ = TrainSettings(microbatches=4, straggler_mitigation=True)
    step = jax.jit(make_train_step(cfg, ocfg, settings_), donate_argnums=())
    ds = data.SyntheticLM(cfg, batch=8, seq=16)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch(0).items()}
    full = dict(batch, microbatch_keep=jnp.ones((4,), jnp.float32))
    # drop the last microbatch (straggler): loss over kept 3 only
    dropped = dict(batch, microbatch_keep=jnp.asarray([1., 1., 1., 0.]))
    _, _, _, m_full = step(params, opt, full, None)
    _, _, _, m_drop = step(params, opt, dropped, None)
    assert np.isfinite(float(m_drop["loss"]))
    # kept-mean differs from full-mean but is the same scale
    assert abs(float(m_drop["loss"]) - float(m_full["loss"])) < 1.0
