"""Partition-rule tests: fallbacks, divisibility on the production mesh
(pure tree logic — no devices needed beyond the default)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.models import api
from repro.sharding import partition


class FakeMesh:
    """Just enough of a Mesh for rule resolution (no devices)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


SINGLE = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_attention_sharding_ladder():
    # phi3: 40 heads % 16 != 0 -> attention replicated (FFN carries TP)
    r = partition.make_rules(C.get("phi3-medium-14b"), SINGLE)
    assert r.physical("heads") is None
    assert r.physical("hd") is None
    # llama3: 128 q-heads divide but kv=8 does not -> q-heads only
    r = partition.make_rules(C.get("llama3-405b"), SINGLE)
    assert r.physical("heads") == "model" and r.physical("kv_heads") is None
    # zamba2: 32/32 heads divide -> full head sharding
    r = partition.make_rules(C.get("zamba2-1p2b"), SINGLE)
    assert r.physical("heads") == "model"
    assert r.physical("kv_heads") == "model"


def test_expert_fallback():
    r = partition.make_rules(C.get("qwen3-moe-30b-a3b"), SINGLE)
    assert r.physical("experts") == "model"       # 128 % 16 == 0
    assert r.physical("expert_ff") is None
    r = partition.make_rules(C.get("granite-moe-3b-a800m"), SINGLE)
    assert r.physical("experts") is None          # 40 % 16 != 0
    assert r.physical("expert_ff") == "model"     # 512 % 16 == 0


def test_vocab_fallback():
    assert partition.make_rules(C.get("yi-9b"), SINGLE).physical("vocab") == "model"
    for arch in ("granite-moe-3b-a800m", "seamless-m4t-large-v2",
                 "internvl2-2b"):
        assert partition.make_rules(C.get(arch), SINGLE).physical("vocab") is None


def test_batch_axes_multi_pod():
    r = partition.make_rules(C.get("yi-9b"), MULTI)
    assert r.physical("batch") == ("pod", "data")
    r = partition.make_rules(C.get("yi-9b"), SINGLE)
    assert r.physical("batch") == ("data",)


@pytest.mark.parametrize("arch", C.ARCHS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["16x16", "2x16x16"])
def test_param_specs_divisible_for_all_archs(arch, mesh):
    """Every parameter leaf's PartitionSpec must divide its shape on the
    production mesh — the property that makes the dry-run compile."""
    cfg = C.get(arch)
    rules = partition.make_rules(cfg, mesh)
    pspecs = partition.tree_pspecs(api.param_specs(cfg), rules)
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    flat_specs = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_specs) == len(flat_shapes)
    for spec, leaf in zip(flat_specs, flat_shapes):
        assert partition.check_divisibility(leaf.shape, spec, mesh), \
            f"{arch}: {leaf.shape} not divisible by {spec}"


@pytest.mark.parametrize("arch", C.ARCHS)
def test_cache_specs_divisible(arch):
    cfg = C.get(arch)
    rules = partition.make_rules(cfg, SINGLE)
    cspecs = partition.tree_pspecs(api.cache_specs(cfg), rules)
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, 128, max_len=32768, enc_len=4096))
    for spec, leaf in zip(
            jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P)),
            jax.tree.leaves(cache)):
        assert partition.check_divisibility(leaf.shape, spec, SINGLE), \
            f"{arch}: cache {leaf.shape} vs {spec}"


def test_mesh_oversubscription_rejected_with_recipe():
    """Regression: ``make_debug_mesh``/``make_production_mesh`` used to
    hand an oversubscribed shape straight to ``jax.make_mesh``, which
    fails with an opaque reshape error deep in sharding internals.  The
    launch helpers must reject the request up front and name the
    ``xla_force_host_platform_device_count`` recipe."""
    from repro.launch import mesh as mesh_mod

    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        mesh_mod.make_debug_mesh(data=ndev + 1, model=1)
    with pytest.raises(ValueError, match=f"needs {2 * ndev} devices"):
        mesh_mod.make_debug_mesh(data=ndev, model=2)
    if ndev < 256:
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            mesh_mod.make_production_mesh()
    # in-budget shapes still build a real mesh
    m = mesh_mod.make_debug_mesh(data=ndev, model=1)
    assert mesh_mod.mesh_chips(m) == ndev


def test_fleet_devices_oversubscription_rejected():
    """The fleet-side device resolver shares the same contract: asking
    for more devices than are visible is an actionable error, not an
    IndexError."""
    from repro.fleet import fleet_devices

    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        fleet_devices(ndev + 1)
    with pytest.raises(ValueError):
        fleet_devices(0)
    assert len(fleet_devices("all")) == ndev
    assert len(fleet_devices(ndev)) == ndev
