"""Observability tests: tracing, event counters, and the exporter.

The contracts under test:

* **zero-overhead-off** — with no active tracer, every instrumentation
  site is one contextvar read returning the shared no-op span; nothing
  is allocated, nothing recorded;
* **bit-identity** — tracing on vs off changes NOTHING about results,
  on all three tiers and through the fleet scheduler;
* **exact counters** — the host-side baked :class:`EventCounters` match
  the interpreter's dynamic ``stat_instrs`` / ``stat_cycles`` profile
  bit-for-bit, and the derived counters (back-edges, lane-steps) match
  first-principles expectations;
* **schema** — the exporter emits Chrome/Perfetto trace-event JSON the
  report CLI can parse back into a span tree that accounts for the
  drain's wall time, with balanced per-job async pairs.
"""
import json

import numpy as np
import pytest

from repro.core import Asm, EGPUConfig, compile_program, run_compiled, \
    run_program
from repro.core import machine as machine_mod
from repro.core.isa import NUM_OP_CLASSES, OpClass
from repro.fleet import Fleet
from repro.obs import NULL_SPAN, Tracer, aggregate, current_tracer, span
from repro.obs import report as report_mod
from repro.programs import build_matmul, build_reduction, build_transpose

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)


def _loop_program(iters: int, threads: int = 32):
    """One LOOP back-edge per iteration (saxpy over shared memory)."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lod(3, 1, 32)
    with a.loop(iters):
        a.fmul(3, 3, 4)
        a.fadd(3, 3, 2)
    a.sto(3, 1, 32)
    a.stop()
    data = np.arange(64, dtype=np.float32) / 7.0
    return a.assemble(threads_active=threads), data


def _suite():
    return [build_reduction(CFG, 32), build_reduction(CFG, 32, use_dot=True),
            build_transpose(CFG, 16), build_matmul(CFG, 8)]


# ------------------------------------------------------------------
# disabled path
# ------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    assert current_tracer() is None
    sp = span("anything", key="value")
    assert sp is NULL_SPAN and sp.active is False
    with sp as inner:
        inner.set(ignored=1)            # must be a no-op, not a crash
    assert span("again") is NULL_SPAN   # no allocation per call site


def test_tracer_scoping_restores_contextvar():
    tr = Tracer("t")
    with tr:
        assert current_tracer() is tr
        with Tracer("nested") as tr2:
            assert current_tracer() is tr2
        assert current_tracer() is tr
    assert current_tracer() is None


# ------------------------------------------------------------------
# bit-identity, all tiers
# ------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["blocks", "superblock"])
def test_compiled_tiers_bit_identical_under_tracing(mode):
    image, data = _loop_program(40)
    cp = compile_program(image, mode=mode)
    ref = cp.run(shared_init=data, tdx_dim=32)
    with Tracer("t"):
        got = cp.run(shared_init=data, tdx_dim=32)
    for leaf in ref._fields:
        assert np.array_equal(np.asarray(getattr(ref, leaf)),
                              np.asarray(getattr(got, leaf))), leaf


def test_interpreter_bit_identical_under_tracing():
    b = _suite()[0]
    ref = run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    with Tracer("t"):
        got = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
    for leaf in ref._fields:
        assert np.array_equal(np.asarray(getattr(ref, leaf)),
                              np.asarray(getattr(got, leaf))), leaf


def test_fleet_drain_bit_identical_under_tracing():
    def drain(trace):
        fleet = Fleet(CFG, batch_size=8, trace=trace)
        hs = [fleet.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
              for b in _suite() * 2]
        res = fleet.drain()
        return [res[h] for h in hs]

    for r0, r1 in zip(drain(False), drain(True)):
        assert np.array_equal(r0.shared_u32(), r1.shared_u32())
        assert r0.cycles == r1.cycles
        assert r0.profile() == r1.profile()


# ------------------------------------------------------------------
# event counters
# ------------------------------------------------------------------

def test_counters_match_interpreter_profile():
    """The host-baked per-class counters are bit-identical to the
    interpreter's dynamically-accumulated Fig.-6 profile."""
    for b in _suite():
        ec = compile_program(b.image).event_counters()
        st = run_program(b.image, shared_init=b.shared_init,
                         tdx_dim=b.tdx_dim)
        assert ec.instrs_by_class == tuple(
            int(x) for x in np.asarray(st.stat_instrs)), b.name
        assert ec.cycles_by_class == tuple(
            int(x) for x in np.asarray(st.stat_cycles)), b.name
        assert ec.cycles == int(st.cycles), b.name
        assert ec.instrs == sum(ec.instrs_by_class)


def test_counters_backedges_and_hazards():
    image, _ = _loop_program(23)
    ec = compile_program(image).event_counters()
    # the final trip falls through instead of jumping back
    assert ec.loop_backedges == 22
    assert len(ec.instrs_by_class) == NUM_OP_CLASSES
    # NOP padding is exactly the hazard-stall class
    assert ec.hazard_nop_instrs == ec.instrs_by_class[OpClass.NOPC]
    assert ec.flat()["instrs.NOPC"] == ec.hazard_nop_instrs


def test_counters_lane_utilization_full_warp():
    """An unpredicated program at full thread count offers and retires
    every lane-step: utilization exactly 1.0."""
    image, _ = _loop_program(8, threads=32)
    ec = compile_program(image).event_counters()
    assert ec.lane_steps_offered > 0
    assert ec.lane_steps_active == ec.lane_steps_offered
    assert ec.lane_utilization == 1.0


def test_counters_aggregate():
    ecs = [compile_program(b.image).event_counters() for b in _suite()]
    agg = aggregate(ecs)
    assert agg.instrs == sum(e.instrs for e in ecs)
    assert agg.loop_backedges == sum(e.loop_backedges for e in ecs)
    assert aggregate([None, None]) is None
    assert aggregate([ecs[0], None]).instrs == ecs[0].instrs


def test_fleet_results_carry_tier_and_counters():
    fleet = Fleet(CFG, batch_size=8, trace=True)
    b = build_matmul(CFG, 8)
    hs = [fleet.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
          for _ in range(8)]
    res = fleet.drain()
    for h in hs:
        assert res[h].tier in ("blocks", "superblock")
        assert res[h].counters is not None
    # per-job counters agree with the interpreter run of the same job
    st = run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    ec = res[hs[0]].counters
    assert ec.instrs_by_class == tuple(
        int(x) for x in np.asarray(st.stat_instrs))


# ------------------------------------------------------------------
# trace schema + report round-trip
# ------------------------------------------------------------------

def _traced_drain(jobs, batch=8):
    fleet = Fleet(CFG, batch_size=batch, trace=True)
    for b in jobs:
        fleet.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim)
    fleet.drain()
    return fleet.tracer


def test_trace_schema_and_span_tree(tmp_path):
    tracer = _traced_drain(_suite() * 2)
    out = tmp_path / "trace.json"
    tracer.save(str(out))

    events = report_mod.load(str(out))
    assert isinstance(events, list) and events
    xs = [e for e in events if e.get("ph") == "X"]
    names = {e["name"] for e in xs}
    assert {"drain", "partition", "bucket", "collect"} <= names
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0
        json.dumps(e)                     # strictly JSON-serializable

    roots = report_mod.build_tree(events)
    fracs = report_mod.coverage(roots, name="drain")
    assert fracs and min(fracs) >= 0.85   # bench gate holds the real bar

    # per-job async pairs are balanced and non-negative
    lats = report_mod.job_latencies(events)
    assert len(lats) == len(_suite() * 2)
    assert all(v >= 0 for v in lats.values())


def test_trace_records_tier_decisions_and_counters():
    # an iteration count no other test uses: the decision is only logged
    # on a compile-cache MISS (a hit never re-runs the TierPolicy)
    image, data = _loop_program(347)
    jobs = [(image, data)] * 8
    fleet = Fleet(CFG, batch_size=8, trace=True)
    for im, d in jobs:
        fleet.submit(im, d, tdx_dim=32)
    fleet.drain()
    events = fleet.tracer.to_chrome()["traceEvents"]
    decisions = report_mod.tier_decisions(events)
    assert decisions, "drain must log TierPolicy decisions"
    for d in decisions:
        assert d["tier"] in ("blocks", "superblock")
        assert "rule" in d and "features" in d
    totals = report_mod.counter_totals(events)
    assert totals and totals["instrs"] > 0


def test_report_cli_renders(tmp_path, capsys):
    tracer = _traced_drain(_suite())
    out = tmp_path / "trace.json"
    tracer.save(str(out))
    assert report_mod.main([str(out)]) == 0
    text = capsys.readouterr().out
    assert "drain" in text and "instrs" in text


# ------------------------------------------------------------------
# compile-time attribution
# ------------------------------------------------------------------

def test_fleet_stats_split_compile_from_wall():
    """A cold drain's XLA compile seconds land in ``compile_s``, not
    ``wall_s``; a warm repeat drain pays (almost) none of it."""
    image, data = _loop_program(501)      # unlikely-iters => cold compile
    fleet = Fleet(CFG, batch_size=4)
    for _ in range(4):
        fleet.submit(image, data, tdx_dim=32)
    fleet.drain()
    cold = fleet.stats.compile_s
    assert cold > 0.0
    assert fleet.stats.wall_s >= 0.0

    for _ in range(4):
        fleet.submit(image, data, tdx_dim=32)
    fleet.drain()
    warm = fleet.stats.compile_s - cold
    assert warm < cold / 10               # caches absorbed the compile
