"""Elastic checkpoint restore + mesh construction — run in a subprocess
with 8 placeholder host devices (the main pytest process must keep the
default single-device view)."""
import subprocess
import sys
import textwrap


def _run(code: str):
    # generous: a cold jax import plus hundreds of virtual host devices
    # takes several minutes on small CI/container machines
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo")


def test_elastic_restore_across_mesh_shapes(tmp_path):
    r = _run(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.training import checkpoint

    tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
             "b": jnp.ones((8,), jnp.float32)}}
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    sh_a = {{"w": NamedSharding(mesh_a, P("data", "model")),
             "b": NamedSharding(mesh_a, P("data"))}}
    placed = jax.tree.map(jax.device_put, tree, sh_a)
    checkpoint.save(r"{tmp_path}", 11, placed)

    # 'failed pod': restore the same logical state onto a (2, 4) mesh
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh_b = {{"w": NamedSharding(mesh_b, P("data", "model")),
             "b": NamedSharding(mesh_b, P("data"))}}
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, _ = checkpoint.restore(r"{tmp_path}", like,
                                           shardings=sh_b)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding.mesh.devices.shape == (2, 4)
    print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_production_mesh_shapes():
    r = _run("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    assert m1.devices.shape == (16, 16)
    assert m1.axis_names == ("data", "model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.shape == (2, 16, 16)
    assert m2.axis_names == ("pod", "data", "model")
    print("MESH_OK")
    """)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr
