"""End-to-end behaviour tests: the public drivers on CPU-sized configs."""
import numpy as np

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_end_to_end(tmp_path):
    losses = train_mod.main([
        "--arch", "granite-moe-3b-a800m", "--smoke", "--steps", "12",
        "--batch", "4", "--seq", "16", "--log-every", "100",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "6",
    ])
    assert len(losses) == 12 and np.isfinite(losses).all()


def test_train_driver_resume(tmp_path):
    ck = str(tmp_path / "ck")
    train_mod.main(["--arch", "yi-9b", "--smoke", "--steps", "6",
                    "--batch", "2", "--seq", "16", "--ckpt-dir", ck,
                    "--ckpt-every", "3", "--log-every", "100"])
    losses = train_mod.main(["--arch", "yi-9b", "--smoke", "--steps", "9",
                             "--batch", "2", "--seq", "16", "--ckpt-dir", ck,
                             "--resume", "--log-every", "100"])
    assert len(losses) >= 3           # resumed from step 6, ran to 9


def test_serve_driver_dynamic_wavefront():
    toks = serve_mod.main(["--arch", "internvl2-2b", "--smoke",
                           "--requests", "4", "--prompt-len", "8",
                           "--max-new", "5", "--max-len", "64"])
    assert toks.shape == (4, 6)


def test_serve_encdec():
    toks = serve_mod.main(["--arch", "seamless-m4t-large-v2", "--smoke",
                           "--requests", "2", "--prompt-len", "8",
                           "--max-new", "4", "--max-len", "32"])
    assert toks.shape == (2, 5)
