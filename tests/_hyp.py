"""Optional-``hypothesis`` shim for the property-based tests.

``from _hyp import given, settings, st`` works whether or not hypothesis
is installed; without it the ``@given`` tests are collected but skipped
(the strategy stubs are never executed).  Deterministic tests in the
same modules keep running either way.
"""
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:          # pragma: no cover - CI installs it
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy objects are only consumed by @given at run time,
        which the skip marker prevents."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
