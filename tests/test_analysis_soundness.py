"""Analyzer soundness fuzzing: the verifier never calls a faulting
program safe.

A seeded random program generator (``repro.programs.generator``) draws
from the full ISA grammar — nested loops, predicate regions,
subroutines, forward jumps, narrow thread-space personalities, shared
memory traffic, and (in hostile mode) deliberately broken constructs.
For every generated program where :func:`analyze` reports **no ERROR**,
a concrete numpy reference run must confirm:

* the program halts and trips no sequencer-stack fault,
* every access the analyzer *proved* in bounds stays in bounds,
* when a static step count is predicted, it matches the executed
  instruction count exactly,
* the analyzer's stack-depth bounds dominate the observed depths.

A small subsample is additionally run through the JAX interpreter tier
to keep the numpy reference itself honest (bit-identical architectural
state, zero hazard violations).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.analysis import analyze
from repro.analysis.concrete import concrete_run
from repro.core import EGPUConfig
from repro.programs.generator import generate_program

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

#: >= 500 programs total across the two sweeps (acceptance criterion)
CLEAN_SEEDS = range(0, 300)
HOSTILE_SEEDS = range(1000, 1260)


def _assert_sound(img) -> str:
    """Check every soundness invariant for one program; returns the
    disposition ('rejected' or 'verified')."""
    rep = analyze(img, img.threads_active)
    if rep.errors():
        return "rejected"
    res = concrete_run(img, img.threads_active)
    facts = rep.facts

    assert res.halted, "verified program did not halt"
    assert not res.stack_faults, \
        f"verified program faulted: {res.stack_faults}"

    proved = {pc for pc, v in facts["access_verdicts"].items()
              if v == "proved"}
    leaked = proved & set(res.oob_pcs)
    assert not leaked, f"proved-in-bounds access went OOB at {sorted(leaked)}"

    ss = facts["static_steps"]
    if ss is not None:
        assert ss == res.steps, \
            f"static_steps {ss} != executed {res.steps}"

    if not facts["analysis_clipped"]:
        assert facts["max_pred_depth"] >= res.max_pred_depth
        assert facts["max_loop_depth"] >= res.max_loop_depth
        assert facts["max_call_depth"] >= res.max_call_depth
    return "verified"


def test_soundness_clean_programs():
    verified = 0
    for seed in CLEAN_SEEDS:
        img = generate_program(CFG, seed)
        if _assert_sound(img) == "verified":
            verified += 1
    # the generator must actually exercise the "safe" verdict
    assert verified >= len(CLEAN_SEEDS) // 2


def test_soundness_hostile_programs():
    rejected = 0
    for seed in HOSTILE_SEEDS:
        img = generate_program(CFG, seed, hostility=1.0)
        if _assert_sound(img) == "rejected":
            rejected += 1
    # hostile mode must actually produce broken programs
    assert rejected >= len(HOSTILE_SEEDS) // 4


def test_hostile_mode_catches_known_fault_kinds():
    """Across the hostile sweep the verifier sees each planted fault
    class at least once (the generator plants all four kinds)."""
    codes: set = set()
    for seed in HOSTILE_SEEDS:
        img = generate_program(CFG, seed, hostility=1.0)
        rep = analyze(img, img.threads_active)
        codes |= {d.code for d in rep.errors()}
        if {"pred-underflow", "bad-branch-target",
                "loop-overflow"} <= codes:
            break
    assert "pred-underflow" in codes
    assert "bad-branch-target" in codes
    assert "loop-overflow" in codes


@pytest.mark.parametrize("seed", [0, 3, 5, 7, 9, 13, 17, 21, 28, 35, 42, 57])
def test_concrete_reference_matches_interpreter(seed):
    """The numpy reference executor is bit-identical to the JAX
    interpreter on generated programs (and the schedule is hazard-free),
    so the soundness sweep's ground truth is itself grounded."""
    from repro.core.executor import run_program
    img = generate_program(CFG, seed)
    rep = analyze(img, img.threads_active)
    if rep.errors():
        pytest.skip("analyzer rejects this seed (conservative)")
    res = concrete_run(img, img.threads_active)
    st = run_program(img, threads=img.threads_active)
    assert bool(st.halted) == res.halted
    assert int(st.steps) == res.steps
    assert np.array_equal(res.regs, np.asarray(st.regs))
    assert np.array_equal(res.shared, np.asarray(st.shared))
    assert int(st.hazard_violations) == 0


@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.booleans())
@settings(max_examples=50, deadline=None)
def test_soundness_property(seed, hostile):
    img = generate_program(CFG, seed, hostility=1.0 if hostile else 0.0)
    _assert_sound(img)
