"""Area/Fmax model vs the paper's Tables 4, 5 and 6."""
import pytest

from repro.core import area_model, table4_configs, table5_configs
from repro.core.area_model import PAPER_TABLE4, PAPER_TABLE5, resources


@pytest.mark.parametrize("name", list(PAPER_TABLE4))
def test_table4_m20k_exact(name):
    cfg = table4_configs()[name]
    r = resources(cfg)
    alm, ff, dsp, m20k, soft, fmax = PAPER_TABLE4[name]
    assert r.m20ks == m20k, f"{name}: M20K {r.m20ks} != paper {m20k}"
    assert r.dsps == dsp
    assert r.fmax_mhz == fmax


@pytest.mark.parametrize("name", list(PAPER_TABLE4))
def test_table4_alm_ff_within_tolerance(name):
    cfg = table4_configs()[name]
    r = resources(cfg)
    alm, ff, *_ = PAPER_TABLE4[name]
    assert abs(r.alms - alm) / alm < 0.15, (r.alms, alm)
    assert abs(r.ffs - ff) / ff < 0.20, (r.ffs, ff)


@pytest.mark.parametrize("name", list(PAPER_TABLE5))
def test_table5_qp(name):
    cfg = table5_configs()[name]
    r = resources(cfg)
    alm, ff, dsp, m20k, soft, fmax = PAPER_TABLE5[name]
    assert abs(r.m20ks - m20k) <= 1      # §5.5 QP halving (1-block slack)
    assert r.dsps == dsp
    assert r.fmax_mhz == 600.0
    assert abs(r.alms - alm) / alm < 0.30


def test_qp_memory_halving_requires_min_register_space():
    from repro.core import EGPUConfig
    small = EGPUConfig(memory_mode="qp", max_threads=512, regs_per_thread=16,
                       shared_kb=8)
    dp = EGPUConfig(memory_mode="dp", max_threads=512, regs_per_thread=16,
                    shared_kb=8)
    # 512*16/16 = 512 <= 2047: below the QP minimum -> same reg M20Ks as DP
    assert area_model.m20k_registers(small) == area_model.m20k_registers(dp)


def test_predicates_cost_about_half_more_logic():
    """§5.3: predicate support increases soft logic by ~50%."""
    from repro.core import EGPUConfig
    base = EGPUConfig(alu_bits=16, shift_bits=16, alu_features="full",
                      predicate_levels=0, shared_kb=32)
    pred = base.replace(predicate_levels=5)
    r0, r1 = resources(base), resources(pred)
    ratio = r1.alms / r0.alms
    assert 1.25 < ratio < 1.75


def test_normalized_cost_and_nios_reference():
    assert area_model.NIOS_ALMS + 100 * area_model.NIOS_DSPS == 1400
    cfg = table4_configs()["medium_dp_b"]
    r = resources(cfg)
    # §7: the benchmark configuration has an equivalent cost ~7400-9000 ALMs
    assert 7000 < r.normalized_cost < 16000
