"""Verified pre-compile optimizer tests.

The contract: :func:`repro.analysis.optimizer.optimize_image` may fold
input-independent computations into LODI and drop dead register writes,
but the optimized image's architectural end state (registers, shared
memory, halt flag) must be **bit-identical** to the original for any
shared-memory input — enforced here across all three execution tiers
(interpreter, basic-block compiler, superblock) over the whole program
suite, plus generated programs.
"""
import numpy as np
import pytest

from repro.analysis.concrete import concrete_run
from repro.analysis.lint import suite
from repro.analysis.optimizer import (OptResult, optimize_image,
                                      optimize_image_cached)
from repro.core import EGPUConfig, compile_program
from repro.core.blockc import run_compiled
from repro.core.executor import run_program
from repro.programs.generator import generate_program

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

SUITE = suite(CFG)

TIERS = {
    "interp": lambda img, b: run_program(
        img, threads=img.threads_active, tdx_dim=b.tdx_dim,
        shared_init=b.shared_init),
    "blocks": lambda img, b: run_compiled(
        img, threads=img.threads_active, tdx_dim=b.tdx_dim,
        shared_init=b.shared_init, mode="blocks"),
    "superblock": lambda img, b: run_compiled(
        img, threads=img.threads_active, tdx_dim=b.tdx_dim,
        shared_init=b.shared_init, mode="superblock"),
}


def _arch_state(st):
    return (np.asarray(st.regs), np.asarray(st.shared), bool(st.halted))


@pytest.mark.parametrize("bench", SUITE, ids=[b.name for b in SUITE])
def test_suite_bit_identical_across_tiers(bench):
    """Acceptance criterion: optimizer output is bit-identical on the
    full suite under every tier."""
    res = optimize_image(bench.image, bench.image.threads_active,
                         tdx_dim=bench.tdx_dim)
    assert not res.reason or not res.changed, res.reason
    for name, tier in TIERS.items():
        ref = _arch_state(tier(bench.image, bench))
        got = _arch_state(tier(res.image, bench))
        assert np.array_equal(ref[0], got[0]), f"{name}: regs differ"
        assert np.array_equal(ref[1], got[1]), f"{name}: shared differs"
        assert ref[2] == got[2], f"{name}: halt flag differs"


@pytest.mark.parametrize("bench", SUITE, ids=[b.name for b in SUITE])
def test_optimized_schedule_is_hazard_free(bench):
    res = optimize_image(bench.image, bench.image.threads_active,
                         tdx_dim=bench.tdx_dim)
    st = run_program(res.image, threads=res.image.threads_active,
                     tdx_dim=bench.tdx_dim, shared_init=bench.shared_init)
    assert int(st.hazard_violations) == 0


def test_fft_actually_optimizes():
    bench = next(b for b in SUITE if b.name.startswith("fft_16"))
    res = optimize_image(bench.image, bench.image.threads_active,
                         tdx_dim=bench.tdx_dim)
    assert res.changed
    assert res.folds >= 1
    assert res.dce_removed >= 1
    assert res.image.n < bench.image.n


def test_reduction_round_trips_unchanged():
    """NOP strip + re-schedule reproduces the input exactly when there
    is nothing to optimize — the reassembler is the identity."""
    bench = next(b for b in SUITE if b.name == "reduction_32_dp")
    res = optimize_image(bench.image, bench.image.threads_active,
                         tdx_dim=bench.tdx_dim)
    assert not res.changed
    assert res.image.words.tobytes() == bench.image.words.tobytes()


def test_input_errors_bail_without_change():
    from repro.core import Asm
    a = Asm(CFG)
    a.lodi(1, CFG.shared_words + 5)
    a.sto(1, 1)
    img = a.assemble(threads_active=32)
    res = optimize_image(img, 32)
    assert not res.changed
    assert res.reason == "input-has-errors"
    assert res.image is img


def test_optimize_cached_hits():
    bench = SUITE[0]
    r1 = optimize_image_cached(bench.image, bench.image.threads_active,
                               tdx_dim=bench.tdx_dim)
    r2 = optimize_image_cached(bench.image, bench.image.threads_active,
                               tdx_dim=bench.tdx_dim)
    assert r1 is r2


def test_compile_program_optimize_flag():
    bench = next(b for b in SUITE if b.name.startswith("fft_16"))
    cp = compile_program(bench.image, bench.image.threads_active,
                         optimize=True)
    st = cp.run(shared_init=bench.shared_init, tdx_dim=bench.tdx_dim)
    ref = run_program(bench.image, threads=bench.image.threads_active,
                      tdx_dim=bench.tdx_dim, shared_init=bench.shared_init)
    assert np.array_equal(np.asarray(st.regs), np.asarray(ref.regs))
    assert np.array_equal(np.asarray(st.shared), np.asarray(ref.shared))


@pytest.mark.parametrize("seed", [0, 2, 3, 5, 11, 19, 23, 31])
def test_generated_programs_optimize_equivalently(seed):
    """Generated programs through the optimizer: the built-in
    differential verification must hold, and the concrete reference
    must agree between original and optimized images."""
    img = generate_program(CFG, seed)
    res = optimize_image(img, img.threads_active)   # raises on divergence
    assert isinstance(res, OptResult)
    a = concrete_run(img, img.threads_active)
    b = concrete_run(res.image, res.image.threads_active)
    assert a.halted == b.halted
    assert np.array_equal(a.regs, b.regs)
    assert np.array_equal(a.shared, b.shared)
