"""Pallas kernel sweeps: interpret-mode vs pure-jnp oracles across shapes,
dtypes and activity masks (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.dot_product import kernel as dpk, ref as dpr
from repro.kernels.flash_attention import kernel as fak, ref as far
from repro.kernels.wavefront_alu import kernel as wak, ref as war
from repro.kernels.wavefront_matmul import kernel as wmk, ref as wmr

RNG = np.random.default_rng(42)


def randf(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# --- wavefront_alu ----------------------------------------------------------

@pytest.mark.parametrize("t,lanes", [(8, 128), (32, 128), (64, 256)])
@pytest.mark.parametrize("op", war.OPS)
def test_wavefront_alu_shapes(t, lanes, op):
    a, b, init = randf(t, lanes), randf(t, lanes), randf(t, lanes)
    act = jnp.asarray(RNG.integers(0, 2, t // 8), jnp.int32)
    got = wak.wavefront_alu(a, b, init, act, op, interpret=True)
    exp = war.wavefront_alu_ref(a, b, init, act, op)
    np.testing.assert_allclose(got, exp, rtol=1e-6)


@given(st.lists(st.integers(0, 1), min_size=4, max_size=4))
@settings(max_examples=8, deadline=None)
def test_wavefront_alu_mask_property(mask):
    """Inactive tiles keep init exactly (eGPU write_enable semantics)."""
    t, lanes = 32, 128
    a, b, init = randf(t, lanes), randf(t, lanes), randf(t, lanes)
    act = jnp.asarray(mask, jnp.int32)
    got = wak.wavefront_alu(a, b, init, act, "add", interpret=True)
    for i, m in enumerate(mask):
        blk = got[i * 8:(i + 1) * 8]
        ref_blk = (a + b if m else init)[i * 8:(i + 1) * 8]
        np.testing.assert_array_equal(np.asarray(blk), np.asarray(ref_blk))


# --- dot_product ------------------------------------------------------------

@pytest.mark.parametrize("t,l", [(8, 128), (64, 128), (32, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_product_sweep(t, l, dtype):
    a, b = randf(t, l, dtype=dtype), randf(t, l, dtype=dtype)
    act = jnp.asarray(RNG.integers(0, 2, t // 8), jnp.int32)
    got = dpk.dot_product(a, b, act, interpret=True)
    exp = dpr.dot_product_ref(a, b, act)
    # f32 tolerance: kernel and reference accumulate t*l (up to 16K)
    # products in different orders, so ulp-level drift scales with the
    # cancellation in the sum
    np.testing.assert_allclose(got, exp, rtol=2e-2 if dtype == jnp.bfloat16
                               else 1e-4)


# --- wavefront_matmul -------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 256),
                                   (384, 256, 128)])
def test_wavefront_matmul_sweep(m, k, n):
    a = randf(m, k) / np.sqrt(k)
    b = randf(k, n) / np.sqrt(k)
    act = jnp.asarray(RNG.integers(0, 2, m // 128), jnp.int32)
    got = wmk.wavefront_matmul(a, b, act, interpret=True)
    exp = wmr.wavefront_matmul_ref(a, b, act)
    np.testing.assert_allclose(got, exp, atol=2e-5)


def test_wavefront_matmul_all_inactive_is_zero():
    a, b = randf(256, 128), randf(128, 128)
    act = jnp.zeros(2, jnp.int32)
    got = wmk.wavefront_matmul(a, b, act, interpret=True)
    assert np.all(np.asarray(got) == 0)


# --- flash_attention --------------------------------------------------------

@pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (128, 512)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(sq, sk, causal):
    b, h, d = 2, 2, 64
    q, k, v = randf(b, h, sq, d), randf(b, h, sk, d), randf(b, h, sk, d)
    lens = jnp.asarray([sk, max(1, sk - 100)], jnp.int32)
    got = fak.flash_attention(q, k, v, lens, causal, interpret=True)
    exp = far.mha_ref(q, k, v, lens, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_flash_attention_bf16():
    b, h, sq, sk, d = 1, 2, 128, 256, 64
    q = randf(b, h, sq, d, dtype=jnp.bfloat16)
    k = randf(b, h, sk, d, dtype=jnp.bfloat16)
    v = randf(b, h, sk, d, dtype=jnp.bfloat16)
    got = fak.flash_attention(q, k, v, None, True, interpret=True)
    exp = far.mha_ref(q, k, v, None, True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


@given(st.integers(1, 4))
@settings(max_examples=4, deadline=None)
def test_flash_attention_ragged_lengths_property(nblocks):
    """Tokens beyond a request's length never influence its output —
    the dynamic-wavefront guarantee at the kernel level."""
    b, h, d = 2, 1, 64
    sk = 128 * nblocks
    q, k, v = randf(b, h, 128, d), randf(b, h, sk, d), randf(b, h, sk, d)
    ln = jnp.asarray([sk // 2, sk], jnp.int32)
    got1 = fak.flash_attention(q, k, v, ln, False, interpret=True)
    # poison the masked tail of request 0: output must not change
    k2 = k.at[0, :, sk // 2:].set(1e4)
    v2 = v.at[0, :, sk // 2:].set(-1e4)
    got2 = fak.flash_attention(q, k2, v2, ln, False, interpret=True)
    np.testing.assert_allclose(np.asarray(got1[0]), np.asarray(got2[0]),
                               atol=1e-5)
