"""Model-zoo tests: per-arch smoke (forward/train step, shapes, no NaNs)
plus the deep consistency checks (prefill+decode == teacher-forced
forward; chunked SSD == recurrence; parallel mLSTM == recurrence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import api, mamba2, xlstm

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, 1024)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_train_step_runs_and_is_finite(arch):
    cfg = C.get_smoke(arch)
    params = api.init_params(KEY, cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: api.loss(cfg, p, batch))(params)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0
    # expected output shape via logits path
    assert 0 < float(loss) < 2 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", C.ARCHS)
def test_smoke_decode_shapes_and_finite(arch):
    cfg = C.get_smoke(arch)
    params = api.init_params(KEY, cfg)
    b = 2
    cache = api.init_cache(cfg, b, max_len=64, enc_len=16)
    lengths = jnp.zeros((b,), jnp.int32)
    tok = jnp.zeros((b,), jnp.int32)
    logits, cache, lengths = api.decode(cfg, params, cache, tok, lengths)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.all(lengths == 1))


def test_full_configs_match_assignment():
    """The exact architecture parameters from the brief."""
    c = C.get("zamba2-1p2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab, c.ssm_state) == (38, 2048, 32, 32, 8192, 32000, 64)
    c = C.get("qwen3-moe-30b-a3b")
    assert (c.n_layers, c.d_model, c.num_experts, c.top_k,
            c.expert_d_ff, c.vocab) == (48, 2048, 128, 8, 768, 151936)
    c = C.get("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab) == (126, 16384, 128, 8, 53248, 128256)
    c = C.get("phi3-medium-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff,
            c.vocab) == (40, 5120, 40, 10, 17920, 100352)
    c = C.get("seamless-m4t-large-v2")
    assert (c.enc_layers, c.dec_layers, c.d_model, c.vocab) == \
        (24, 24, 1024, 256206)
    c = C.get("minitron-4b")
    assert (c.n_layers, c.d_model, c.vocab) == (32, 3072, 256000)
    c = C.get("yi-9b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (48, 4096, 11008, 64000)
    c = C.get("granite-moe-3b-a800m")
    assert (c.num_experts, c.top_k, c.expert_d_ff, c.vocab) == \
        (40, 8, 512, 49155)
    c = C.get("xlstm-350m")
    assert (c.n_layers, c.d_model, c.vocab) == (24, 1024, 50304)
    c = C.get("internvl2-2b")
    assert (c.n_layers, c.d_model, c.vocab) == (24, 2048, 92553)


def test_prefill_decode_matches_teacher_forcing_dense():
    """KV-cache correctness: prefill P tokens then decode the rest, logits
    must match the full forward pass."""
    from repro.models import transformer
    cfg = C.get_smoke("yi_9b").replace(dtype=jnp.float32)
    params = api.init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)))
    full_logits = transformer.forward(cfg, params, toks)
    p = 5
    logits_p, cache, lengths = transformer.prefill(cfg, params, toks[:, :p],
                                                   max_len=16)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, p - 1]), atol=2e-4)
    for i in range(p, 12):
        logits_i, cache, lengths = transformer.decode_step(
            cfg, params, cache, toks[:, i], lengths)
        np.testing.assert_allclose(np.asarray(logits_i),
                                   np.asarray(full_logits[:, i]), atol=2e-4,
                                   err_msg=f"position {i}")


def test_ssd_chunked_equals_recurrence():
    """mamba2: chunked parallel training path == step-by-step decode."""
    cfg = C.get_smoke("zamba2_1p2b").replace(dtype=jnp.float32)
    p, _ = mamba2.ssd_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(5)
    u = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.3,
                    jnp.float32)
    y_par, final = mamba2.ssd_apply(cfg, p, u, return_state=True)
    st = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                   jnp.float32)
    ys = []
    for t in range(16):
        y_t, st = mamba2.ssd_decode(cfg, p, u[:, t], st)
        ys.append(y_t)
    y_rec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(st), atol=3e-4)


def test_mlstm_parallel_equals_recurrence():
    cfg = C.get_smoke("xlstm_350m").replace(dtype=jnp.float32)
    p, _ = xlstm.mlstm_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)) * 0.5,
                    jnp.float32)
    y_par = xlstm.mlstm_apply(cfg, p, x)
    st = xlstm.mlstm_state(cfg, 2)
    ys = []
    for t in range(12):
        y_t, st = xlstm.mlstm_decode(cfg, p, x[:, t], st)
        ys.append(y_t)
    y_rec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=3e-4)


def test_zamba2_prefill_decode_consistency():
    from repro.models import zamba2
    cfg = C.get_smoke("zamba2_1p2b").replace(dtype=jnp.float32)
    params = api.init_params(KEY, cfg)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 10)))
    full = zamba2.forward(cfg, params, toks)
    logits_p, cache, lengths = zamba2.prefill(cfg, params, toks[:, :6],
                                              max_len=16)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, 5]), atol=5e-4)
    logits_d, cache, lengths = zamba2.decode_step(cfg, params, cache,
                                                  toks[:, 6], lengths)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, 6]), atol=5e-4)


def test_moe_expert_choice_routes_by_gate():
    """High-gate tokens must reach their expert; output differs from zeros
    and matches the dense oracle within routing-approximation error."""
    from repro.models import moe
    cfg = C.get_smoke("qwen3_moe_30b_a3b").replace(dtype=jnp.float32)
    p, _ = moe.moe_params(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.5,
                    jnp.float32)
    y_ec = moe.moe_apply(cfg, p, x, mode="expert_choice")
    y_td = moe.moe_apply(cfg, p, x, mode="token_dense")
    assert jnp.any(jnp.abs(y_ec) > 0)
    # both routings produce correlated outputs (cosine > 0.5)
    a, b = y_ec.ravel(), y_td.ravel()
    cos = jnp.dot(a, b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + 1e-9)
    assert float(cos) > 0.5


def test_long_context_flags():
    assert C.get("zamba2-1p2b").supports_long_context()
    assert C.get("xlstm-350m").supports_long_context()
    assert not C.get("yi-9b").supports_long_context()
    cells = list(C.cells())
    assert len(cells) == 40
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8
