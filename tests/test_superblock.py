"""Superblock-tier tests: the folded static path vs the interpreter.

The contract under test: the superblock runner — LOOP back-edges
unrolled into straight-line traces or ``fori_loop``-fused, no block
``switch`` dispatch at all — produces final machine states
**bit-identical** to :func:`repro.core.executor.run_program` on every
leaf, across the program suite and the configuration space, exactly like
the basic-block tier it sits on top of.  Also pinned here: the schedule
flattening invariant (a folded schedule always executes the exact
simulated path), the trace-budget fallback to the basic-block driver,
and the fleet's superblock tier counters.
"""
import numpy as np
import pytest

from repro.core import (Asm, BlockCompileError, CompiledProgram, EGPUConfig,
                        Op, Typ, compile_program, run_compiled, run_program)
from repro.core import blockc
from repro.core import machine as machine_mod
from repro.core.blockc import _sched_execd, _sched_insts, _trace_cost
from repro.fleet import Fleet
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose)

CFG = EGPUConfig(max_threads=32, regs_per_thread=32, shared_kb=4,
                 alu_bits=32, shift_bits=32, predicate_levels=4,
                 has_dot=True, has_invsqr=True)

CONFIGS = {
    "dp": CFG,
    "qp": CFG.replace(memory_mode="qp"),
    "alu16": CFG.replace(alu_bits=16, shift_bits=16),
    "nopred": CFG.replace(predicate_levels=0),
}


def _assert_states_equal(ref, got, label):
    for leaf in ref._fields:
        r = np.asarray(getattr(ref, leaf))
        g = np.asarray(getattr(got, leaf))
        assert np.array_equal(r, g), f"{label}: {leaf} differs"


def _suite(cfg):
    builders = [
        lambda: build_reduction(cfg, 32),
        lambda: build_reduction(cfg, 32, use_dot=True),
        lambda: build_reduction(cfg, 32, no_dynamic=True),
        lambda: build_transpose(cfg, 16),
        lambda: build_matmul(cfg, 8),
        lambda: build_bitonic(cfg, 16),
        lambda: build_fft(cfg, 16),
    ]
    out = []
    for b in builders:
        try:
            out.append(b())
        except ValueError:
            pass            # feature not present in this config
    return out


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_superblock_equivalence_sweep(name):
    """Acceptance: superblock == interpreter, bit for bit, every leaf,
    every suite program, every config axis — and the suite actually
    lands on the superblock tier (zero switch dispatches)."""
    cfg = CONFIGS[name]
    benches = _suite(cfg)
    assert benches, name
    for b in benches:
        cp = compile_program(b.image, mode="superblock")
        assert cp.mode == "superblock", b.name
        assert cp.switch_dispatches == 0, b.name
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        got = cp.run(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        _assert_states_equal(ref, got, f"{name}/{b.name}")


def test_schedule_flattens_to_the_simulated_path():
    """The fold invariant: for every suite program the schedule executes
    exactly ``sim.steps`` instructions, and loop-heavy programs fold to
    far fewer *traced* instructions than executed ones."""
    for b in _suite(CFG):
        cp = compile_program(b.image)
        assert cp.schedule is not None, b.name
        assert _sched_execd(cp.schedule) == cp.sim.steps, b.name
        assert _sched_insts(cp.schedule) <= cp.sim.steps, b.name
    mm = compile_program(build_matmul(CFG, 8).image)
    assert _sched_insts(mm.schedule) < mm.sim.steps // 4  # loops folded


def test_loop_unroll_small_counts():
    """A small LOOP unrolls fully; result and every leaf match."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    with a.loop(7):
        a.add(2, 2, 5)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False, mode="superblock")
    _assert_states_equal(ref, got, "unroll")
    assert machine_mod.shared_as_u32(got)[0] == 7


def test_loop_fori_large_counts():
    """A large LOOP takes the ``fori_loop`` path: the folded schedule
    stays tiny while the executed path is tens of thousands of steps."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    with a.loop(5000):
        a.add(2, 2, 5)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    cp = compile_program(img, mode="superblock")
    assert cp.sim.steps > 10_000
    assert _trace_cost(cp.schedule) < 64          # body traced once
    ref = run_program(img, tdx_dim=32)
    got = cp.run(tdx_dim=32)
    _assert_states_equal(ref, got, "fori")
    assert machine_mod.shared_as_u32(got)[0] == 5000


def test_nested_loops_fold_recursively():
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    with a.loop(40):
        with a.loop(25):
            a.add(2, 2, 5)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    cp = compile_program(img, mode="superblock")
    assert _sched_execd(cp.schedule) == cp.sim.steps
    ref = run_program(img, tdx_dim=32)
    _assert_states_equal(ref, cp.run(tdx_dim=32), "nested")
    assert machine_mod.shared_as_u32(cp.run(tdx_dim=32))[0] == 1000


def test_jsr_inside_loop_inside_predicate():
    """JSR/RTS nested in a LOOP nested in IF/ELSE — the loop body spans
    a call and returns, and still folds."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 8)
    a.lodi(5, 1)
    a.lodi(6, 0)
    a.if_("lt", 1, 2, typ=Typ.U32)
    with a.loop(3):
        a.jsr("incr")
    a.else_()
    a.lodi(6, 99)
    a.endif()
    a.sto(6, 1, 0)
    a.stop()
    a.label("incr")
    a.add(6, 6, 5)
    a.rts()
    img = a.assemble(threads_active=32)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False, mode="superblock")
    _assert_states_equal(ref, got, "jsr-in-loop")
    out = machine_mod.shared_as_u32(got)[:32]
    assert np.array_equal(out, np.where(np.arange(32) < 8, 3, 99))


def test_first_iteration_peels_on_mid_body_entry():
    """A JMP into the middle of a loop body: the first (partial)
    iteration fails the fold comparison and peels off inline; the
    remaining full iterations still fold."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    a.lodi(6, 2)
    a.init(4)
    a.jmp("mid")
    a.label("head")
    a.add(2, 2, 6)
    a.label("mid")
    a.add(2, 2, 5)
    a.loop_("head")
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32, schedule_nops=False)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False, mode="superblock")
    _assert_states_equal(ref, got, "peel")
    # 5 executions of "mid" (+1), 4 of "head" (+2)
    assert machine_mod.shared_as_u32(got)[0] == 13


def test_unbalanced_if_inside_loop_body():
    """pdepth grows across iterations (IF with no ENDIF in the body) —
    the superblock carries pdepth dynamically, so folding stays exact."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 8)
    with a.loop(3):
        a.emit(Op.IF_LT, ra=1, rb=2, typ=Typ.U32)
        a.lodi(3, 7)
    a.sto(3, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    ref = run_program(img, tdx_dim=32)
    got = run_compiled(img, tdx_dim=32, fallback=False, mode="superblock")
    _assert_states_equal(ref, got, "unbalanced-if")


def test_predicates_inside_fori_folded_loop():
    """Balanced IF/ENDIF inside a loop large enough for the fori path."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 8)
    a.lodi(4, 0)
    a.lodi(5, 1)
    with a.loop(300):
        a.if_("lt", 1, 2, typ=Typ.U32)
        a.add(4, 4, 5)
        a.endif()
    a.sto(4, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    cp = compile_program(img, mode="superblock")
    assert _trace_cost(cp.schedule) < cp.sim.steps // 10
    ref = run_program(img, tdx_dim=32)
    _assert_states_equal(ref, cp.run(tdx_dim=32), "pred-fori")


def test_trace_budget_falls_back_to_blocks():
    """Over the trace budget, ``mode="auto"`` silently drops to the
    basic-block driver and ``mode="superblock"`` raises — the
    superblock → basic-block → interpreter chain."""
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    with a.loop(200):
        a.add(2, 2, 5)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    old = blockc._MAX_TRACE
    blockc._MAX_TRACE = 4            # schedule cannot fit
    try:
        cp = CompiledProgram(img, 32)
        assert cp.mode == "blocks"
        assert cp.switch_dispatches == cp.sim.dispatches > 0
        with pytest.raises(BlockCompileError):
            CompiledProgram(img, 32, mode="superblock")
    finally:
        blockc._MAX_TRACE = old
    ref = run_program(img, tdx_dim=32)
    _assert_states_equal(ref, cp.run(tdx_dim=32), "budget-fallback")


def test_blocks_mode_still_available_and_identical():
    """``mode="blocks"`` forces the basic-block driver; both compiled
    tiers agree with the interpreter on every leaf."""
    for b in _suite(CFG)[:3]:
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        got = run_compiled(b.image, shared_init=b.shared_init,
                           tdx_dim=b.tdx_dim, fallback=False, mode="blocks")
        _assert_states_equal(ref, got, f"blocks/{b.name}")
        cp = compile_program(b.image, mode="blocks")
        assert cp.mode == "blocks"


def test_superblock_batched_lock_step():
    """run_batch on the superblock tier: per-slot results equal per-job
    interpreter runs (different data, same folded trace)."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lodi(5, 0)
    a.lodi(6, 1)
    with a.loop(50):
        a.add(5, 5, 6)
        a.fadd(2, 2, 2)
    a.sto(2, 1, 0)
    a.sto(5, 1, 32)
    a.stop()
    img = a.assemble(threads_active=32)
    cp = compile_program(img, mode="superblock")
    rng = np.random.default_rng(11)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(4)]
    out = cp.run_batch(datas, [32] * 4)
    for i, d in enumerate(datas):
        ref = run_program(img, shared_init=d, tdx_dim=32)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              np.asarray(out.shared)[i]), i
        assert int(out.cycles[i]) == int(ref.cycles)
        assert int(out.steps[i]) == int(ref.steps)


def test_fleet_superblock_tier_counters():
    """Same-program groups land on the superblock tier and the stats
    split (superblock vs blocks-only) is reported."""
    a = Asm(CFG)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lodi(6, 1)
    with a.loop(20):
        a.fadd(2, 2, 2)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    rng = np.random.default_rng(3)
    datas = [rng.standard_normal(32).astype(np.float32) for _ in range(6)]
    fleet = Fleet(CFG, batch_size=4)
    hs = [fleet.submit(img, d, tdx_dim=32) for d in datas]
    results = fleet.drain()
    assert fleet.stats.compiled_jobs == 6
    assert fleet.stats.superblock_jobs == 6
    assert fleet.stats.superblock_batches == fleet.stats.compiled_batches == 2
    for d, h in zip(datas, hs):
        ref = run_program(img, shared_init=d, tdx_dim=32)
        assert np.array_equal(machine_mod.shared_as_u32(ref),
                              results[h].shared_u32())


def test_validate_false_matches_fast_interpreter():
    a = Asm(CFG)
    a.tdx(1)
    a.lodi(2, 0)
    a.lodi(5, 1)
    with a.loop(100):
        a.add(2, 2, 5)
    a.sto(2, 1, 0)
    a.stop()
    img = a.assemble(threads_active=32)
    ref = run_program(img, validate=False, tdx_dim=32)
    got = run_compiled(img, validate=False, tdx_dim=32, fallback=False,
                       mode="superblock")
    _assert_states_equal(ref, got, "validate=False")
