"""Quickstart: assemble and run an eGPU program, inspect cycles/profile.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Asm, benchmark_config, machine, profile, run_program

# 1. Configure an eGPU instance (static scalability: every knob is a
#    configuration-time parameter, paper Tables 4-6).
cfg = benchmark_config("dp", has_dot=True)     # 512 threads, 32 regs, 128KB
print(f"eGPU: {cfg.max_threads} threads x {cfg.regs_per_thread} regs, "
      f"{cfg.shared_kb}KB shared, Fmax {cfg.fmax_mhz} MHz")

from repro.core import resources
r = resources(cfg)
print(f"resources: {r.alms} ALMs, {r.dsps} DSPs, {r.m20ks} M20Ks "
      f"(normalized cost {r.normalized_cost})")

# 2. Write a kernel in eGPU assembly: y[i] = a[i] * b[i] + a[i],
#    then a SUM reduction written back with a 1-cycle MCU store
#    (dynamic scalability, paper §3.1).
a = Asm(cfg)
a.tdx(1)                       # r1 = thread id
a.lod(2, 1, 0)                 # r2 = a[i]        (shared[0:256])
a.lod(3, 1, 256)               # r3 = b[i]        (shared[256:512])
a.fmul(4, 2, 3)                # r4 = a*b
a.fadd(4, 4, 2)                # r4 += a
a.sto(4, 1, 512)               # y[i] = r4
a.sum_(5, 4)                   # SP0.r5 = sum(y)  (dot-product unit)
a.lodi(6, 768, tsc="mcu")
a.sto(5, 6, 0, tsc="mcu")      # shared[768] = total, single-cycle write
a.stop()

img = a.assemble(threads_active=256)
print(f"\nprogram: {img.n} instructions "
      f"(incl. auto-inserted hazard NOPs), IW={img.words[0]:011x}...")

# 3. Load data, run, verify.
rng = np.random.default_rng(0)
av, bv = rng.standard_normal(256).astype(np.float32), \
    rng.standard_normal(256).astype(np.float32)
st = run_program(img, shared_init=np.concatenate([av, bv]), tdx_dim=256)

y = machine.shared_as_f32(st)[512:768]
total = machine.shared_as_f32(st)[768]
assert np.allclose(y, av * bv + av, atol=1e-5)
assert np.isclose(total, (av * bv + av).sum(), rtol=1e-4)
print(f"correct. cycles={int(st.cycles)} "
      f"({cfg.cycles_to_us(int(st.cycles)):.3f} us at {cfg.fmax_mhz} MHz), "
      f"hazard violations={int(st.hazard_violations)}")
print("profile:", {k: v for k, v in profile(st).items() if v[1]})
