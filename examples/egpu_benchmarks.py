"""Run the paper's five benchmarks (Table 7/8) and the dynamic-scaling
ablation end to end, printing the comparison against the paper.

  PYTHONPATH=src python examples/egpu_benchmarks.py
"""
from repro.core import benchmark_config
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose, run_bench)

PAPER = {"reduction": 202, "transpose": 5529, "matmul": 26278,
         "bitonic": 3728, "fft": 1695}

print(f"{'benchmark':<14} {'cycles':>8} {'us':>8} {'ok':>4} {'NOPs%':>6}")
for name, builder, n, kw in [
        ("reduction", build_reduction, 64, {}),
        ("transpose", build_transpose, 64, {}),
        ("matmul", build_matmul, 32, {}),
        ("bitonic", build_bitonic, 64, {"pred": 2}),
        ("fft", build_fft, 64, {})]:
    cfg = benchmark_config("dp", predicate_levels=kw.pop("pred", 0))
    r = run_bench(builder(cfg, n, **kw))
    total = sum(c for c, _ in r.profile.values())
    nops = 100 * r.profile["NOPC"][0] / max(1, total)
    print(f"{name:<14} {r.cycles:>8} {r.time_us:>8.2f} "
          f"{'yes' if r.correct else 'NO':>4} {nops:>5.1f}%")

print("\ndynamic scalability (reduction-64): ", end="")
dyn = run_bench(build_reduction(benchmark_config("dp"), 64))
nod = run_bench(build_reduction(
    benchmark_config("dp", predicate_levels=4), 64, no_dynamic=True))
print(f"TSC {dyn.cycles} cycles vs predicated {nod.cycles} "
      f"-> {nod.cycles/dyn.cycles:.1f}x win")
