"""Serving example: batched prefill + decode with dynamic-wavefront
request masking (ragged request lifetimes, the paper's TSC semantics at
request granularity).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_mod

if __name__ == "__main__":
    serve_mod.main([
        "--arch", "qwen3-moe-30b-a3b", "--smoke",
        "--requests", "8", "--prompt-len", "16",
        "--max-new", "24", "--max-len", "128",
    ])
