"""End-to-end training driver example: train a ~100M-param dense LM for a
few hundred steps on synthetic data with checkpointing and fault
tolerance.  (On TPU the same launcher runs the full config on the
production mesh; here a width-reduced yi-9b variant runs on CPU.)

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train as train_mod

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args, _ = ap.parse_known_args()
    train_mod.main([
        "--arch", "yi-9b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_train_lm",
        "--ckpt-every", "100",
        "--log-every", "20",
    ])
