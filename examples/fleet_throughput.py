"""Fleet throughput demo: serve a queue of eGPU jobs on batched cores.

Submits a heterogeneous stream of assembled programs — different kernels,
sizes, shared-memory images, runtime thread counts — to a 32-core fleet,
drains it in vmapped batches, and compares against the one-core
``run_program`` loop.

  PYTHONPATH=src python examples/fleet_throughput.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np

from repro.core import machine as machine_mod
from repro.core import run_program
from repro.fleet import Fleet
from benchmarks.fleet import build_jobs, fleet_config


def main() -> None:
    cfg = fleet_config()
    jobs = build_jobs(cfg, 96, mix="suite")
    print(f"{len(jobs)} jobs over {len({b.name for b in jobs})} distinct "
          f"programs; eGPU config: {cfg.max_threads} threads, "
          f"{cfg.shared_kb}KB shared, {cfg.memory_mode.upper()} memory\n")

    def submit_all(fleet):
        return [fleet.submit(b.image, b.shared_init, tdx_dim=b.tdx_dim,
                             tag=b.name,
                             weight=b.image.static_cycle_estimate())
                for b in jobs]

    # first drain compiles the per-batch fleet runners; time steady state
    warm = Fleet(cfg, batch_size=32)
    submit_all(warm)
    t0 = time.perf_counter()
    warm.drain()
    compile_s = time.perf_counter() - t0

    fleet = Fleet(cfg, batch_size=32)
    handles = submit_all(fleet)
    t0 = time.perf_counter()
    results = fleet.drain()
    fleet_s = time.perf_counter() - t0

    # correctness spot-check + simulated-time accounting
    sim_us = 0.0
    for b, h in zip(jobs[:8], handles[:8]):
        st = run_program(b.image, shared_init=b.shared_init,
                         tdx_dim=b.tdx_dim)
        assert np.array_equal(machine_mod.shared_as_u32(st),
                              results[h].shared_u32()), b.name
    for h in handles:
        assert results[h].hazard_violations == 0
        sim_us += results[h].time_us

    t0 = time.perf_counter()
    for b in jobs:
        run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    serial_s = time.perf_counter() - t0

    s = fleet.stats
    print(f"fleet : {len(jobs)} jobs in {fleet_s * 1e3:7.1f} ms "
          f"({len(jobs) / fleet_s:7.1f} jobs/s) across {s.batches} "
          f"dispatches ({s.compiled_jobs} jobs on the block-compiled "
          f"tier, {s.pad_slots} filler slots; first-run compile "
          f"took {compile_s:.1f} s)")
    print(f"serial: {len(jobs)} jobs in {serial_s * 1e3:7.1f} ms "
          f"({len(jobs) / serial_s:7.1f} jobs/s)")
    print(f"speedup {serial_s / fleet_s:.2f}x | simulated eGPU time "
          f"{sim_us / 1e3:.2f} ms @ {cfg.fmax_mhz:.0f} MHz")

    h = handles[0]
    print(f"\nper-job result (handle {h}, {results[h].tag}): "
          f"{results[h].cycles} cycles, {results[h].steps} instructions")
    mix = {k: v for k, v in results[h].profile().items() if v[1]}
    print(f"instruction mix: {mix}")


if __name__ == "__main__":
    main()
