"""Benchmark-trend gate: fail CI when aggregate speedups regress.

Compares freshly measured ``BENCH_*.json`` files against the committed
baselines and exits 1 when any tracked metric regresses by more than
``--max-regress`` (default 20%).  Only *ratio* metrics (speedups,
residency gain, auto-tier efficiency) are compared — absolute wall
times depend on the runner hardware and would make the gate flap, but
a speedup of tier A over tier B on the same box is hardware-portable.

Usage (the ``bench-trend`` CI job)::

    # stash the committed baselines before the benchmarks overwrite them
    mkdir -p .bench-baseline && cp BENCH_*.json .bench-baseline/
    python -m benchmarks.compiled && python -m benchmarks.superblock \
        && python -m benchmarks.fleet
    python -m benchmarks.check_trend --baseline .bench-baseline --current .

A metric present in the baseline but missing from the fresh run also
fails the gate: a silently vanished metric is how a perf regression
hides from a trend line.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _compiled_metrics(data: dict) -> dict[str, float]:
    """Ratio metrics from ``BENCH_compiled.json``."""
    m: dict[str, float] = {}
    for row in data.get("single_core", []):
        if row.get("name") == "aggregate":
            m["compiled/single_core_aggregate_speedup"] = row["speedup"]
    for row in data.get("fleet", []):
        m[f"compiled/fleet_{row['mix']}_speedup"] = row["speedup"]
    superblock = data.get("superblock", {})
    for row in superblock.get("single_core", []):
        if row.get("name") == "aggregate":
            m["superblock/aggregate_vs_blocks"] = row["speedup_vs_blocks"]
            m["superblock/aggregate_vs_interp"] = row["speedup_vs_interp"]
    auto_tier = data.get("auto_tier", {})
    sweep = auto_tier.get("sweep", [])
    # min over the sweep of faster_tier_time / chosen_tier_time: 1.0
    # means the auto tier always picked the faster tier.  Points where
    # the two tiers measured within the benchmark's noise floor are
    # excluded — they flip run to run and would make the trend flap.
    floor = auto_tier.get("noise_floor_us", 0.0)
    vals = [
        1.0 / row["auto_vs_faster"]
        for row in sweep
        if row.get("tier_gap_us", float("inf")) > floor
    ]
    if vals:
        m["auto_tier/worst_efficiency"] = round(min(vals), 3)
    roofline = data.get("roofline", {})
    lane = roofline.get("suite_lane_utilization")
    if lane is not None:
        # exact counter-derived ratio (active / offered lane-steps):
        # hardware-independent, so any drop is a real predication or
        # suite-composition change, not runner noise
        m["roofline/suite_lane_utilization"] = lane
    roof = roofline.get("roof", {})
    if "superblock" in roof and "blocks" in roof:
        peak_super = roof["superblock"]["peak_minstrs_per_sec"]
        peak_blocks = roof["blocks"]["peak_minstrs_per_sec"]
        m["roofline/superblock_vs_blocks_peak"] = round(
            peak_super / peak_blocks, 3
        )
    return m


def _fleet_metrics(rows: list) -> dict[str, float]:
    """Ratio metrics from ``BENCH_fleet.json`` (a list of mix rows)."""
    m: dict[str, float] = {}
    for row in rows:
        if "residency_speedup" in row:
            m["fleet/residency_speedup"] = row["residency_speedup"]
        elif "speedup" in row:
            m[f"fleet/vmapped_{row['mix']}_speedup"] = row["speedup"]
        elif row.get("kind") == "multidevice":
            ndev = row.get("devices", 0)
            if ndev and ndev > 1:
                m[f"fleet/multidevice_scaling_n{ndev}"] = row["scaling"]
        elif row.get("kind") == "serve" and row.get("mode") == "clean":
            # clean-run serving p99, tracked inverted (1000/p99_ms) so
            # compare()'s lower-is-regression convention applies; the
            # "p99" in the name selects the wider latency slack
            p99 = row.get("p99_ms", 0.0)
            if p99 > 0:
                rate = int(row.get("rate_jobs_per_sec", 0))
                m[f"fleet/serve_clean_p99_inv_{rate}"] = round(
                    1000.0 / p99, 3)
    return m


_EXTRACTORS = {
    "BENCH_compiled.json": _compiled_metrics,
    "BENCH_fleet.json": _fleet_metrics,
}

#: metrics whose very existence depends on the runner's environment —
#: ``fleet/multidevice_*`` is only measured when more than one device
#: is visible (the ``multi-device`` CI job forces 4 host devices, the
#: plain jobs see 1) — so "present in baseline, missing from current"
#: is a skip for these, not a vanished-metric failure
OPTIONAL_PREFIXES = ("fleet/multidevice",)


def load_metrics(root: str) -> dict[str, float]:
    metrics: dict[str, float] = {}
    for fname, extract in _EXTRACTORS.items():
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            metrics.update(extract(json.load(f)))
    return metrics


def compare(
    baseline: dict[str, float],
    current: dict[str, float],
    max_regress: float,
) -> list[str]:
    """Return human-readable failure lines (empty == gate passes)."""
    failures = []
    for name in sorted(baseline):
        base = baseline[name]
        if name not in current:
            if name.startswith(OPTIONAL_PREFIXES):
                print(f"  SKIPPED  {name}: baseline={base} "
                      f"(not measured in this environment)")
                continue
            failures.append(f"{name}: present in baseline ({base}) but "
                            f"missing from the current run")
            continue
        cur = current[name]
        ratio = cur / base if base else float("inf")
        # latency percentiles carry scheduling jitter the throughput
        # ratios don't: a tail-latency metric gets a wider band so the
        # trend catches sustained regressions without flapping on one
        # slow runner
        limit = max(max_regress, 0.5) if "p99" in name else max_regress
        status = "OK"
        if ratio < 1.0 - limit:
            status = "REGRESSED"
            failures.append(
                f"{name}: {base} -> {cur} "
                f"({(1.0 - ratio) * 100:.1f}% worse, limit "
                f"{limit * 100:.0f}%)"
            )
        print(f"{status:>9}  {name}: baseline={base} current={cur} "
              f"(x{ratio:.2f})")
    for name in sorted(set(current) - set(baseline)):
        print(f"      NEW  {name}: {current[name]}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--current", default=".",
                    help="directory holding the freshly measured ones")
    ap.add_argument("--max-regress", type=float, default=0.20,
                    help="allowed fractional drop per metric (0.20 = 20%%)")
    args = ap.parse_args()

    baseline = load_metrics(args.baseline)
    current = load_metrics(args.current)
    if not baseline:
        print(f"# no baseline metrics under {args.baseline}; nothing to "
              f"compare", file=sys.stderr)
        sys.exit(2)
    failures = compare(baseline, current, args.max_regress)
    if failures:
        print(f"# TREND FAIL ({len(failures)} metric(s)):", file=sys.stderr)
        for line in failures:
            print(f"#   {line}", file=sys.stderr)
        sys.exit(1)
    print(f"# trend gate passed: {len(baseline)} metric(s) within "
          f"{args.max_regress * 100:.0f}%", file=sys.stderr)


if __name__ == "__main__":
    main()
