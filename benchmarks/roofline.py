"""Roofline analysis with loop-calibrated cost extraction.

Methodology (documented in EXPERIMENTS.md §Roofline):

XLA's ``compiled.cost_analysis()`` counts a while/scan loop body ONCE
regardless of trip count (verified by a controlled probe: a scan of 1, 8
and 32 chained matmuls all report identical FLOPs).  Every production
model here scans its layer stack (and SSD chunk / recurrent seq loops),
so the raw dry-run numbers undercount.  We recover exact totals by
compiling small *fully unrolled* variants (``cfg.scan_layers=False``)
over a grid of (layers L, sequence S, batch B) and fitting the exact
polynomial cost structure

    f(L, S, B) = [ (1, S, S^2) (x) (1, L) (x) (1, B) ]  .  c

— every HLO cost term (FLOPs, bytes accessed, collective bytes) is
polynomial of degree <= 2 in S (attention), affine in L (stacked layers)
and affine in B (the B^0 component is the weight traffic / gradient
collectives, which do not scale with batch).  zamba2 adds the
shared-attention site count G as a basis dimension; decode cells drop
the S^2 term (cache ops are linear).  The fit is exact up to top_k sort
terms (negligible).

Roofline terms per (arch x shape), single-pod mesh, v5e constants:

    compute    = per-device FLOPs / 197e12
    memory     = per-device bytes accessed / 819e9
    collective = per-device collective bytes / 50e9

(per-device x 256 chips == the global formula in the brief).
"""
from __future__ import annotations

import argparse
import itertools
import json
import math
import os
import time

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


# --------------------------------------------------------------------------
# fit plans
# --------------------------------------------------------------------------

def _fit_plan(arch: str, kind: str):
    """Returns (L_combos, S_points, B_points, use_s2, use_g)."""
    if arch == "zamba2_1p2b":
        Ls = ((6, 6), (12, 6), (6, 3))
        use_g = True
    elif arch == "xlstm_350m":
        Ls = ((8, 0), (16, 0))
        use_g = False
    elif arch == "seamless_m4t_large_v2":
        Ls = ((2, 0), (4, 0))
        use_g = False
    else:
        Ls = ((1, 0), (2, 0))
        use_g = False

    if kind == "decode":
        S = (256, 512)
        use_s2 = False
    elif arch == "xlstm_350m":
        S = (4, 8, 16) if kind == "train" else (2, 4, 8)
        use_s2 = kind == "train"     # mLSTM parallel form is quadratic
    else:
        S = (256, 512, 1024)
        use_s2 = True
    return Ls, S, use_s2, use_g


def _basis(L, G, S, B, use_s2, use_g):
    s_terms = [1.0, S, S * S] if use_s2 else [1.0, S]
    l_terms = [1.0, L, G] if use_g else [1.0, L]
    return [st * lt * bt for st in s_terms for lt in l_terms
            for bt in (1.0, B)]


def _small_cfg(cfg, arch, L, period):
    kw = dict(scan_layers=False)
    if arch == "zamba2_1p2b":
        return cfg.replace(n_layers=L, shared_attn_period=period, **kw)
    if arch == "seamless_m4t_large_v2":
        return cfg.replace(n_layers=L, enc_layers=L // 2, dec_layers=L // 2,
                           **kw)
    return cfg.replace(n_layers=L, **kw)


def measure_point(arch, shape_name, L, period, S, B, mesh):
    import repro.configs as C
    import jax
    from repro.launch import specs as specs_mod
    from repro.launch.dryrun import collective_bytes

    base_cfg = C.get(arch)
    shape = C.SHAPES[shape_name]
    cfg = _small_cfg(base_cfg, arch, L, period)
    enc_len = None
    if cfg.family == "vlm":
        frac = cfg.num_patches / shape.seq_len
        cfg = cfg.replace(num_patches=max(4, int(round(frac * S))))
    if cfg.family == "encdec" and shape.kind == "decode":
        enc_len = max(16, int(specs_mod.ENC_LEN * S / shape.seq_len))
    sshape = C.ShapeSpec(shape.name, S, B, shape.kind)
    cell = specs_mod.build_cell(arch, shape_name, mesh, cfg=cfg,
                                shape=sshape, enc_len=enc_len, pin_out=True)
    with mesh:
        kw = {}
        if cell.out_shardings is not None:
            kw["out_shardings"] = cell.out_shardings
        compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate_argnums, **kw
                           ).lower(*cell.args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops") or 0.0),
        "bytes": float(ca.get("bytes accessed") or 0.0),
        "coll": float(coll["total_bytes"]),
    }


def _extrap_b(v16, v32, b_full):
    """Pointwise affine-in-B extrapolation, clamped monotone (cost is
    affine and non-decreasing in batch)."""
    slope = max(0.0, (v32 - v16) / 16.0)
    return max(v32, v16 + slope * (b_full - 16))


def _extrap_s(svals, s_points, s_full, use_s2):
    """Quadratic (or linear) in S with non-negative leading coefficient;
    falls back to monotone linear if the quadratic term fits negative
    (fusion-regime noise must not turn into a negative S^2 cost)."""
    s = np.array(s_points, np.float64)
    y = np.array(svals, np.float64)
    if use_s2 and len(s) >= 3:
        v = np.vander(s / s[-1], 3)            # normalized for conditioning
        c2, c1, c0 = np.linalg.solve(v, y)
        if c2 >= 0 and c1 >= -1e-9 * abs(y[-1]):
            x = s_full / s[-1]
            return float(max(c2 * x * x + max(c1, 0) * x + c0, y.max()))
    slope = max(0.0, (y[-1] - y[0]) / (s[-1] - s[0]))
    return float(max(y[-1] + slope * (s_full - s[-1]), y.max()))


def _extrap_l(lvals, l_combos, l_full, g_full, use_g):
    """Affine in L (and shared-site count G for zamba2), slopes clamped
    non-negative."""
    if use_g and len(l_combos) >= 3:
        (l1, p1), (l2, p2), (l3, p3) = l_combos[:3]
        g1, g2, g3 = (math.ceil(l1 / p1), math.ceil(l2 / p2),
                      math.ceil(l3 / p3))
        a = np.array([[1, l1, g1], [1, l2, g2], [1, l3, g3]], np.float64)
        c0, cl, cg = np.linalg.solve(a, np.array(lvals[:3], np.float64))
        cl, cg = max(cl, 0.0), max(cg, 0.0)
        return float(max(c0 + cl * l_full + cg * g_full, max(lvals)))
    (l1, _), (l2, _) = l_combos[:2]
    slope = max(0.0, (lvals[1] - lvals[0]) / (l2 - l1))
    return float(max(lvals[1] + slope * (l_full - l2), max(lvals)))


def calibrate_cell(arch, shape_name, mesh, cache_dir="results/roofline_fit",
                   verbose=True):
    import repro.configs as C
    os.makedirs(cache_dir, exist_ok=True)
    fname = os.path.join(cache_dir, f"{arch}__{shape_name}.json")
    if os.path.exists(fname):
        return json.load(open(fname))

    cfg = C.get(arch)
    shape = C.SHAPES[shape_name]
    Ls, Ss, use_s2, use_g = _fit_plan(arch, shape.kind)
    Bs = (1,) if shape.global_batch == 1 else (16, 32)

    # measure the grid
    points = {}
    for (L, period), S, B in itertools.product(Ls, Ss, Bs):
        t0 = time.time()
        m = measure_point(arch, shape_name, L, period, S, B, mesh)
        points[(L, period, S, B)] = m
        if verbose:
            print(f"  point L={L} S={S} B={B}: flops={m['flops']:.3e} "
                  f"({time.time()-t0:.0f}s)", flush=True)

    if use_g:
        L_full = cfg.n_layers
        G_full = len(range(0, cfg.n_layers, cfg.shared_attn_period))
    else:
        L_full, G_full = cfg.n_layers, 0

    out = {"arch": arch, "shape": shape_name,
           "fit_points": len(points),
           "points": {f"L{L}_p{p}_S{S}_B{B}": m
                      for (L, p, S, B), m in points.items()}}
    for key in ("flops", "bytes", "coll"):
        # hierarchical monotone extrapolation: B -> S -> (L, G)
        lvals = []
        for (L, period) in Ls:
            svals = []
            for S in Ss:
                if len(Bs) == 2:
                    vb = _extrap_b(points[(L, period, S, 16)][key],
                                   points[(L, period, S, 32)][key],
                                   shape.global_batch)
                else:
                    vb = points[(L, period, S, Bs[0])][key]
                svals.append(vb)
            lvals.append(_extrap_s(svals, Ss, shape.seq_len, use_s2))
        out[key] = _extrap_l(lvals, Ls, L_full, G_full, use_g)
    with open(fname, "w") as f:
        json.dump(out, f, indent=1)
    return out


# --------------------------------------------------------------------------
# Roofline table assembly
# --------------------------------------------------------------------------

def model_flops(arch, shape, params_total, cfg):
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference); N = active params
    excluding the embedding lookup."""
    n = params_total
    embed = cfg.vocab * cfg.d_model
    n_eff = n - embed
    if cfg.num_experts:
        expert = cfg.n_layers * cfg.num_experts * 3 * cfg.d_model \
            * cfg.expert_d_ff
        n_eff = n_eff - expert + expert * cfg.top_k / cfg.num_experts
    if shape.kind == "train":
        return 6.0 * n_eff * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_eff * shape.global_batch * shape.seq_len
    return 2.0 * n_eff * shape.global_batch


def roofline_row(arch, shape_name, dry_rec, cal, chips=256):
    import repro.configs as C
    cfg = C.get(arch)
    shape = C.SHAPES[shape_name]
    f_dev, b_dev, c_dev = cal["flops"], cal["bytes"], cal["coll"]
    t_comp = f_dev / PEAK_FLOPS
    t_mem = b_dev / HBM_BW
    t_coll = c_dev / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(arch, shape, dry_rec.get("params", 0), cfg)
    useful = mf / (f_dev * chips) if f_dev else 0.0
    bound = max(t_comp, t_mem, t_coll)
    return {
        "arch": arch, "shape": shape_name,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": t_comp / bound if bound else 0.0,
        "flops_per_device": f_dev, "bytes_per_device": b_dev,
        "coll_bytes_per_device": c_dev,
    }


def main():
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import jax  # noqa: F401
    from repro.launch import mesh as mesh_mod
    import repro.configs as C

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    mesh = mesh_mod.make_production_mesh()

    cells = [(a, s.name) for a, s, ok, _ in C.cells() if ok]
    # cheap cells first so partial results are useful early
    order = {"decode_32k": 0, "long_500k": 1, "prefill_32k": 2, "train_4k": 3}
    cells.sort(key=lambda c: order[c[1]])
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch
                 and (args.shape is None or s == args.shape)]
    rows = []
    for arch, shape in cells:
        dr = os.path.join(args.dryrun_dir, f"{arch}__{shape}__16x16.json")
        dry = json.load(open(dr)) if os.path.exists(dr) else {}
        try:
            print(f"calibrating {arch} {shape}", flush=True)
            cal = calibrate_cell(arch, shape, mesh)
            row = roofline_row(arch, shape, dry, cal)
            rows.append(row)
            print(f"OK  {arch:22s} {shape:12s} comp={row['t_compute_s']:.2e}s "
                  f"mem={row['t_memory_s']:.2e}s "
                  f"coll={row['t_collective_s']:.2e}s "
                  f"dom={row['dominant']:10s} "
                  f"useful={row['useful_flops_ratio']:.2f}", flush=True)
            with open(os.path.join(args.out, "roofline.json"), "w") as f:
                json.dump(rows, f, indent=1)
        except Exception as e:
            import traceback
            print(f"FAIL {arch} {shape}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=2)


if __name__ == "__main__":
    main()
