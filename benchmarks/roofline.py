"""GPGPU roofline: achieved instruction throughput per execution tier.

Classic rooflines bound FLOPs against memory traffic; a soft GPGPU's
equivalent bounds *architectural instruction throughput* against the
machine's issue and data-parallel limits.  For every suite program the
host path simulation's :class:`~repro.obs.EventCounters` give the exact
retired-instruction and issue-cycle counts (bit-identical to the
interpreter's counters), so dividing by each tier's measured
steady-state wall time yields achieved instrs/sec per tier — and two
utilization terms bound how much of the paper's scaling headroom each
program actually uses:

* **lane utilization** — active / offered vector lane-steps: the
  fraction of the SIMT data-parallel roof not lost to predicated-off
  lanes and partial warps (TSC masks);
* **issue efficiency** — retired instructions / issue cycles: the
  fraction of the dual-issue roof not lost to hazard NOP padding.

Rows are printed in the harness CSV contract and merged into
``BENCH_compiled.json`` under the ``"roofline"`` key (next to the
``"superblock"`` / ``"auto_tier"`` sections), so the trend pipeline can
track throughput per tier release over release.

  PYTHONPATH=src python -m benchmarks.roofline             # full
  PYTHONPATH=src python -m benchmarks.roofline --smoke     # quick pass
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.fleet import fleet_config  # noqa: E402
from benchmarks.superblock import _loop_nested, _loop_saxpy  # noqa: E402
from repro.core import compile_program, run_program  # noqa: E402
from repro.core.blockc import BlockCompileError  # noqa: E402
from repro.programs import (build_bitonic, build_fft, build_matmul,  # noqa: E402
                            build_reduction, build_transpose)

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _suite(cfg, smoke: bool):
    """Straight-line *and* loop-heavy programs: the former exercise the
    blocks tier's fused superinstructions, the latter the superblock
    tier's folded back-edges."""
    out = [build_reduction(cfg, 32), build_transpose(cfg, 16),
           build_matmul(cfg, 8), _loop_saxpy(cfg, 512)]
    if not smoke:
        out += [build_reduction(cfg, 32, use_dot=True),
                build_bitonic(cfg, 16), build_fft(cfg, 16),
                _loop_saxpy(cfg, 1024), _loop_nested(cfg, 32, 16)]
    return out


def _time(f, repeats: int) -> float:
    f()                                    # warm the jit/compile caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def _tier_times(b, repeats: int) -> dict[str, float | None]:
    run = dict(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    times: dict[str, float | None] = {
        "interp": _time(lambda: run_program(b.image, **run), repeats)}
    cp_b = compile_program(b.image, mode="blocks")
    times["blocks"] = _time(lambda: cp_b.run(**run), repeats)
    try:
        cp_s = compile_program(b.image, mode="superblock")
    except BlockCompileError:
        times["superblock"] = None         # no foldable static path
    else:
        times["superblock"] = _time(lambda: cp_s.run(**run), repeats)
    return times


def bench(smoke: bool = False, repeats: int | None = None) -> dict:
    cfg = fleet_config()
    repeats = repeats or (2 if smoke else 5)
    rows = []
    for b in _suite(cfg, smoke):
        ec = compile_program(b.image).event_counters()
        times = _tier_times(b, repeats)
        row = {
            "name": b.name,
            "instrs": ec.instrs, "cycles": ec.cycles,
            "loop_backedges": ec.loop_backedges,
            "lane_utilization": round(ec.lane_utilization, 4),
            "issue_efficiency": round(ec.instrs / ec.cycles, 4)
            if ec.cycles else 1.0,
            "tiers": {},
        }
        for tier, t in times.items():
            if t is None:
                continue
            row["tiers"][tier] = {
                "us": round(t * 1e6, 1),
                "minstrs_per_sec": round(ec.instrs / t / 1e6, 3),
            }
        rows.append(row)

    # the roof per tier: the best throughput any program achieved on it
    roof = {}
    for tier in ("interp", "blocks", "superblock"):
        vals = [r["tiers"][tier]["minstrs_per_sec"]
                for r in rows if tier in r["tiers"]]
        if vals:
            roof[tier] = {"peak_minstrs_per_sec": max(vals),
                          "programs": len(vals)}
    offered = sum(r["instrs"] / max(r["lane_utilization"], 1e-9)
                  for r in rows if r["lane_utilization"] > 0)
    active = sum(r["instrs"] for r in rows if r["lane_utilization"] > 0)
    return {"programs": rows, "roof": roof,
            "suite_lane_utilization":
                round(active / offered, 4) if offered else 1.0}


def rows_csv(out: dict) -> list[tuple]:
    rows = []
    for r in out["programs"]:
        for tier, t in r["tiers"].items():
            rows.append((f"roofline/{r['name']}_{tier}", t["us"],
                         f"minstrs_per_sec={t['minstrs_per_sec']};"
                         f"lane_util={r['lane_utilization']};"
                         f"issue_eff={r['issue_efficiency']}"))
    return rows


def _merge_json(path: str, out: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["roofline"] = out
    with open(path, "w") as f:
        json.dump(data, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced suite, no json write")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--json", default=os.path.join(_REPO_ROOT,
                                                   "BENCH_compiled.json"))
    args = ap.parse_args()

    out = bench(args.smoke, args.repeats)

    print("name,us_per_call,derived")
    for name, us, derived in rows_csv(out):
        print(f"{name},{us},{derived}")

    roof = ", ".join(f"{t}={v['peak_minstrs_per_sec']}"
                     for t, v in out["roof"].items())
    print(f"# peak Minstrs/s per tier: {roof}; suite lane utilization: "
          f"{out['suite_lane_utilization']}", file=sys.stderr)
    if not args.smoke:      # CI pass: don't clobber the tracked numbers
        _merge_json(args.json, out)
        print(f"# merged into {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
