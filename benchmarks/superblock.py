"""Superblock benchmark: LOOP back-edges, tier costs, and auto-selection.

The loop-heavy half of the suite is where the basic-block driver pays a
``lax.switch`` dispatch on every LOOP back-edge; the superblock tier
folds the static path and pays none — but its fixed per-call cost
(state assembly + launch) can *lose* below a few hundred back-edges.
Three tiers, head to head, on a loop-heavy program mix:

  * the interpreter (``run_program`` — reference semantics),
  * the basic-block driver (``mode="blocks"`` — PR-2 behaviour),
  * the superblock runner (``mode="superblock"``),

plus the ``mode="auto"`` :class:`~repro.core.blockc.TierPolicy` pick,
with results asserted bit-identical before any timing, and a fleet
drain of same-program loop jobs to exercise the scheduler's superblock
tier.  The **crossover sweep** (``bench_auto_tier``) times blocks vs
superblock vs auto through the light path over back-edge counts
8 -> 2048, records the measured crossover point and the per-tier fixed
overheads, and **asserts the auto tier stays within
``AUTO_TOLERANCE`` of the faster tier on both sides** of the crossover.
Results are merged into ``BENCH_compiled.json`` under the
``"superblock"`` and ``"auto_tier"`` keys.

  PYTHONPATH=src python -m benchmarks.superblock            # full
  PYTHONPATH=src python -m benchmarks.superblock --smoke    # CI gate

Both modes **fail the build** (exit 1) when the auto tier misses the
crossover; ``--smoke`` additionally fails when a loop-heavy program
stops being superblock-eligible (a dispatch-count regression: its
switch dispatches must be 0 under the forced superblock tier while the
blocks tier's are > 0) or when the aggregate superblock speedup over
the basic-block tier regresses below the gate threshold.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from benchmarks.fleet import fleet_config  # noqa: E402
from repro.core import Asm, compile_program, run_program  # noqa: E402
from repro.core.blockc import (DEFAULT_TIER_POLICY, _sched_insts,  # noqa: E402
                               _trace_cost)
from repro.fleet import Fleet  # noqa: E402
from repro.obs import Tracer  # noqa: E402
from repro.programs import build_matmul, build_transpose  # noqa: E402

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: --smoke gate: the superblock tier must keep at least this aggregate
#: speedup over the basic-block driver on the loop-heavy mix ...
SMOKE_MIN_SPEEDUP = 1.2
#: ... and every mix program must land on the superblock tier (its
#: switch-dispatch count is 0 by construction; the blocks tier's > 0).

#: the auto tier must stay within this factor of the faster forced tier
#: at every swept back-edge count (acceptance: within 5%)
AUTO_TOLERANCE = 1.05

#: inter-tier gaps below this are within the observed run-to-run jitter
#: of a loaded CPU host (which tier "wins" flips between runs near the
#: true crossover): when the two tiers measure this close, either pick
#: satisfies the within-5%-of-faster contract to the extent it is
#: measurable, so such points pass the gate
NOISE_FLOOR_US = 150.0

#: crossover sweep: LOOP back-edge counts (full mode; smoke uses a
#: reduced two-point sweep, one on each side of the crossover)
SWEEP_BACKEDGES = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
SMOKE_BACKEDGES = (64, 1024)


class _Bench:
    def __init__(self, name, image, shared_init=None, tdx_dim=16):
        self.name = name
        self.image = image
        self.shared_init = shared_init
        self.tdx_dim = tdx_dim


def _loop_saxpy(cfg, iters: int) -> _Bench:
    """y[t] = a*y[t] + x[t], ``iters`` times — one LOOP back-edge per
    iteration, the pure back-edge-dispatch stress test."""
    a = Asm(cfg)
    a.tdx(1)
    a.lod(2, 1, 0)                  # x[t]
    a.lod(3, 1, 32)                 # y[t]
    with a.loop(iters):
        a.fmul(3, 3, 4)
        a.fadd(3, 3, 2)
    a.sto(3, 1, 32)
    a.stop()
    rng = np.random.default_rng(iters)
    data = rng.standard_normal(64).astype(np.float32)
    return _Bench(f"loop_saxpy_{iters}", a.assemble(threads_active=32),
                  shared_init=data, tdx_dim=32)


def _loop_nested(cfg, outer: int, inner: int) -> _Bench:
    """Nested LOOPs: the folded schedule is a repeat inside a repeat."""
    a = Asm(cfg)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lodi(5, 3)
    with a.loop(outer):
        with a.loop(inner):
            a.add(2, 2, 5)
        a.xor(2, 2, 1)
    a.sto(2, 1, 0)
    a.stop()
    data = np.arange(32, dtype=np.uint32)
    return _Bench(f"loop_nested_{outer}x{inner}",
                  a.assemble(threads_active=32), shared_init=data,
                  tdx_dim=32)


def _suite(cfg, smoke: bool) -> list[_Bench]:
    """Loop-heavy mix: every program's executed path crosses a LOOP
    back-edge many times (the regime the superblock tier targets)."""
    mm = build_matmul(cfg, 8)
    tr = build_transpose(cfg, 16)
    out = [
        _Bench(mm.name, mm.image, mm.shared_init, mm.tdx_dim),
        _Bench(tr.name, tr.image, tr.shared_init, tr.tdx_dim),
        _loop_saxpy(cfg, 512),
    ]
    if not smoke:
        # the small-iteration cases document the crossover: below a few
        # hundred back-edges the fixed trace overhead can eat the
        # dispatch win on CPU (the full JSON keeps both data points)
        out += [_loop_saxpy(cfg, 64), _loop_saxpy(cfg, 1024),
                _loop_nested(cfg, 32, 16)]
    return out


def _assert_bit_identical(b, cps):
    ref = run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    for label, cp in cps.items():
        got = cp.run(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        for leaf in ref._fields:
            assert np.array_equal(np.asarray(getattr(ref, leaf)),
                                  np.asarray(getattr(got, leaf))), \
                f"{b.name}/{label}: {leaf} differs from the interpreter"


def _compile_super_or_auto(image):
    """``mode="superblock"`` when eligible; if the program ever stops
    fitting the trace budget, fall back to ``mode="auto"`` — which then
    compiles to the blocks tier with switch_dispatches > 0, and the
    smoke gate reports a dispatch regression instead of crashing."""
    from repro.core import BlockCompileError
    try:
        return compile_program(image, mode="superblock")
    except BlockCompileError:
        return compile_program(image, mode="auto")


def bench_single_core(cfg, smoke: bool, repeats: int) -> list[dict]:
    rows = []
    tot = {"interp": 0.0, "blocks": 0.0, "super": 0.0}
    for b in _suite(cfg, smoke):
        cps = {
            "blocks": compile_program(b.image, mode="blocks"),
            "super": _compile_super_or_auto(b.image),
        }
        auto = compile_program(b.image)        # the TierPolicy pick
        _assert_bit_identical(b, cps)
        run = dict(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        t = _time_interleaved({
            "interp": lambda: run_program(b.image, **run),
            "blocks": lambda: cps["blocks"].run(**run),
            "super": lambda: cps["super"].run(**run),
        }, repeats)
        ti, tb, ts = t["interp"], t["blocks"], t["super"]
        tot["interp"] += ti
        tot["blocks"] += tb
        tot["super"] += ts
        sched = cps["super"].schedule
        rows.append({
            "name": b.name,
            "steps": cps["super"].sim.steps,
            "dispatches_blocks": cps["blocks"].switch_dispatches,
            "dispatches_super": cps["super"].switch_dispatches,
            "sched_insts": _sched_insts(sched) if sched else None,
            "trace_cost": _trace_cost(sched) if sched else None,
            "auto_tier": auto.mode,
            "interp_us": round(ti * 1e6, 1),
            "blocks_us": round(tb * 1e6, 1),
            "super_us": round(ts * 1e6, 1),
            "speedup_vs_blocks": round(tb / ts, 2),
            "speedup_vs_interp": round(ti / ts, 2),
            "bit_identical": True,
        })
    rows.append({
        "name": "aggregate",
        "interp_us": round(tot["interp"] * 1e6, 1),
        "blocks_us": round(tot["blocks"] * 1e6, 1),
        "super_us": round(tot["super"] * 1e6, 1),
        "speedup_vs_blocks": round(tot["blocks"] / tot["super"], 2),
        "speedup_vs_interp": round(tot["interp"] / tot["super"], 2),
    })
    return rows


def _time_interleaved(fns: dict, repeats: int) -> dict:
    """Best-of-``repeats`` per entry, rounds interleaved across entries
    so drift (thermal, scheduler) hits every tier alike — what keeps a
    5%-tolerance comparison honest on a shared machine."""
    for f in fns.values():
        f()                                    # warm every jit cache
    best = {k: float("inf") for k in fns}
    for _ in range(repeats):
        for k, f in fns.items():
            t0 = time.perf_counter()
            f()
            best[k] = min(best[k], time.perf_counter() - t0)
    return best


def bench_auto_tier(cfg, smoke: bool, repeats: int) -> dict:
    """The crossover sweep: blocks vs superblock vs the auto pick, over
    LOOP back-edge counts, all through the light path
    (:meth:`CompiledProgram.run_light` — these callers only read
    shared/cycles).  Records the measured crossover and the per-tier
    fixed overheads; asserts the auto tier is within
    :data:`AUTO_TOLERANCE` of the faster tier at every point."""
    rows = []
    for n in (SMOKE_BACKEDGES if smoke else SWEEP_BACKEDGES):
        b = _loop_saxpy(cfg, n)
        cb = compile_program(b.image, mode="blocks")
        cs = compile_program(b.image, mode="superblock")
        ca = compile_program(b.image)          # auto, default policy
        # light == full on the leaves the light path returns
        ref = run_program(b.image, shared_init=b.shared_init,
                          tdx_dim=b.tdx_dim)
        for cp in (cb, cs, ca):
            sh, cyc, halted = cp.run_light(shared_init=b.shared_init,
                                           tdx_dim=b.tdx_dim)
            assert np.array_equal(np.asarray(ref.shared), np.asarray(sh))
            assert int(ref.cycles) == cyc and bool(ref.halted) == halted
        run = dict(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        t = _time_interleaved({
            "blocks": lambda: cb.run_light(**run),
            "super": lambda: cs.run_light(**run),
            "auto": lambda: ca.run_light(**run),
        }, repeats)
        faster = "blocks" if t["blocks"] <= t["super"] else "superblock"
        # the gate judges the *decision*: the tier auto chose, measured
        # through its forced twin, against the faster tier.  (auto_us is
        # the same computation as its chosen tier behind a separately
        # jitted object, so gating on auto_us directly would mostly
        # measure jit-instance timing noise, not the policy.)
        chosen = t["blocks"] if ca.mode == "blocks" else t["super"]
        ratio = chosen / min(t["blocks"], t["super"])
        gap_us = abs(t["blocks"] - t["super"]) * 1e6
        rows.append({
            "backedges": n,
            "dispatches": cb.switch_dispatches,
            "execd": cb.sim.steps,
            "trace_cost": _trace_cost(cs.schedule),
            "blocks_us": round(t["blocks"] * 1e6, 1),
            "super_us": round(t["super"] * 1e6, 1),
            "auto_us": round(t["auto"] * 1e6, 1),
            "auto_tier": ca.mode,
            "faster_tier": faster,
            "auto_vs_faster": round(ratio, 3),
            "tier_gap_us": round(gap_us, 1),
            "auto_ok": bool(ratio <= AUTO_TOLERANCE
                            or gap_us <= NOISE_FLOOR_US),
        })

    # the measured crossover: the first swept back-edge count from which
    # the superblock tier stays faster (None if it never takes over)
    crossover = None
    for i, r in enumerate(rows):
        if all(x["faster_tier"] == "superblock" for x in rows[i:]):
            crossover = r["backedges"]
            break

    # per-tier fixed overhead, from the fori-regime points (backedges >=
    # 16): a linear fit of per-call time against the quantity each
    # driver's marginal cost scales with (blocks: switch dispatches;
    # superblock: executed instructions through the fused fori body)
    fori = [r for r in rows if r["backedges"] >= 16]
    fit = {}
    if len(fori) >= 2:
        bd = np.polyfit([r["dispatches"] for r in fori],
                        [r["blocks_us"] for r in fori], 1)
        sd = np.polyfit([r["execd"] for r in fori],
                        [r["super_us"] for r in fori], 1)
        fit = {
            "blocks_fixed_us": round(float(bd[1]), 1),
            "blocks_per_dispatch_us": round(float(bd[0]), 3),
            "super_fixed_us": round(float(sd[1]), 1),
            "super_per_exec_us": round(float(sd[0]), 4),
        }
    return {
        "sweep": rows,
        "crossover_backedges": crossover,
        "auto_tolerance": AUTO_TOLERANCE,
        "noise_floor_us": NOISE_FLOOR_US,
        "policy_table": {k: v for k, v
                         in DEFAULT_TIER_POLICY.table.items()},
        **fit,
    }


def bench_fleet(cfg, smoke: bool, batch: int, repeats: int) -> dict:
    """Same-program loop jobs through the scheduler: all of them must
    land on the superblock tier (stats.superblock_jobs == jobs)."""
    b = _loop_saxpy(cfg, 64)
    n_jobs = batch * (2 if smoke else 8)
    rng = np.random.default_rng(0)
    datas = [rng.standard_normal(64).astype(np.float32)
             for _ in range(n_jobs)]

    def once():
        fleet = Fleet(cfg, batch_size=batch)
        for d in datas:
            fleet.submit(b.image, d, tdx_dim=b.tdx_dim)
        t0 = time.perf_counter()
        fleet.drain()
        assert fleet.stats.superblock_jobs == n_jobs
        return time.perf_counter() - t0

    once()                                 # warm compiles
    jps = n_jobs / min(once() for _ in range(repeats))
    return {"mix": "loop_saxpy", "batch": batch, "jobs": n_jobs,
            "superblock_jobs_per_sec": round(jps, 1)}


def bench(smoke: bool = False, batch: int = 32,
          repeats: int | None = None) -> dict:
    cfg = fleet_config()
    repeats = repeats or (2 if smoke else 5)
    return {
        "single_core": bench_single_core(cfg, smoke, repeats),
        "fleet": [bench_fleet(cfg, smoke, batch, max(2, repeats // 2))],
        "auto_tier": bench_auto_tier(cfg, smoke, max(5, repeats)),
    }


def rows_csv(out: dict) -> list[tuple]:
    rows = []
    for r in out["single_core"]:
        rows.append((f"superblock/{r['name']}", r["super_us"],
                     f"blocks_us={r['blocks_us']};"
                     f"interp_us={r['interp_us']};"
                     f"vs_blocks={r['speedup_vs_blocks']}x;"
                     f"vs_interp={r['speedup_vs_interp']}x"))
    for r in out.get("fleet", ()):
        rows.append((f"superblock_fleet/{r['mix']}_batch{r['batch']}",
                     round(1e6 / r["superblock_jobs_per_sec"], 1),
                     f"jobs_per_sec={r['superblock_jobs_per_sec']}"))
    for r in out.get("auto_tier", {}).get("sweep", ()):
        rows.append((f"auto_tier/loop_saxpy_{r['backedges']}",
                     r["auto_us"],
                     f"blocks_us={r['blocks_us']};"
                     f"super_us={r['super_us']};tier={r['auto_tier']};"
                     f"vs_faster={r['auto_vs_faster']}x"))
    return rows


def _merge_json(path: str, out: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["superblock"] = {k: v for k, v in out.items() if k != "auto_tier"}
    data["auto_tier"] = out["auto_tier"]
    with open(path, "w") as f:
        json.dump(data, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced mix; exit 1 on dispatch/speedup "
                         "regression")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--json", default=os.path.join(_REPO_ROOT,
                                                   "BENCH_compiled.json"))
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a repro.obs trace of the whole run")
    args = ap.parse_args()

    tracer = Tracer("bench-superblock") if args.trace else None
    with (tracer if tracer is not None else contextlib.nullcontext()):
        out = bench(args.smoke, args.batch, args.repeats)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"# wrote trace {args.trace}", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in rows_csv(out):
        print(f"{name},{us},{derived}")

    if not args.smoke:      # CI pass: don't clobber the tracked numbers
        _merge_json(args.json, out)
        print(f"# merged into {args.json}", file=sys.stderr)

    per_prog = out["single_core"][:-1]
    agg = out["single_core"][-1]["speedup_vs_blocks"]
    bad_dispatch = [r["name"] for r in per_prog
                    if r["dispatches_super"] != 0
                    or r["dispatches_blocks"] <= 0]
    sweep = out["auto_tier"]["sweep"]
    bad_auto = [r["backedges"] for r in sweep if not r["auto_ok"]]
    print(f"# aggregate superblock-vs-blocks speedup: {agg}x; "
          f"dispatch regressions: {bad_dispatch or 'none'}; "
          f"crossover: {out['auto_tier']['crossover_backedges']} "
          f"back-edges; auto-tier misses: {bad_auto or 'none'}",
          file=sys.stderr)
    # the auto-tier contract gates BOTH modes: mode="auto" must stay
    # within AUTO_TOLERANCE of the faster tier on both sides of the
    # measured crossover, or the cost model has rotted
    if bad_auto:
        print(f"# FAIL: auto tier more than "
              f"{round((AUTO_TOLERANCE - 1) * 100)}% off the faster "
              f"tier at back-edge counts {bad_auto}", file=sys.stderr)
        sys.exit(1)
    if args.smoke:
        if bad_dispatch:
            print(f"# SMOKE FAIL: {bad_dispatch} not on the superblock "
                  f"tier (switch dispatches must drop to 0)",
                  file=sys.stderr)
            sys.exit(1)
        if agg < SMOKE_MIN_SPEEDUP:
            print(f"# SMOKE FAIL: need >= {SMOKE_MIN_SPEEDUP}x over the "
                  f"basic-block tier", file=sys.stderr)
            sys.exit(1)
        print("# smoke gate passed", file=sys.stderr)


if __name__ == "__main__":
    main()
