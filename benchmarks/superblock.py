"""Superblock benchmark: LOOP back-edges with and without unrolling.

The loop-heavy half of the suite is where the basic-block driver pays a
``lax.switch`` dispatch on every LOOP back-edge; the superblock tier
folds the static path and pays none.  Three tiers, head to head, on a
loop-heavy program mix:

  * the interpreter (``run_program`` — reference semantics),
  * the basic-block driver (``mode="blocks"`` — PR-2 behaviour),
  * the superblock runner (``mode="superblock"``),

with results asserted bit-identical before any timing, plus a fleet
drain of same-program loop jobs to exercise the scheduler's superblock
tier.  Results are merged into ``BENCH_compiled.json`` under the
``"superblock"`` key.

  PYTHONPATH=src python -m benchmarks.superblock            # full
  PYTHONPATH=src python -m benchmarks.superblock --smoke    # CI gate

``--smoke`` **fails the build** (exit 1) when a loop-heavy program stops
landing on the superblock tier (a dispatch-count regression: its switch
dispatches must be 0 while the blocks tier's are > 0) or when the
aggregate superblock speedup over the basic-block tier regresses below
the gate threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from benchmarks.compiled import _time  # noqa: E402
from benchmarks.fleet import fleet_config  # noqa: E402
from repro.core import Asm, compile_program, run_program  # noqa: E402
from repro.core.blockc import _sched_insts, _trace_cost  # noqa: E402
from repro.fleet import Fleet  # noqa: E402
from repro.programs import build_matmul, build_transpose  # noqa: E402

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: --smoke gate: the superblock tier must keep at least this aggregate
#: speedup over the basic-block driver on the loop-heavy mix ...
SMOKE_MIN_SPEEDUP = 1.2
#: ... and every mix program must land on the superblock tier (its
#: switch-dispatch count is 0 by construction; the blocks tier's > 0).


class _Bench:
    def __init__(self, name, image, shared_init=None, tdx_dim=16):
        self.name = name
        self.image = image
        self.shared_init = shared_init
        self.tdx_dim = tdx_dim


def _loop_saxpy(cfg, iters: int) -> _Bench:
    """y[t] = a*y[t] + x[t], ``iters`` times — one LOOP back-edge per
    iteration, the pure back-edge-dispatch stress test."""
    a = Asm(cfg)
    a.tdx(1)
    a.lod(2, 1, 0)                  # x[t]
    a.lod(3, 1, 32)                 # y[t]
    with a.loop(iters):
        a.fmul(3, 3, 4)
        a.fadd(3, 3, 2)
    a.sto(3, 1, 32)
    a.stop()
    rng = np.random.default_rng(iters)
    data = rng.standard_normal(64).astype(np.float32)
    return _Bench(f"loop_saxpy_{iters}", a.assemble(threads_active=32),
                  shared_init=data, tdx_dim=32)


def _loop_nested(cfg, outer: int, inner: int) -> _Bench:
    """Nested LOOPs: the folded schedule is a repeat inside a repeat."""
    a = Asm(cfg)
    a.tdx(1)
    a.lod(2, 1, 0)
    a.lodi(5, 3)
    with a.loop(outer):
        with a.loop(inner):
            a.add(2, 2, 5)
        a.xor(2, 2, 1)
    a.sto(2, 1, 0)
    a.stop()
    data = np.arange(32, dtype=np.uint32)
    return _Bench(f"loop_nested_{outer}x{inner}",
                  a.assemble(threads_active=32), shared_init=data,
                  tdx_dim=32)


def _suite(cfg, smoke: bool) -> list[_Bench]:
    """Loop-heavy mix: every program's executed path crosses a LOOP
    back-edge many times (the regime the superblock tier targets)."""
    mm = build_matmul(cfg, 8)
    tr = build_transpose(cfg, 16)
    out = [
        _Bench(mm.name, mm.image, mm.shared_init, mm.tdx_dim),
        _Bench(tr.name, tr.image, tr.shared_init, tr.tdx_dim),
        _loop_saxpy(cfg, 512),
    ]
    if not smoke:
        # the small-iteration cases document the crossover: below a few
        # hundred back-edges the fixed trace overhead can eat the
        # dispatch win on CPU (the full JSON keeps both data points)
        out += [_loop_saxpy(cfg, 64), _loop_saxpy(cfg, 1024),
                _loop_nested(cfg, 32, 16)]
    return out


def _assert_bit_identical(b, cps):
    ref = run_program(b.image, shared_init=b.shared_init, tdx_dim=b.tdx_dim)
    for label, cp in cps.items():
        got = cp.run(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        for leaf in ref._fields:
            assert np.array_equal(np.asarray(getattr(ref, leaf)),
                                  np.asarray(getattr(got, leaf))), \
                f"{b.name}/{label}: {leaf} differs from the interpreter"


def bench_single_core(cfg, smoke: bool, repeats: int) -> list[dict]:
    rows = []
    tot = {"interp": 0.0, "blocks": 0.0, "super": 0.0}
    for b in _suite(cfg, smoke):
        cps = {
            "blocks": compile_program(b.image, mode="blocks"),
            # auto, NOT mode="superblock": if the program ever stops
            # fitting the trace budget this compiles to the blocks tier
            # with switch_dispatches > 0, which the smoke gate reports
            # as a dispatch regression instead of crashing
            "super": compile_program(b.image, mode="auto"),
        }
        _assert_bit_identical(b, cps)
        run = dict(shared_init=b.shared_init, tdx_dim=b.tdx_dim)
        ti = _time(lambda: run_program(b.image, **run), repeats)
        tb = _time(lambda: cps["blocks"].run(**run), repeats)
        ts = _time(lambda: cps["super"].run(**run), repeats)
        tot["interp"] += ti
        tot["blocks"] += tb
        tot["super"] += ts
        sched = cps["super"].schedule
        rows.append({
            "name": b.name,
            "steps": cps["super"].sim.steps,
            "dispatches_blocks": cps["blocks"].switch_dispatches,
            "dispatches_super": cps["super"].switch_dispatches,
            "sched_insts": _sched_insts(sched) if sched else None,
            "trace_cost": _trace_cost(sched) if sched else None,
            "interp_us": round(ti * 1e6, 1),
            "blocks_us": round(tb * 1e6, 1),
            "super_us": round(ts * 1e6, 1),
            "speedup_vs_blocks": round(tb / ts, 2),
            "speedup_vs_interp": round(ti / ts, 2),
            "bit_identical": True,
        })
    rows.append({
        "name": "aggregate",
        "interp_us": round(tot["interp"] * 1e6, 1),
        "blocks_us": round(tot["blocks"] * 1e6, 1),
        "super_us": round(tot["super"] * 1e6, 1),
        "speedup_vs_blocks": round(tot["blocks"] / tot["super"], 2),
        "speedup_vs_interp": round(tot["interp"] / tot["super"], 2),
    })
    return rows


def bench_fleet(cfg, smoke: bool, batch: int, repeats: int) -> dict:
    """Same-program loop jobs through the scheduler: all of them must
    land on the superblock tier (stats.superblock_jobs == jobs)."""
    b = _loop_saxpy(cfg, 64)
    n_jobs = batch * (2 if smoke else 8)
    rng = np.random.default_rng(0)
    datas = [rng.standard_normal(64).astype(np.float32)
             for _ in range(n_jobs)]

    def once():
        fleet = Fleet(cfg, batch_size=batch)
        for d in datas:
            fleet.submit(b.image, d, tdx_dim=b.tdx_dim)
        t0 = time.perf_counter()
        fleet.drain()
        assert fleet.stats.superblock_jobs == n_jobs
        return time.perf_counter() - t0

    once()                                 # warm compiles
    jps = n_jobs / min(once() for _ in range(repeats))
    return {"mix": "loop_saxpy", "batch": batch, "jobs": n_jobs,
            "superblock_jobs_per_sec": round(jps, 1)}


def bench(smoke: bool = False, batch: int = 32,
          repeats: int | None = None) -> dict:
    cfg = fleet_config()
    repeats = repeats or (2 if smoke else 5)
    return {
        "single_core": bench_single_core(cfg, smoke, repeats),
        "fleet": [bench_fleet(cfg, smoke, batch, max(2, repeats // 2))],
    }


def rows_csv(out: dict) -> list[tuple]:
    rows = []
    for r in out["single_core"]:
        rows.append((f"superblock/{r['name']}", r["super_us"],
                     f"blocks_us={r['blocks_us']};"
                     f"interp_us={r['interp_us']};"
                     f"vs_blocks={r['speedup_vs_blocks']}x;"
                     f"vs_interp={r['speedup_vs_interp']}x"))
    for r in out.get("fleet", ()):
        rows.append((f"superblock_fleet/{r['mix']}_batch{r['batch']}",
                     round(1e6 / r["superblock_jobs_per_sec"], 1),
                     f"jobs_per_sec={r['superblock_jobs_per_sec']}"))
    return rows


def _merge_json(path: str, out: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["superblock"] = out
    with open(path, "w") as f:
        json.dump(data, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced mix; exit 1 on dispatch/speedup "
                         "regression")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--json", default=os.path.join(_REPO_ROOT,
                                                   "BENCH_compiled.json"))
    args = ap.parse_args()

    out = bench(args.smoke, args.batch, args.repeats)

    print("name,us_per_call,derived")
    for name, us, derived in rows_csv(out):
        print(f"{name},{us},{derived}")

    if not args.smoke:      # CI pass: don't clobber the tracked numbers
        _merge_json(args.json, out)
        print(f"# merged into {args.json}", file=sys.stderr)

    per_prog = out["single_core"][:-1]
    agg = out["single_core"][-1]["speedup_vs_blocks"]
    bad_dispatch = [r["name"] for r in per_prog
                    if r["dispatches_super"] != 0
                    or r["dispatches_blocks"] <= 0]
    print(f"# aggregate superblock-vs-blocks speedup: {agg}x; "
          f"dispatch regressions: {bad_dispatch or 'none'}",
          file=sys.stderr)
    if args.smoke:
        if bad_dispatch:
            print(f"# SMOKE FAIL: {bad_dispatch} not on the superblock "
                  f"tier (switch dispatches must drop to 0)",
                  file=sys.stderr)
            sys.exit(1)
        if agg < SMOKE_MIN_SPEEDUP:
            print(f"# SMOKE FAIL: need >= {SMOKE_MIN_SPEEDUP}x over the "
                  f"basic-block tier", file=sys.stderr)
            sys.exit(1)
        print("# smoke gate passed", file=sys.stderr)


if __name__ == "__main__":
    main()
