"""One benchmark per paper table.

Each function returns a list of row-dicts and is callable standalone;
``benchmarks/run.py`` orchestrates all of them and emits the CSV the
harness contract requires.
"""
from __future__ import annotations

from repro.core import (area_model, benchmark_config, nios_model,
                        table4_configs, table5_configs)
from repro.core.area_model import resources
from repro.programs import (build_bitonic, build_fft, build_matmul,
                            build_reduction, build_transpose, run_bench)


# --------------------------------------------------------------------------
# Tables 4 & 5: fitting results (area / Fmax model vs paper)
# --------------------------------------------------------------------------

def table_area():
    rows = []
    for name, cfg in {**table4_configs(), **table5_configs()}.items():
        paper = {**area_model.PAPER_TABLE4, **area_model.PAPER_TABLE5}[name]
        r = resources(cfg)
        rows.append({
            "table": "4/5", "config": name,
            "alms": r.alms, "alms_paper": paper[0],
            "alm_err": round((r.alms - paper[0]) / paper[0], 3),
            "ffs": r.ffs, "ffs_paper": paper[1],
            "dsps": r.dsps, "dsps_paper": paper[2],
            "m20ks": r.m20ks, "m20ks_paper": paper[3],
            "fmax": r.fmax_mhz, "fmax_paper": paper[5],
        })
    return rows


def table6_alu():
    rows = []
    for (bits, feat), (alm, ff) in area_model.ALU_TABLE.items():
        rows.append({"table": "6", "alu": f"{bits}-bit {feat}",
                     "alms": alm, "ffs": ff})
    return rows


# --------------------------------------------------------------------------
# Table 7: vector reduction / matrix transpose / matrix-matrix multiply
# --------------------------------------------------------------------------

_PAPER_T7 = {  # (bench, n) -> (dp, qp, dot cycles)
    ("reduction", 32): (168, 160, 62), ("reduction", 64): (202, 194, 94),
    ("reduction", 128): (216, 208, 101),
    ("transpose", 32): (1720, 1208, None), ("transpose", 64): (5529, 3481, None),
    ("transpose", 128): (20481, 12649, None),
    ("matmul", 32): (111546, 103354, 19800),
    ("matmul", 64): (451066, 418671, 84425),
}


def _norm_cost(cfg):
    return resources(cfg).normalized_cost


def _row(bench, n, variant, r, paper_cycles, nios_cycles, cfg):
    nios_t = nios_cycles / nios_model.NIOS_FMAX_MHZ
    nios_norm = 1400  # Nios cost units (§7)
    egpu_norm = _norm_cost(cfg)
    return {
        "bench": bench, "n": n, "variant": variant,
        "cycles": r.cycles, "time_us": round(r.time_us, 2),
        "paper_cycles": paper_cycles,
        "cycles_vs_paper": (round(r.cycles / paper_cycles, 2)
                            if paper_cycles else None),
        "correct": r.correct, "hazards": r.hazard_violations,
        "nios_cycles": nios_cycles,
        "ratio_time_vs_nios": round(nios_t / r.time_us, 1),
        "normalized_vs_nios": round((nios_t * nios_norm)
                                    / (r.time_us * egpu_norm), 2),
        "bus_overhead_pct": round(100 * r.bus_cycles
                                  / (r.cycles + r.bus_cycles), 1),
    }


def table7(sizes=(32, 64, 128)):
    rows = []
    for n in sizes:
        for bench, builder in (("reduction", build_reduction),
                               ("transpose", build_transpose),
                               ("matmul", build_matmul)):
            if bench == "matmul" and n > 64:
                continue   # n=128 exceeds the CI budget; run via --full
            paper = _PAPER_T7.get((bench, n), (None, None, None))
            nios = nios_model.cycles(bench, n)
            for i, mode in enumerate(("dp", "qp")):
                cfg = benchmark_config(mode)
                r = run_bench(builder(cfg, n))
                rows.append(_row(bench, n, mode, r, paper[i], nios, cfg))
            if bench in ("reduction", "matmul"):
                cfg = benchmark_config("dp", has_dot=True)
                r = run_bench(builder(cfg, n, use_dot=True))
                rows.append(_row(bench, n, "dot", r, paper[2], nios, cfg))
    return rows


# --------------------------------------------------------------------------
# Table 8: bitonic sort and FFT
# --------------------------------------------------------------------------

_PAPER_T8 = {
    ("bitonic", 32): (1742, 1543), ("bitonic", 64): (3728, 3054),
    ("bitonic", 128): (8326, 6536), ("bitonic", 256): (16578, 11974),
    ("fft", 32): (876, 714), ("fft", 64): (1695, 1312),
    ("fft", 128): (3463, 2558), ("fft", 256): (6813, 4736),
}


def table8(sizes=(32, 64, 128, 256)):
    rows = []
    for n in sizes:
        for bench, builder, kw in (
                ("bitonic", build_bitonic, {"pred": 2}),
                ("fft", build_fft, {})):
            paper = _PAPER_T8[(bench, n)]
            nios = nios_model.cycles(bench, n)
            for i, mode in enumerate(("dp", "qp")):
                cfg = benchmark_config(mode,
                                       predicate_levels=kw.get("pred", 0))
                r = run_bench(builder(cfg, n))
                rows.append(_row(bench, n, mode, r, paper[i], nios, cfg))
    return rows


# --------------------------------------------------------------------------
# Fig. 6: instruction-mix profile
# --------------------------------------------------------------------------

def profile_mix():
    rows = []
    cases = [("reduction", build_reduction, 64, {}),
             ("transpose", build_transpose, 64, {}),
             ("matmul", build_matmul, 32, {}),
             ("bitonic", build_bitonic, 64, {"pred": 2}),
             ("fft", build_fft, 64, {})]
    for name, builder, n, kw in cases:
        cfg = benchmark_config("dp", predicate_levels=kw.get("pred", 0))
        r = run_bench(builder(cfg, n))
        total = max(1, sum(c for c, _ in r.profile.values()))
        row = {"bench": name, "n": n}
        for cls, (cyc, _cnt) in r.profile.items():
            row[f"pct_{cls.lower()}"] = round(100 * cyc / total, 1)
        rows.append(row)
    return rows


# --------------------------------------------------------------------------
# Dynamic-scalability ablation (the paper's core mechanism)
# --------------------------------------------------------------------------

def dynamic_scaling(sizes=(32, 64, 128)):
    rows = []
    for n in sizes:
        dyn = run_bench(build_reduction(benchmark_config("dp"), n))
        nod = run_bench(build_reduction(
            benchmark_config("dp", predicate_levels=4), n, no_dynamic=True))
        rows.append({
            "bench": "reduction", "n": n,
            "tsc_cycles": dyn.cycles, "predicated_cycles": nod.cycles,
            "dynamic_speedup": round(nod.cycles / dyn.cycles, 2),
            "both_correct": dyn.correct and nod.correct,
        })
    return rows
