"""Admission-lint overhead benchmark.

Measures the latency of :func:`repro.fleet.scheduler.check_job` with and
without the static verifier on the warm path (``analyze_cached`` makes
repeated submits of the same program a dict lookup), plus the cold
one-shot cost of a full ``analyze`` per suite program.

Acceptance criterion for the admission wiring: warm-path ``check_job``
with lint enabled is within 5% of ``lint=False``.

  PYTHONPATH=src python -m benchmarks.analysis [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.analysis import analyze, analyze_cached  # noqa: E402
from repro.analysis.lint import _default_config, suite  # noqa: E402
from repro.fleet.scheduler import check_job  # noqa: E402


def _time_paired(fn_a, fn_b, reps: int, rounds: int = 9):
    """Best-of-N for two functions, interleaved so clock drift and
    frequency scaling hit both equally; returns (sec_a, sec_b) per call."""
    best_a = best_b = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn_a()
        best_a = min(best_a, (time.perf_counter() - t0) / reps)
        t0 = time.perf_counter()
        for _ in range(reps):
            fn_b()
        best_b = min(best_b, (time.perf_counter() - t0) / reps)
    return best_a, best_b


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="fewer reps (CI gate)")
    p.add_argument("--json", action="store_true")
    args = p.parse_args(argv)

    cfg = _default_config()
    benches = suite(cfg)
    reps = 200 if args.smoke else 2000

    # cold analyze cost per program (one-shot, amortised by the cache)
    cold = {}
    for b in benches:
        t0 = time.perf_counter()
        analyze(b.image, b.image.threads_active, tdx_dim=b.tdx_dim)
        cold[b.name] = time.perf_counter() - t0

    # warm the admission cache, then time the steady-state submit path
    for b in benches:
        analyze_cached(b.image, b.image.threads_active, tdx_dim=b.tdx_dim)

    def warm_with_lint():
        for b in benches:
            check_job(cfg, b.image, b.shared_init,
                      b.image.threads_active, tdx_dim=b.tdx_dim)

    def warm_without_lint():
        for b in benches:
            check_job(cfg, b.image, b.shared_init,
                      b.image.threads_active, tdx_dim=b.tdx_dim,
                      lint=False)

    t_off, t_on = _time_paired(warm_without_lint, warm_with_lint, reps)
    overhead = (t_on - t_off) / t_off if t_off > 0 else 0.0

    result = {
        "programs": len(benches),
        "reps": reps,
        "check_job_lint_off_us": t_off * 1e6,
        "check_job_lint_on_us": t_on * 1e6,
        "warm_overhead_pct": overhead * 100.0,
        "cold_analyze_ms": {k: v * 1e3 for k, v in cold.items()},
        "pass_5pct_budget": overhead <= 0.05,
    }
    if args.json:
        print(json.dumps(result, indent=2))
    else:
        print(f"admission lint overhead over {len(benches)} suite programs "
              f"({reps} reps):")
        print(f"  check_job lint=False : {t_off * 1e6:9.2f} us/sweep")
        print(f"  check_job lint=True  : {t_on * 1e6:9.2f} us/sweep")
        print(f"  warm overhead        : {overhead * 100.0:9.2f} %"
              f"   (budget: 5%)")
        print(f"  cold analyze         : "
              f"{sum(cold.values()) * 1e3:9.2f} ms total, "
              f"worst {max(cold.values()) * 1e3:.2f} ms "
              f"({max(cold, key=lambda k: cold[k])})")
    return 0 if result["pass_5pct_budget"] else 1


if __name__ == "__main__":
    sys.exit(main())
