"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), where
``derived`` carries the table-specific payload (cycles, vs-paper ratio,
normalized cost, roofline terms ...), and persists every row to
``BENCH_paper_tables.json`` at the repo root (plus ``BENCH_fleet.json``
for the fleet throughput section) so the perf trajectory is tracked
across PRs.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --full     # + matmul-128 etc.
  PYTHONPATH=src python -m benchmarks.run --no-fleet # skip fleet section
  PYTHONPATH=src python -m benchmarks.run --smoke    # quick CI pass
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks import paper_tables  # noqa: E402

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ROWS: list[dict] = []


_PERSIST = True          # --smoke disables writing the tracked BENCH files


def emit(name, us, derived):
    print(f"{name},{us},{derived}")
    _ROWS.append({"name": name, "us_per_call": us, "derived": derived})


def _dump(path, obj):
    if not _PERSIST:
        return
    with open(os.path.join(_REPO_ROOT, path), "w") as f:
        json.dump(obj, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-fleet", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest sizes / fewest rounds, for CI")
    args = ap.parse_args()
    global _PERSIST
    _PERSIST = not args.smoke

    print("name,us_per_call,derived")

    # Tables 4/5/6 — area model (no runtime: us = 0)
    for row in paper_tables.table_area():
        emit(f"table4_5/{row['config']}", 0,
             f"alm={row['alms']}(paper {row['alms_paper']});"
             f"m20k={row['m20ks']}(paper {row['m20ks_paper']});"
             f"dsp={row['dsps']};fmax={row['fmax']}")
    for row in paper_tables.table6_alu():
        emit(f"table6/{row['alu'].replace(' ', '_')}", 0,
             f"alm={row['alms']};ff={row['ffs']}")

    # Table 7
    sizes = (32,) if args.smoke else (32, 64, 128) if args.full else (32, 64)
    for row in paper_tables.table7(sizes):
        emit(f"table7/{row['bench']}_{row['n']}_{row['variant']}",
             row["time_us"],
             f"cycles={row['cycles']};paper={row['paper_cycles']};"
             f"x_paper={row['cycles_vs_paper']};correct={row['correct']};"
             f"nios_speedup={row['ratio_time_vs_nios']};"
             f"normalized={row['normalized_vs_nios']}")

    # Table 8
    sizes8 = (32,) if args.smoke \
        else (32, 64, 128, 256) if args.full else (32, 64)
    for row in paper_tables.table8(sizes8):
        emit(f"table8/{row['bench']}_{row['n']}_{row['variant']}",
             row["time_us"],
             f"cycles={row['cycles']};paper={row['paper_cycles']};"
             f"x_paper={row['cycles_vs_paper']};correct={row['correct']};"
             f"nios_speedup={row['ratio_time_vs_nios']};"
             f"normalized={row['normalized_vs_nios']}")

    # Fig. 6 profile
    for row in paper_tables.profile_mix():
        payload = ";".join(f"{k}={v}" for k, v in row.items()
                           if k.startswith("pct_"))
        emit(f"fig6/{row['bench']}_{row['n']}", 0, payload)

    # Dynamic-scalability ablation
    for row in paper_tables.dynamic_scaling(
            (32,) if args.smoke else (32, 64) if not args.full
            else (32, 64, 128)):
        emit(f"dynamic_scaling/reduction_{row['n']}", 0,
             f"tsc={row['tsc_cycles']};predicated={row['predicated_cycles']};"
             f"speedup={row['dynamic_speedup']}x")

    # Roofline (from the dry-run + calibration batches, if present)
    rl = "results/roofline/roofline.json"
    if os.path.exists(rl):
        for row in json.load(open(rl)):
            emit(f"roofline/{row['arch']}__{row['shape']}",
                 round(max(row['t_compute_s'], row['t_memory_s'],
                           row['t_collective_s']) * 1e6, 1),
                 f"dom={row['dominant']};comp={row['t_compute_s']:.2e};"
                 f"mem={row['t_memory_s']:.2e};coll={row['t_collective_s']:.2e};"
                 f"useful={row['useful_flops_ratio']:.2f}")

    # persist the paper tables before the fleet section so a fleet
    # failure can't discard the rows already collected
    _dump("BENCH_paper_tables.json", _ROWS)

    # Fleet throughput (batched multi-core engine vs serial loop)
    if not args.no_fleet:
        from benchmarks import fleet as fleet_bench
        rounds = 8 if args.full else 1 if args.smoke else 2
        fleet_rows = fleet_bench.bench(batch=32, rounds=rounds,
                                       mixes=("light", "suite"))
        for r in fleet_rows:
            emit(f"fleet/{r['mix']}_batch{r['batch']}",
                 round(1e6 * r["fleet_s"] / r["jobs"], 1),
                 f"jobs_per_sec={r['fleet_jobs_per_sec']};"
                 f"serial_jobs_per_sec={r['serial_jobs_per_sec']};"
                 f"speedup={r['speedup']}x")
        _dump("BENCH_fleet.json", fleet_rows)
        _dump("BENCH_paper_tables.json", _ROWS)  # + the fleet rows

    # Block compiler vs interpreter (single core; + fleet tiers unless
    # --no-fleet, which skips every fleet-engine benchmark)
    from benchmarks import compiled as compiled_bench
    comp = compiled_bench.bench(smoke=args.smoke,
                                include_fleet=not args.no_fleet)
    for name, us, derived in compiled_bench.rows_csv(comp):
        emit(name, us, derived)
    if not args.no_fleet:       # only persist the complete two-section file
        _dump("BENCH_compiled.json", comp)
    _dump("BENCH_paper_tables.json", _ROWS)      # + the compiled-tier rows


if __name__ == "__main__":
    main()
