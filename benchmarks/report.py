"""Generate EXPERIMENTS.md tables from results/ JSON artifacts."""
from __future__ import annotations

import json
import os

import repro.configs as C

GB = 1 << 30


def _load(path):
    return json.load(open(path)) if os.path.exists(path) else None


def dryrun_table(dirname="results/dryrun"):
    lines = [
        "| arch | shape | mesh | params | arg B/dev | temp B/dev | "
        "HLO flops/dev | coll B/dev | AR/AG/RS/A2A/CP | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, ok, why in C.cells():
        for mesh in ("16x16", "2x16x16"):
            if not ok:
                if mesh == "16x16":
                    lines.append(f"| {arch} | {shape.name} | — | — | — | — | "
                                 f"— | — | skipped: {why.split(':')[0]} | — |")
                continue
            rec = _load(os.path.join(dirname,
                                     f"{arch}__{shape.name}__{mesh}.json"))
            if rec is None:
                continue
            m = rec["memory"]
            cl = rec["collectives"]
            cnt = cl["count"]
            lines.append(
                f"| {arch} | {shape.name} | {mesh} | "
                f"{rec['params']/1e9:.2f}B | "
                f"{(m['argument_bytes'] or 0)/GB:.2f}G | "
                f"{(m['temp_bytes'] or 0)/GB:.2f}G | "
                f"{rec['cost']['flops']:.2e} | "
                f"{cl['total_bytes']:.2e} | "
                f"{cnt['all-reduce']}/{cnt['all-gather']}/"
                f"{cnt['reduce-scatter']}/{cnt['all-to-all']}/"
                f"{cnt['collective-permute']} | "
                f"{rec['compile_s']} |")
    return "\n".join(lines)


def roofline_table(path="results/roofline/roofline.json"):
    rows = _load(path)
    if not rows:
        return "(roofline calibration pending)"
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | what would move the bottleneck |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        hint = _bottleneck_hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {hint} |")
    return "\n".join(lines)


def _bottleneck_hint(r):
    d = r["dominant"]
    kind = C.SHAPES[r["shape"]].kind
    if d == "collective":
        return ("shard experts wider / bucket+overlap the DP all-reduce"
                if "moe" in r["arch"] else
                "overlap grad all-reduce with backward; reduce-scatter "
                "instead of all-reduce")
    if d == "memory":
        if kind == "decode":
            return "decode is cache-bandwidth bound (physics); grow batch " \
                   "or quantize the KV cache"
        return "larger microbatch per chip / fuse normalizations; " \
               "cast activations bf16"
    return "already compute-bound: raise MXU occupancy via larger tiles"


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### Roofline\n")
        print(roofline_table())
